"""Scenario grid: (scenario x router x adaptation) under the fleet sim.

``repro.serving.scenario`` names whole serving CONDITIONS — adversarial
link shapes, device zoos, adaptation-mode ladders — as seeded, frozen
schemas.  This benchmark sweeps every selected scenario through the
routing policies and adaptation controllers that apply to it and writes
one scorecard row per cell: p95 / mean decision latency, deadline hit
rate, the delivered-return proxy (mode fidelity for in-deadline
decisions, zero for late ones), and the uplink byte bill.

Rows go to ``BENCH_scenarios.json`` stamped ``transport: "sim"``
(``repro.perfstamp``) with the full scenario definitions embedded, so a
baseline carries its own seeds.  ``--against`` refuses apples-to-oranges
diffs twice over: a transport or mode mismatch (sim-vs-real) exits 2 via
``perfstamp.check_comparable``, and so does a baseline whose
(name, seed) scenario set shares nothing with the current run — a delta
across different scenarios is a different experiment, not a regression.

``--smoke`` is the bounded CI gate, run on the designed deterministic
adversary ``trace_dropout`` (two 1 s dropouts to 4 Mb/s on a 100 Mb/s
uplink): the rule controller must beat the BEST STATIC configuration —
best by delivered return, i.e. the config you would actually deploy
without adaptation — on all three axes at once: delivered return no
lower, p95 no higher, uplink bytes no higher.  (The best static here is
the full-fidelity mode, which is also the ``"none"`` no-adaptation
baseline; a compact-only static has a lower p95 but caps return at its
fidelity everywhere, so beating it on bytes while sending full payloads
in good regimes is impossible by construction — the return-ranked
definition is the meaningful one.)

Grid bounds: single-device scenarios run at n_servers=1 where every
router is identical, so only ``round_robin`` is swept; device-zoo
scenarios run one server per profile and sweep every registered router.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from repro import perfstamp
from repro.serving.fleet import router_names
from repro.serving.scenario import get_scenario, scenario_names

ARTIFACT = "BENCH_scenarios.json"
GATE_SCENARIO = "trace_dropout"
PAYLOAD_BYTES = 10_000    # the reference wire payload (fp32 z at X=50-ish)


def adaptations_for(scenario) -> tuple:
    """The controllers that make sense for this scenario's mode ladder:
    the no-adaptation default, one static per non-default mode, and the
    rule controller when there is actually a ladder to climb."""
    pols = ["none"]
    pols += [f"static:{i}" for i in range(1, len(scenario.modes))]
    if len(scenario.modes) > 1:
        pols.append("rule")
    return tuple(pols)


def run_cell(scenario, *, router: str, adaptation: str,
             payload_bytes: int, n_servers: int) -> dict:
    sim = scenario.sim(payload_bytes, n_servers=n_servers, router=router,
                       adaptation=adaptation)
    rep = sim.report(scenario.n_clients)
    return {
        "scenario": scenario.name, "seed": scenario.seed,
        "adversarial": scenario.adversarial,
        "router": router, "n_servers": n_servers,
        "adaptation": adaptation, "payload_bytes": payload_bytes,
        "n_requests": rep.n_requests,
        "p95_ms": rep.p95_s * 1e3,
        "mean_ms": rep.mean_s * 1e3,
        "deadline_hit_rate": rep.deadline_hit_rate,
        "delivered_return": rep.delivered_return,
        "total_uplink_bytes": rep.total_uplink_bytes,
        "mode_counts": rep.mode_counts(),
    }


def sweep(names, *, payload_bytes: int = PAYLOAD_BYTES) -> list[dict]:
    rows = []
    for name in names:
        s = get_scenario(name)
        n_servers = max(1, len(s.devices))
        routers = router_names() if n_servers > 1 else ("round_robin",)
        for router in routers:
            for pol in adaptations_for(s):
                r = run_cell(s, router=router, adaptation=pol,
                             payload_bytes=payload_bytes,
                             n_servers=n_servers)
                rows.append(r)
                print(f"  {s.name:<16} {router:<16} {pol:<10} "
                      f"p95 {r['p95_ms']:8.2f} ms  "
                      f"return {r['delivered_return']:.4f}  "
                      f"hit {r['deadline_hit_rate']:.3f}  "
                      f"{r['total_uplink_bytes']/1e6:7.3f} MB")
    return rows


def write_artifact(rows: list[dict], names, *,
                   payload_bytes: int, path: str = ARTIFACT) -> dict:
    doc = perfstamp.stamp(
        {"kind": "scenario_grid", "payload_bytes": payload_bytes,
         "scenarios": {n: get_scenario(n).to_dict() for n in names},
         "rows": rows},
        backend="sim", transport="sim")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"  wrote {path} [mode={doc['mode']} transport={doc['transport']}]")
    return doc


def _scenario_keys(doc: dict) -> set:
    return {(n, s.get("seed")) for n, s in doc.get("scenarios", {}).items()}


def check_against(baseline_path: str, *, artifact: str = ARTIFACT) -> None:
    """Refuse cross-transport AND cross-scenario comparisons: the
    baseline must be sim-stamped like us (sim-vs-real is a calibration,
    see benchmarks/realfleet.py) and must share at least one
    (scenario name, seed) with the current run — a diff across different
    scenarios or reseeded links is a different experiment."""
    with open(artifact) as f:
        current = json.load(f)
    with open(baseline_path) as f:
        baseline = json.load(f)
    perfstamp.check_comparable(current, baseline,
                               what=f"{artifact} vs {baseline_path}")
    cur, base = _scenario_keys(current), _scenario_keys(baseline)
    common = cur & base
    if not common:
        raise ValueError(
            f"no common (scenario, seed) between {artifact} "
            f"{sorted(cur)} and {baseline_path} {sorted(base)}: "
            f"cross-scenario comparison refused")
    for m in perfstamp.mismatches(current, baseline):
        print(f"  warning: {m}")
    print(f"  {artifact} comparable with {baseline_path} on "
          f"{len(common)} shared scenario(s) "
          f"[mode={current.get('mode')} "
          f"transport={current.get('transport')}]")


def smoke_gate(rows: list[dict], *,
               scenario: str = GATE_SCENARIO) -> bool:
    """The adaptation gate on the designed deterministic adversary.

    Statics are ranked by delivered return (the config you would deploy
    without adaptation); the rule controller must match-or-beat that
    best static on return, p95 AND uplink bytes simultaneously."""
    cells = [r for r in rows
             if r["scenario"] == scenario and r["n_servers"] == 1]
    statics = [r for r in cells if r["adaptation"] != "rule"]
    rules = [r for r in cells if r["adaptation"] == "rule"]
    if not statics or not rules:
        print(f"  gate: scenario {scenario!r} missing static or rule "
              f"cells — did the sweep include it?")
        return False
    best = max(statics, key=lambda r: r["delivered_return"])
    rule = rules[0]
    checks = (
        ("delivered_return >=",
         rule["delivered_return"] >= best["delivered_return"],
         f"{rule['delivered_return']:.4f} vs {best['delivered_return']:.4f}"),
        ("p95 <=", rule["p95_ms"] <= best["p95_ms"],
         f"{rule['p95_ms']:.2f} ms vs {best['p95_ms']:.2f} ms"),
        ("uplink bytes <=",
         rule["total_uplink_bytes"] <= best["total_uplink_bytes"],
         f"{rule['total_uplink_bytes']} vs {best['total_uplink_bytes']}"),
    )
    ok = True
    print(f"  gate [{scenario}]: rule vs best static "
          f"({best['adaptation']}, return-ranked)")
    for label, passed, detail in checks:
        print(f"    {label:<20} {detail}: {passed}")
        ok = ok and passed
    return ok


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated scenario names (default: all "
                         "registered)")
    ap.add_argument("--payload-bytes", type=int, default=PAYLOAD_BYTES,
                    help="the deployment's default wire payload that "
                         "mode 0 sends")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: on the designed adversarial scenario "
                         "the rule controller must match-or-beat the "
                         "best static configuration on delivered return, "
                         "p95 and uplink bytes (exit 1 on failure)")
    ap.add_argument("--out", default=ARTIFACT)
    ap.add_argument("--against", metavar="OLD.json",
                    help="check the written artifact is comparable with "
                         "OLD.json (exit 2 on transport/mode mismatch or "
                         "disjoint scenario sets)")
    args = ap.parse_args(argv)

    names = (tuple(args.scenarios.split(","))
             if args.scenarios else scenario_names())
    for n in names:
        get_scenario(n)            # fail fast on typos
    rows = sweep(names, payload_bytes=args.payload_bytes)
    write_artifact(rows, names, payload_bytes=args.payload_bytes,
                   path=args.out)
    if args.smoke:
        if GATE_SCENARIO not in names:
            print(f"  smoke requires the {GATE_SCENARIO!r} scenario in "
                  f"the sweep")
            raise SystemExit(1)
        ok = smoke_gate(rows)
        print(f"  smoke: rule controller dominates best static on "
              f"{GATE_SCENARIO}: {ok}")
        if not ok:
            raise SystemExit(1)
    if args.against:
        try:
            check_against(args.against, artifact=args.out)
        except ValueError as e:
            print(f"  REFUSED: {e}")
            raise SystemExit(2)


if __name__ == "__main__":
    main()
