"""Sim-to-real fleet calibration: FleetQueueSim vs the real fleet.

Everything fleet-shaped elsewhere in this repo is a prediction —
:class:`repro.serving.fleet.FleetQueueSim` says what ``n_servers``
micro-batching servers behind a router SHOULD do.  This benchmark runs
that exact deployment for real (``repro.serving.realfleet``: spawned
worker processes, localhost sockets, the same registered routers) and
reports measured p95 decision latency next to the sim's prediction, per
(n_servers, router) cell — the DistrEdge-style calibration the ROADMAP
asks for before trusting fleet capacity numbers.

Methodology: one manifest produces BOTH sides.  The batched service
curve t(B) is measured in-process first (that curve drives the sim AND
caps real-fleet admission at its largest measured batch), the uplink is
modelled as the measured localhost loopback (effectively unshaped), and
the SAME open-loop load (N clients at ``--rate-hz``, the Table 6
protocol) is applied to the simulator and to the live fleet.  With
``--shaped-mbps R`` every worker token-bucket-shapes its request ingress
at R Mb/s (``repro.serving.realfleet.ShapingConfig``) and the sim uplink
is modelled at the same rate — calibrating the shaped-uplink sim cells
against a real bottleneck instead of raw loopback; the shaping config is
stamped into every row and the artifact header.

Rows are written to ``BENCH_realfleet.json`` stamped with
``transport: "socket"`` (``repro.perfstamp``): measured-fleet artifacts
only ever compare against other measured-fleet artifacts — ``--against``
exits 2 on a sim-stamped or unstamped baseline, because a sim-vs-real
delta is a calibration result, not a regression.

``--smoke`` is the bounded CI gate: n_servers in {1, 2}, every registered
router, small N — measured p95 must stay within ``tol_rel * predicted +
tol_abs`` of the sim, with zero failed requests and zero leaked worker
processes.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

import jax
import numpy as np

from repro import perfstamp
from repro.deploy import Deployment, DeploymentConfig
from repro.serving.fleet import router_names
from repro.serving.netsim import shaped
from repro.serving.realfleet import ShapingConfig, pack_payload, run_load

ARTIFACT = "BENCH_realfleet.json"

# localhost loopback stand-in for the shaped uplink: multi-Gb/s and
# ~0.1 ms RTT — transfer time is negligible against service time, which
# is exactly what the real fleet's clients see
LOOPBACK_MBPS = 10_000.0
LOOPBACK_RTT_MS = 0.2


def small_config(*, n_servers: int = 2,
                 router: str = "round_robin") -> DeploymentConfig:
    """The calibration deployment: small enough that worker spawn +
    precompile stays CI-bounded, big enough that t(B) is measurable."""
    return DeploymentConfig.standard(k=4, c_in=4, h=24, backend="xla",
                                     max_batch=4, n_servers=n_servers,
                                     router=router)


def calibrate(cfg: DeploymentConfig, *, n_servers_list=(1, 2),
              routers=None, n_clients: int = 4, rate_hz: float = 20.0,
              duration_s: float = 1.5, seed: int = 0,
              timeout_s: float = 30.0,
              shaped_mbps: float = None) -> list[dict]:
    """Measured vs predicted p95 per (n_servers, router) cell.

    ONE fleet is spawned per fleet size and re-used across routers
    (routing is a parent-side decision, exactly as in the sim), so the
    spawn + jit cost is paid once per size, not once per cell.

    ``shaped_mbps`` token-bucket-shapes every worker's request ingress
    (``repro.serving.realfleet.ShapingConfig``) and models the sim
    uplink at the same rate — the shaped-uplink cells are then measured
    against a sim of the SAME bottleneck, not raw loopback.
    """
    dep = Deployment.build(cfg)
    params = dep.init(jax.random.PRNGKey(seed))
    client, bsrv = dep.serving_pair(params)
    obs = jax.random.uniform(jax.random.PRNGKey(seed + 1),
                             (1, cfg.in_h, cfg.in_w,
                              cfg.spec.layers[0].c_in))
    payload = client.encode_fn(obs)
    body = pack_payload({k: np.asarray(v) for k, v in payload.items()})

    times = bsrv.measure(payload, batch_sizes=tuple(
        b for b in (1, 2, 4, 8) if b <= cfg.max_batch), iters=10)
    model = bsrv.service_model()
    curve = " ".join(f"t({b})={t*1e3:.2f}ms" for b, t in sorted(times.items()))
    print(f"  measured service curve: {curve}")

    shaping = (None if shaped_mbps is None
               else ShapingConfig(rate_mbps=shaped_mbps))
    uplink_mbps = LOOPBACK_MBPS if shaped_mbps is None else shaped_mbps
    uplink_rtt_ms = LOOPBACK_RTT_MS if shaped_mbps is None else 2.0
    if shaping is not None:
        print(f"  ingress shaping: {shaping.rate_mbps} Mb/s token bucket, "
              f"burst {shaping.burst_bytes} B (sim uplink matched)")

    routers = tuple(routers) if routers else router_names()
    rows = []
    for ns in sorted(set(n_servers_list)):
        fleet = dep.fleet(params, n_servers=ns, service_model=model,
                          timeout_s=timeout_s, shaping=shaping)
        fleet_rows = []
        try:
            for router in routers:
                fleet.set_router(router)
                sim = dep.fleet_sim(
                    model, uplink=shaped(uplink_mbps,
                                         rtt_ms=uplink_rtt_ms),
                    rate_hz=rate_hz, horizon_s=duration_s, n_servers=ns,
                    router=router, max_batch=fleet.max_batch,
                    max_wait_s=0.0)
                predicted = sim.p95(n_clients)
                rep = run_load(fleet.client, body, n_clients=n_clients,
                               rate_hz=rate_hz, duration_s=duration_s)
                fleet_rows.append({
                    "n_servers": ns, "router": router,
                    "n_clients": n_clients, "rate_hz": rate_hz,
                    "duration_s": duration_s,
                    "shaping": None if shaping is None
                    else shaping.to_dict(),
                    "n_requests": rep.n_requests,
                    "n_failures": rep.n_failures,
                    "predicted_p95_ms": predicted * 1e3,
                    "measured_p95_ms": rep.p95() * 1e3,
                    "measured_p50_ms": rep.p50() * 1e3,
                    "max_served_batch":
                        fleet.stats["max_served_batch"],
                })
                r = fleet_rows[-1]
                print(f"  {ns}x {router:<16} N={n_clients} "
                      f"predicted p95 {r['predicted_p95_ms']:7.2f} ms  "
                      f"measured p95 {r['measured_p95_ms']:7.2f} ms "
                      f"(p50 {r['measured_p50_ms']:.2f} ms, "
                      f"{rep.n_requests} reqs, {rep.n_failures} failed)")
        finally:
            leaked = fleet.close()
        for r in fleet_rows:
            r["leaked_workers"] = len(leaked)
        rows.extend(fleet_rows)
        if leaked:
            print(f"  WARNING: {ns}x fleet leaked worker pids {leaked}")
    return rows


def write_artifact(rows: list[dict], cfg: DeploymentConfig,
                   *, path: str = ARTIFACT,
                   shaping: ShapingConfig = None) -> dict:
    doc = perfstamp.stamp({"kind": "realfleet_calibration",
                           "config": cfg.to_dict(),
                           "shaping": None if shaping is None
                           else shaping.to_dict(),
                           "rows": rows},
                          backend=cfg.backend, transport="socket")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"  wrote {path} [mode={doc['mode']} transport={doc['transport']}]")
    return doc


def check_against(baseline_path: str, *, artifact: str = ARTIFACT) -> list:
    """Refuse cross-transport comparisons: a socket-measured artifact is
    only comparable with another socket-measured artifact (sim-vs-real is
    calibration, handled above, never a perf diff)."""
    with open(artifact) as f:
        current = json.load(f)
    with open(baseline_path) as f:
        baseline = json.load(f)
    perfstamp.check_comparable(current, baseline,
                               what=f"{artifact} vs {baseline_path}")
    soft = perfstamp.mismatches(current, baseline)
    for m in soft:
        print(f"  warning: {m}")
    print(f"  {artifact} comparable with {baseline_path} "
          f"[mode={current.get('mode')} "
          f"transport={current.get('transport')}]")
    return soft


def smoke_gate(rows: list[dict], *, tol_rel: float = 3.0,
               tol_abs_ms: float = 25.0) -> bool:
    """The CI gate: every cell's measured p95 within one-sided tolerance
    of the sim prediction, zero failures, zero leaked workers.

    One-sided because the sim is an idealised lower bound — it does not
    model OS scheduling, GIL contention between the load-generator
    threads, or socket syscall overhead, so measured < predicted is fine
    and only measured >> predicted indicates a broken serving path (e.g.
    an accidental batch-hold or a compile in the hot loop)."""
    ok = True
    for r in rows:
        bound = tol_rel * r["predicted_p95_ms"] + tol_abs_ms
        cell_ok = (r["measured_p95_ms"] <= bound
                   and r["n_failures"] == 0
                   and r["leaked_workers"] == 0)
        print(f"  gate {r['n_servers']}x {r['router']:<16} measured "
              f"{r['measured_p95_ms']:7.2f} ms <= {bound:7.2f} ms, "
              f"failures={r['n_failures']}, "
              f"leaked={r['leaked_workers']}: {cell_ok}")
        ok = ok and cell_ok
    return ok


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--manifest", default=None,
                    help="deployment manifest JSON (see python -m "
                         "repro.deploy); default: the small calibration "
                         "deployment")
    ap.add_argument("--n-servers", default="1,2",
                    help="comma-separated fleet sizes to spawn")
    ap.add_argument("--routers", default=None,
                    help="comma-separated routing policies (default: all "
                         "registered)")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rate-hz", type=float, default=20.0)
    ap.add_argument("--duration-s", type=float, default=1.5)
    ap.add_argument("--shaped-mbps", type=float, default=None,
                    help="token-bucket-shape worker request ingress at "
                         "this rate and model the sim uplink to match "
                         "(default: unshaped loopback)")
    ap.add_argument("--smoke", action="store_true",
                    help="bounded CI gate: measured p95 within tolerance "
                         "of the FleetQueueSim prediction, no failed "
                         "requests, no leaked workers (exit 1 on failure)")
    ap.add_argument("--tol-rel", type=float, default=3.0)
    ap.add_argument("--tol-abs-ms", type=float, default=25.0)
    ap.add_argument("--out", default=ARTIFACT)
    ap.add_argument("--against", metavar="OLD.json",
                    help="check the written artifact is comparable with "
                         "OLD.json (exit 2 on a mode or transport "
                         "mismatch, e.g. sim-vs-real)")
    args = ap.parse_args(argv)

    if args.manifest:
        with open(args.manifest) as f:
            cfg = DeploymentConfig.from_dict(json.load(f))
    else:
        cfg = small_config()
    sizes = tuple(int(s) for s in args.n_servers.split(","))
    routers = tuple(args.routers.split(",")) if args.routers else None

    rows = calibrate(cfg, n_servers_list=sizes, routers=routers,
                     n_clients=args.clients, rate_hz=args.rate_hz,
                     duration_s=args.duration_s,
                     shaped_mbps=args.shaped_mbps)
    write_artifact(rows, cfg, path=args.out,
                   shaping=None if args.shaped_mbps is None
                   else ShapingConfig(rate_mbps=args.shaped_mbps))
    if args.smoke:
        ok = smoke_gate(rows, tol_rel=args.tol_rel,
                        tol_abs_ms=args.tol_abs_ms)
        print(f"  smoke: all calibration cells within tolerance, no "
              f"failures, no leaked workers: {ok}")
        if not ok:
            raise SystemExit(1)
    if args.against:
        try:
            check_against(args.against, artifact=args.out)
        except ValueError as e:
            print(f"  REFUSED: {e}")
            raise SystemExit(2)


if __name__ == "__main__":
    main()
