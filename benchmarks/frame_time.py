"""Paper Figure 2: per-frame encoder processing time vs input size.

Mean of N consecutive inferences with standard deviation, swept over
input sizes.  Execution paths stand in for the paper's device matrix and
are selected declaratively: each (size, backend) cell is ONE
:class:`repro.deploy.DeploymentConfig` resolved by ``Deployment.build``
(the execution-backend registry in ``repro.core.backends``):

* ``xla``      — jit / XLA convs (the embedded-GPU shader analogue);
* ``fused``    — the whole PassPlan as ONE Pallas kernel
  (``kernels.miniconv_pass.miniconv_encoder``; interpret mode on CPU);
* ``per_pass`` — the ``reference`` backend: one pallas_call per shader
  pass (the legacy oracle).

``--compare`` benchmarks fused vs per_pass vs XLA head-to-head (the
ISSUE-1 acceptance check: fused <= per_pass at every size).  5 FPS
feasibility per size is derived like the paper's Pi-Zero X<500
observation.  ``--tune`` runs the :mod:`repro.core.tuning` autotuner per
size and records tuned-vs-default frame-time deltas.  Results are always
written to ``BENCH_frame_time.json``, stamped with the execution mode
(interpret vs compiled), backend set and a host fingerprint via
:mod:`repro.perfstamp`; ``--against OLD.json`` refuses (exit 2) to
compare artifacts recorded under different execution modes.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro import perfstamp
from repro.deploy import Deployment, DeploymentConfig

ARTIFACT = "BENCH_frame_time.json"
C_IN = 4


def _write(doc: dict, artifact: str, *, backend=None) -> dict:
    """Stamp mode/host (+ backend) onto ``doc`` and write it."""
    doc = perfstamp.stamp(doc, backend=backend)
    with open(artifact, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"  wrote {artifact} [mode={doc['mode']} host={doc['host']}]")
    return doc


def time_frames(fn, x, *, n: int = 20, warm: int = 3) -> tuple[float, float]:
    for _ in range(warm):
        jax.block_until_ready(fn(x))         # compile / warm, blocked
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts)), float(np.std(ts))


def median_frames(fn, x, *, n: int = 8, warm: int = 3) -> float:
    """Median with several warm-up calls: the first couple of post-compile
    interpret-mode runs are 2-3x slower (allocator/trace-cache warm-up),
    which poisons a 2-sample mean."""
    for _ in range(warm):
        jax.block_until_ready(fn(x))
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _deployment(x_size: int, mode: str, *, k: int) -> Deployment:
    """One declarative config per (input size, execution backend) cell."""
    return Deployment.build(DeploymentConfig.standard(
        k=k, c_in=C_IN, h=x_size, backend=mode))


def _path(dep: Deployment, edge_params):
    """The encoder-only (edge half) execution path of a deployment."""
    fn = lambda x: dep.split.edge_apply(edge_params, x)
    return jax.jit(fn) if dep.backend.mode == "xla" else fn


def _edge_params(dep: Deployment, seed: int = 0):
    return dep.init(jax.random.PRNGKey(seed))["edge"]


def run(sizes=(64, 128, 256, 400), *, k: int = 4, n: int = 20,
        modes=("xla",), artifact: str = ARTIFACT):
    rows = []
    for x_size in sizes:
        x = jax.random.uniform(jax.random.PRNGKey(1),
                               (1, x_size, x_size, C_IN))
        row = {"x": x_size}
        for mode in modes:
            dep = _deployment(x_size, mode, k=k)
            # interpret-mode paths execute the kernel body in Python; keep
            # their repeat count small so the sweep stays tractable
            n_mode = n if dep.backend.mode == "xla" else max(n // 5, 3)
            mean, std = time_frames(_path(dep, _edge_params(dep)), x,
                                    n=n_mode)
            row[f"{mode}_ms"] = mean * 1e3
            row[f"{mode}_std_ms"] = std * 1e3
        first = f"{modes[0]}_ms"
        row["fps5_ok"] = row[first] < 200.0
        rows.append(row)
        print("  " + " ".join(f"{kk}={v:.2f}" if isinstance(v, float)
                              else f"{kk}={v}" for kk, v in row.items()))
    if artifact:
        _write({"spec_k": k, "modes": list(modes), "rows": rows}, artifact,
               backend=",".join(modes))
    return rows


def run_compare(sizes=(64, 128, 256), *, k: int = 4, n: int = 20,
                batch: int = 8, artifact: str = ARTIFACT):
    """Fused vs legacy per-pass vs XLA, plus batched vs sequential fused.

    Returns (rows, ok) where ``ok`` combines the ISSUE-1 criterion
    (fused <= per_pass at every size) with the ISSUE-2 criterion: one
    batched (B, H, W, C) fused launch is no slower than B sequential
    single-frame fused launches at every size.
    """
    rows = run(sizes, k=k, n=n, modes=("xla", "fused", "per_pass"),
               artifact=None)
    for r in rows:
        dep = _deployment(r["x"], "fused", k=k)
        fused = _path(dep, _edge_params(dep))
        xb = jax.random.uniform(jax.random.PRNGKey(1),
                                (batch, r["x"], r["x"], C_IN))
        frames = [xb[i:i + 1] for i in range(batch)]

        def seq(frames_, _fused=fused):
            # the per-request serving path: B distinct frames, B
            # dispatches, B pad/slice epilogues, each blocked like a real
            # response
            for fr in frames_:
                out = jax.block_until_ready(_fused(fr))
            return out

        n_b = max(n // 2, 5)
        r["fused_batched_ms"] = median_frames(fused, xb, n=n_b) * 1e3
        r["fused_seq_ms"] = median_frames(seq, frames, n=n_b) * 1e3
        r["batch"] = batch
    ok_fused = all(r["fused_ms"] <= r["per_pass_ms"] for r in rows)
    ok_batched = all(r["fused_batched_ms"] <= r["fused_seq_ms"]
                     for r in rows)
    for r in rows:
        speedup = r["per_pass_ms"] / max(r["fused_ms"], 1e-9)
        bspeed = r["fused_seq_ms"] / max(r["fused_batched_ms"], 1e-9)
        print(f"  x={r['x']}: fused {r['fused_ms']:.2f}ms vs per_pass "
              f"{r['per_pass_ms']:.2f}ms ({speedup:.1f}x), "
              f"xla {r['xla_ms']:.2f}ms | B={batch} batched "
              f"{r['fused_batched_ms']:.2f}ms vs sequential "
              f"{r['fused_seq_ms']:.2f}ms ({bspeed:.2f}x)")
    print(f"  fused <= per_pass at every size: {ok_fused}")
    print(f"  batched (B={batch}) <= {batch} sequential fused calls at "
          f"every size: {ok_batched}")
    if artifact:
        _write({"spec_k": k, "batch": batch, "rows": rows}, artifact,
               backend="xla,fused,per_pass")
    return rows, ok_fused and ok_batched


def run_tune(sizes=(48,), *, k: int = 4, n: int = 8, max_batch: int = 4,
             iters: int = 3, artifact: str = ARTIFACT):
    """Autotune each size and measure tuned vs default frame time.

    For every input size one :class:`DeploymentConfig` (default ``fused``
    backend) is handed to :func:`repro.core.tuning.tune`; the winning
    :class:`TunedPlan` is frozen into the config and both the tuned and
    the untuned deployment serve the same batch.  When the tuner's
    winner IS the default execution cell the default measurement is
    reused verbatim — re-measuring an identical path would let timer
    noise flip the sign of a zero delta.

    Returns (rows, ok) where ``ok`` requires the tuned median to be no
    slower than the default for at least one size (the ISSUE-6 gate).
    """
    from repro.core.tuning import tune

    rows = []
    for x_size in sizes:
        cfg = DeploymentConfig.standard(k=k, c_in=C_IN, h=x_size,
                                        max_batch=max_batch)
        tp = tune(cfg, iters=iters)
        dep_def = Deployment.build(cfg)
        dep_tun = Deployment.build(dataclasses.replace(cfg, tuning=tp))
        xb = jax.random.uniform(jax.random.PRNGKey(1),
                                (max_batch, x_size, x_size, C_IN))
        fn_def = _path(dep_def, _edge_params(dep_def))
        default_ms = median_frames(fn_def, xb, n=n) * 1e3
        same_cell = (dep_tun.backend.name == dep_def.backend.name
                     and dep_tun.tile_h == dep_def.tile_h
                     and dep_tun.stream_chunk == dep_def.stream_chunk)
        if same_cell:
            tuned_ms = default_ms
        else:
            fn_tun = _path(dep_tun, _edge_params(dep_tun))
            tuned_ms = median_frames(fn_tun, xb, n=n) * 1e3
            if tuned_ms > default_ms:
                # one paired re-measurement round before believing a
                # regression: interpret-mode medians at small sizes move
                # by more than real tuned-vs-default deltas
                default_ms = min(default_ms,
                                 median_frames(fn_def, xb, n=n) * 1e3)
                tuned_ms = min(tuned_ms,
                               median_frames(fn_tun, xb, n=n) * 1e3)
        row = {"x": x_size, "batch": max_batch,
               "default_backend": dep_def.backend.name,
               "default_ms": default_ms,
               "tuned_backend": tp.backend, "tuned_tile_h": tp.tile_h,
               "tuned_micro_batch": tp.micro_batch, "tuned_ms": tuned_ms,
               "same_cell": same_cell,
               "delta_ms": tuned_ms - default_ms,
               "searched": tp.searched, "pruned": tp.pruned}
        rows.append(row)
        print(f"  x={x_size}: tuned [{tp.backend} tile_h={tp.tile_h} "
              f"micro={tp.micro_batch}] {tuned_ms:.2f}ms vs default "
              f"[{dep_def.backend.name}] {default_ms:.2f}ms "
              f"(delta {row['delta_ms']:+.2f}ms, searched {tp.searched}, "
              f"pruned {tp.pruned})")
    ok = any(r["tuned_ms"] <= r["default_ms"] for r in rows)
    print(f"  tuned <= default for >=1 size: {ok}")
    if artifact:
        _write({"spec_k": k, "kind": "tune", "batch": max_batch,
                "rows": rows}, artifact, backend="tuned")
    return rows, ok


def check_against(baseline_path: str, *, artifact: str = ARTIFACT) -> list:
    """Gate a cross-artifact comparison on matching execution stamps.

    Raises ValueError (CLI: exit 2) when ``artifact`` and the baseline
    were recorded under different — or unrecorded — execution modes;
    returns the list of soft mismatches (host/backend) otherwise.
    """
    with open(artifact) as f:
        current = json.load(f)
    with open(baseline_path) as f:
        baseline = json.load(f)
    perfstamp.check_comparable(current, baseline,
                               what=f"{artifact} vs {baseline_path}")
    soft = perfstamp.mismatches(current, baseline)
    for m in soft:
        print(f"  warning: {m}")
    print(f"  {artifact} comparable with {baseline_path} "
          f"[mode={current.get('mode')}]")
    return soft


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default="64,128,256,400")
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--n", type=int, default=20)
    ap.add_argument("--interpret", action="store_true",
                    help="also time the per_pass interpret path")
    ap.add_argument("--compare", action="store_true",
                    help="benchmark fused vs per_pass vs xla")
    ap.add_argument("--tune", action="store_true",
                    help="autotune per size and record tuned-vs-default "
                         "frame-time deltas")
    ap.add_argument("--tune-iters", type=int, default=3,
                    help="timing repeats per tuner candidate")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="--tune serving batch / tuner max_batch")
    ap.add_argument("--against", metavar="OLD.json",
                    help="after the run, check the written artifact is "
                         "comparable with OLD.json (exit 2 on an "
                         "execution-mode mismatch)")
    args = ap.parse_args(argv)
    sizes = tuple(int(s) for s in args.sizes.split(","))
    if args.tune:
        _, ok = run_tune(sizes, k=args.k, n=args.n,
                         max_batch=args.max_batch, iters=args.tune_iters)
        if not ok:          # gate CI on the tuning acceptance criterion
            raise SystemExit(1)
    elif args.compare:
        _, ok = run_compare(sizes, k=args.k, n=args.n)
        if not ok:          # gate CI on the acceptance criterion
            raise SystemExit(1)
    else:
        modes = ("xla", "per_pass") if args.interpret else ("xla",)
        run(sizes, k=args.k, n=args.n, modes=modes)
    if args.against:
        try:
            check_against(args.against)
        except ValueError as e:
            print(f"  REFUSED: {e}")
            raise SystemExit(2)


if __name__ == "__main__":
    main()
