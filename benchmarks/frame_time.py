"""Paper Figure 2: per-frame encoder processing time vs input size.

Mean of N consecutive inferences with standard deviation, swept over
input sizes.  Execution paths stand in for the paper's device matrix and
are selected declaratively: each (size, backend) cell is ONE
:class:`repro.deploy.DeploymentConfig` resolved by ``Deployment.build``
(the execution-backend registry in ``repro.core.backends``):

* ``xla``      — jit / XLA convs (the embedded-GPU shader analogue);
* ``fused``    — the whole PassPlan as ONE Pallas kernel
  (``kernels.miniconv_pass.miniconv_encoder``; interpret mode on CPU);
* ``per_pass`` — the ``reference`` backend: one pallas_call per shader
  pass (the legacy oracle).

``--compare`` benchmarks fused vs per_pass vs XLA head-to-head (the
ISSUE-1 acceptance check: fused <= per_pass at every size).  5 FPS
feasibility per size is derived like the paper's Pi-Zero X<500
observation.  Results are always written to ``BENCH_frame_time.json`` so
the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.deploy import Deployment, DeploymentConfig

ARTIFACT = "BENCH_frame_time.json"
C_IN = 4


def time_frames(fn, x, *, n: int = 20) -> tuple[float, float]:
    fn(x)                                    # compile / warm
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts)), float(np.std(ts))


def median_frames(fn, x, *, n: int = 8, warm: int = 3) -> float:
    """Median with several warm-up calls: the first couple of post-compile
    interpret-mode runs are 2-3x slower (allocator/trace-cache warm-up),
    which poisons a 2-sample mean."""
    for _ in range(warm):
        jax.block_until_ready(fn(x))
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _deployment(x_size: int, mode: str, *, k: int) -> Deployment:
    """One declarative config per (input size, execution backend) cell."""
    return Deployment.build(DeploymentConfig.standard(
        k=k, c_in=C_IN, h=x_size, backend=mode))


def _path(dep: Deployment, edge_params):
    """The encoder-only (edge half) execution path of a deployment."""
    fn = lambda x: dep.split.edge_apply(edge_params, x)
    return jax.jit(fn) if dep.backend.mode == "xla" else fn


def _edge_params(dep: Deployment, seed: int = 0):
    return dep.init(jax.random.PRNGKey(seed))["edge"]


def run(sizes=(64, 128, 256, 400), *, k: int = 4, n: int = 20,
        modes=("xla",), artifact: str = ARTIFACT):
    rows = []
    for x_size in sizes:
        x = jax.random.uniform(jax.random.PRNGKey(1),
                               (1, x_size, x_size, C_IN))
        row = {"x": x_size}
        for mode in modes:
            dep = _deployment(x_size, mode, k=k)
            # interpret-mode paths execute the kernel body in Python; keep
            # their repeat count small so the sweep stays tractable
            n_mode = n if dep.backend.mode == "xla" else max(n // 5, 3)
            mean, std = time_frames(_path(dep, _edge_params(dep)), x,
                                    n=n_mode)
            row[f"{mode}_ms"] = mean * 1e3
            row[f"{mode}_std_ms"] = std * 1e3
        first = f"{modes[0]}_ms"
        row["fps5_ok"] = row[first] < 200.0
        rows.append(row)
        print("  " + " ".join(f"{kk}={v:.2f}" if isinstance(v, float)
                              else f"{kk}={v}" for kk, v in row.items()))
    if artifact:
        with open(artifact, "w") as f:
            json.dump({"spec_k": k, "modes": list(modes), "rows": rows}, f,
                      indent=2)
        print(f"  wrote {artifact}")
    return rows


def run_compare(sizes=(64, 128, 256), *, k: int = 4, n: int = 20,
                batch: int = 8, artifact: str = ARTIFACT):
    """Fused vs legacy per-pass vs XLA, plus batched vs sequential fused.

    Returns (rows, ok) where ``ok`` combines the ISSUE-1 criterion
    (fused <= per_pass at every size) with the ISSUE-2 criterion: one
    batched (B, H, W, C) fused launch is no slower than B sequential
    single-frame fused launches at every size.
    """
    rows = run(sizes, k=k, n=n, modes=("xla", "fused", "per_pass"),
               artifact=None)
    for r in rows:
        dep = _deployment(r["x"], "fused", k=k)
        fused = _path(dep, _edge_params(dep))
        xb = jax.random.uniform(jax.random.PRNGKey(1),
                                (batch, r["x"], r["x"], C_IN))
        frames = [xb[i:i + 1] for i in range(batch)]

        def seq(frames_, _fused=fused):
            # the per-request serving path: B distinct frames, B
            # dispatches, B pad/slice epilogues, each blocked like a real
            # response
            for fr in frames_:
                out = jax.block_until_ready(_fused(fr))
            return out

        n_b = max(n // 2, 5)
        r["fused_batched_ms"] = median_frames(fused, xb, n=n_b) * 1e3
        r["fused_seq_ms"] = median_frames(seq, frames, n=n_b) * 1e3
        r["batch"] = batch
    ok_fused = all(r["fused_ms"] <= r["per_pass_ms"] for r in rows)
    ok_batched = all(r["fused_batched_ms"] <= r["fused_seq_ms"]
                     for r in rows)
    for r in rows:
        speedup = r["per_pass_ms"] / max(r["fused_ms"], 1e-9)
        bspeed = r["fused_seq_ms"] / max(r["fused_batched_ms"], 1e-9)
        print(f"  x={r['x']}: fused {r['fused_ms']:.2f}ms vs per_pass "
              f"{r['per_pass_ms']:.2f}ms ({speedup:.1f}x), "
              f"xla {r['xla_ms']:.2f}ms | B={batch} batched "
              f"{r['fused_batched_ms']:.2f}ms vs sequential "
              f"{r['fused_seq_ms']:.2f}ms ({bspeed:.2f}x)")
    print(f"  fused <= per_pass at every size: {ok_fused}")
    print(f"  batched (B={batch}) <= {batch} sequential fused calls at "
          f"every size: {ok_batched}")
    if artifact:
        with open(artifact, "w") as f:
            json.dump({"spec_k": k, "batch": batch, "rows": rows}, f,
                      indent=2)
        print(f"  wrote {artifact}")
    return rows, ok_fused and ok_batched


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default="64,128,256,400")
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--n", type=int, default=20)
    ap.add_argument("--interpret", action="store_true",
                    help="also time the per_pass interpret path")
    ap.add_argument("--compare", action="store_true",
                    help="benchmark fused vs per_pass vs xla")
    args = ap.parse_args(argv)
    sizes = tuple(int(s) for s in args.sizes.split(","))
    if args.compare:
        _, ok = run_compare(sizes, k=args.k, n=args.n)
        if not ok:          # gate CI on the acceptance criterion
            raise SystemExit(1)
    else:
        modes = ("xla", "per_pass") if args.interpret else ("xla",)
        run(sizes, k=args.k, n=args.n, modes=modes)


if __name__ == "__main__":
    main()
