"""Paper Figure 2: per-frame encoder processing time vs input size.

Mean of N consecutive inferences with standard deviation, swept over
input sizes.  Two execution paths stand in for the paper's device matrix:
``compiled`` (jit / XLA — the embedded-GPU shader analogue) and
``interpret`` (the Pallas kernel body executed in Python — the weak-CPU
analogue).  5 FPS feasibility per size is derived like the paper's
Pi-Zero X<500 observation.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.miniconv import miniconv_apply, miniconv_init, standard_spec


def time_frames(fn, x, *, n: int = 20) -> tuple[float, float]:
    fn(x)                                    # compile / warm
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts)), float(np.std(ts))


def run(sizes=(64, 128, 256, 400), *, k: int = 4, n: int = 20,
        include_interpret: bool = False):
    spec = standard_spec(c_in=4, k=k)
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    rows = []
    for x_size in sizes:
        x = jax.random.uniform(jax.random.PRNGKey(1), (1, x_size, x_size, 4))
        compiled = jax.jit(lambda x: miniconv_apply(params, spec, x))
        mean_c, std_c = time_frames(compiled, x, n=n)
        row = {"x": x_size, "compiled_ms": mean_c * 1e3,
               "compiled_std_ms": std_c * 1e3,
               "fps5_ok": mean_c < 0.2}
        if include_interpret:
            interp = lambda x: miniconv_apply(params, spec, x,
                                              use_kernel=True)
            mean_i, std_i = time_frames(interp, x, n=max(n // 10, 2))
            row["interpret_ms"] = mean_i * 1e3
        rows.append(row)
        print("  " + " ".join(f"{k}={v:.2f}" if isinstance(v, float)
                              else f"{k}={v}" for k, v in row.items()))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default="64,128,256,400")
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--interpret", action="store_true")
    args = ap.parse_args(argv)
    run(tuple(int(s) for s in args.sizes.split(",")), k=args.k,
        include_interpret=args.interpret)


if __name__ == "__main__":
    main()
