"""Paper Figure 2: per-frame encoder processing time vs input size.

Mean of N consecutive inferences with standard deviation, swept over
input sizes.  Execution paths stand in for the paper's device matrix:

* ``xla``      — jit / XLA convs (the embedded-GPU shader analogue);
* ``fused``    — the whole PassPlan as ONE Pallas kernel
  (``kernels.miniconv_pass.miniconv_encoder``; interpret mode on CPU);
* ``per_pass`` — the legacy reference: one pallas_call per shader pass.

``--compare`` benchmarks fused vs per_pass vs XLA head-to-head (the
ISSUE-1 acceptance check: fused <= per_pass at every size).  5 FPS
feasibility per size is derived like the paper's Pi-Zero X<500
observation.  Results are always written to ``BENCH_frame_time.json`` so
the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.miniconv import miniconv_apply, miniconv_init, standard_spec

ARTIFACT = "BENCH_frame_time.json"


def time_frames(fn, x, *, n: int = 20) -> tuple[float, float]:
    fn(x)                                    # compile / warm
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts)), float(np.std(ts))


def _path(params, spec, mode):
    if mode == "xla":
        return jax.jit(lambda x: miniconv_apply(params, spec, x))
    return lambda x: miniconv_apply(params, spec, x, use_kernel=mode)


def run(sizes=(64, 128, 256, 400), *, k: int = 4, n: int = 20,
        modes=("xla",), artifact: str = ARTIFACT):
    spec = standard_spec(c_in=4, k=k)
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    rows = []
    for x_size in sizes:
        x = jax.random.uniform(jax.random.PRNGKey(1), (1, x_size, x_size, 4))
        row = {"x": x_size}
        for mode in modes:
            # interpret-mode paths execute the kernel body in Python; keep
            # their repeat count small so the sweep stays tractable
            n_mode = n if mode == "xla" else max(n // 5, 3)
            mean, std = time_frames(_path(params, spec, mode), x, n=n_mode)
            row[f"{mode}_ms"] = mean * 1e3
            row[f"{mode}_std_ms"] = std * 1e3
        first = f"{modes[0]}_ms"
        row["fps5_ok"] = row[first] < 200.0
        rows.append(row)
        print("  " + " ".join(f"{kk}={v:.2f}" if isinstance(v, float)
                              else f"{kk}={v}" for kk, v in row.items()))
    if artifact:
        with open(artifact, "w") as f:
            json.dump({"spec_k": k, "modes": list(modes), "rows": rows}, f,
                      indent=2)
        print(f"  wrote {artifact}")
    return rows


def run_compare(sizes=(64, 128, 256), *, k: int = 4, n: int = 20,
                artifact: str = ARTIFACT):
    """Fused vs legacy per-pass vs XLA.

    Returns (rows, ok) where ``ok`` is the ISSUE-1 acceptance criterion:
    fused <= per_pass at every size.
    """
    rows = run(sizes, k=k, n=n, modes=("xla", "fused", "per_pass"),
               artifact=artifact)
    ok = all(r["fused_ms"] <= r["per_pass_ms"] for r in rows)
    for r in rows:
        speedup = r["per_pass_ms"] / max(r["fused_ms"], 1e-9)
        print(f"  x={r['x']}: fused {r['fused_ms']:.2f}ms vs per_pass "
              f"{r['per_pass_ms']:.2f}ms ({speedup:.1f}x), "
              f"xla {r['xla_ms']:.2f}ms")
    print(f"  fused <= per_pass at every size: {ok}")
    return rows, ok


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default="64,128,256,400")
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--n", type=int, default=20)
    ap.add_argument("--interpret", action="store_true",
                    help="also time the per_pass interpret path")
    ap.add_argument("--compare", action="store_true",
                    help="benchmark fused vs per_pass vs xla")
    args = ap.parse_args(argv)
    sizes = tuple(int(s) for s in args.sizes.split(","))
    if args.compare:
        _, ok = run_compare(sizes, k=args.k, n=args.n)
        if not ok:          # gate CI on the acceptance criterion
            raise SystemExit(1)
    else:
        modes = ("xla", "per_pass") if args.interpret else ("xla",)
        run(sizes, k=args.k, n=args.n, modes=modes)


if __name__ == "__main__":
    main()
