"""Benchmark orchestrator: one section per paper table/figure.

Prints ``name,metric,value`` CSV rows after each section so the output is
machine-readable (bench_output.txt).  Smoke-scale by default — each
section's module exposes a CLI with ``--full`` / size flags for
paper-scale runs.
"""
from __future__ import annotations

import sys
import time


def section(title):
    print(f"\n==== {title} " + "=" * max(0, 60 - len(title)))


def main() -> None:
    t0 = time.time()
    csv: list[tuple[str, str, float]] = []

    section("Table 2-4: learning (smoke scale)")
    from benchmarks import learning
    rows = learning.run(total_steps=512, tasks=("pendulum",),
                        encoders=("miniconv4", "full_cnn"))
    for r in rows:
        csv.append((f"learning/{r.task}/{r.encoder}", "final_return",
                    r.final))

    section("Figure 2: per-frame time vs input size (fused vs per-pass)")
    from benchmarks import frame_time
    for row in frame_time.run_compare(sizes=(64, 128), n=10)[0]:
        for mode in ("xla", "fused", "per_pass"):
            csv.append((f"frame_time/x{row['x']}", f"{mode}_ms",
                        row[f"{mode}_ms"]))

    section("Figure 3: sustained inference")
    from benchmarks import sustained
    out = sustained.run(n_frames=100, x_size=128)
    for name, d in out.items():
        csv.append((f"sustained/{name}", "mean_ms", d["mean_ms"]))
        csv.append((f"sustained/{name}", "drift_pct", d["drift_pct"]))

    section("Table 5: decision latency under bandwidth shaping")
    from benchmarks import decision_latency
    for row in decision_latency.run(n_decisions=200):
        csv.append((f"latency/{row['mbps']:g}mbps", "server_only_ms",
                    row["server_only_ms"]))
        csv.append((f"latency/{row['mbps']:g}mbps", "split_ms",
                    row["split_ms"]))

    section("Table 6: server scalability (FIFO vs micro-batched)")
    from benchmarks import scalability
    rows6, p95s6 = scalability.run(n_max=128)
    for name, n in rows6.items():
        csv.append((f"scalability/{name}", "max_clients", float(n)))
    for n, (fifo_ms, batched_ms) in p95s6.items():
        csv.append((f"scalability/n{n}", "fifo_p95_ms", fifo_ms))
        csv.append((f"scalability/n{n}", "batched_p95_ms", batched_ms))

    section("Eq. 1: break-even bandwidth")
    from benchmarks import break_even
    for row in break_even.run():
        csv.append((f"break_even/{row['config']}", "pred_mbps",
                    row["pred"]))
        csv.append((f"break_even/{row['config']}", "sim_mbps", row["sim"]))

    section("Roofline table (from dry-run artifacts, if present)")
    from benchmarks import roofline_table
    roofline_table.main([])

    section("MiniConv pass-plan roofline")
    roofline_table.miniconv_table()

    section("CSV")
    print("name,metric,value")
    for name, metric, value in csv:
        print(f"{name},{metric},{value:.4f}")
    print(f"\ntotal bench time {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
