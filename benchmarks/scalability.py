"""Paper Table 6: server scalability at a fixed decision rate.

Max concurrent clients a single server sustains at 10 Hz within a p95
decision-latency budget of 100 ms, server-only vs split-policy.  Service
times are measured on this host from the real jitted networks; queueing
is the deterministic FIFO simulation.
"""
from __future__ import annotations

import argparse

from benchmarks.decision_latency import build
from repro.serving.netsim import shaped
from repro.serving.server import PolicyServer, QueueSim


def run(*, mbps: float = 100.0, rate_hz: float = 10.0,
        budget_ms: float = 100.0, n_max: int = 256):
    (edge_fn, split_srv, mono_srv, obs, wire_bytes,
     frame_bytes) = build()
    payload = edge_fn(obs)
    s_split = PolicyServer(serve_fn=split_srv).measure(payload)
    s_mono = PolicyServer(serve_fn=mono_srv).measure(obs)

    rows = {}
    for name, svc, payload_bytes in (
            ("server_only", s_mono, frame_bytes),
            ("split_policy", s_split, wire_bytes)):
        sim = QueueSim(service_time_s=svc, uplink=shaped(mbps),
                       payload_bytes=payload_bytes, rate_hz=rate_hz,
                       horizon_s=5.0)
        rows[name] = sim.max_clients(p95_budget_s=budget_ms / 1e3,
                                     n_max=n_max)
        print(f"  {name:<13} service={svc*1e3:6.2f}ms payload="
              f"{payload_bytes:>7}B -> {rows[name]:>4} clients "
              f"@ {rate_hz:.0f}Hz p95<{budget_ms:.0f}ms")
    ratio = rows["split_policy"] / max(rows["server_only"], 1)
    print(f"  scaling factor: {ratio:.1f}x (paper: 12 -> 36 = 3.0x)")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mbps", type=float, default=100.0)
    ap.add_argument("--budget-ms", type=float, default=100.0)
    args = ap.parse_args(argv)
    run(mbps=args.mbps, budget_ms=args.budget_ms)


if __name__ == "__main__":
    main()
