"""Paper Table 6: server scalability at a fixed decision rate.

Max concurrent clients a single server sustains at 10 Hz within a p95
decision-latency budget of 100 ms, server-only vs split-policy, and —
beyond the paper — split-policy with server-side MICRO-BATCHING: the
server accumulates queued requests (up to ``--max-batch``) and serves
them with one batched call whose service time t(B) is measured on this
host from the real jitted batched network.  Queueing is the deterministic
FIFO / batch-aware simulation (``repro.serving.server``).

The FLEET table extrapolates Table 6 to ``n_servers`` sharded servers
behind each routing policy (``repro.serving.fleet``): supported clients
vs fleet size, every server charging the same measured t(B) curve, all
fed from the shared shaped uplink.  The fleet shape is config-level —
``DeploymentConfig.n_servers`` / ``router`` — so a manifest alone turns
the single-server reproduction into a capacity-planning model.

``--smoke`` runs a fast CI gate: at N=8 clients the micro-batched p95
must not exceed the FIFO p95 (greedy batching strictly dominates FIFO
when t(B) is sublinear; a regression here means the batched path or the
simulator broke), and the fleet table must be MONOTONE — more servers
never supports fewer clients, for every routing policy.  ``--manifest``
builds the whole split pipeline from a serialised
:class:`repro.deploy.DeploymentConfig` (the file ``python -m
repro.deploy`` writes) instead of the built-in default, so the gates
exercise exactly the deployment that would ship.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

# make `python benchmarks/scalability.py` work from any cwd: the shared
# setup lives in the sibling benchmarks package, which is rooted at the
# repo top level, not on the default script path
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.decision_latency import (build, load_manifest,
                                         measure_service_curve)
from repro.serving.fleet import router_names
from repro.serving.netsim import shaped
from repro.serving.server import BatchQueueSim, PolicyServer, QueueSim


def run(*, mbps: float = 100.0, rate_hz: float = 10.0,
        budget_ms: float = 100.0, n_max: int = 256, max_batch: int = 8,
        max_wait_ms: float = 0.0, iters: int = 10, horizon_s: float = 5.0,
        config=None, setup=None, model=None):
    setup = setup or build(config=config)
    s_mono = PolicyServer(serve_fn=setup.mono_server_fn).measure(
        setup.obs, iters=iters)
    if model is None:
        _, model = measure_service_curve(setup, max_batch=max_batch,
                                         max_wait_s=max_wait_ms / 1e3,
                                         iters=iters)
    s_split = model(1)

    sims = {
        "server_only": (QueueSim(service_time_s=s_mono, uplink=shaped(mbps),
                                 payload_bytes=setup.frame_bytes,
                                 rate_hz=rate_hz, horizon_s=horizon_s),
                        s_mono, setup.frame_bytes),
        "split_fifo": (QueueSim(service_time_s=s_split, uplink=shaped(mbps),
                                payload_bytes=setup.wire_bytes,
                                rate_hz=rate_hz, horizon_s=horizon_s),
                       s_split, setup.wire_bytes),
        "split_batched": (BatchQueueSim(service_time_s=s_split,
                                        uplink=shaped(mbps),
                                        payload_bytes=setup.wire_bytes,
                                        rate_hz=rate_hz, horizon_s=horizon_s,
                                        max_batch=max_batch,
                                        max_wait_s=max_wait_ms / 1e3,
                                        service_model=model),
                          s_split, setup.wire_bytes),
    }
    rows = {}
    for name, (sim, svc, payload_bytes) in sims.items():
        rows[name] = sim.max_clients(p95_budget_s=budget_ms / 1e3,
                                     n_max=n_max)
        print(f"  {name:<13} service={svc*1e3:6.2f}ms payload="
              f"{payload_bytes:>7}B -> {rows[name]:>4} clients "
              f"@ {rate_hz:.0f}Hz p95<{budget_ms:.0f}ms")
    ratio = rows["split_fifo"] / max(rows["server_only"], 1)
    print(f"  scaling factor (split FIFO): {ratio:.1f}x "
          f"(paper: 12 -> 36 = 3.0x)")
    batch_ratio = rows["split_batched"] / max(rows["split_fifo"], 1)
    print(f"  micro-batching gain over FIFO: {batch_ratio:.1f}x "
          f"(max_batch={max_batch})")

    p95s = {}
    for n in (8, min(32, n_max)):
        f = sims["split_fifo"][0].p95(n) * 1e3
        b = sims["split_batched"][0].p95(n) * 1e3
        p95s[n] = (f, b)
        print(f"  N={n:>3}: split p95 FIFO {f:8.2f} ms vs batched "
              f"{b:8.2f} ms")
    return rows, p95s


def fleet_table(setup, model, *, mbps: float = 100.0, rate_hz: float = 10.0,
                budget_ms: float = 100.0, horizon_s: float = 2.0,
                n_servers_list=(1, 2, 4, 8), routers=None,
                n_max: int = 4096, max_batch=None, max_wait_s=None):
    """Clients supported vs fleet size, per routing policy.

    Every simulation is driven from the deployment manifest: payload
    bytes, micro-batching policy and the configured fleet shape come
    from ``setup.deployment`` (``DeploymentConfig.n_servers/router``);
    ``model`` is the measured t(B) curve charged by every server.  The
    configured ``n_servers`` is always included in the sweep.
    """
    dep = setup.deployment
    routers = tuple(routers) if routers else router_names()
    sizes = sorted(set(n_servers_list) | {dep.config.n_servers})
    # batching-policy overrides keep the sim on the SAME policy the t(B)
    # curve was measured under when the CLI deviates from the manifest
    base = dep.fleet_sim(model, uplink=shaped(mbps), rate_hz=rate_hz,
                         horizon_s=horizon_s, max_batch=max_batch,
                         max_wait_s=max_wait_s)
    table = {}
    for router in routers:
        marker = " (configured)" if router == dep.config.router else ""
        table[router] = {
            s: base.with_servers(s, router).max_clients(
                p95_budget_s=budget_ms / 1e3, n_max=n_max)
            for s in sizes}
        cells = "  ".join(f"{s}x:{table[router][s]:>5}" for s in sizes)
        print(f"  fleet {router:<16} {cells}{marker}")
    return table


def check_fleet_monotone(table, *, min_gain_at_4x: float = 0.0,
                         n_max: int = None) -> bool:
    """The --smoke fleet gate: more servers never supports fewer clients
    (per routing policy), and optionally 4 servers must carry at least
    ``min_gain_at_4x`` times the single-server population.  A 4-server
    row that saturates the ``n_max`` search cap passes the gain check —
    capacity is at least the measurable bound, not sublinear."""
    ok = True
    for router, row in table.items():
        sizes = sorted(row)
        mono = all(row[a] <= row[b] for a, b in zip(sizes, sizes[1:]))
        gain = row[4] / max(row[1], 1) if {1, 4} <= set(sizes) else None
        capped = gain is not None and n_max is not None and row[4] >= n_max
        scaled = gain is None or capped or gain >= min_gain_at_4x
        print(f"  fleet gate {router:<16} monotone={mono}"
              + (f" gain@4x={gain:.1f}" if gain is not None else "")
              + (" (>= search cap)" if capped else ""))
        ok = ok and mono and scaled
    return ok


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mbps", type=float, default=100.0)
    ap.add_argument("--fleet-mbps", type=float, default=1000.0,
                    help="shared ingress bandwidth for the FLEET table "
                         "(a fleet front door is provisioned beyond the "
                         "paper's single 100 Mb/s shaped link)")
    ap.add_argument("--budget-ms", type=float, default=100.0)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=0.0)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI gate: batched p95 <= FIFO p95 at N=8 "
                         "clients, and the fleet table is monotone in "
                         "n_servers with >= 2x clients at 4 servers")
    ap.add_argument("--no-fleet", action="store_true",
                    help="skip the fleet table (single-server rows only)")
    ap.add_argument("--real-fleet", action="store_true",
                    help="after the fleet table, calibrate its predictions "
                         "against the REAL spawned fleet on localhost "
                         "(benchmarks.realfleet; uses the manifest when "
                         "given, else the small calibration deployment)")
    ap.add_argument("--manifest", default=None,
                    help="deployment manifest JSON to build the pipeline "
                         "from (see python -m repro.deploy)")
    args = ap.parse_args(argv)
    config = load_manifest(args.manifest) if args.manifest else None
    setup = build(config=config)
    if args.smoke:
        _, model = measure_service_curve(setup, max_batch=args.max_batch,
                                         max_wait_s=args.max_wait_ms / 1e3,
                                         iters=5)
        rows, p95s = run(mbps=args.mbps, budget_ms=args.budget_ms,
                         max_batch=args.max_batch,
                         max_wait_ms=args.max_wait_ms,
                         n_max=64, iters=5, horizon_s=2.0,
                         setup=setup, model=model)
        fifo, batched = p95s[8]
        # 5% relative tolerance: both sims are driven by a wall-clock
        # measured t(B) curve, and a single noisy sample on a shared CI
        # runner can make the curve locally superlinear without any code
        # regression
        ok = batched <= 1.05 * fifo + 1e-9
        print(f"  smoke: batched p95 {batched:.2f} ms <= 1.05 * FIFO p95 "
              f"{fifo:.2f} ms at N=8: {ok}")
        table = fleet_table(setup, model, mbps=args.fleet_mbps,
                            budget_ms=args.budget_ms, horizon_s=2.0,
                            n_max=2048, max_batch=args.max_batch,
                            max_wait_s=args.max_wait_ms / 1e3)
        fleet_ok = check_fleet_monotone(table, min_gain_at_4x=2.0,
                                        n_max=2048)
        print(f"  smoke: fleet monotone in n_servers with >= 2x clients "
              f"at 4 servers: {fleet_ok}")
        if not (ok and fleet_ok):
            raise SystemExit(1)
    else:
        _, model = measure_service_curve(setup, max_batch=args.max_batch,
                                         max_wait_s=args.max_wait_ms / 1e3)
        run(mbps=args.mbps, budget_ms=args.budget_ms,
            max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
            setup=setup, model=model)
        if not args.no_fleet:
            fleet_table(setup, model, mbps=args.fleet_mbps,
                        budget_ms=args.budget_ms,
                        max_batch=args.max_batch,
                        max_wait_s=args.max_wait_ms / 1e3)
    if args.real_fleet:
        # the sim tables above are predictions; close the loop by running
        # the same deployment as real worker processes and comparing p95
        from benchmarks.realfleet import calibrate, small_config, \
            write_artifact
        rcfg = config or small_config()
        print("  real-fleet calibration (localhost, measured vs predicted):")
        rows = calibrate(rcfg, n_servers_list=(1, 2))
        write_artifact(rows, rcfg)


if __name__ == "__main__":
    main()
