"""Population-engine throughput: P members in ONE jitted program vs the
P=1-run-P-times sequential baseline, plus the correctness gates that make
the number trustworthy.

What is measured
----------------
Aggregate env-steps/sec (summed over members, end-to-end wall including
XLA compile) for P in {1, 4, 16} population runs against running the
single-run engine P times from scratch — each sequential run rebuilds its
engine and recompiles, exactly like ``benchmarks/learning.py`` runs its
conditions today.  That is the cost the population engine removes: the
population compiles its chunk ONCE for all P members (``lax.map`` lanes),
so on CPU hosts — where compile dominates smoke-scale runs — aggregate
throughput scales with P.  Steady-state (cache-warm) numbers are reported
alongside for honesty; rows are stamped via ``repro.perfstamp`` and
marked ``regime: "collection"`` (warmup-only budget, as in the PR 5
off-policy comparison — both sides run the identical random-action
program).

``--smoke`` additionally gates (CI):
* P=16 aggregate collection throughput >= 3x the P=1 sequential baseline;
* member 0 of a P=2 population (with gradient updates, tiny config) is
  BITWISE-equal to ``repro.rl.train.train`` at the same seed;
* the eval protocol is deterministic: bitwise replay at a fixed seed and
  a finite ``final_100_mean`` on a shortened episode window.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import perfstamp
from repro.envs import make_pixel_env
from repro.rl.agent import make_agent
from repro.rl.ddpg import DDPGConfig
from repro.rl.population import (PopulationSpec, evaluate, final_100_mean,
                                 make_population_engine, split_member_keys,
                                 train_population)
from repro.rl.rollout import make_engine
from repro.rl.train import train, _pipeline_encoder

TASK = "pendulum"
ENCODER = "miniconv4"
BENCH_PATH = "BENCH_population.json"
DEFAULT_POPS = (1, 4, 16)


def _collection_cfg(total_steps: int, n_envs: int = 2) -> DDPGConfig:
    """learning_starts above the budget -> the whole run is random-action
    collection (PR 5's regime): population and sequential sides execute
    the identical warmup program, so the comparison isolates compile
    amortisation + launch overhead from learning compute."""
    return DDPGConfig(n_envs=n_envs, learning_starts=total_steps + n_envs,
                      buffer_size=max(total_steps * n_envs, n_envs),
                      batch_size=n_envs)


def measure_single(total_steps: int, *, seed: int = 0,
                   n_envs: int = 2) -> dict:
    """One FROM-SCRATCH single-run engine pass (fresh build -> fresh XLA
    compile, like every ``benchmarks/learning.py`` condition), plus a
    cache-warm second pass for the steady-state number."""
    env = make_pixel_env(TASK, train=True)
    encoder = _pipeline_encoder(ENCODER, env.obs_shape[-1])
    cfg = _collection_cfg(total_steps, n_envs)
    agent = make_agent("ddpg", encoder, env.action_dim, cfg=cfg)
    engine = make_engine(env, agent, total_steps)
    phases = engine.plan()

    def one_pass(key):
        k_init, key = jax.random.split(key)
        carry = engine.init(k_init)
        jax.block_until_ready(carry.obs)    # init outside the window
        t0 = time.perf_counter()
        steps = 0
        for phase in phases:
            key, sub = jax.random.split(key)
            carry, rewards, dones, _ = engine.run(carry, sub, phase)
            steps += int(np.asarray(rewards).size)
        jax.block_until_ready(dones)
        return steps, time.perf_counter() - t0

    steps, wall = one_pass(jax.random.PRNGKey(seed))       # compiles
    _, steady = one_pass(jax.random.PRNGKey(seed + 1))     # cache-warm
    return {"steps": steps, "wall_s": wall, "steady_s": steady}


def measure_population(P: int, total_steps: int, *, seed: int = 0,
                      n_envs: int = 2) -> dict:
    """One from-scratch population pass (P members, one compile) plus a
    cache-warm second pass."""
    env = make_pixel_env(TASK, train=True)
    encoder = _pipeline_encoder(ENCODER, env.obs_shape[-1])
    cfg = _collection_cfg(total_steps, n_envs)
    engine = make_population_engine(env, "ddpg", encoder, env.action_dim,
                                    cfg, {}, P, total_steps)
    phases = engine.plan()

    def one_pass(seed0):
        keys = jnp.stack([jax.random.PRNGKey(seed0 + i) for i in range(P)])
        k_init, keys = split_member_keys(keys)
        carry = engine.init(k_init)
        jax.block_until_ready(carry.obs)    # init outside the window
        t0 = time.perf_counter()
        steps = 0
        for phase in phases:
            keys, subs = split_member_keys(keys)
            carry, rewards, dones, _ = engine.run(carry, subs, phase)
            steps += int(np.asarray(rewards).size)   # all P members
        jax.block_until_ready(dones)
        return steps, time.perf_counter() - t0

    steps, wall = one_pass(seed)             # compiles (once, for all P)
    _, steady = one_pass(seed + P)           # cache-warm
    return {"steps": steps, "wall_s": wall, "steady_s": steady}


def run_grid(pops=DEFAULT_POPS, *, total_steps: int = 64, seed: int = 0,
             n_envs: int = 2) -> list[dict]:
    """Rows: per P, population aggregate throughput vs the sequential
    baseline P x (one from-scratch single run)."""
    base = measure_single(total_steps, seed=seed, n_envs=n_envs)
    print(f"  baseline single run: {base['steps']} steps in "
          f"{base['wall_s']:.1f}s (steady pass {base['steady_s']:.2f}s)")
    rows = []
    for P in pops:
        pop = measure_population(P, total_steps, seed=seed, n_envs=n_envs)
        seq_wall = P * base["wall_s"]                # P from-scratch runs
        agg_sps = pop["steps"] / pop["wall_s"]
        seq_sps = (P * base["steps"]) / seq_wall
        row = {"P": P, "task": TASK, "algo": "ddpg", "encoder": ENCODER,
               "regime": "collection", "includes_compile": True,
               "total_steps_per_member": total_steps, "n_envs": n_envs,
               "population_steps": pop["steps"],
               "population_wall_s": pop["wall_s"],
               "population_steady_s": pop["steady_s"],
               "sequential_wall_s": seq_wall,
               "aggregate_steps_per_sec": agg_sps,
               "sequential_steps_per_sec": seq_sps,
               "steady_aggregate_steps_per_sec":
                   pop["steps"] / pop["steady_s"],
               "speedup_vs_sequential": agg_sps / seq_sps}
        rows.append(row)
        print(f"  P={P:<3} population {pop['wall_s']:6.1f}s "
              f"({agg_sps:7.1f} agg steps/s, steady "
              f"{row['steady_aggregate_steps_per_sec']:7.1f}) vs "
              f"sequential {seq_wall:6.1f}s -> "
              f"{row['speedup_vs_sequential']:.1f}x")
    return rows


def check_member0_parity(*, total_steps: int = 32) -> dict:
    """Member 0 of a P=2 population (WITH gradient updates — tiny config
    so the update path is exercised, not just collection) vs a single
    ``train()`` run at the same seed: params and episode returns must be
    bitwise identical."""
    small = {"batch_size": 8, "buffer_size": 64, "learning_starts": 8,
             "n_envs": 2}
    spec = PopulationSpec(tasks=(TASK,), seeds=(0, 1),
                          total_steps=total_steps, encoder=ENCODER,
                          cfg_overrides=small)
    pop = train_population(spec, eval_episodes=0)
    single = train(TASK, ENCODER, total_steps=total_steps, seed=0,
                   cfg=DDPGConfig(**small))
    m0 = pop.members[0]
    params_equal = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(m0.params),
                        jax.tree.leaves(single.params)))
    returns_equal = (m0.episode_returns == single.episode_returns
                     and m0.truncated_returns == single.truncated_returns)
    row = {"total_steps": total_steps, "n_members": len(pop.members),
           "params_bitwise": bool(params_equal),
           "returns_bitwise": bool(returns_equal),
           "bitwise": bool(params_equal and returns_equal)}
    print(f"  member-0 parity (P=2, with updates): params "
          f"{'BITWISE' if params_equal else 'DIFFER'}, returns "
          f"{'BITWISE' if returns_equal else 'DIFFER'}")
    return row


def check_eval_protocol(*, n_episodes: int = 4, max_steps: int = 40,
                        seed: int = 7) -> dict:
    """The final-100-episode protocol on a shortened window: same seed
    twice must replay bitwise, and the summary metric must be finite."""
    env = make_pixel_env(TASK, train=False)
    encoder = _pipeline_encoder(ENCODER, env.obs_shape[-1])
    agent = make_agent("ddpg", encoder, env.action_dim)
    params = agent.init(jax.random.PRNGKey(0)).params
    r1 = evaluate(agent, params, n_episodes, env=env, seed=seed,
                  max_steps=max_steps)
    r2 = evaluate(agent, params, n_episodes, env=env, seed=seed,
                  max_steps=max_steps)
    row = {"n_episodes": n_episodes, "max_steps": max_steps,
           "final_100_mean": final_100_mean(r1),
           "bitwise_replay": bool(np.array_equal(r1, r2))}
    print(f"  eval protocol: replay "
          f"{'BITWISE' if row['bitwise_replay'] else 'DIFFERS'}, "
          f"final_100_mean={row['final_100_mean']:.1f} "
          f"({n_episodes} episodes x {max_steps} steps)")
    return row


def write_bench(rows, parity, eval_row, *, total_steps: int,
                path: str = BENCH_PATH) -> dict:
    doc = perfstamp.stamp({
        "benchmark": "population",
        "host_detail": {"platform": platform.platform(),
                        "backend": jax.default_backend()},
        "total_steps_per_member": total_steps,
        "lane_mode": "exact",
        "rows": rows,
        "member0_parity": parity,
        "eval_protocol": eval_row,
    }, backend=jax.default_backend())
    Path(path).write_text(json.dumps(doc, indent=2))
    print(f"  wrote {path}")
    return doc


def check_smoke(doc: dict) -> None:
    """CI gate for the population engine (see module docstring)."""
    assert doc["member0_parity"]["bitwise"], \
        "member 0 of the population is not bitwise-equal to the " \
        "single-run engine"
    ev = doc["eval_protocol"]
    assert ev["bitwise_replay"], "eval protocol is not deterministic"
    assert np.isfinite(ev["final_100_mean"]), \
        f"non-finite eval metric: {ev['final_100_mean']}"
    by_p = {r["P"]: r for r in doc["rows"]}
    for r in doc["rows"]:
        assert r["aggregate_steps_per_sec"] > 0, f"P={r['P']}: zero agg"
        if r["P"] > 1:
            assert r["speedup_vs_sequential"] >= 1.0, \
                f"P={r['P']}: population slower than sequential " \
                f"({r['speedup_vs_sequential']:.2f}x)"
    top = max(by_p)
    sp = by_p[top]["speedup_vs_sequential"]
    assert sp >= 3.0, \
        f"P={top} aggregate throughput only {sp:.2f}x sequential (< 3x)"
    print(f"  smoke gate OK: P={top} {sp:.1f}x sequential, member-0 "
          "bitwise, eval deterministic")


def compare_against(doc: dict, against_path: str) -> None:
    """Refuse cross-mode comparisons; report per-P speedup deltas."""
    old = json.loads(Path(against_path).read_text())
    try:
        perfstamp.check_comparable(old, doc, what="population benchmarks")
    except ValueError as e:
        print(f"  --against: {e}")
        sys.exit(2)
    old_by_p = {r["P"]: r for r in old.get("rows", [])}
    for r in doc["rows"]:
        o = old_by_p.get(r["P"])
        if o is None:
            continue
        print(f"  P={r['P']}: speedup {o['speedup_vs_sequential']:.1f}x -> "
              f"{r['speedup_vs_sequential']:.1f}x; agg steps/s "
              f"{o['aggregate_steps_per_sec']:.1f} -> "
              f"{r['aggregate_steps_per_sec']:.1f}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=64,
                    help="collection steps per member")
    ap.add_argument("--pops", default=",".join(map(str, DEFAULT_POPS)))
    ap.add_argument("--n-envs", type=int, default=2)
    ap.add_argument("--smoke", action="store_true",
                    help="gate: >=3x at the largest P, member-0 bitwise "
                         "parity, deterministic eval")
    ap.add_argument("--against", default=None,
                    help="prior BENCH_population.json to diff against "
                         "(refuses cross-mode artifacts)")
    ap.add_argument("--json", default=BENCH_PATH)
    args = ap.parse_args(argv)
    pops = tuple(int(p) for p in args.pops.split(","))

    rows = run_grid(pops, total_steps=args.steps, n_envs=args.n_envs)
    parity = check_member0_parity()
    eval_row = check_eval_protocol()
    doc = write_bench(rows, parity, eval_row, total_steps=args.steps,
                      path=args.json)
    if args.against:
        compare_against(doc, args.against)
    if args.smoke:
        check_smoke(doc)


if __name__ == "__main__":
    main()
