"""Paper Figure 3: sustained inference over many consecutive frames.

Reports per-frame time drift over a long run (the paper observes Jetson
thermal throttling and CPU-vs-GPU stability on the Pi Zero).  Thermal
state does not exist here; the reproducible part is the *stability*
comparison between an op-by-op interpreted path (the paper's CPU/PyTorch
condition) and the compiled path (the OpenGL condition), plus drift
detection over the horizon.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.miniconv import miniconv_apply, miniconv_init, standard_spec


def sustained(fn, x, n_frames: int) -> np.ndarray:
    fn(x)
    ts = np.empty(n_frames)
    for i in range(n_frames):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        ts[i] = time.perf_counter() - t0
    return ts


def run(*, n_frames: int = 200, x_size: int = 128, k: int = 4):
    spec = standard_spec(c_in=4, k=k)
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    x = jax.random.uniform(jax.random.PRNGKey(1), (1, x_size, x_size, 4))

    compiled = jax.jit(lambda x: miniconv_apply(params, spec, x))
    eager = lambda x: miniconv_apply(params, spec, x)   # op-by-op dispatch

    out = {}
    for name, fn, n in (("compiled", compiled, n_frames),
                        ("eager", eager, max(n_frames // 10, 10))):
        ts = sustained(fn, x, n)
        head, tail = ts[: n // 4].mean(), ts[-n // 4:].mean()
        out[name] = {
            "mean_ms": ts.mean() * 1e3, "p99_ms":
                float(np.percentile(ts, 99) * 1e3),
            "drift_pct": 100.0 * (tail - head) / head,
            "cv_pct": 100.0 * ts.std() / ts.mean(),
        }
        print(f"  {name:<9} mean={out[name]['mean_ms']:.3f}ms "
              f"p99={out[name]['p99_ms']:.3f}ms "
              f"drift={out[name]['drift_pct']:+.1f}% "
              f"cv={out[name]['cv_pct']:.1f}%")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--frames", type=int, default=200)
    ap.add_argument("--size", type=int, default=128)
    args = ap.parse_args(argv)
    run(n_frames=args.frames, x_size=args.size)


if __name__ == "__main__":
    main()
