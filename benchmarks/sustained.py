"""Paper Figure 3: sustained inference over many consecutive frames.

Reports per-frame time drift over a long run (the paper observes Jetson
thermal throttling and CPU-vs-GPU stability on the Pi Zero).  Thermal
state does not exist here; the reproducible part is the *stability*
comparison between an op-by-op interpreted path (the paper's CPU/PyTorch
condition) and the compiled path (the OpenGL condition), plus drift
detection over the horizon.

Execution paths come from :mod:`repro.deploy`: every condition is one
:class:`DeploymentConfig` resolved by ``Deployment.build``, so the run
honours frozen ``tuning`` blocks and streaming decisions exactly like a
served policy would.  ``--manifest DEPLOY.json`` sustains the manifest's
own deployment (tuned backend included) instead of the default pair.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import perfstamp
from repro.deploy import Deployment, DeploymentConfig


def sustained(fn, x, n_frames: int, *, warmup: int = 3) -> np.ndarray:
    for _ in range(warmup):
        jax.block_until_ready(fn(x))  # compile + settle before the clock
    ts = np.empty(n_frames)
    for i in range(n_frames):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        ts[i] = time.perf_counter() - t0
    return ts


def _edge_fn(dep: Deployment, *, jit: bool, seed: int = 0):
    """The encoder (edge half) path of a deployment, optionally jitted."""
    edge_params = dep.init(jax.random.PRNGKey(seed))["edge"]
    fn = lambda x: dep.split.edge_apply(edge_params, x)
    return jax.jit(fn) if jit else fn


def run(*, n_frames: int = 200, x_size: int = 128, k: int = 4,
        manifest: str | None = None):
    if manifest is not None:
        with open(manifest) as f:
            cfg = DeploymentConfig.from_dict(json.load(f))
        dep = Deployment.build(cfg)
        x_size = cfg.in_h
        label = dep.backend.name
        if cfg.tuning is not None:
            label += f"[tuned tile_h={dep.tile_h}]"
        # jit only the xla path: pallas tiers are already jitted inside,
        # and the outer-jit vs raw-dispatch contrast is the experiment
        conditions = ((label, _edge_fn(dep, jit=dep.backend.mode == "xla"),
                       n_frames),)
        for line in dep.build_log:
            print(f"  {line}")
    else:
        dep = Deployment.build(DeploymentConfig.standard(
            k=k, c_in=4, h=x_size, backend="xla"))
        conditions = (
            ("compiled", _edge_fn(dep, jit=True), n_frames),
            ("eager", _edge_fn(dep, jit=False), max(n_frames // 10, 10)),
        )
    c_in = dep.config.spec.layers[0].c_in
    x = jax.random.uniform(jax.random.PRNGKey(1),
                           (1, x_size, x_size, c_in))

    out = {}
    for name, fn, n in conditions:
        ts = sustained(fn, x, n)
        head, tail = ts[: n // 4].mean(), ts[-n // 4:].mean()
        out[name] = perfstamp.stamp({
            "mean_ms": ts.mean() * 1e3, "p99_ms":
                float(np.percentile(ts, 99) * 1e3),
            "drift_pct": 100.0 * (tail - head) / head,
            "cv_pct": 100.0 * ts.std() / ts.mean(),
        }, backend=dep.backend.name)
        print(f"  {name:<9} mean={out[name]['mean_ms']:.3f}ms "
              f"p99={out[name]['p99_ms']:.3f}ms "
              f"drift={out[name]['drift_pct']:+.1f}% "
              f"cv={out[name]['cv_pct']:.1f}% "
              f"[{out[name]['mode']}]")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--frames", type=int, default=200)
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--manifest", metavar="DEPLOY.json",
                    help="sustain this deployment manifest's execution "
                         "path (tuning block honoured) instead of the "
                         "compiled/eager default pair")
    args = ap.parse_args(argv)
    run(n_frames=args.frames, x_size=args.size, manifest=args.manifest)


if __name__ == "__main__":
    main()
