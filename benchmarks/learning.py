"""Paper Tables 2-4: learning performance per (task, algorithm) with the
three encoder conditions (MiniConv K=4, K=16, Full-CNN).

The pure-JAX environments are simplified (DESIGN.md §4), so absolute
returns are not comparable to the paper; the benchmark reproduces the
comparison STRUCTURE — within-task Best/Mean/Final per encoder — and the
tooling.  Default is smoke scale; pass ``--full`` for long runs.
"""
from __future__ import annotations

import argparse

from repro.rl.train import train

ENCODERS = ("miniconv4", "miniconv16", "full_cnn")
TASKS = ("walker", "hopper", "pendulum")     # PPO / SAC / DDPG per paper


def run(*, total_steps: int = 512, tasks=TASKS, encoders=ENCODERS,
        seed: int = 0, verbose: bool = False):
    rows = []
    for task in tasks:
        for enc in encoders:
            res = train(task, enc, total_steps=total_steps, seed=seed,
                        verbose=verbose)
            rows.append(res)
            print(f"  {task:<10} {res.algo:<5} {enc:<11} "
                  f"best={res.best:8.1f} final={res.final:8.1f} "
                  f"mean={res.mean:8.1f} episodes={len(res.episode_returns)}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=512)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale (hours on CPU)")
    ap.add_argument("--tasks", default=",".join(TASKS))
    args = ap.parse_args(argv)
    steps = 200_000 if args.full else args.steps
    print("task,algo,encoder,best,final,mean,episodes")
    rows = run(total_steps=steps, tasks=args.tasks.split(","))
    for r in rows:
        print(f"{r.task},{r.algo},{r.encoder},{r.best:.1f},{r.final:.1f},"
              f"{r.mean:.1f},{len(r.episode_returns)}")


if __name__ == "__main__":
    main()
