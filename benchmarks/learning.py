"""Paper Tables 2-4: learning performance per (task, algorithm) with the
three encoder conditions (MiniConv K=4, K=16, Full-CNN) — now with
learning THROUGHPUT (env-steps/sec) per condition, written to
``BENCH_learning.json`` so the perf trajectory tracks training speed too.

The pure-JAX environments are simplified (DESIGN.md §4), so absolute
returns are not comparable to the paper; the benchmark reproduces the
comparison STRUCTURE — within-task Best/Mean/Final per encoder — and the
tooling.  Default is smoke scale; pass ``--full`` for long runs.

Throughput modes
----------------
``--smoke``   one encoder per task (all three algorithms), gated on finite
              Best/Mean/Final and nonzero steps/sec — the CI learning gate.
``--compare`` additionally measures the off-policy engines against the
              pre-refactor per-step Python loop (single env, numpy replay,
              one jitted call per step — reimplemented here as the
              throughput baseline) and reports the speedup.  Both sides
              exclude compile time (steady-state steps/sec).
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import perfstamp
from repro.rl.agent import make_agent
from repro.rl.buffers import ReplayBuffer
from repro.rl.rollout import make_engine
from repro.rl.train import TASK_ALGO, _pipeline_encoder, train

ENCODERS = ("miniconv4", "miniconv16", "full_cnn")
TASKS = ("walker", "hopper", "pendulum")     # PPO / SAC / DDPG per paper
BENCH_PATH = "BENCH_learning.json"


def _smoke_cfgs():
    """Bounded algorithm configs for the CI smoke gate: same algorithms,
    same engines, smaller XLA programs (the default PPO iteration —
    128 steps x 8 envs x 4 epochs — compiles for minutes on CPU hosts).
    learning_starts is pulled below the 256-step smoke budget so the gate
    actually executes interleaved SAC/DDPG gradient updates, not just
    random-action warmup (batch 32 keeps those updates cheap).

    DDPG runs ONE env: pendulum episodes are a fixed 200 steps, so at
    n_envs=4 a 256-step budget is 64 steps per env and every episode is
    truncated (the episodes_completed=0 cell this gate now rejects); one
    env completes a full episode inside the budget."""
    from repro.rl.ddpg import DDPGConfig
    from repro.rl.ppo import PPOConfig
    from repro.rl.sac import SACConfig
    return {"ppo": PPOConfig(n_envs=4, n_steps=32, n_epochs=2,
                             n_minibatches=4),
            "sac": SACConfig(n_envs=4, learning_starts=192, batch_size=32),
            "ddpg": DDPGConfig(n_envs=1, learning_starts=192,
                               batch_size=32)}


def run(*, total_steps: int = 512, tasks=TASKS, encoders=ENCODERS,
        seed: int = 0, verbose: bool = False, cfgs=None):
    rows = []
    for task in tasks:
        for enc in encoders:
            cfg = (cfgs or {}).get(TASK_ALGO[task])
            res = train(task, enc, total_steps=total_steps, seed=seed,
                        verbose=verbose, cfg=cfg)
            rows.append(res)
            s = res.summary()
            steady = s["steady_steps_per_sec"]
            print(f"  {task:<10} {res.algo:<5} {enc:<11} "
                  f"best={res.best:8.1f} final={res.final:8.1f} "
                  f"mean={res.mean:8.1f} episodes={s['episodes']} "
                  f"({s['episodes_truncated']} truncated) "
                  f"steps/s={res.steps_per_sec:7.1f} "
                  f"compile_s={res.compile_s:6.1f} "
                  f"steady/s={steady if steady is None else round(steady, 1)}")
    return rows


# ---------------------------------------------------------------------------
# Throughput: compiled engine (steady state) vs the legacy per-step loop
# ---------------------------------------------------------------------------

def measure_engine_throughput(task: str, encoder_name: str, *,
                              total_steps: int, seed: int = 0,
                              n_envs=None) -> float:
    """Steady-state env-steps/sec of the compiled engine.

    Runs the training plan once to compile every chunk shape, then
    re-initialises and times a second, cache-warm pass — the number a
    long run converges to (compile cost amortises away at paper scale).
    """
    algo = TASK_ALGO[task]
    from repro.envs import make_pixel_env
    env = make_pixel_env(task, train=True)
    encoder = _pipeline_encoder(encoder_name, env.obs_shape[-1])
    agent = make_agent(algo, encoder, env.action_dim, n_envs=n_envs)
    engine = make_engine(env, agent, total_steps)
    phases = engine.plan()

    def one_pass(key):
        # init (params, env resets, ring allocation) happens OUTSIDE the
        # timed window — the legacy baseline's timer also starts after
        # its setup, so the two sides measure the same thing: the loop
        carry = engine.init(key)
        jax.block_until_ready(carry.obs)
        t0 = time.perf_counter()
        steps = 0
        for phase in phases:
            key, sub = jax.random.split(key)
            carry, rewards, dones, _ = engine.run(carry, sub, phase)
            steps += int(np.asarray(rewards).size)
        jax.block_until_ready(dones)
        return steps / (time.perf_counter() - t0)

    one_pass(jax.random.PRNGKey(seed))              # compile pass
    return one_pass(jax.random.PRNGKey(seed + 1))   # timed, cache-warm


def measure_legacy_throughput(task: str, encoder_name: str, *,
                              total_steps: int, seed: int = 0) -> float:
    """env-steps/sec of the PRE-REFACTOR off-policy loop (the baseline).

    Faithful to the seed trainer: ONE env, one jitted env-step and one
    jitted act call per step, host-side numpy replay buffer, a fresh
    ``np.random.default_rng(seed + t)`` per warmup step, and one gradient
    update per step once past ``learning_starts``.  Compile time is
    excluded (every jitted piece is warmed before the timed loop) so the
    comparison against the engine is steady-state vs steady-state.
    """
    algo = TASK_ALGO[task]
    if algo == "ppo":
        raise ValueError("legacy baseline is the OFF-policy per-step loop")
    from repro.envs import make_pixel_env
    env = make_pixel_env(task, train=True)
    encoder = _pipeline_encoder(encoder_name, env.obs_shape[-1])
    agent = make_agent(algo, encoder, env.action_dim)
    cfg = agent.cfg

    state = agent.init(jax.random.PRNGKey(seed))
    buf = ReplayBuffer(cfg.buffer_size, env.obs_shape, env.action_dim, seed)
    reset_jit = jax.jit(env.reset)
    step_jit = jax.jit(env.step)
    act_jit = jax.jit(agent.act)

    def update_step(state, batch, key):
        state, m = agent.update(state, batch, key)
        return agent.target_update(state), m
    update_jit = jax.jit(update_step)

    key = jax.random.PRNGKey(seed + 1)
    env_state, obs = reset_jit(jax.random.PRNGKey(seed + 2))

    # warm every jitted piece so the timed loop is steady-state
    a, _ = act_jit(state.params, obs[None], key)
    s2 = step_jit(env_state, a[0])
    buf.add_batch(np.asarray(obs)[None], np.asarray(a), np.zeros(1, np.float32),
                  np.asarray(obs)[None], np.zeros(1, bool))
    if total_steps > cfg.learning_starts:
        batch = jax.tree.map(jnp.asarray, buf.sample(cfg.batch_size))
        jax.block_until_ready(update_jit(state, batch, key)[0])
    jax.block_until_ready(s2)
    buf = ReplayBuffer(cfg.buffer_size, env.obs_shape, env.action_dim, seed)

    t0 = time.perf_counter()
    for t in range(total_steps):
        key, sub = jax.random.split(key)
        if t < cfg.learning_starts:
            action = jnp.asarray(np.random.default_rng(seed + t).uniform(
                -1, 1, env.action_dim).astype(np.float32))
        else:
            action, _ = act_jit(state.params, obs[None], sub)
            action = action[0]
        env_state, next_obs, reward, done = step_jit(env_state, action)
        buf.add_batch(np.asarray(obs)[None], np.asarray(action)[None],
                      np.asarray(reward)[None], np.asarray(next_obs)[None],
                      np.asarray(done)[None])
        obs = next_obs
        if t >= cfg.learning_starts and len(buf) >= cfg.batch_size:
            key, ku = jax.random.split(key)
            batch = jax.tree.map(jnp.asarray, buf.sample(cfg.batch_size))
            state, _ = update_jit(state, batch, ku)
    jax.block_until_ready(obs)
    return total_steps / (time.perf_counter() - t0)


def compare_offpolicy(task: str = "pendulum", encoder: str = "miniconv4", *,
                      total_steps: int = 256, seed: int = 0,
                      n_envs: int = 8, reps: int = 3) -> dict:
    """Engine (vectorised, compiled) vs the legacy loop (single env — it
    HAS no n_envs; that asymmetry is the point of the refactor).

    Measured in the COLLECTION regime (total_steps below learning_starts,
    so neither side runs gradient updates): the update math is identical
    on both sides, so collection isolates exactly what the refactor
    changed — per-step host dispatch, host RNG construction, numpy replay
    traffic — from compute the two loops share.  The JSON row carries
    ``regime: "collection"`` to keep the number honest.

    The two measurements interleave ``reps`` times and the BEST of each
    side is compared (timeit-style: min time == max sustained throughput),
    so throttling windows on a shared host bias neither side.
    """
    engine, legacy = [], []
    for _ in range(reps):
        engine.append(measure_engine_throughput(
            task, encoder, total_steps=total_steps, seed=seed,
            n_envs=n_envs))
        legacy.append(measure_legacy_throughput(
            task, encoder, total_steps=total_steps, seed=seed))
    engine_sps = float(np.max(engine))
    legacy_sps = float(np.max(legacy))
    row = {"task": task, "algo": TASK_ALGO[task], "encoder": encoder,
           "total_steps": total_steps, "n_envs": n_envs,
           "regime": "collection",
           "engine_steps_per_sec": engine_sps,
           "legacy_steps_per_sec": legacy_sps,
           "engine_reps": engine, "legacy_reps": legacy,
           "speedup": engine_sps / legacy_sps}
    print(f"  off-policy COLLECTION throughput [{task}/{encoder}]: "
          f"engine {engine_sps:.1f} (n_envs={n_envs}) vs legacy per-step "
          f"loop {legacy_sps:.1f} env-steps/s -> {row['speedup']:.1f}x")
    return row


def write_bench(rows, *, total_steps: int, compare_row=None,
                path: str = BENCH_PATH) -> dict:
    doc = perfstamp.stamp({
        "benchmark": "learning",
        "host_detail": {"platform": platform.platform(),
                        "backend": jax.default_backend()},
        "total_steps": total_steps,
        "conditions": [r.summary() | {"wall_time_s": r.wall_time_s}
                       for r in rows],
    }, backend=jax.default_backend())
    if compare_row is not None:
        doc["offpolicy_throughput"] = compare_row
    Path(path).write_text(json.dumps(doc, indent=2))
    print(f"  wrote {path}")
    return doc


def check_smoke(doc: dict) -> None:
    """CI gate: every condition finite with nonzero throughput, and at
    least one COMPLETED episode per condition — Best/Mean/Final must be
    real episodic statistics, not truncated-partial fallbacks."""
    for c in doc["conditions"]:
        name = f"{c['task']}/{c['encoder']}"
        for k in ("best", "final", "mean"):
            assert np.isfinite(c[k]), f"{name}: non-finite {k}={c[k]}"
        assert c["episodes"] >= 1, f"{name}: no episodes recorded"
        assert c["episodes_completed"] >= 1, \
            f"{name}: 0 completed episodes — stats fall back to " \
            "truncated partials (bound episode length or raise the budget)"
        assert c["steps_per_sec"] > 0, f"{name}: zero throughput"
        assert np.isfinite(c["compile_s"]) and c["compile_s"] >= 0, \
            f"{name}: bad compile_s={c['compile_s']}"
        steady = c["steady_steps_per_sec"]
        assert steady is None or steady > 0, \
            f"{name}: bad steady_steps_per_sec={steady}"
    thr = doc.get("offpolicy_throughput")
    if thr is not None:
        assert thr["engine_steps_per_sec"] > 0 \
            and thr["legacy_steps_per_sec"] > 0, "zero throughput measured"
    print(f"  smoke gate OK: {len(doc['conditions'])} conditions finite, "
          f"steps/sec > 0")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=512)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale (hours on CPU)")
    ap.add_argument("--tasks", default=",".join(TASKS))
    ap.add_argument("--encoders", default=",".join(ENCODERS))
    ap.add_argument("--smoke", action="store_true",
                    help="one encoder per task (all three algorithms) and "
                         "gate on finite returns + nonzero steps/sec")
    ap.add_argument("--compare", action="store_true",
                    help="also measure off-policy engine vs the legacy "
                         "per-step loop (steady-state env-steps/sec)")
    ap.add_argument("--json", default=BENCH_PATH)
    args = ap.parse_args(argv)
    steps = 200_000 if args.full else args.steps
    encoders = ("miniconv4",) if args.smoke else \
        tuple(args.encoders.split(","))
    rows = run(total_steps=steps, tasks=args.tasks.split(","),
               encoders=encoders, cfgs=_smoke_cfgs() if args.smoke else None)
    compare_row = None
    if args.compare:
        compare_row = compare_offpolicy(total_steps=min(steps, 256))
    doc = write_bench(rows, total_steps=steps, compare_row=compare_row,
                      path=args.json)
    if args.smoke:
        check_smoke(doc)
    print("task,algo,encoder,best,final,mean,episodes,steps_per_sec")
    for r in rows:
        s = r.summary()
        print(f"{r.task},{r.algo},{r.encoder},{r.best:.1f},{r.final:.1f},"
              f"{r.mean:.1f},{s['episodes']},{r.steps_per_sec:.1f}")


if __name__ == "__main__":
    main()
