"""§Roofline table: renders the dry-run sweep results (JSONL emitted by
repro.launch.dryrun) as the per-(arch x shape x mesh) roofline table used
in EXPERIMENTS.md, with the dominant-term classification and the
MODEL_FLOPS utilisation ratio."""
from __future__ import annotations

import argparse
import glob
import json

HEADER = (f"{'arch':<24} {'shape':<12} {'mesh':<7} {'compute_s':>10} "
          f"{'memory_s':>10} {'coll_s':>9} {'bottleneck':<11} "
          f"{'useful':>7} {'peak/dev':>9}")


def load(paths):
    rows = []
    seen = {}
    for path in paths:
        for line in open(path):
            d = json.loads(line)
            if "error" in d:
                continue
            key = (d["arch"], d["shape"], d["mesh"],
                   json.dumps(d.get("overrides", {}), sort_keys=True))
            seen[key] = d           # later rows win (re-runs)
    rows = sorted(seen.values(),
                  key=lambda d: (d["arch"], d["shape"], d["mesh"]))
    return rows


def render(rows, *, only_baseline: bool = True):
    print(HEADER)
    for d in rows:
        if only_baseline and d.get("overrides"):
            continue
        peak = (d.get("peak_memory_bytes") or 0) / 2 ** 30
        print(f"{d['arch']:<24} {d['shape']:<12} {d['mesh']:<7} "
              f"{d['compute_s']:>10.4f} {d['memory_s']:>10.4f} "
              f"{d['collective_s']:>9.4f} {d['bottleneck']:<11} "
              f"{d['useful_flops_ratio']:>7.3f} {peak:>8.2f}G")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--glob", default="results/dryrun_*.jsonl")
    ap.add_argument("--all", action="store_true",
                    help="include override (perf-iteration) rows")
    args = ap.parse_args(argv)
    paths = sorted(glob.glob(args.glob))
    if not paths:
        print(f"no dry-run results match {args.glob}; run "
              f"python -m repro.launch.dryrun --all --mesh both --out ...")
        return
    render(load(paths), only_baseline=not args.all)


if __name__ == "__main__":
    main()
