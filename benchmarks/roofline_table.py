"""§Roofline table: renders the dry-run sweep results (JSONL emitted by
repro.launch.dryrun) as the per-(arch x shape x mesh) roofline table used
in EXPERIMENTS.md, with the dominant-term classification and the
MODEL_FLOPS utilisation ratio.

``--miniconv`` additionally renders the MiniConv encoder roofline derived
from the compiled :class:`~repro.core.passplan.PassPlan` — per-layer pass
count, samples/pixel vs the shader budget, FLOPs, and bytes moved — so the
table always agrees with what the kernels actually execute."""
from __future__ import annotations

import argparse
import glob
import json

HEADER = (f"{'arch':<24} {'shape':<12} {'mesh':<7} {'compute_s':>10} "
          f"{'memory_s':>10} {'coll_s':>9} {'bottleneck':<11} "
          f"{'useful':>7} {'peak/dev':>9}")


def load(paths):
    rows = []
    seen = {}
    for path in paths:
        for line in open(path):
            d = json.loads(line)
            if "error" in d:
                continue
            key = (d["arch"], d["shape"], d["mesh"],
                   json.dumps(d.get("overrides", {}), sort_keys=True))
            seen[key] = d           # later rows win (re-runs)
    rows = sorted(seen.values(),
                  key=lambda d: (d["arch"], d["shape"], d["mesh"]))
    return rows


def render(rows, *, only_baseline: bool = True):
    print(HEADER)
    for d in rows:
        if only_baseline and d.get("overrides"):
            continue
        peak = (d.get("peak_memory_bytes") or 0) / 2 ** 30
        print(f"{d['arch']:<24} {d['shape']:<12} {d['mesh']:<7} "
              f"{d['compute_s']:>10.4f} {d['memory_s']:>10.4f} "
              f"{d['collective_s']:>9.4f} {d['bottleneck']:<11} "
              f"{d['useful_flops_ratio']:>7.3f} {peak:>8.2f}G")


def miniconv_table(x_sizes=(84, 400), ks=(4, 16), c_in: int = 12):
    """Per-layer MiniConv roofline, derived entirely from the PassPlan."""
    from repro.core.miniconv import standard_spec

    hdr = (f"{'spec':<14} {'x':>4} {'layer':>5} {'passes':>6} "
           f"{'samples':>8} {'budget%':>8} {'mflops':>8} {'kB_in':>7} "
           f"{'kB_out':>7} {'flops/B':>8}")
    print(hdr)
    for k in ks:
        spec = standard_spec(c_in=c_in, k=k)
        for x in x_sizes:
            plan = spec.plan(x)
            for lp in plan.layers:
                passes = [p for p in plan.passes if p.layer == lp.index]
                samples = max(p.samples for p in passes)
                in_b = lp.in_h * lp.in_w * lp.c_in * 4
                out_b = lp.out_h * lp.out_w * lp.c_out * 4
                w_b = lp.kernel ** 2 * lp.c_in * lp.c_out * 4
                flops = sum(p.flops for p in passes)
                # per-pass execution re-reads the input once per pass
                bytes_moved = in_b * len(passes) + out_b + w_b
                print(f"miniconv{k:<6} {x:>4} {lp.index:>5} "
                      f"{len(passes):>6} {samples:>8} "
                      f"{100 * samples / plan.budget.max_samples:>7.0f}% "
                      f"{flops / 1e6:>8.2f} {in_b / 1e3:>7.1f} "
                      f"{out_b / 1e3:>7.1f} {flops / bytes_moved:>8.1f}")
            print(f"miniconv{k:<6} {x:>4} total {plan.total_passes:>6} "
                  f"{plan.max_pass_samples:>8} "
                  f"{'':>8} {plan.flops_per_frame / 1e6:>8.2f} "
                  f"feature_bytes={plan.feature_bytes}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--glob", default="results/dryrun_*.jsonl")
    ap.add_argument("--all", action="store_true",
                    help="include override (perf-iteration) rows")
    ap.add_argument("--miniconv", action="store_true",
                    help="render the PassPlan-derived MiniConv roofline")
    args = ap.parse_args(argv)
    if args.miniconv:
        miniconv_table()
        return
    paths = sorted(glob.glob(args.glob))
    if not paths:
        print(f"no dry-run results match {args.glob}; run "
              f"python -m repro.launch.dryrun --all --mesh both --out ...")
        return
    render(load(paths), only_baseline=not args.all)


if __name__ == "__main__":
    main()
