"""Paper §4.2: the break-even bandwidth equation, validated against the
simulated pipeline (the "modeling twist").

  B* = 32 X^2 (1 - K/(4*2^(2n))) / j

Checks (a) the paper's Pi-Zero number (~50.4 Mb/s), (b) that the netsim
crossover lands at the predicted B* for a sweep of configurations, and
(c) the pod-boundary generalisation for the assigned LLMs.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import ARCHS
from repro.core.latency import (PodSplitConfig, SplitConfig,
                                break_even_bandwidth,
                                pod_break_even_bandwidth,
                                paper_pi_zero_config)
from repro.serving.client import DecisionLoop
from repro.serving.netsim import ShapedLink


def crossover_mbps(cfg: SplitConfig, *, lo=1e5, hi=1e10) -> float:
    """Bisection on the simulated pipelines for the latency crossover."""
    def diff(bps):
        link = lambda: ShapedLink(bandwidth_bps=bps, propagation_s=0.0)
        so = DecisionLoop(link=link(), server_time_s=0.0, split=False,
                          payload_bytes=cfg.frame_bytes, action_bytes=0)
        sp = DecisionLoop(link=link(), server_time_s=0.0, split=True,
                          edge_time_s=cfg.encode_time_s,
                          payload_bytes=cfg.feature_bytes, action_bytes=0)
        return sp.decision_latency() - so.decision_latency()
    for _ in range(80):
        mid = (lo + hi) / 2
        if diff(mid) < 0:
            lo = mid
        else:
            hi = mid
    return mid / 1e6


def run():
    paper = paper_pi_zero_config()
    b_star = break_even_bandwidth(paper) / 1e6
    sim = crossover_mbps(paper)
    print(f"  paper config: predicted B*={b_star:.1f} Mb/s "
          f"(paper: 50.4), simulated crossover={sim:.1f} Mb/s")
    rows = [{"config": "paper", "pred": b_star, "sim": sim}]
    for x, n, k, j in ((256, 2, 4, 0.05), (512, 3, 16, 0.2),
                       (84, 3, 4, 0.01)):
        cfg = SplitConfig(x, n, k, j)
        p = break_even_bandwidth(cfg) / 1e6
        s = crossover_mbps(cfg)
        rows.append({"config": f"X{x}n{n}K{k}", "pred": p, "sim": s})
        print(f"  X={x} n={n} K={k} j={j}: predicted {p:.1f} "
              f"simulated {s:.1f} Mb/s")
        assert abs(p - s) / p < 0.02, "equation disagrees with simulation"

    # pod-boundary generalisation: int8 wire on the hidden state vs bf16
    print("  pod-boundary break-even (edge stage = 1/4 of layers, "
          "int8 wire vs bf16 baseline):")
    for arch_id in ("llama3-8b", "qwen3-0.6b"):
        cfg = ARCHS[arch_id]
        hidden = 32 * 1024 * cfg.d_model * 4        # (B=32, S=1k) fp32
        pod = PodSplitConfig(hidden_bytes_full=hidden, wire_itemsize=1.0,
                             edge_time_s=0.004,
                             raw_bytes=hidden // 2)  # bf16 baseline
        print(f"    {arch_id:<12} B*={pod_break_even_bandwidth(pod)/1e9:.1f}"
              f" Gb/s (DCN-relevant)")
    return rows


def main(argv=None):
    argparse.ArgumentParser(description=__doc__).parse_args(argv)
    run()


if __name__ == "__main__":
    main()
