"""Paper §4.2: the break-even bandwidth equation, validated against the
simulated pipeline (the "modeling twist").

  B* = 32 X^2 (1 - K/(4*2^(2n))) / j

Checks (a) the paper's Pi-Zero number (~50.4 Mb/s), (b) that the netsim
crossover lands at the predicted B* for a sweep of configurations, and
(c) the pod-boundary generalisation for the assigned LLMs.

``--manifest DEPLOY.json`` derives the :class:`SplitConfig` from a real
deployment manifest instead of hand-picked constants — X and the
stride-2 count come from the manifest's spec/plan, and the encode time
``j`` is *measured* on this host from the built deployment's edge path
(tuning block honoured), so the break-even number answers "at what
bandwidth does THIS deployment stop paying for itself".
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.core.latency import (PodSplitConfig, SplitConfig,
                                break_even_bandwidth,
                                pod_break_even_bandwidth,
                                paper_pi_zero_config)
from repro.serving.client import DecisionLoop
from repro.serving.netsim import ShapedLink


def crossover_mbps(cfg: SplitConfig, *, lo=1e5, hi=1e10) -> float:
    """Bisection on the simulated pipelines for the latency crossover."""
    def diff(bps):
        link = lambda: ShapedLink(bandwidth_bps=bps, propagation_s=0.0)
        so = DecisionLoop(link=link(), server_time_s=0.0, split=False,
                          payload_bytes=cfg.frame_bytes, action_bytes=0)
        sp = DecisionLoop(link=link(), server_time_s=0.0, split=True,
                          edge_time_s=cfg.encode_time_s,
                          payload_bytes=cfg.feature_bytes, action_bytes=0)
        return sp.decision_latency() - so.decision_latency()
    for _ in range(80):
        mid = (lo + hi) / 2
        if diff(mid) < 0:
            lo = mid
        else:
            hi = mid
    return mid / 1e6


def split_config_from_manifest(path: str, *, encode_time_s=None,
                               n_time: int = 16):
    """SplitConfig for a deployment manifest: geometry from the spec,
    encode time measured on the built deployment's edge path."""
    from repro.deploy import Deployment, DeploymentConfig

    with open(path) as f:
        cfg = DeploymentConfig.from_dict(json.load(f))
    dep = Deployment.build(cfg)
    if encode_time_s is None:
        edge_params = dep.init(jax.random.PRNGKey(0))["edge"]
        c_in = cfg.spec.layers[0].c_in
        x = jax.random.uniform(jax.random.PRNGKey(1),
                               (1, cfg.in_h, cfg.in_w, c_in))
        fn = lambda xx: dep.split.edge_apply(edge_params, xx)
        for _ in range(3):
            jax.block_until_ready(fn(x))
        ts = []
        for _ in range(n_time):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            ts.append(time.perf_counter() - t0)
        encode_time_s = float(np.median(ts))
    n_stride2 = sum(1 for layer in cfg.spec.layers if layer.stride == 2)
    return SplitConfig(x_size=cfg.in_h, n_stride2=n_stride2,
                       k_channels=cfg.spec.layers[-1].c_out,
                       encode_time_s=encode_time_s), dep


def run_manifest(path: str):
    cfg, dep = split_config_from_manifest(path)
    pred = break_even_bandwidth(cfg) / 1e6
    sim = crossover_mbps(cfg)
    print(f"  manifest {path} [{dep.backend.name}]: X={cfg.x_size} "
          f"n={cfg.n_stride2} K={cfg.k_channels} "
          f"j={cfg.encode_time_s * 1e3:.3f}ms (measured)")
    print(f"  predicted B*={pred:.2f} Mb/s, simulated crossover="
          f"{sim:.2f} Mb/s")
    assert abs(pred - sim) / pred < 0.02, \
        "equation disagrees with simulation"
    return {"config": path, "pred": pred, "sim": sim}


def run():
    paper = paper_pi_zero_config()
    b_star = break_even_bandwidth(paper) / 1e6
    sim = crossover_mbps(paper)
    print(f"  paper config: predicted B*={b_star:.1f} Mb/s "
          f"(paper: 50.4), simulated crossover={sim:.1f} Mb/s")
    rows = [{"config": "paper", "pred": b_star, "sim": sim}]
    for x, n, k, j in ((256, 2, 4, 0.05), (512, 3, 16, 0.2),
                       (84, 3, 4, 0.01)):
        cfg = SplitConfig(x, n, k, j)
        p = break_even_bandwidth(cfg) / 1e6
        s = crossover_mbps(cfg)
        rows.append({"config": f"X{x}n{n}K{k}", "pred": p, "sim": s})
        print(f"  X={x} n={n} K={k} j={j}: predicted {p:.1f} "
              f"simulated {s:.1f} Mb/s")
        assert abs(p - s) / p < 0.02, "equation disagrees with simulation"

    # pod-boundary generalisation: int8 wire on the hidden state vs bf16
    print("  pod-boundary break-even (edge stage = 1/4 of layers, "
          "int8 wire vs bf16 baseline):")
    for arch_id in ("llama3-8b", "qwen3-0.6b"):
        cfg = ARCHS[arch_id]
        hidden = 32 * 1024 * cfg.d_model * 4        # (B=32, S=1k) fp32
        pod = PodSplitConfig(hidden_bytes_full=hidden, wire_itemsize=1.0,
                             edge_time_s=0.004,
                             raw_bytes=hidden // 2)  # bf16 baseline
        print(f"    {arch_id:<12} B*={pod_break_even_bandwidth(pod)/1e9:.1f}"
              f" Gb/s (DCN-relevant)")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--manifest", metavar="DEPLOY.json",
                    help="derive the split config (and measure j) from "
                         "this deployment manifest instead of the paper "
                         "constants sweep")
    args = ap.parse_args(argv)
    if args.manifest:
        run_manifest(args.manifest)
    else:
        run()


if __name__ == "__main__":
    main()
