"""Paper Table 5: end-to-end decision latency under bandwidth shaping.

Median over N decisions of (observation available -> action received),
server-only (full RGBA frame transmitted, Full-CNN + head on the server)
vs split-policy (MiniConv on-device, K=4 uint8 features transmitted).
Compute-stage times are measured on this host with the real jitted
networks; the link is the deterministic token-bucket shaper.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core.miniconv import (miniconv_feature_shape, standard_spec)
from repro.core.wire import frame_bytes_rgba, get_codec
from repro.rl.networks import (full_cnn_apply, full_cnn_init,
                               miniconv_edge_apply, miniconv_encoder_init,
                               miniconv_server_apply, mlp_apply, mlp_init)
from repro.serving.client import DecisionLoop, EdgeClient
from repro.serving.netsim import shaped
from repro.serving.server import PolicyServer

X_SIZE = 84           # paper's task-scale observation (84x84, 3 frames)
C_IN = 12             # RGBA x 3 stacked frames at the upload boundary


def build(*, k: int = 4, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    spec = standard_spec(c_in=C_IN, k=k)
    enc = miniconv_encoder_init(key, spec, h=X_SIZE, w=X_SIZE)
    cnn = full_cnn_init(key, C_IN, h=X_SIZE, w=X_SIZE)
    head = mlp_init(key, [512, 256, 3])
    codec = get_codec("uint8")
    fh, fw, fc = miniconv_feature_shape(spec, X_SIZE, X_SIZE)

    @jax.jit
    def edge_fn(obs):
        return codec.encode(miniconv_edge_apply(enc["edge"], spec, obs))

    @jax.jit
    def split_server_fn(payload):
        feats = codec.decode(payload)
        z = miniconv_server_apply(enc["server"], feats)
        return mlp_apply(head, z)

    @jax.jit
    def mono_server_fn(obs):
        return mlp_apply(head, full_cnn_apply(cnn, obs))

    obs = jax.random.uniform(key, (1, X_SIZE, X_SIZE, C_IN))
    wire_bytes = codec.wire_bytes((1, fh, fw, fc))
    frame_bytes = frame_bytes_rgba(X_SIZE) * 3      # 3 stacked RGBA frames
    return edge_fn, split_server_fn, mono_server_fn, obs, wire_bytes, \
        frame_bytes


def run(bandwidths=(10, 25, 50, 100), *, n_decisions: int = 1000,
        k: int = 4):
    (edge_fn, split_srv, mono_srv, obs, wire_bytes,
     frame_bytes) = build(k=k)
    client = EdgeClient(encode_fn=edge_fn, wire_bytes=wire_bytes)
    j = client.measure(obs)
    payload = edge_fn(obs)
    s_split = PolicyServer(serve_fn=split_srv).measure(payload)
    s_mono = PolicyServer(serve_fn=mono_srv).measure(obs)
    print(f"  stages: edge={j*1e3:.2f}ms split_srv={s_split*1e3:.2f}ms "
          f"mono_srv={s_mono*1e3:.2f}ms wire={wire_bytes}B "
          f"frame={frame_bytes}B")

    rows = []
    for mbps in bandwidths:
        so = DecisionLoop(link=shaped(mbps), server_time_s=s_mono,
                          split=False, payload_bytes=frame_bytes)
        sp = DecisionLoop(link=shaped(mbps), server_time_s=s_split,
                          split=True, edge_time_s=j,
                          payload_bytes=wire_bytes)
        row = {"mbps": mbps,
               "server_only_ms": so.median_latency(n_decisions) * 1e3,
               "split_ms": sp.median_latency(n_decisions) * 1e3}
        rows.append(row)
        print(f"  {mbps:>5} Mb/s  server-only {row['server_only_ms']:7.1f} "
              f"ms   split {row['split_ms']:7.1f} ms")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bandwidths", default="10,25,50,100")
    ap.add_argument("--decisions", type=int, default=1000)
    ap.add_argument("--k", type=int, default=4)
    args = ap.parse_args(argv)
    run(tuple(float(b) for b in args.bandwidths.split(",")),
        n_decisions=args.decisions, k=args.k)


if __name__ == "__main__":
    main()
