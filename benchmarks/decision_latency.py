"""Paper Table 5: end-to-end decision latency under bandwidth shaping.

Median over N decisions of (observation available -> action received),
server-only (full RGBA frame transmitted, Full-CNN + head on the server)
vs split-policy (MiniConv on-device, K=4 uint8 features transmitted).
Compute-stage times are measured on this host with the real jitted
networks; the link is the deterministic token-bucket shaper.

The whole split pipeline — encoder, plan, codec, serving halves, payload
accounting — is constructed from ONE declarative
:class:`repro.deploy.DeploymentConfig` via ``Deployment.build``
(``--manifest`` loads that config from a serialised JSON manifest
instead, the same file ``python -m repro.deploy`` writes).

``--clients N`` additionally reports p95 decision latency for N clients
sharing one split-policy server, FIFO vs micro-batching (the batch-aware
queue simulation fed by the measured batched service-time curve).
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax

from repro.deploy import Deployment, DeploymentConfig
from repro.rl.networks import full_cnn_apply, full_cnn_init, mlp_apply, mlp_init
from repro.serving.client import DecisionLoop, EdgeClient
from repro.serving.netsim import shaped
from repro.serving.server import (BatchingPolicyServer, BatchQueueSim,
                                  PolicyServer, QueueSim)

X_SIZE = 84           # paper's task-scale observation (84x84, 3 frames)
C_IN = 12             # RGBA x 3 stacked frames at the upload boundary


@dataclasses.dataclass(frozen=True)
class ServingSetup:
    """Jitted halves + payload accounting shared by the serving benchmarks.

    Everything here is RESOLVED from ``deployment`` (one
    ``Deployment.build``); the fields are kept flat because the latency
    and scalability loops consume them directly.
    """

    deployment: Deployment
    edge_fn: object               # obs -> single-request payload
    split_server_fn: object       # payload -> action
    split_server_batch_fn: object  # stacked micro-batch payload -> actions
    mono_server_fn: object        # obs -> action
    obs: object
    wire_bytes: int
    frame_bytes: int
    params: object = None         # deployment params (real-fleet workers
    #                               rebuild their jitted halves from these)


def standard_config(*, k: int = 4, backend: str = "xla",
                    max_batch: int = 8) -> DeploymentConfig:
    """The benchmark's canonical deployment: the paper's K-channel encoder
    at task scale.  ``xla`` is the timing-portable default on this host;
    pass ``backend="fused"`` (or a manifest) for the kernel path."""
    return DeploymentConfig.standard(k=k, c_in=C_IN, h=X_SIZE,
                                     backend=backend, max_batch=max_batch)


def build(*, k: int = 4, seed: int = 0,
          config: DeploymentConfig | None = None) -> ServingSetup:
    cfg = config or standard_config(k=k)
    dep = Deployment.build(cfg)
    c_in = cfg.spec.layers[0].c_in      # manifests may deviate from C_IN
    key = jax.random.PRNGKey(seed)
    params = dep.init(key)
    cnn = full_cnn_init(key, c_in, h=cfg.in_h, w=cfg.in_w)
    head = mlp_init(key, [cfg.head_dim, 256, 3])

    def head_fn(z):
        return mlp_apply(head, z)

    edge_fn = dep.edge_fn(params)
    split_server_fn = dep.server_fn(params, head=head_fn)
    split_server_batch_fn = dep.server_batch_fn(params, head=head_fn)

    @jax.jit
    def mono_server_fn(obs):
        return mlp_apply(head, full_cnn_apply(cnn, obs))

    obs = jax.random.uniform(key, (1, cfg.in_h, cfg.in_w, c_in))
    return ServingSetup(dep, edge_fn, split_server_fn, split_server_batch_fn,
                        mono_server_fn, obs, dep.wire_bytes, dep.frame_bytes,
                        params)


def run(bandwidths=(10, 25, 50, 100), *, n_decisions: int = 1000,
        k: int = 4, config: DeploymentConfig | None = None):
    setup = build(k=k, config=config)
    wire_bytes, frame_bytes = setup.wire_bytes, setup.frame_bytes
    client = EdgeClient(encode_fn=setup.edge_fn, wire_bytes=wire_bytes)
    j = client.measure(setup.obs)
    payload = setup.edge_fn(setup.obs)
    s_split = PolicyServer(serve_fn=setup.split_server_fn).measure(payload)
    s_mono = PolicyServer(serve_fn=setup.mono_server_fn).measure(setup.obs)
    print(f"  stages: edge={j*1e3:.2f}ms split_srv={s_split*1e3:.2f}ms "
          f"mono_srv={s_mono*1e3:.2f}ms wire={wire_bytes}B "
          f"frame={frame_bytes}B")

    rows = []
    for mbps in bandwidths:
        so = DecisionLoop(link=shaped(mbps), server_time_s=s_mono,
                          split=False, payload_bytes=frame_bytes)
        sp = DecisionLoop(link=shaped(mbps), server_time_s=s_split,
                          split=True, edge_time_s=j,
                          payload_bytes=wire_bytes)
        row = {"mbps": mbps,
               "server_only_ms": so.median_latency(n_decisions) * 1e3,
               "split_ms": sp.median_latency(n_decisions) * 1e3}
        rows.append(row)
        print(f"  {mbps:>5} Mb/s  server-only {row['server_only_ms']:7.1f} "
              f"ms   split {row['split_ms']:7.1f} ms")
    return rows


def measure_service_curve(setup: ServingSetup, *, max_batch: int = 8,
                          max_wait_s: float = 0.0, iters: int = 10):
    """Measure the batched split server's t(B) curve on this host.

    Shared by this benchmark and ``benchmarks.scalability`` so the two
    FIFO-vs-batched reports can never drift apart in how they sample the
    curve.  The server comes from the deployment's own batching policy
    (``Deployment.server``), overridden by the sweep arguments.
    Returns ({batch: seconds}, BatchServiceModel).
    """
    payload = setup.edge_fn(setup.obs)
    bsrv = BatchingPolicyServer(serve_batch_fn=setup.split_server_batch_fn,
                                max_batch=max_batch, max_wait_s=max_wait_s)
    times = bsrv.measure(payload, batch_sizes=tuple(
        b for b in (1, 2, 4, 8, 16) if b <= max_batch), iters=iters)
    model = bsrv.service_model()
    curve = " ".join(f"t({b})={t*1e3:.2f}ms" for b, t in sorted(times.items()))
    print(f"  batched service curve: {curve}")
    return times, model


def run_queue(*, n_clients: int = 8, mbps: float = 100.0, k: int = 4,
              max_batch: int = 8, max_wait_ms: float = 0.0,
              rate_hz: float = 10.0, setup: ServingSetup = None,
              real_fleet: bool = False):
    """p95 decision latency at N clients: FIFO server vs micro-batching.

    The batched p95 uses the MEASURED service-time curve t(B) of the
    batched split server, so the comparison reflects real amortisation on
    this host, not an assumed speedup.  When the deployment manifest sets
    ``n_servers > 1`` the sharded fleet p95 is reported too — same
    measured curve on every server, routed by the configured policy.

    ``real_fleet=True`` additionally SPAWNS the manifest's fleet on
    localhost (``repro.serving.realfleet``) and prints measured wall-clock
    p95 under the same open-loop load next to the loopback-link sim
    prediction — the per-run sim-to-real calibration
    (``benchmarks/realfleet.py`` is the full sweep).
    """
    setup = setup or build(k=k)
    times, model = measure_service_curve(setup, max_batch=max_batch,
                                         max_wait_s=max_wait_ms / 1e3)
    common = dict(service_time_s=model(1), uplink=shaped(mbps),
                  payload_bytes=setup.wire_bytes, rate_hz=rate_hz,
                  horizon_s=5.0)
    fifo = QueueSim(**common)
    bat = BatchQueueSim(**common, max_batch=max_batch,
                        max_wait_s=max_wait_ms / 1e3, service_model=model)
    row = {"n_clients": n_clients,
           "service_ms": {b: t * 1e3 for b, t in times.items()},
           "fifo_p95_ms": fifo.p95(n_clients) * 1e3,
           "batched_p95_ms": bat.p95(n_clients) * 1e3}
    print(f"  N={n_clients} @ {rate_hz:.0f}Hz: p95 FIFO "
          f"{row['fifo_p95_ms']:.2f} ms vs micro-batched "
          f"{row['batched_p95_ms']:.2f} ms "
          f"(max_batch={max_batch}, max_wait={max_wait_ms:.0f}ms)")
    cfg = setup.deployment.config
    if cfg.n_servers > 1:
        # same batching policy as the FIFO/batched rows above (and as the
        # measured t(B) curve), not the manifest's — the three p95s must
        # be comparable
        fleet = setup.deployment.fleet_sim(model, uplink=shaped(mbps),
                                           rate_hz=rate_hz,
                                           max_batch=max_batch,
                                           max_wait_s=max_wait_ms / 1e3)
        row["fleet_p95_ms"] = fleet.p95(n_clients) * 1e3
        row["n_servers"] = cfg.n_servers
        row["router"] = cfg.router
        print(f"  N={n_clients} fleet ({cfg.n_servers} servers, "
              f"{cfg.router}): p95 {row['fleet_p95_ms']:.2f} ms")
    if real_fleet:
        row.update(run_real_fleet(setup, n_clients=n_clients,
                                  rate_hz=rate_hz))
    return row


def run_real_fleet(setup: ServingSetup, *, n_clients: int = 8,
                   rate_hz: float = 10.0, duration_s: float = 2.0,
                   timeout_s: float = 30.0) -> dict:
    """Measured p95 from the manifest's REAL fleet vs the loopback sim.

    The service curve is re-measured on ``Deployment.server_batch_fn``
    exactly as the workers serve it (no benchmark-local head), so the sim
    prediction and the spawned fleet charge the same t(B); the uplink is
    the localhost loopback, so both sides see negligible transfer time.
    """
    import numpy as np
    from repro.serving.realfleet import pack_payload, run_load

    dep = setup.deployment
    cfg = dep.config
    payload = setup.edge_fn(setup.obs)
    srv = dep.server(setup.params)
    srv.measure(payload, batch_sizes=tuple(
        b for b in (1, 2, 4, 8, 16) if b <= cfg.max_batch), iters=10)
    model = srv.service_model()
    fleet = dep.fleet(setup.params, service_model=model,
                      timeout_s=timeout_s)
    try:
        sim = dep.fleet_sim(model, uplink=shaped(10_000.0, rtt_ms=0.2),
                            rate_hz=rate_hz, horizon_s=duration_s,
                            max_batch=fleet.max_batch, max_wait_s=0.0)
        predicted = sim.p95(n_clients)
        body = pack_payload({k: np.asarray(v) for k, v in payload.items()})
        rep = run_load(fleet.client, body, n_clients=n_clients,
                       rate_hz=rate_hz, duration_s=duration_s)
    finally:
        leaked = fleet.close()
    out = {"real_predicted_p95_ms": predicted * 1e3,
           "real_measured_p95_ms": rep.p95() * 1e3,
           "real_n_failures": rep.n_failures,
           "real_leaked_workers": len(leaked)}
    print(f"  N={n_clients} REAL fleet ({cfg.n_servers} servers, "
          f"{cfg.router}, localhost): measured p95 "
          f"{out['real_measured_p95_ms']:.2f} ms vs loopback-sim "
          f"{out['real_predicted_p95_ms']:.2f} ms "
          f"({rep.n_requests} reqs, {rep.n_failures} failed, "
          f"{len(leaked)} leaked)")
    return out


def load_manifest(path: str) -> DeploymentConfig:
    """Load a serialised DeploymentConfig (``python -m repro.deploy``)."""
    with open(path) as f:
        return DeploymentConfig.from_dict(json.load(f))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bandwidths", default="10,25,50,100")
    ap.add_argument("--decisions", type=int, default=1000)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--manifest", default=None,
                    help="deployment manifest JSON to build the pipeline "
                         "from (overrides --k)")
    ap.add_argument("--clients", type=int, default=8,
                    help="N clients for the FIFO-vs-batched p95 report "
                         "(0 disables)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--real-fleet", action="store_true",
                    help="also spawn the manifest's real multi-process "
                         "fleet on localhost and report measured p95 "
                         "next to the loopback sim prediction")
    args = ap.parse_args(argv)
    config = load_manifest(args.manifest) if args.manifest else None
    run(tuple(float(b) for b in args.bandwidths.split(",")),
        n_decisions=args.decisions, k=args.k, config=config)
    if args.clients:
        run_queue(n_clients=args.clients, k=args.k,
                  max_batch=args.max_batch,
                  setup=build(k=args.k, config=config),
                  real_fleet=args.real_fleet)


if __name__ == "__main__":
    main()
