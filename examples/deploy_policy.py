"""The declarative deployment flow, end to end, in one page:

  manifest (DeploymentConfig)  ->  Deployment.build  ->  served policy

Builds the paper's standard split policy from ONE frozen config, ships
it through JSON (exactly what would travel to the device, like the
paper's compiled shader bundles), and drives the resolved pipeline:
edge encode -> wire payload -> micro-batched server -> actions.

  PYTHONPATH=src python examples/deploy_policy.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.deploy import Deployment, DeploymentConfig


def main():
    # ---- 1. declare the deployment ----------------------------------------
    cfg = DeploymentConfig.standard(
        k=4, c_in=12, h=84,          # the paper's K=4 encoder at task scale
        backend="fused",             # whole PassPlan as ONE Pallas kernel
        codec="uint8",               # the paper's wire format
        max_batch=8,                 # server micro-batching policy
    )
    print("manifest:")
    print(cfg.to_json(indent=2))

    # ---- 2. ship the manifest (JSON round-trip) ---------------------------
    shipped = DeploymentConfig.from_json(cfg.to_json())
    assert shipped == cfg

    # ---- 3. compile it ----------------------------------------------------
    dep = Deployment.build(shipped)
    print(f"\nbackend={dep.backend.name}: {dep.backend.description}")
    print(f"plan: {dep.plan.total_passes} shader passes -> "
          f"feature {dep.plan.feature_shape}, {dep.wire_bytes} B on the "
          f"wire (raw frame {dep.frame_bytes} B)")
    print(f"VMEM-safe micro-batch on TPU: B <= {dep.max_safe_batch} "
          f"(configured max_batch={dep.config.max_batch})")

    # ---- 4. serve it ------------------------------------------------------
    params = dep.init(jax.random.PRNGKey(0))
    client, server = dep.serving_pair(params)

    obs = jax.random.uniform(jax.random.PRNGKey(1), (3, 84, 84, 12))
    payloads = [client.encode_fn(obs[i:i + 1]) for i in range(3)]
    actions = server.serve(payloads)      # ONE batched launch for 3 clients
    print(f"\nserved {len(actions)} queued requests in one micro-batch; "
          f"each action/feature vector: {actions[0].shape}")

    # the served result equals the monolithic forward pass
    ref = dep.encoder.apply(params, obs)
    batched = jnp.stack(actions)
    err = float(jnp.max(jnp.abs(batched - ref)))
    print(f"max |served - monolithic| = {err:.2e} "
          f"(uint8 wire quantisation)")
    assert err < 0.05

    # ---- 5. size the fleet ------------------------------------------------
    # the same manifest drives capacity planning: n_servers sharded
    # micro-batching servers behind a routing policy, each charging the
    # measured t(B) curve of THIS host's server
    from repro.serving.netsim import shaped
    bsrv = dep.server(params)
    bsrv.measure(payloads[0], batch_sizes=(1, 2, 4, 8), iters=3)
    fleet = dep.fleet_sim(bsrv.service_model(), uplink=shaped(1000),
                          horizon_s=2.0)
    n_target = 500
    need = fleet.min_servers(n_target, p95_budget_s=0.1, n_servers_max=16)
    one = fleet.with_servers(1).max_clients(n_max=1024)
    if need:
        print(f"\nfleet sizing ({fleet.router}): {need} server(s) keep "
              f"{n_target} clients @ 10 Hz under p95 < 100 ms "
              f"(1 server supports {one})")
    else:            # min_servers returns 0 when no fleet size suffices
        print(f"\nfleet sizing ({fleet.router}): even 16 servers cannot "
              f"keep {n_target} clients under p95 < 100 ms on this host "
              f"(1 server supports {one})")

    # ---- 6. the same config drives training -------------------------------
    # repro.rl.train accepts deploy_config=..., so the trained encoder and
    # the served encoder can never disagree on spec/plan/head:
    #   train("pendulum", "miniconv4",
    #         deploy_config=dataclasses.replace(cfg, backend="xla"))
    print("\ndone: one manifest -> plan, kernels, codec, client, server, "
          "fleet plan.")


if __name__ == "__main__":
    main()
