"""Quickstart: the MiniConv library + split-policy pipeline in 60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.latency import SplitConfig, break_even_bandwidth
from repro.core.miniconv import (PI_ZERO_BUDGET, miniconv_apply,
                                 miniconv_feature_shape, miniconv_init,
                                 standard_spec)
from repro.core.split import make_split_policy
from repro.core.wire import frame_bytes_rgba

# 1. Build a MiniConv encoder that satisfies the paper's Pi-Zero shader
#    budget: <=8 bound textures, <=64 texture samples per output pixel,
#    4 output channels per pass.
spec = standard_spec(c_in=12, k=4)         # 3 stacked RGBA frames -> K=4
spec.validate()                            # raises if any pass violates
print(f"encoder: {len(spec.layers)} layers, {spec.total_passes} shader "
      f"passes, K={spec.k_out}, n_stride2={spec.n_stride2}")
for i, l in enumerate(spec.layers):
    print(f"  layer {i}: {l.kernel}x{l.kernel} s{l.stride} "
          f"{l.c_in}->{l.c_out} ({PI_ZERO_BUDGET.samples(l.kernel, l.c_in)}"
          f"/{PI_ZERO_BUDGET.max_samples} samples/px)")

# 2. Split-policy: encoder on-device, head on the server, uint8 wire.
params = miniconv_init(jax.random.PRNGKey(0), spec)
head = jax.random.normal(jax.random.PRNGKey(1), (11 * 11 * 4, 3)) * 0.1
policy = make_split_policy(
    lambda p, obs: miniconv_apply(p, spec, obs),
    lambda p, feats: feats.reshape(feats.shape[0], -1) @ p,
    codec="uint8")

obs = jax.random.uniform(jax.random.PRNGKey(2), (1, 84, 84, 12))
payload = policy.edge_step(params, obs)          # runs on-device
action = policy.server_step(head, payload)       # runs on the server
fshape = (1,) + miniconv_feature_shape(spec, 84, 84)
print(f"\nobs {obs.shape} -> wire {policy.wire_bytes(fshape)} bytes "
      f"(raw frame: {frame_bytes_rgba(84) * 3} bytes) -> action "
      f"{action.shape}")

# 3. The paper's break-even equation: below B*, split wins.
cfg = SplitConfig(x_size=400, n_stride2=spec.n_stride2, k_channels=4,
                  encode_time_s=0.1)
print(f"\nbreak-even bandwidth (Pi-Zero config): "
      f"{break_even_bandwidth(cfg)/1e6:.1f} Mb/s (paper: ~50.4)")
