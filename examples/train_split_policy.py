"""End-to-end driver (the paper's kind): train a split visual policy with
RL, then DEPLOY it split and measure decision latency under bandwidth
shaping — learning + Figure 5 pipeline in one script.

  PYTHONPATH=src python examples/train_split_policy.py \
      --task pendulum --encoder miniconv4 --steps 2048
"""
import argparse

import jax
import numpy as np

from repro.deploy import Deployment, DeploymentConfig
from repro.envs.wrappers import make_pixel_env
from repro.rl.train import train
from repro.serving.client import DecisionLoop
from repro.serving.netsim import shaped
from repro.serving.server import PolicyServer


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--task", default="pendulum",
                    choices=["pendulum", "hopper", "walker"])
    ap.add_argument("--encoder", default="miniconv4",
                    choices=["miniconv4", "miniconv16", "full_cnn"])
    ap.add_argument("--steps", type=int, default=2048)
    args = ap.parse_args(argv)

    # ---- 1. learn (paper §4.1, smoke scale) ------------------------------
    print(f"training {args.encoder} on {args.task} "
          f"({args.steps} env steps)...")
    result = train(args.task, args.encoder, total_steps=args.steps)
    s = result.summary()
    print(f"  best={result.best:.1f} mean={result.mean:.1f} "
          f"final={result.final:.1f} over {s['episodes']} episodes "
          f"({s['episodes_truncated']} truncated) at "
          f"{result.steps_per_sec:.1f} env-steps/s")

    if not args.encoder.startswith("miniconv"):
        print("full_cnn has no split deployment; done.")
        return

    # ---- 2. deploy split (paper §4.3) -------------------------------------
    # ONE declarative config resolves the spec, plan, codec and both
    # serving halves; the same manifest could ship to the device as JSON.
    cfg = DeploymentConfig.from_encoder_name(args.encoder, c_in=9, h=84,
                                             backend="xla")
    dep = Deployment.build(cfg)
    env = make_pixel_env(args.task, train=False)
    _, obs = env.reset(jax.random.PRNGKey(1))
    obs = obs[None]                       # the client serves one frame

    # serve the TRAINED parameters straight from the manifest: the
    # Deployment accepts TrainResult.params (its "encoder" entry is the
    # edge/server split), and the agent's policy_head is the served head
    from repro.rl.agent import make_agent
    agent = make_agent(result.algo, dep.encoder, env.action_dim)
    client = dep.client(result.params)
    server_fn = dep.server_fn(result.params,
                              head=agent.policy_head(result.params))

    j = client.measure(obs)
    srv = PolicyServer(server_fn).measure(client.encode_fn(obs))
    frame_bytes = dep.frame_bytes

    print(f"\ndeployment: edge {j*1e3:.2f} ms, wire "
          f"{client.wire_bytes} B (raw {frame_bytes} B)")
    print(f"{'Mb/s':>6} {'server-only(ms)':>16} {'split(ms)':>10}")
    for mbps in (10, 25, 50, 100):
        so = DecisionLoop(link=shaped(mbps), server_time_s=srv,
                          split=False, payload_bytes=frame_bytes)
        sp = DecisionLoop(link=shaped(mbps), server_time_s=srv,
                          split=True, edge_time_s=j,
                          payload_bytes=client.wire_bytes)
        print(f"{mbps:>6} {so.median_latency(100)*1e3:>16.1f} "
              f"{sp.median_latency(100)*1e3:>10.1f}")


if __name__ == "__main__":
    main()
