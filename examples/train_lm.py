"""End-to-end LM training driver: ~100M-parameter model, a few hundred
steps on the synthetic pipeline, with checkpointing and restore.

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import os
import tempfile

import jax

from repro.configs import get_config
from repro.data import lm_batches
from repro.models.registry import build_model
from repro.nn.module import param_count
from repro.train import checkpoint
from repro.train.trainer import TrainConfig, Trainer


def hundred_m_config():
    """qwen3 family scaled to ~100M params for the CPU driver."""
    return dataclasses.replace(
        get_config("qwen3-0.6b"), n_layers=10, n_pattern=10, d_model=640,
        n_heads=10, n_kv_heads=5, head_dim=64, d_ff=2560, vocab=49152,
        dtype="float32")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    cfg = hundred_m_config()
    ckpt_dir = args.ckpt or os.path.join(tempfile.gettempdir(),
                                         "repro_lm_ckpt")
    print(f"model: {param_count(build_model(cfg).init(jax.random.PRNGKey(0)))/1e6:.1f}M params "
          f"(analytic {cfg.param_count()/1e6:.1f}M)")

    trainer = Trainer(cfg, TrainConfig(
        batch=args.batch, steps=args.steps, lr=6e-4, warmup=20,
        log_every=20, ckpt_dir=ckpt_dir, remat=False))
    data = lm_batches(cfg.vocab, args.batch, args.seq)
    params, _, hist = trainer.run(
        data, hook=lambda i, m: print(
            f"  step {i:>5} loss {m['loss']:.4f} "
            f"({m['wall_s']:.0f}s)"))

    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}; "
          f"checkpoint at {ckpt_dir} (step {checkpoint.latest_step(ckpt_dir)})")
    restored = checkpoint.restore(ckpt_dir, {"params": params})["params"]
    batch = next(data)
    model = trainer.model
    l1, _ = model.loss(params, batch, remat=False)
    l2, _ = model.loss(restored, batch, remat=False)
    assert abs(float(l1) - float(l2)) < 1e-5, "restore mismatch"
    print("checkpoint restore verified (loss identical)")


if __name__ == "__main__":
    main()
