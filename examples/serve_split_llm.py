"""Split-serving an assigned LLM across a bandwidth-shaped link with
batched requests — the paper's architecture generalised to the
pod-boundary setting (DESIGN.md §2), plus the wire-codec ablation.

  PYTHONPATH=src python examples/serve_split_llm.py --arch qwen3-0.6b
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core.wire import CODECS, get_codec
from repro.models.registry import get_model
from repro.serving.netsim import shaped
from repro.serving.server import PolicyServer


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen3-0.6b")
    ap.add_argument("--edge-segments", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8, help="requests/batch")
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args(argv)

    cfg, model = get_model(args.arch, reduced=True)
    if cfg.family == "audio":
        raise SystemExit("enc-dec archs use the natural encoder/decoder "
                         "split; see DESIGN.md §5")
    params = model.init(jax.random.PRNGKey(0))
    edge_p, server_p = model.split_params(params, args.edge_segments)
    B, S = args.batch, args.seq
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 3,
                                cfg.vocab)
    hidden = model.edge_forward(edge_p, tokens)
    hshape = hidden.shape
    print(f"{args.arch}: boundary activation {hshape} "
          f"({np.prod(hshape)*4/1e6:.2f} MB fp32) for {B} batched requests")

    # reference output for quality accounting
    ref = model.server_forward(server_p, hidden).astype(jnp.float32)

    print(f"\n{'codec':<14} {'wire MB':>8} {'tx@1Gb/s ms':>12} "
          f"{'server ms':>10} {'top1 agree':>11} {'max |dlogit|':>13}")
    for name in sorted(CODECS):
        codec = get_codec(name)
        payload = codec.encode(hidden)
        wire = codec.wire_bytes(hshape)

        @jax.jit
        def serve(payload):
            h = codec.decode(payload, dtype=cfg.jnp_dtype)
            return model.server_forward(server_p, h)

        t = PolicyServer(serve).measure(payload)
        out = serve(payload).astype(jnp.float32)
        agree = float((out.argmax(-1) == ref.argmax(-1)).mean())
        dmax = float(jnp.abs(out - ref).max())
        link = shaped(1000)   # 1 Gb/s DCN-class link
        print(f"{name:<14} {wire/1e6:>8.2f} {link.tx_time(wire)*1e3:>12.2f} "
              f"{t*1e3:>10.1f} {agree:>11.3f} {dmax:>13.3f}")

    print("\nthe uint8/int8 rows are the paper's insight at the pod "
          "boundary: 4x less DCN traffic for negligible logit change.")


if __name__ == "__main__":
    main()
