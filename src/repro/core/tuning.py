"""Per-manifest kernel autotuning: measure the live kernel, freeze the winner.

RLtools wins its speed comparisons by exhaustively specialising kernels to
the deployment target; DistrEdge shows edge-CNN serving throughput is won
by matching tiling/partitioning to the device.  This module does the same
for a :class:`~repro.deploy.DeploymentConfig`, automatically:

1. :func:`default_candidates` spans the search space — execution backend
   (registry-driven, ``repro.core.backends``) x ``tile_h`` x micro-batch
   size — for the manifest's serving shape.
2. :func:`prune_candidates` cuts the grid with a cost model derived from
   the :class:`~repro.core.passplan.PassPlan` (VMEM residency, FLOPs,
   moved bytes, launch/grid-step overheads), so only a handful of
   plausible candidates are ever measured.
3. :func:`tune` benchmarks the survivors through the REAL pipeline
   (``Deployment.build`` + ``encoder.apply``) and returns the winning
   :class:`TunedPlan`, stamped with the execution mode and host it was
   measured on.

The ``TunedPlan`` freezes into the manifest (``DeploymentConfig.tuning``,
JSON round-trip) and ``Deployment.build`` resolves it automatically — so
every entry point (serving t(B) curves, fleet sims, ``rl/train``, all
benchmarks) inherits tuned kernels with zero call-site changes.

Both the timer and the measurement function are injectable, which makes
the tuner deterministic under test stubs and lets the pruning tests drive
it with the cost model itself.
"""
from __future__ import annotations

import dataclasses
import math
import statistics
import time
from typing import Callable, Iterable, Optional, Sequence

from repro.core.backends import backend_names, get_backend
from repro.core.passplan import DEFAULT_VMEM_LIMIT
from repro.schema import check_version

TUNING_VERSION = 1

# Coarse per-unit costs for the pruning model.  Absolute values are
# irrelevant — pruning only compares candidates against each other — but
# the ratios encode what actually dominates: per-launch dispatch and (in
# interpret mode especially) per-grid-step overhead, not arithmetic.
_FLOP_RATE = 5e9            # sustained f32 FLOPs/s
_BYTES_RATE = 2e9           # HBM<->VMEM bytes/s
_LAUNCH_OVERHEAD_S = 5e-4   # one pallas_call / XLA dispatch
_STEP_OVERHEAD_S = 5e-5     # one grid step (interpret-mode loop iteration)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the search grid: HOW to execute the serving batch."""

    backend: str             # execution-backend name (registry)
    tile_h: int              # fused-kernel output-row tile height
    micro_batch: int         # frames per launch (splits max_batch)


@dataclasses.dataclass(frozen=True)
class TunedPlan:
    """The measured winner, frozen into the deployment manifest.

    ``time_s`` is the median launch time at ``micro_batch`` frames;
    ``per_frame_s`` the serving cost per frame at the manifest's
    ``max_batch`` (``ceil(max_batch/micro_batch)`` launches amortised).
    ``mode``/``host`` record WHERE the measurement holds
    (``repro.perfstamp``) so a manifest tuned interpret-on-CPU is not
    mistaken for compiled-TPU truth.  All fields are scalars, keeping
    :class:`~repro.deploy.DeploymentConfig` hashable.
    """

    backend: str
    tile_h: int
    micro_batch: int
    time_s: float = 0.0
    per_frame_s: float = 0.0
    mode: str = "interpret"
    host: str = ""
    searched: int = 0        # candidates actually measured
    pruned: int = 0          # candidates cut by the cost model
    version: int = TUNING_VERSION

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TunedPlan":
        d = dict(d)
        version = check_version("TunedPlan tuning block",
                                d.pop("version", TUNING_VERSION),
                                (TUNING_VERSION,))
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown TunedPlan fields: {sorted(unknown)}")
        return cls(version=version, **d)


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

def _plan_and_head(config):
    """(plan, vmem_head_plan_or_None) for a config-like object."""
    plan = config.spec.plan(config.in_h, config.in_w)
    head = plan.head(config.head_dim, activation=config.head_act)
    return plan, head


def _fused_head(config, backend) -> bool:
    """Mirror of ``Deployment.build``'s head-fusion decision."""
    return backend.fused_head or (config.head_placement == "fused"
                                  and backend.mode == "fused")


def estimated_cost_s(config, cand: Candidate) -> float:
    """Modelled per-frame serving cost of ``cand`` at ``config.max_batch``.

    Derived entirely from the PassPlan: FLOPs (encoder + projection),
    bytes moved through VMEM, grid-step counts per execution tier, and
    launch dispatch — affine in the quantities the tuner actually trades
    off (launch amortisation vs per-step overhead vs VMEM feasibility).
    """
    backend = get_backend(cand.backend)
    plan, head_plan = _plan_and_head(config)
    micro = max(1, min(cand.micro_batch, config.max_batch))
    n_launch_groups = math.ceil(config.max_batch / micro)

    flops = plan.flops_per_frame + head_plan.flops
    first = plan.layers[0]
    in_bytes = first.padded_in_h * first.padded_in_w * first.c_in_pad * 4
    out_bytes = plan.feature_bytes * 4 + head_plan.out_dim * 4
    per_frame = flops / _FLOP_RATE + (in_bytes + out_bytes) / _BYTES_RATE

    tile_h = max(1, min(cand.tile_h, plan.out_h))
    n_tiles = math.ceil(plan.out_h / tile_h)
    if backend.mode == "xla":
        launches, steps = 1, 0
    elif backend.mode == "per_pass":
        # grid = (batch, out_row, kernel_row) per ShaderPass
        launches = plan.total_passes
        steps = micro * sum(l.out_h * l.kernel * math.ceil(l.c_out / 4)
                            for l in plan.layers)
    elif backend.mode == "grouped":
        # one launch per layer, grid = (batch, out_row, group)
        launches = len(plan.layers)
        steps = micro * sum(l.out_h * math.ceil(l.c_out / 4)
                            for l in plan.layers)
    else:                                  # fused tiers
        launches = 1
        steps = micro * n_tiles
        if backend.streamed:
            # streaming re-fetches each chunk's input block; extra chunks
            # only appear past the VMEM-safe size, modelled as extra
            # launch groups below
            max_safe = plan.max_safe_batch(
                head=head_plan if _fused_head(config, backend) else None,
                tile_h=tile_h)
            if max_safe >= 1 and micro > max_safe:
                launches = math.ceil(micro / max_safe)
    t_launch = (launches * _LAUNCH_OVERHEAD_S + steps * _STEP_OVERHEAD_S
                + micro * per_frame)
    return n_launch_groups * t_launch / config.max_batch


def vmem_feasible(config, cand: Candidate, *,
                  compiled: Optional[bool] = None,
                  vmem_limit: int = DEFAULT_VMEM_LIMIT) -> bool:
    """Can ``cand`` launch at all?  Compiled fused launches must fit the
    VMEM residency budget; streamed backends only need ONE frame to fit;
    interpret / non-fused tiers are unconstrained."""
    backend = get_backend(cand.backend)
    if compiled is None:
        from repro.perfstamp import execution_mode
        compiled = execution_mode(config.interpret) == "compiled"
    if not compiled or backend.mode != "fused":
        return True
    plan, head_plan = _plan_and_head(config)
    head = head_plan if _fused_head(config, backend) else None
    max_safe = plan.max_safe_batch(head=head, tile_h=cand.tile_h,
                                   vmem_limit=vmem_limit)
    if backend.streamed:
        return max_safe >= 1
    return cand.micro_batch <= max_safe


# ---------------------------------------------------------------------------
# Search space
# ---------------------------------------------------------------------------

def default_candidates(config, *,
                       backends: Optional[Sequence[str]] = None,
                       tile_hs: Optional[Sequence[int]] = None,
                       micro_batches: Optional[Sequence[int]] = None
                       ) -> tuple[Candidate, ...]:
    """The registry-driven search grid for one manifest.

    Backends default to every registered execution backend; ``tile_h``
    spans powers of two up to the feature height; micro-batches span
    powers of two up to ``max_batch`` plus ``max_batch`` itself and the
    plan's VMEM-safe size.  The grid is canonically ordered (sorted,
    deduplicated), which is what makes the tuner deterministic.
    """
    plan, head_plan = _plan_and_head(config)
    if backends is None:
        backends = backend_names()
    if tile_hs is None:
        tile_hs = sorted({t for t in (4, 8, 16, plan.out_h)
                          if 1 <= t <= plan.out_h}) or [plan.out_h]
    if micro_batches is None:
        mbs = {1 << i for i in range(config.max_batch.bit_length())
               if 1 << i <= config.max_batch}
        mbs.add(config.max_batch)
        max_safe = plan.max_safe_batch(head=head_plan, tile_h=config.tile_h)
        if 1 <= max_safe <= config.max_batch:
            mbs.add(max_safe)
        micro_batches = sorted(mbs)
    out = []
    for b in backends:
        name = get_backend(b).name
        for t in sorted(set(tile_hs)):
            for m in sorted(set(micro_batches)):
                out.append(Candidate(backend=name, tile_h=t, micro_batch=m))
    # non-fused tiers ignore tile_h — collapse their duplicates
    seen, uniq = set(), []
    for c in out:
        key = (c.backend, c.tile_h if get_backend(c.backend).mode == "fused"
               else 0, c.micro_batch)
        if key not in seen:
            seen.add(key)
            uniq.append(c)
    return tuple(uniq)


def baseline_candidate(config) -> Candidate:
    """The manifest's current (untuned) execution point, with ``tile_h``
    clamped the way the kernel clamps it (so it matches the grid's
    canonical form)."""
    plan, _ = _plan_and_head(config)
    return Candidate(backend=get_backend(config.backend).name,
                     tile_h=max(1, min(config.tile_h, plan.out_h)),
                     micro_batch=config.max_batch)


def prune_candidates(config, candidates: Iterable[Candidate], *,
                     keep_ratio: float = 3.0,
                     compiled: Optional[bool] = None
                     ) -> tuple[tuple[Candidate, ...], int]:
    """(survivors, n_pruned) after VMEM-feasibility + cost-ratio cuts.

    A candidate survives when it can launch (``vmem_feasible``) and its
    modelled cost is within ``keep_ratio`` of the cheapest feasible
    candidate.  The manifest's own baseline point always survives, so
    tuning can never regress below "measure what you already had".
    """
    cands = list(candidates)
    base = baseline_candidate(config)
    feasible = [c for c in cands
                if vmem_feasible(config, c, compiled=compiled)]
    if not feasible:
        raise ValueError(
            "no VMEM-feasible tuning candidate: even a single frame "
            "exceeds the fused-kernel budget — lower in_h/in_w or split "
            "the spec")
    costs = {c: estimated_cost_s(config, c) for c in feasible}
    best = min(costs.values())
    kept = [c for c in feasible if costs[c] <= keep_ratio * best]
    if base not in kept and vmem_feasible(config, base, compiled=compiled):
        kept.append(base)
    return tuple(kept), max(0, len(cands) - len(kept))


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

def measure_candidate(config, cand: Candidate, *, iters: int = 5,
                      timer: Callable[[], float] = time.perf_counter,
                      seed: int = 0) -> float:
    """Median wall-clock seconds of ONE encoder launch at
    ``cand.micro_batch`` frames, through the real pipeline
    (``Deployment.build`` -> ``encoder.apply``)."""
    import jax
    import jax.numpy as jnp
    from repro.deploy import Deployment
    cfg = dataclasses.replace(config, backend=cand.backend,
                              tile_h=cand.tile_h, tuning=None,
                              max_batch=max(config.max_batch,
                                            cand.micro_batch))
    dep = Deployment.build(cfg)
    params = dep.init(jax.random.PRNGKey(seed))
    x = jax.random.uniform(
        jax.random.PRNGKey(seed + 1),
        (cand.micro_batch, config.in_h, config.in_w,
         config.spec.layers[0].c_in))
    apply = dep.encoder.apply
    jax.block_until_ready(apply(params, x))       # compile / warm caches
    samples = []
    for _ in range(iters):
        t0 = timer()
        jax.block_until_ready(apply(params, x))
        samples.append(timer() - t0)
    return statistics.median(samples)


def _serving_cost(config, cand: Candidate, t_launch: float) -> float:
    """Per-frame cost of serving ``max_batch`` frames in
    ``micro_batch``-sized launches, each costing ``t_launch``."""
    micro = max(1, min(cand.micro_batch, config.max_batch))
    return math.ceil(config.max_batch / micro) * t_launch / config.max_batch


def tune(config, *, candidates: Optional[Sequence[Candidate]] = None,
         iters: int = 5, keep_ratio: float = 3.0,
         timer: Callable[[], float] = time.perf_counter,
         measure: Optional[Callable] = None,
         log: Optional[Callable[[str], None]] = None) -> TunedPlan:
    """Autotune one manifest: prune the grid, measure survivors, freeze
    the winner.

    ``measure(config, cand)`` -> launch seconds is injectable (tests use
    the cost model itself, or a stub timer); the default measures the
    live kernel via :func:`measure_candidate`.  Scoring is per-frame
    serving cost at ``config.max_batch``; ties break toward the
    canonical candidate order, so identical measurements always pick the
    same winner (determinism).
    """
    from repro.perfstamp import execution_mode, host_fingerprint
    if candidates is None:
        candidates = default_candidates(config)
    kept, n_pruned = prune_candidates(config, candidates,
                                      keep_ratio=keep_ratio)
    if measure is None:
        def measure(cfg, cand):
            return measure_candidate(cfg, cand, iters=iters, timer=timer)
    best_c, best_t, best_cost = None, None, float("inf")
    for cand in kept:
        t_launch = measure(config, cand)
        cost = _serving_cost(config, cand, t_launch)
        if log is not None:
            log(f"  {cand.backend:>12} tile_h={cand.tile_h:<3} "
                f"micro={cand.micro_batch:<3} t={t_launch * 1e3:8.3f} ms "
                f"-> {cost * 1e6:9.1f} us/frame")
        if cost < best_cost:
            best_c, best_t, best_cost = cand, t_launch, cost
    assert best_c is not None
    return TunedPlan(backend=best_c.backend, tile_h=best_c.tile_h,
                     micro_batch=best_c.micro_batch, time_s=best_t,
                     per_frame_s=best_cost,
                     mode=execution_mode(config.interpret),
                     host=host_fingerprint(), searched=len(kept),
                     pruned=n_pruned)


def suggest_tuning(config) -> Candidate:
    """Cheapest cost-model candidate WITHOUT measuring — used for
    over-budget diagnostics (``Deployment.build``'s VMEM error reports
    this as the suggested ``tile_h``/micro-batch) and as a starting point
    when a full tune is too expensive."""
    kept, _ = prune_candidates(config, default_candidates(config))
    return min(kept, key=lambda c: estimated_cost_s(config, c))


__all__ = ["Candidate", "TunedPlan", "TUNING_VERSION", "baseline_candidate",
           "default_candidates", "estimated_cost_s", "measure_candidate",
           "prune_candidates", "suggest_tuning", "tune", "vmem_feasible"]
