"""Split-policy / split-model abstraction (the paper's core contribution).

A :class:`SplitModel` partitions any (params, x) -> y function into an
*edge* half and a *server* half with a wire codec at the boundary:

    features      = edge_apply(edge_params, obs)          # on-device
    payload       = codec.encode(features)                # uint8 buffer
    --- network / inter-pod link ---
    features'     = codec.decode(payload)
    action/logits = server_apply(server_params, features') # remote

For RL policies the edge half is a MiniConv encoder; for the assigned
transformer architectures the edge half is the first ``n_edge_layers``
blocks (see repro.models.transformer.split_forward) and the link is the
inter-pod DCN.

``split_train_apply`` runs the full composition *with* the quantisation in
the forward pass (straight-through estimator) so training matches the
deployed numerics — the paper trains end-to-end in float and deploys the
quantised wire; both modes are supported via ``quantize_in_train``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.wire import WireCodec, get_codec

Params = Any


@dataclasses.dataclass(frozen=True)
class SplitModel:
    edge_apply: Callable[[Params, jnp.ndarray], jnp.ndarray]
    server_apply: Callable[[Params, jnp.ndarray], Any]
    codec: WireCodec
    quantize_in_train: bool = False
    # For MiniConv edges: the compiled PassPlan the edge half executes
    # (see repro.core.passplan).  None for non-MiniConv splits.
    plan: Any = None

    # ---- deployment path ---------------------------------------------------
    def edge_step(self, edge_params, obs):
        """Runs on-device; returns the wire payload."""
        feats = self.edge_apply(edge_params, obs)
        return self.codec.encode(feats)

    def server_step(self, server_params, payload):
        feats = self.codec.decode(payload)
        return self.server_apply(server_params, feats)

    # ---- batched deployment path -------------------------------------------
    def edge_step_batch(self, edge_params, obs_batch):
        """Encode a stacked (B, ...) observation batch in ONE edge call.

        The MiniConv edge executes the whole batch as a single fused
        kernel launch (batch is the kernel's outer grid dimension) and the
        codec quantises per example, so each request's payload is bitwise
        the payload the single-frame path would have produced.
        """
        feats = self.edge_apply(edge_params, obs_batch)
        return self.codec.encode_batch(feats)

    def server_step_batch(self, server_params, payload_batch):
        """Serve a stacked micro-batch payload (see ``wire.stack_payloads``)
        with one decode + one server_apply over the leading batch axis."""
        feats = self.codec.decode_batch(payload_batch)
        return self.server_apply(server_params, feats)

    def wire_bytes(self, feature_shape: Optional[tuple] = None, *,
                   batch: int = 1) -> int:
        if feature_shape is None:
            if self.plan is None:
                raise ValueError("feature_shape required for plan-less split")
            feature_shape = self.plan.feature_shape
        return self.codec.wire_bytes_batch(feature_shape, batch)

    # ---- training path (single process, differentiable) --------------------
    def apply(self, params, obs):
        feats = self.edge_apply(params["edge"], obs)
        if self.quantize_in_train:
            feats = straight_through(self.codec, feats)
        return self.server_apply(params["server"], feats)


def straight_through(codec: WireCodec, x: jnp.ndarray) -> jnp.ndarray:
    """Quantise in the forward pass, identity gradient in the backward."""
    q = codec.decode(codec.encode(x), dtype=x.dtype)
    return x + jax.lax.stop_gradient(q - x)


def make_split_policy(edge_apply, server_apply, *, codec: str = "uint8",
                      quantize_in_train: bool = False) -> SplitModel:
    return SplitModel(edge_apply=edge_apply, server_apply=server_apply,
                      codec=get_codec(codec),
                      quantize_in_train=quantize_in_train)


def make_miniconv_split(spec, server_apply, *, h: int, w: Optional[int] = None,
                        codec: str = "uint8", use_kernel="fused",
                        quantize_in_train: bool = False) -> SplitModel:
    """Split policy whose edge half is a MiniConv encoder compiled to a
    :class:`~repro.core.passplan.PassPlan`.

    .. deprecated::
        Thin shim over :meth:`repro.deploy.Deployment.build` — the one
        canonical pipeline constructor.  The built deployment's split is
        returned with ``server_apply`` substituted, so custom server
        halves keep working; new code should construct a
        :class:`repro.deploy.DeploymentConfig` and use
        ``Deployment.build(cfg).split`` directly.
    """
    from repro.deploy import Deployment, DeploymentConfig  # lazy: layering

    cfg = DeploymentConfig(spec=spec, in_h=h, in_w=h if w is None else w,
                           backend=use_kernel, codec=codec,
                           quantize_in_train=quantize_in_train)
    dep = Deployment.build(cfg)
    return dataclasses.replace(dep.split, server_apply=server_apply)
