"""Wire codecs for the split boundary.

The paper transmits the on-device encoder's K-channel feature map as an
uncompressed uint8 buffer.  We generalise this into a codec interface so the
same machinery serves (a) the RL split policy (uint8 feature maps) and
(b) the pod-boundary transformer split (uint8/int8 affine-quantised hidden
states crossing the inter-pod link).

All codecs are jit-compatible pure functions; ``wire_bytes`` gives the exact
on-the-wire size used by the latency model and by the collective-bytes
accounting in the roofline analysis.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

Payload = dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class WireCodec:
    """Base: float32 passthrough."""

    name: str = "float32"
    itemsize: float = 4.0
    overhead_bytes_per_tensor: int = 0

    def encode(self, x: jnp.ndarray) -> Payload:
        return {"data": x.astype(jnp.float32)}

    def decode(self, payload: Payload, dtype=jnp.float32) -> jnp.ndarray:
        return payload["data"].astype(dtype)

    def wire_bytes(self, shape: tuple) -> int:
        return math.prod(shape) * int(self.itemsize) + \
            self.overhead_bytes_per_tensor

    def wire_bits(self, shape: tuple) -> int:
        return 8 * self.wire_bytes(shape)

    # ---- batched serving ---------------------------------------------------
    def encode_batch(self, x: jnp.ndarray) -> Payload:
        """Encode a stacked batch with PER-EXAMPLE quantisation parameters.

        ``encode`` computes one scale/zero over the whole tensor, which
        would couple the dynamic ranges of unrelated requests in a
        micro-batch; vmapping over the leading axis keeps each request's
        wire numerics identical to the single-frame path.
        """
        return jax.vmap(self.encode)(x)

    def decode_batch(self, payload: Payload, dtype=jnp.float32):
        return jax.vmap(lambda p: self.decode(p, dtype))(payload)

    def wire_bytes_batch(self, shape: tuple, batch: int) -> int:
        """Exact link bytes of a ``batch``-request micro-batch (each
        request carries its own quantisation header)."""
        return batch * self.wire_bytes(shape)


@dataclasses.dataclass(frozen=True)
class BF16Codec(WireCodec):
    name: str = "bf16"
    itemsize: float = 2.0

    def encode(self, x):
        return {"data": x.astype(jnp.bfloat16)}

    def decode(self, payload, dtype=jnp.float32):
        return payload["data"].astype(dtype)


@dataclasses.dataclass(frozen=True)
class Uint8AffineCodec(WireCodec):
    """Per-tensor affine quantisation to uint8 (the paper's wire format for
    features in [0,1]; scale/zero travel as an 8-byte header)."""

    name: str = "uint8"
    itemsize: float = 1.0
    overhead_bytes_per_tensor: int = 8

    def encode(self, x):
        xf = x.astype(jnp.float32)
        lo = jnp.min(xf)
        hi = jnp.max(xf)
        scale = jnp.maximum(hi - lo, 1e-8) / 255.0
        q = jnp.clip(jnp.round((xf - lo) / scale), 0, 255).astype(jnp.uint8)
        return {"data": q, "scale": scale, "zero": lo}

    def decode(self, payload, dtype=jnp.float32):
        return (payload["data"].astype(jnp.float32) * payload["scale"]
                + payload["zero"]).astype(dtype)


@dataclasses.dataclass(frozen=True)
class Int8ChannelCodec(WireCodec):
    """Per-channel (last axis) symmetric int8 — used for transformer hidden
    states at the pod boundary, where per-channel scales matter."""

    name: str = "int8_channel"
    itemsize: float = 1.0

    def encode(self, x):
        xf = x.astype(jnp.float32)
        amax = jnp.max(jnp.abs(xf), axis=tuple(range(xf.ndim - 1)),
                       keepdims=True)
        scale = jnp.maximum(amax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
        return {"data": q, "scale": scale}

    def decode(self, payload, dtype=jnp.float32):
        return (payload["data"].astype(jnp.float32)
                * payload["scale"]).astype(dtype)

    def wire_bytes(self, shape):
        return math.prod(shape) + 4 * shape[-1]


CODECS: dict[str, WireCodec] = {
    "float32": WireCodec(),
    "bf16": BF16Codec(),
    "uint8": Uint8AffineCodec(),
    "int8_channel": Int8ChannelCodec(),
}


def get_codec(name: str) -> WireCodec:
    return CODECS[name]


def roundtrip(codec: WireCodec, x: jnp.ndarray) -> jnp.ndarray:
    """Quantise-dequantise (what the server-side half actually sees)."""
    return codec.decode(codec.encode(x), dtype=x.dtype)


def stack_payloads(payloads) -> Payload:
    """Stack single-request payload dicts into one micro-batch payload.

    The result has a new leading batch axis on every tensor (data AND
    quantisation headers) and round-trips through
    :meth:`WireCodec.decode_batch`.
    """
    payloads = list(payloads)
    if not payloads:
        raise ValueError("cannot stack an empty payload list")
    return {k: jnp.stack([p[k] for p in payloads]) for k in payloads[0]}


def unstack_payload(payload: Payload) -> list[Payload]:
    """Inverse of :func:`stack_payloads`."""
    n = next(iter(payload.values())).shape[0]
    return [{k: v[i] for k, v in payload.items()} for i in range(n)]


def frame_bytes_rgba(x_size: int) -> int:
    """Bytes of a full RGBA frame (the server-only pipeline's payload)."""
    return 4 * x_size * x_size


def feature_bytes(x_size: int, n_stride2: int, k: int) -> int:
    """Bytes of the K-channel feature map after n stride-2 layers (paper).

    Derived via the PassPlan spatial rule (ceil per stride-2 layer, matching
    SAME convs and the real feature shape) — the old ``x // 2**n`` floor
    disagreed with the emitted tensor for non-divisible sizes (e.g. 100x100
    with n=3 produces a 13x13 map, not 12x12).
    """
    from repro.core.passplan import out_spatial_chain  # lazy: import order
    s = out_spatial_chain(x_size, (2,) * n_stride2)
    return k * s * s
