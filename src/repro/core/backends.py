"""Execution-backend registry: HOW a compiled PassPlan is executed.

The paper's artifact separates WHAT is deployed (the
:class:`~repro.core.miniconv.MiniConvSpec`, lowered to a budget-checked
:class:`~repro.core.passplan.PassPlan`) from HOW it executes on a given
substrate (fragment shaders on the Pi, Pallas kernels on TPU, plain XLA
for training).  This module makes the HOW a first-class, registered
object so that :class:`repro.deploy.DeploymentConfig` can name it
declaratively and new backends (future: multi-chip sharded, CUDA, ...)
plug in without touching any call site.

Registered backends
-------------------
``xla``
    XLA SAME convs — the differentiable training path.
``reference`` (alias ``per_pass``)
    One ``pallas_call`` per :class:`~repro.core.passplan.ShaderPass`; the
    shader oracle the fused tiers are parity-tested against.
``grouped``
    One ``pallas_call`` per layer, output-group as a grid dimension.
``fused``
    The whole PassPlan as ONE ``pallas_call`` (VMEM-chained layers).
``fused+head`` (alias ``fused_head``)
    ``fused`` with the server-side projection executed as an in-kernel
    epilogue — encoder + head in a single launch (the batched-serving /
    replay-encoding hot path).
``fused+stream`` (alias ``fused_stream``)
    The fused kernel pipelined over batch CHUNKS
    (:func:`~repro.kernels.miniconv_pass.miniconv_encoder_stream`): lifts
    the batch-must-fit-VMEM rule (``PassPlan.max_safe_batch``) by
    streaming ``chunk_b``-frame input blocks HBM->VMEM, double-buffered
    on compiled TPU, multi-launch split as the portable fallback.

Each backend maps to a ``miniconv_apply`` kernel mode; the legacy
``use_kernel=`` strings resolve through this registry, so an unknown name
fails with the full list of registered backends instead of silently
falling through to an arbitrary path.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional


@dataclasses.dataclass(frozen=True)
class ExecutionBackend:
    """One way of executing a compiled MiniConv pass plan.

    ``mode`` is the kernel-layer execution tier
    (``repro.core.miniconv.miniconv_apply``'s ``use_kernel``);
    ``fused_head`` marks backends whose head projection runs INSIDE the
    kernel epilogue rather than as a separate XLA matmul.
    """

    name: str
    mode: str                    # miniconv_apply execution tier
    fused_head: bool = False
    streamed: bool = False       # batch-chunked VMEM streaming (fused only)
    description: str = ""

    @property
    def is_pallas(self) -> bool:
        """True when this backend executes Pallas kernels (and is therefore
        subject to the VMEM residency budget when compiled on TPU)."""
        return self.mode != "xla"


_REGISTRY: dict[str, ExecutionBackend] = {}
_ALIASES: dict[str, str] = {}


def register_backend(backend: ExecutionBackend, *,
                     aliases: Iterable[str] = ()) -> ExecutionBackend:
    """Register an execution backend (idempotent for identical entries)."""
    existing = _REGISTRY.get(backend.name)
    if existing is not None and existing != backend:
        raise ValueError(f"backend {backend.name!r} already registered "
                         f"as {existing}")
    _REGISTRY[backend.name] = backend
    for a in aliases:
        if _ALIASES.get(a, backend.name) != backend.name:
            raise ValueError(f"alias {a!r} already points at "
                             f"{_ALIASES[a]!r}")
        _ALIASES[a] = backend.name
    return backend


def backend_names(*, include_aliases: bool = False) -> tuple[str, ...]:
    names = list(_REGISTRY)
    if include_aliases:
        names += sorted(_ALIASES)
    return tuple(names)


def get_backend(name) -> ExecutionBackend:
    """Resolve a backend by name or alias.

    Also accepts the historical ``use_kernel`` values ``False``/``None``
    (-> ``xla``) and ``True`` (-> ``reference``).  Unknown names raise with
    the full registered list so a typo'd manifest fails loudly.
    """
    if name is False or name is None:
        name = "xla"
    elif name is True:           # backwards compat: old boolean flag
        name = "reference"
    if not isinstance(name, str):
        raise ValueError(f"backend must be a registered name, got {name!r}; "
                         f"registered: {', '.join(backend_names())}")
    resolved = _ALIASES.get(name, name)
    try:
        return _REGISTRY[resolved]
    except KeyError:
        raise ValueError(
            f"unknown execution backend {name!r}; registered backends: "
            f"{', '.join(backend_names(include_aliases=True))} "
            f"(False/None -> 'xla', True -> 'reference')") from None


register_backend(ExecutionBackend(
    "xla", "xla",
    description="XLA SAME convs — the differentiable training path"))
register_backend(ExecutionBackend(
    "reference", "per_pass",
    description="one pallas_call per ShaderPass (the shader oracle)"),
    aliases=("per_pass",))
register_backend(ExecutionBackend(
    "grouped", "grouped",
    description="one pallas_call per layer, output-group as grid dim"))
register_backend(ExecutionBackend(
    "fused", "fused",
    description="whole PassPlan as ONE pallas_call (VMEM-chained layers)"))
register_backend(ExecutionBackend(
    "fused+head", "fused", fused_head=True,
    description="fused kernel with the projection as an in-kernel epilogue"),
    aliases=("fused_head",))
register_backend(ExecutionBackend(
    "fused+stream", "fused", fused_head=True, streamed=True,
    description="fused+head pipelined over batch chunks — streams "
                "chunk_b-frame blocks HBM->VMEM past max_safe_batch"),
    aliases=("fused_stream",))


__all__ = ["ExecutionBackend", "backend_names", "get_backend",
           "register_backend"]
