"""Core: the paper's contribution — MiniConv encoders, the split-policy
architecture, wire codecs, and the decision-latency model."""

from repro.core.backends import (ExecutionBackend, backend_names,
                                 get_backend, register_backend)
from repro.core.latency import (LinkModel, SplitConfig, break_even_bandwidth,
                                decision_latency_server_only,
                                decision_latency_split,
                                paper_pi_zero_config)
from repro.core.miniconv import (MiniConvSpec, LayerSpec, ShaderBudget,
                                 PI_ZERO_BUDGET, miniconv_apply,
                                 miniconv_feature_shape, miniconv_init,
                                 standard_spec)
from repro.core.passplan import (DEFAULT_VMEM_LIMIT, HeadPlan, LayerPlan,
                                 PassPlan, ShaderPass, build_pass_plan,
                                 count_passes, out_spatial_chain)
from repro.core.split import (SplitModel, make_miniconv_split,
                              make_split_policy, straight_through)
from repro.core.wire import (CODECS, WireCodec, feature_bytes,
                             frame_bytes_rgba, get_codec, roundtrip)

__all__ = [
    "ExecutionBackend", "backend_names", "get_backend", "register_backend",
    "LinkModel", "SplitConfig", "break_even_bandwidth",
    "decision_latency_server_only", "decision_latency_split",
    "paper_pi_zero_config", "MiniConvSpec", "LayerSpec", "ShaderBudget",
    "PI_ZERO_BUDGET", "miniconv_apply", "miniconv_feature_shape",
    "miniconv_init", "standard_spec", "DEFAULT_VMEM_LIMIT", "HeadPlan",
    "LayerPlan", "PassPlan", "ShaderPass", "build_pass_plan", "count_passes",
    "out_spatial_chain", "SplitModel", "make_miniconv_split",
    "make_split_policy", "straight_through", "CODECS", "WireCodec",
    "feature_bytes", "frame_bytes_rgba", "get_codec", "roundtrip",
]
