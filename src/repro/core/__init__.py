"""Core: the paper's contribution — MiniConv encoders, the split-policy
architecture, wire codecs, and the decision-latency model.

Module map
----------
``miniconv``
    MiniConv specs under the fragment-shader budget (``MiniConvSpec`` /
    ``ShaderBudget``) and the reference ``miniconv_apply`` dispatcher
    over the backend registry.
``passplan``
    The PassPlan IR: every shape, pad, FLOP and byte of the shader-pass
    schedule, plus the batch-aware VMEM model (``vmem_bytes`` /
    ``max_safe_batch`` / ``check_batch``) the kernels and the tuner
    both price against.
``backends``
    The execution-backend registry (``xla`` / ``reference`` /
    ``grouped`` / ``fused`` / ``fused+head`` / ``fused+stream``) that
    ``Deployment.build`` and the tuner enumerate.
``tuning``
    The per-manifest autotuner: candidate enumeration over
    (backend, tile_h, micro-batch), PassPlan-derived cost-model pruning,
    live-kernel measurement, and the frozen ``TunedPlan`` that ships in
    the deployment manifest.
``split``
    The edge/server split model with the straight-through quantised
    wire boundary.
``wire``
    Wire codecs (uint8 / float16 / ...) and payload accounting.
``latency``
    The paper's decision-latency and break-even-bandwidth equations.
"""

from repro.core.backends import (ExecutionBackend, backend_names,
                                 get_backend, register_backend)
from repro.core.latency import (LinkModel, SplitConfig, break_even_bandwidth,
                                decision_latency_server_only,
                                decision_latency_split,
                                paper_pi_zero_config)
from repro.core.miniconv import (MiniConvSpec, LayerSpec, ShaderBudget,
                                 PI_ZERO_BUDGET, miniconv_apply,
                                 miniconv_feature_shape, miniconv_init,
                                 standard_spec)
from repro.core.passplan import (DEFAULT_VMEM_LIMIT, HeadPlan, LayerPlan,
                                 PassPlan, ShaderPass, build_pass_plan,
                                 count_passes, out_spatial_chain)
from repro.core.split import (SplitModel, make_miniconv_split,
                              make_split_policy, straight_through)
from repro.core.tuning import (Candidate, TunedPlan, default_candidates,
                               estimated_cost_s, prune_candidates,
                               suggest_tuning, tune)
from repro.core.wire import (CODECS, WireCodec, feature_bytes,
                             frame_bytes_rgba, get_codec, roundtrip)

__all__ = [
    "ExecutionBackend", "backend_names", "get_backend", "register_backend",
    "LinkModel", "SplitConfig", "break_even_bandwidth",
    "decision_latency_server_only", "decision_latency_split",
    "paper_pi_zero_config", "MiniConvSpec", "LayerSpec", "ShaderBudget",
    "PI_ZERO_BUDGET", "miniconv_apply", "miniconv_feature_shape",
    "miniconv_init", "standard_spec", "DEFAULT_VMEM_LIMIT", "HeadPlan",
    "LayerPlan", "PassPlan", "ShaderPass", "build_pass_plan", "count_passes",
    "out_spatial_chain", "SplitModel", "make_miniconv_split",
    "make_split_policy", "straight_through", "Candidate", "TunedPlan",
    "default_candidates", "estimated_cost_s", "prune_candidates",
    "suggest_tuning", "tune", "CODECS", "WireCodec",
    "feature_bytes", "frame_bytes_rgba", "get_codec", "roundtrip",
]
