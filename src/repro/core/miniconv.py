"""MiniConv: a library of small convolutional encoders that compile cleanly
to per-pass execution under embedded-GPU ("fragment shader") constraints.

The paper's constraint model (retained verbatim, §3):

* one pass writes exactly 4 output channels (RGBA texture);
* a pass may bind at most 8 input textures => C_in <= 32 per pass;
* a pass has a finite per-pixel sampling budget (64 samples in the paper's
  Pi Zero 2 W deployment): ``k_h * k_w * ceil(C_in / 4) <= 64``.

On TPU these become VMEM-tiling constraints for the Pallas kernel
(`repro.kernels.miniconv_pass`): a pass is one kernel invocation whose
input block holds ceil(C_in/4) packed 4-channel planes and whose output
tile is one 4-channel plane.  ``MiniConvSpec.validate()`` enforces the
budget so that any encoder built here is deployable on both substrates.

Encoders are trained end-to-end with the downstream policy (PyTorch in the
paper, `repro.rl` here); at deployment only the encoder runs on-device and
its K-channel uint8 feature map crosses the network (`repro.core.wire`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.nn.layers import conv2d, conv2d_init
from repro.nn.module import KeyGen


# ---------------------------------------------------------------------------
# Constraint model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShaderBudget:
    """Embedded-GPU constraints a MiniConv pass must respect (paper §3)."""

    max_textures: int = 8        # bound input textures per pass
    channels_per_texture: int = 4  # RGBA packing
    max_samples: int = 64        # texture samples per output pixel
    out_channels_per_pass: int = 4  # one RGBA render target

    @property
    def max_in_channels(self) -> int:
        return self.max_textures * self.channels_per_texture

    def samples(self, kernel: int, c_in: int) -> int:
        textures = math.ceil(c_in / self.channels_per_texture)
        return kernel * kernel * textures

    def check_pass(self, kernel: int, c_in: int) -> list[str]:
        errs = []
        if c_in > self.max_in_channels:
            errs.append(
                f"pass reads {c_in} channels > {self.max_in_channels} "
                f"({self.max_textures} textures x {self.channels_per_texture})")
        s = self.samples(kernel, c_in)
        if s > self.max_samples:
            errs.append(
                f"pass needs {s} samples/pixel "
                f"({kernel}x{kernel} x {math.ceil(c_in / 4)} textures) "
                f"> budget {self.max_samples}")
        return errs


PI_ZERO_BUDGET = ShaderBudget()  # the paper's Raspberry Pi Zero 2 W numbers


# ---------------------------------------------------------------------------
# Encoder specification
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One conv layer = ceil(c_out/4) shader passes over the same input."""

    kernel: int
    stride: int
    c_in: int
    c_out: int
    activation: str = "relu"    # relu | sigmoid | linear

    @property
    def n_passes(self) -> int:
        return math.ceil(self.c_out / 4)


@dataclasses.dataclass(frozen=True)
class MiniConvSpec:
    layers: tuple[LayerSpec, ...]
    budget: ShaderBudget = PI_ZERO_BUDGET

    @property
    def k_out(self) -> int:
        return self.layers[-1].c_out

    @property
    def n_stride2(self) -> int:
        return sum(1 for l in self.layers if l.stride == 2)

    @property
    def total_passes(self) -> int:
        from repro.core.passplan import count_passes  # lazy: avoids cycle
        return count_passes(self)

    def validate(self) -> None:
        errs: list[str] = []
        for i, l in enumerate(self.layers):
            for e in self.budget.check_pass(l.kernel, l.c_in):
                errs.append(f"layer {i}: {e}")
            if i and l.c_in != self.layers[i - 1].c_out:
                errs.append(f"layer {i}: c_in {l.c_in} != previous c_out "
                            f"{self.layers[i - 1].c_out}")
        if errs:
            raise ValueError("MiniConvSpec violates shader budget:\n  " +
                             "\n  ".join(errs))

    def plan(self, h: int, w: Optional[int] = None, *,
             batch: Optional[int] = None):
        """Lower this spec onto an input size (see ``core.passplan``);
        ``batch=B`` additionally checks the fused kernel's B-frame VMEM
        residency against the budget."""
        from repro.core.passplan import build_pass_plan  # lazy: avoids cycle
        return build_pass_plan(self, h, w, batch=batch)

    def out_spatial(self, x: int) -> int:
        from repro.core.passplan import out_spatial_chain
        return out_spatial_chain(x, (l.stride for l in self.layers))

    def feature_bytes(self, x: int) -> int:
        """Transmitted feature bytes for an X-by-X input (uint8 wire)."""
        return self.plan(x).feature_bytes

    def flops_per_frame(self, x: int) -> int:
        return self.plan(x).flops_per_frame


def standard_spec(c_in: int = 12, k: int = 4, *, n_stride2: int = 3,
                  hidden: int = 16,
                  budget: ShaderBudget = PI_ZERO_BUDGET) -> MiniConvSpec:
    """The encoder family used in the paper's experiments.

    Defaults give the K=4, n=3 Pi-Zero configuration: three stride-2 layers,
    4x4 then 3x3 kernels, every pass within the 64-sample budget:
      4x4 x ceil(12/4)=3 textures = 48 samples; 3x3 x 4 = 36 samples.
    """
    layers = [LayerSpec(4, 2, c_in, hidden)]
    for _ in range(n_stride2 - 2):
        layers.append(LayerSpec(3, 2, hidden, hidden))
    layers.append(LayerSpec(3, 2, hidden, k, activation="sigmoid"))
    spec = MiniConvSpec(tuple(layers), budget)
    spec.validate()
    return spec


# ---------------------------------------------------------------------------
# init / apply
# ---------------------------------------------------------------------------

def miniconv_init(key, spec: MiniConvSpec, *, dtype=jnp.float32):
    kg = KeyGen(key)
    return {f"layer{i}": conv2d_init(kg(), l.kernel, l.kernel, l.c_in, l.c_out,
                                     dtype=dtype)
            for i, l in enumerate(spec.layers)}


_ACTS: dict[str, Callable] = {
    "relu": jax.nn.relu,
    "sigmoid": jax.nn.sigmoid,
    "linear": lambda x: x,
}


def _normalize_mode(use_kernel) -> str:
    """Resolve ``use_kernel`` to a kernel execution tier via the backend
    registry (``repro.core.backends``).  ``True`` keeps its historical
    meaning (the per-pass reference oracle); unknown strings raise with the
    full list of registered backends instead of falling through."""
    from repro.core.backends import get_backend  # lazy: avoids cycle
    return get_backend(use_kernel).mode


def miniconv_apply(params, spec: MiniConvSpec, x, *,
                   use_kernel=False, tile_h: int = 8, plan=None,
                   head=None, head_act: str = "relu", interpret=None,
                   stream_chunk=None):
    """x: (B, H, W, C_in) float in [0,1] -> (B, H', W', K).

    Execution modes (``use_kernel``):

    * ``False`` / ``"xla"``  — XLA SAME convs (the training path).
    * ``"per_pass"``         — legacy reference: one ``pallas_call`` per
      :class:`~repro.core.passplan.ShaderPass` (the shader oracle).
    * ``"grouped"``          — one ``pallas_call`` per layer; output-group is
      a grid dimension so the input row is loaded once per row and reused
      across groups.
    * ``"fused"``            — the whole :class:`~repro.core.passplan.PassPlan`
      as ONE ``pallas_call``: layers chained through VMEM-resident
      intermediates, ``tile_h`` output rows per grid step.

    ``use_kernel=True`` is accepted as an alias for ``"per_pass"``.
    ``plan`` lets callers that already compiled the PassPlan (e.g.
    ``core.split.make_miniconv_split``) reuse it instead of re-lowering
    per call; it must match the input's spatial size.

    ``head`` (dense params dict ``{"kernel": (F, D)[, "bias": (D,)]}`` or a
    ``(w, b)`` tuple) appends the server-side flatten + dense projection and
    makes the return value ``(features, head_act(flat @ w + b))``.  In
    ``"fused"`` mode the projection runs INSIDE the kernel as a per-tile
    epilogue (see ``kernels.miniconv_pass.miniconv_encoder``); other modes
    compute the same epilogue with XLA so training and deployment share one
    call signature.

    ``interpret`` forces Pallas interpret (True) or compiled (False)
    execution for the kernel tiers; ``None`` keeps the environment-derived
    default (interpret off-TPU, compiled on TPU or with
    ``REPRO_PALLAS_COMPILE=1``).

    ``stream_chunk`` (fused tiers only) streams the micro-batch through
    VMEM in ``stream_chunk``-frame chunks
    (:func:`~repro.kernels.miniconv_pass.miniconv_encoder_stream`),
    lifting the batch-must-fit-VMEM cap.  ``use_kernel="fused+stream"``
    selects streaming with ``stream_chunk`` defaulting to the plan's
    ``max_safe_batch``; batches within one chunk fall through to the plain
    fused launch, so results are bitwise identical either way.
    """
    from repro.core.backends import get_backend  # lazy: avoids cycle
    backend = get_backend(use_kernel)
    mode = backend.mode
    if head is not None:
        hw, hb = ((head["kernel"], head.get("bias"))
                  if isinstance(head, dict) else head)
    if mode == "fused":
        from repro.kernels.miniconv_pass import (miniconv_encoder,
                                                 miniconv_encoder_stream)
        if plan is None:
            plan = spec.plan(x.shape[1], x.shape[2])
        elif (plan.in_h, plan.in_w) != (x.shape[1], x.shape[2]):
            raise ValueError(
                f"plan was built for {(plan.in_h, plan.in_w)} input but got "
                f"{x.shape[1:3]}; rebuild with spec.plan(h, w)")
        ws = [params[f"layer{i}"]["kernel"] for i in range(len(spec.layers))]
        bs = [params[f"layer{i}"]["bias"] for i in range(len(spec.layers))]
        if backend.streamed and stream_chunk is None:
            hp = (plan.head(hw.shape[-1], activation=head_act)
                  if head is not None else None)
            stream_chunk = max(1, plan.max_safe_batch(head=hp,
                                                      tile_h=tile_h))
        if stream_chunk is not None:
            return miniconv_encoder_stream(
                x, ws, bs, plan, chunk_b=stream_chunk, tile_h=tile_h,
                head_w=hw if head is not None else None,
                head_b=hb if head is not None else None,
                head_act=head_act, interpret=interpret)
        if head is not None:
            return miniconv_encoder(x, ws, bs, plan, tile_h=tile_h,
                                    head_w=hw, head_b=hb, head_act=head_act,
                                    interpret=interpret)
        return miniconv_encoder(x, ws, bs, plan, tile_h=tile_h,
                                interpret=interpret)
    if mode in ("per_pass", "grouped"):
        from repro.kernels.ops import miniconv_layer  # lazy: avoids cycles
    for i, l in enumerate(spec.layers):
        p = params[f"layer{i}"]
        if mode == "xla":
            x = conv2d(p, x, stride=l.stride, padding="SAME")
        else:
            x = miniconv_layer(x, p["kernel"], p["bias"], stride=l.stride,
                               fused_groups=(mode == "grouped"),
                               interpret=interpret)
        x = _ACTS[l.activation](x)
    if head is not None:
        z = x.reshape(x.shape[0], -1) @ hw
        if hb is not None:
            z = z + hb
        return x, _ACTS[head_act](z)
    return x


def miniconv_feature_shape(spec: MiniConvSpec, h: int, w: int) -> tuple:
    return spec.plan(h, w).feature_shape
