"""Pass-plan IR: the compiled form of a MiniConv encoder.

The paper (§3) compiles a small conv encoder into an ordered sequence of
fragment-shader passes, each subject to the embedded-GPU constraint model:

* a pass renders ONE RGBA target      -> ``ShaderPass.out_lo/out_hi``
  (<= 4 output channels);
* a pass binds <= 8 input textures    -> ``ShaderPass.texture_bindings``
  (4 packed channels per texture, so C_in <= 32);
* a pass has a per-pixel sampling
  budget (64 on the Pi Zero 2 W)      -> ``ShaderPass.samples``
  = k_h * k_w * ceil(C_in / 4).

:class:`PassPlan` makes that compiled schedule a first-class object: it
lowers a :class:`~repro.core.miniconv.MiniConvSpec` plus a concrete input
size into per-layer records (:class:`LayerPlan`: spatial shapes, SAME
padding, channel-group count) and a flat ordered pass list
(:class:`ShaderPass`: texture bindings, kernel slice, stride, activation,
output group, per-pass sample count).  Every pass is checked against the
:class:`~repro.core.miniconv.ShaderBudget` at *plan build time*, so an
un-buildable plan never reaches a kernel.

The plan is the single source of truth for derived quantities that were
previously re-computed (inconsistently — ceil vs floor) in several places:

* pass count             -> ``PassPlan.total_passes`` / :func:`count_passes`
* output spatial shape   -> ``PassPlan.out_h/out_w`` / :func:`out_spatial_chain`
* transmitted bytes      -> ``PassPlan.feature_bytes`` (uint8 wire)
* FLOPs per frame        -> ``PassPlan.flops_per_frame``

``MiniConvSpec.out_spatial/feature_bytes/flops_per_frame``,
``core.wire.feature_bytes``, ``core.latency.SplitConfig.feature_bytes`` and
the ``benchmarks/roofline_table --miniconv`` table all re-derive from here.

The Pallas execution paths consume the plan directly:
``repro.kernels.miniconv_pass.miniconv_encoder`` executes the whole plan as
ONE fused kernel (layers chained through VMEM-resident intermediates,
``TILE_H`` output rows per grid step), while the legacy per-pass kernel
executes one ``pallas_call`` per :class:`ShaderPass` and serves as the
reference oracle.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional, Sequence

from repro.core.miniconv import (LayerSpec, MiniConvSpec, ShaderBudget,
                                 PI_ZERO_BUDGET)


# ---------------------------------------------------------------------------
# Spatial primitives (THE ceil rule — everything else derives from these)
# ---------------------------------------------------------------------------

def out_size(x: int, stride: int) -> int:
    """Output side of a SAME conv: ceil(x / stride)."""
    return -(-x // stride)


def out_spatial_chain(x: int, strides: Iterable[int]) -> int:
    """Spatial side after a chain of SAME convs with the given strides."""
    for s in strides:
        x = out_size(x, s)
    return x


def same_pads(size: int, kernel: int, stride: int) -> tuple[int, int]:
    """(lo, hi) zero padding so a VALID conv reproduces XLA's SAME conv."""
    total = max((out_size(size, stride) - 1) * stride + kernel - size, 0)
    return total // 2, total - total // 2


def count_passes(spec: MiniConvSpec) -> int:
    """Total shader passes for a spec (spatial-size independent)."""
    return sum(-(-l.c_out // 4) for l in spec.layers)


def _round4(c: int) -> int:
    return -(-c // 4) * 4


# TPU VMEM per core (~16 MB).  The fused kernel keeps the WHOLE micro-batch
# input plus the final layer's padded intermediate resident on-chip, so the
# deployable batch size is bounded by this budget (see
# ``PassPlan.vmem_bytes`` / ``max_safe_batch``).
DEFAULT_VMEM_LIMIT = 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# IR records
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One conv layer lowered onto a concrete input size."""

    index: int
    kernel: int
    stride: int
    activation: str
    c_in: int
    c_out: int
    in_h: int
    in_w: int
    out_h: int
    out_w: int
    pad_top: int
    pad_bottom: int
    pad_left: int
    pad_right: int

    @property
    def n_groups(self) -> int:
        return -(-self.c_out // 4)

    @property
    def c_in_pad(self) -> int:
        return _round4(self.c_in)

    @property
    def c_out_pad(self) -> int:
        return _round4(self.c_out)

    @property
    def padded_in_h(self) -> int:
        return self.in_h + self.pad_top + self.pad_bottom

    @property
    def padded_in_w(self) -> int:
        return self.in_w + self.pad_left + self.pad_right

    @property
    def flops(self) -> int:
        return (2 * self.out_h * self.out_w * self.kernel * self.kernel
                * self.c_in * self.c_out)


@dataclasses.dataclass(frozen=True)
class ShaderPass:
    """One fragment-shader pass: the unit the paper's compiler emits."""

    layer: int                  # owning layer index
    group: int                  # output-group index within the layer
    kernel: int
    stride: int
    activation: str
    c_in: int
    out_lo: int                 # output channel slice [out_lo, out_hi)
    out_hi: int                 # out_hi - out_lo <= 4 (one RGBA target)
    out_h: int
    out_w: int

    @property
    def texture_bindings(self) -> tuple[tuple[int, int], ...]:
        """Input channel ranges packed 4-per-texture, as bound by the pass."""
        return tuple((lo, min(lo + 4, self.c_in))
                     for lo in range(0, self.c_in, 4))

    @property
    def in_textures(self) -> int:
        return len(self.texture_bindings)

    @property
    def samples(self) -> int:
        """Texture samples per output pixel (the paper's budgeted quantity)."""
        return self.kernel * self.kernel * self.in_textures

    @property
    def flops(self) -> int:
        return (2 * self.out_h * self.out_w * self.kernel * self.kernel
                * self.c_in * (self.out_hi - self.out_lo))


@dataclasses.dataclass(frozen=True)
class HeadPlan:
    """The server-side linear projection fused into the encoder epilogue.

    ``repro.kernels.miniconv_pass.miniconv_encoder`` executes this as a
    per-tile matmul accumulated in VMEM (the ``head_w``/``head_b``
    arguments); ``in_dim`` is the flattened feature count of the owning
    :class:`PassPlan` and is validated against it at build time.
    """

    in_dim: int
    out_dim: int
    activation: str = "relu"

    @property
    def flops(self) -> int:
        return 2 * self.in_dim * self.out_dim

    @property
    def param_bytes(self) -> int:
        return 4 * (self.in_dim + 1) * self.out_dim


@dataclasses.dataclass(frozen=True)
class PassPlan:
    """An ordered, budget-checked shader-pass schedule for one input size."""

    spec: MiniConvSpec
    in_h: int
    in_w: int
    layers: tuple[LayerPlan, ...]
    passes: tuple[ShaderPass, ...]
    budget: ShaderBudget = PI_ZERO_BUDGET

    # ---- derived truths ---------------------------------------------------
    @property
    def out_h(self) -> int:
        return self.layers[-1].out_h

    @property
    def out_w(self) -> int:
        return self.layers[-1].out_w

    @property
    def k_out(self) -> int:
        return self.layers[-1].c_out

    @property
    def feature_shape(self) -> tuple[int, int, int]:
        return (self.out_h, self.out_w, self.k_out)

    @property
    def total_passes(self) -> int:
        return len(self.passes)

    @property
    def feature_bytes(self) -> int:
        """Bytes of the transmitted K-channel feature map (uint8 wire)."""
        return self.out_h * self.out_w * self.k_out

    @property
    def flat_features(self) -> int:
        """Flattened feature count — the fused head's input width."""
        return self.out_h * self.out_w * self.k_out

    @property
    def flops_per_frame(self) -> int:
        return sum(p.flops for p in self.passes)

    def head(self, out_dim: int, activation: str = "relu") -> HeadPlan:
        """Plan the fused projection epilogue for this feature shape."""
        if out_dim <= 0:
            raise ValueError(f"head out_dim must be positive, got {out_dim}")
        return HeadPlan(in_dim=self.flat_features, out_dim=out_dim,
                        activation=activation)

    def flops_per_batch(self, batch: int,
                        head: Optional[HeadPlan] = None) -> int:
        """FLOPs of one fused launch over a ``batch``-frame micro-batch."""
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        per_frame = self.flops_per_frame
        if head is not None:
            if head.in_dim != self.flat_features:
                raise ValueError(
                    f"head.in_dim {head.in_dim} != plan.flat_features "
                    f"{self.flat_features}")
            per_frame += head.flops
        return batch * per_frame

    @property
    def max_pass_samples(self) -> int:
        return max(p.samples for p in self.passes)

    # ---- VMEM residency of the fused kernel --------------------------------
    def _vmem_terms(self, *, head: Optional[HeadPlan] = None,
                    tile_h: int = 8, itemsize: int = 4) -> tuple[int, int]:
        """(fixed_bytes, per_frame_bytes) of the fused-kernel VMEM residency.

        Mirrors the allocation pattern of
        ``repro.kernels.miniconv_pass.miniconv_encoder``: the whole-batch
        padded input block (scales with B), the final layer's padded-input
        scratch, per-layer padded weights/biases, one output tile, and —
        with a fused head — the tiled lane-padded head weight plus the
        projection scratch.  An estimate (the compiler adds its own
        spills), but affine in batch, which is what the deployability
        check needs.
        """
        first, last = self.layers[0], self.layers[-1]
        tile_h = max(1, min(tile_h, self.out_h))
        n_tiles = -(-self.out_h // tile_h)
        rows_need_max = (n_tiles * tile_h - 1) * last.stride + last.kernel
        scratch_rows = max(last.padded_in_h, rows_need_max)
        x0_rows = scratch_rows if len(self.layers) == 1 \
            else first.padded_in_h
        per_frame = x0_rows * first.padded_in_w * first.c_in_pad * itemsize
        fixed = tile_h * last.out_w * last.c_out_pad * itemsize  # out tile
        if len(self.layers) > 1:
            fixed += (scratch_rows * last.padded_in_w * last.c_in_pad
                      * 4)                                       # fp32 scratch
        for l in self.layers:
            fixed += (l.kernel * l.kernel * l.c_in_pad * l.c_out_pad
                      + l.c_out_pad) * itemsize                  # weights+bias
        if head is not None:
            if head.in_dim != self.flat_features:
                raise ValueError(
                    f"head.in_dim {head.in_dim} != plan.flat_features "
                    f"{self.flat_features}")
            d_pad = -(-head.out_dim // 128) * 128   # lane-padded for the MXU
            tile_flat = tile_h * last.out_w * last.c_out_pad
            fixed += n_tiles * tile_flat * d_pad * itemsize   # tiled weight
            fixed += d_pad * (4 + 2 * itemsize)    # z scratch + bias + z out
        return fixed, per_frame

    def vmem_bytes(self, batch: int = 1, *, head: Optional[HeadPlan] = None,
                   tile_h: int = 8, itemsize: int = 4) -> int:
        """Estimated VMEM bytes of ONE fused launch over a B-frame batch."""
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        fixed, per_frame = self._vmem_terms(head=head, tile_h=tile_h,
                                            itemsize=itemsize)
        return fixed + batch * per_frame

    def max_safe_batch(self, *, head: Optional[HeadPlan] = None,
                       tile_h: int = 8, itemsize: int = 4,
                       vmem_limit: int = DEFAULT_VMEM_LIMIT) -> int:
        """Largest micro-batch whose fused launch fits the VMEM budget
        (0 when even the batch-independent residency exceeds it)."""
        fixed, per_frame = self._vmem_terms(head=head, tile_h=tile_h,
                                            itemsize=itemsize)
        return max(0, (vmem_limit - fixed) // per_frame)

    def check_batch(self, batch: int, *, head: Optional[HeadPlan] = None,
                    tile_h: int = 8, itemsize: int = 4,
                    vmem_limit: int = DEFAULT_VMEM_LIMIT) -> None:
        """Raise if a B-frame fused launch exceeds the VMEM budget."""
        need = self.vmem_bytes(batch, head=head, tile_h=tile_h,
                               itemsize=itemsize)
        if need > vmem_limit:
            raise ValueError(
                f"micro-batch {batch} needs ~{need / 2**20:.2f} MiB VMEM "
                f"> budget {vmem_limit / 2**20:.2f} MiB for a "
                f"{self.in_h}x{self.in_w} input; max safe batch is "
                f"{self.max_safe_batch(head=head, tile_h=tile_h, itemsize=itemsize, vmem_limit=vmem_limit)} "
                f"(split the batch or lower the input size)")

    def validate(self) -> None:
        errs: list[str] = []
        for p in self.passes:
            for e in self.budget.check_pass(p.kernel, p.c_in):
                errs.append(f"layer {p.layer} pass {p.group}: {e}")
        if errs:
            raise ValueError("PassPlan violates shader budget:\n  " +
                             "\n  ".join(errs))


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

def build_pass_plan(spec: MiniConvSpec, h: int, w: Optional[int] = None, *,
                    validate: bool = True, batch: Optional[int] = None,
                    tile_h: int = 8,
                    vmem_limit: int = DEFAULT_VMEM_LIMIT) -> PassPlan:
    """Lower ``spec`` applied to an (h, w) input into a :class:`PassPlan`.

    Raises ``ValueError`` at build time if any emitted pass exceeds the
    spec's :class:`ShaderBudget` — the kernel layer can assume every plan it
    receives is deployable.  With ``batch=B`` the plan is additionally
    checked against the fused kernel's VMEM residency model: the WHOLE
    B-frame micro-batch input must fit the ``vmem_limit`` budget
    (:meth:`PassPlan.check_batch`), so an un-launchable micro-batch is
    rejected before it reaches a compiled kernel.
    """
    w = h if w is None else w
    layers: list[LayerPlan] = []
    passes: list[ShaderPass] = []
    cur_h, cur_w = h, w
    for i, l in enumerate(spec.layers):
        oh, ow = out_size(cur_h, l.stride), out_size(cur_w, l.stride)
        pt, pb = same_pads(cur_h, l.kernel, l.stride)
        pl_, pr = same_pads(cur_w, l.kernel, l.stride)
        layers.append(LayerPlan(index=i, kernel=l.kernel, stride=l.stride,
                                activation=l.activation, c_in=l.c_in,
                                c_out=l.c_out, in_h=cur_h, in_w=cur_w,
                                out_h=oh, out_w=ow, pad_top=pt, pad_bottom=pb,
                                pad_left=pl_, pad_right=pr))
        for g, lo in enumerate(range(0, l.c_out, 4)):
            passes.append(ShaderPass(layer=i, group=g, kernel=l.kernel,
                                     stride=l.stride, activation=l.activation,
                                     c_in=l.c_in, out_lo=lo,
                                     out_hi=min(lo + 4, l.c_out),
                                     out_h=oh, out_w=ow))
        cur_h, cur_w = oh, ow
    plan = PassPlan(spec=spec, in_h=h, in_w=w, layers=tuple(layers),
                    passes=tuple(passes), budget=spec.budget)
    if validate:
        plan.validate()
    if batch is not None:
        plan.check_batch(batch, tile_h=tile_h, vmem_limit=vmem_limit)
    return plan


__all__ = ["DEFAULT_VMEM_LIMIT", "HeadPlan", "LayerPlan", "PassPlan",
           "ShaderPass", "build_pass_plan", "count_passes", "out_size",
           "out_spatial_chain", "same_pads"]
