"""Closed-loop decision-latency model (paper §4.2) and its generalisation.

Paper's simplified model: link bandwidth B (bits/s), square input of side X,
n stride-2 encoder layers, per-frame on-device encode time j, K transmitted
channels; both pipelines send uncompressed uint8 buffers:

  server-only payload : 4 X^2 bytes (RGBA frame)
  split payload       : K (X/2^n)^2 bytes

Split inference wins iff  B < 32 X^2 (1 - K / (4 * 2^(2n))) / j.

``decision_latency_*`` add the measurable constant terms (server compute,
action return, fixed network RTT) used by the end-to-end simulator in
``repro.serving``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LinkModel:
    bandwidth_bps: float            # shaped link bandwidth, bits/s
    rtt_s: float = 0.004            # propagation round trip (both pipelines)

    def tx_time(self, payload_bytes: float) -> float:
        return 8.0 * payload_bytes / self.bandwidth_bps


@dataclasses.dataclass(frozen=True)
class SplitConfig:
    x_size: int                     # input side X
    n_stride2: int                  # n
    k_channels: int                 # K
    encode_time_s: float            # j

    @property
    def frame_bytes(self) -> int:
        return 4 * self.x_size ** 2

    @property
    def feature_bytes(self) -> int:
        # PassPlan spatial rule: ceil per stride-2 layer (matches the real
        # feature shape; the continuous X/2^n model below is the paper's
        # closed-form approximation of this).
        from repro.core.passplan import out_spatial_chain
        return self.k_channels * out_spatial_chain(
            self.x_size, (2,) * self.n_stride2) ** 2


def break_even_bandwidth(cfg: SplitConfig) -> float:
    """Bits/s below which the split pipeline has lower decision latency.

    Derivation (paper): latency_server_only = 32 X^2 / B;
    latency_split = j + 8 K (X/2^n)^2 / B.  Setting them equal:
      B* = (32 X^2 - 8 K X^2 / 2^(2n)) / j = 32 X^2 (1 - K/(4*2^(2n))) / j.
    """
    x, n, k, j = (cfg.x_size, cfg.n_stride2, cfg.k_channels,
                  cfg.encode_time_s)
    return 32.0 * x * x * (1.0 - k / (4.0 * 2.0 ** (2 * n))) / j


def decision_latency_server_only(cfg: SplitConfig, link: LinkModel, *,
                                 server_time_s: float = 0.0,
                                 action_bytes: int = 64) -> float:
    return (link.tx_time(cfg.frame_bytes) + server_time_s
            + link.tx_time(action_bytes) + link.rtt_s)


def decision_latency_split(cfg: SplitConfig, link: LinkModel, *,
                           server_time_s: float = 0.0,
                           action_bytes: int = 64) -> float:
    return (cfg.encode_time_s + link.tx_time(cfg.feature_bytes)
            + server_time_s + link.tx_time(action_bytes) + link.rtt_s)


def paper_pi_zero_config() -> SplitConfig:
    """Figure 3b's configuration: X=400, n=3, j~=0.1s, K=4 => B* ~= 50.4 Mb/s."""
    return SplitConfig(x_size=400, n_stride2=3, k_channels=4,
                       encode_time_s=0.1)


# ---------------------------------------------------------------------------
# Generalisation to the pod-boundary transformer split (DESIGN.md §2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PodSplitConfig:
    """Split a transformer at a layer boundary across the inter-pod link."""

    hidden_bytes_full: int          # boundary activation bytes, fp32
    wire_itemsize: float            # codec bytes/elem (1.0 for int8)
    edge_time_s: float              # time to run the edge-side stage
    raw_bytes: int                  # what would cross without the split
                                    # (e.g. full input or fp32 activation)

    @property
    def wire_bytes(self) -> float:
        return self.hidden_bytes_full * self.wire_itemsize / 4.0


def pod_break_even_bandwidth(cfg: PodSplitConfig) -> float:
    saved_bytes = cfg.raw_bytes - cfg.wire_bytes
    if saved_bytes <= 0:
        return 0.0
    return 8.0 * saved_bytes / cfg.edge_time_s
