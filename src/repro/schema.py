"""Shared schema-versioning helpers for serialised config dataclasses.

Every long-lived JSON schema in the repo (`DeploymentConfig`, `Scenario`,
`TunedPlan`, `ShapingConfig`) writes a ``version`` field and refuses
versions it cannot read via :func:`check_version`, raising the typed
:class:`SchemaVersionError` — a ``ValueError`` subclass so existing
``pytest.raises(ValueError, match="version")`` callers keep working —
instead of silently dropping unknown fields.
"""

from __future__ import annotations

from typing import Sequence

__all__ = ["SchemaVersionError", "check_version"]


class SchemaVersionError(ValueError):
    """A serialised schema names a version this build cannot read."""


def check_version(kind: str, version, readable: Sequence[int]) -> int:
    """Validate a loaded dict's schema version; return it on success."""
    if version not in tuple(readable):
        raise SchemaVersionError(
            f"{kind} schema version {version!r} is not readable by this "
            f"build (readable: {', '.join(str(v) for v in readable)}); "
            "refusing to load rather than silently dropping fields"
        )
    return version
