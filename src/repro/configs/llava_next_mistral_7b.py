"""llava-next-mistral-7b  [vlm]  [hf:llava-hf/llava-v1.6-mistral-7b-hf]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000 — Mistral-7B
language backbone; the ViT/SigLIP vision tower + projector is a STUB
(``input_specs`` provides anyres patch embeddings: 5 tiles x 576 = 2880
vision tokens prepended to the text sequence).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="llava-next-mistral-7b",
    family="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    pattern=("attn",),
    n_pattern=32,
    rope_theta=1_000_000.0,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=False,
    n_frontend_tokens=2880,   # anyres: 4 tiles + base, 576 patches each
)
