"""recurrentgemma-9b  [hybrid]  [arXiv:2402.19427 (Griffin); RG-9B card]

38L d_model=4096 16H (GQA kv=1, i.e. MQA) d_ff=12288 vocab=256000 —
RG-LRU + local attention in a 1:2 (attn : recurrent) block ratio:
pattern (rec, rec, swa) x 12 + (rec, rec), local window 2048.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    source="arXiv:2402.19427",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256_000,
    pattern=("rec", "rec", "swa"),
    n_pattern=12,
    remainder=("rec", "rec"),
    sliding_window=2048,
    rnn_width=4096,
    rope_theta=10_000.0,
    mlp="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
    logit_softcap=30.0,
)
