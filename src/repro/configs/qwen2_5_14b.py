"""qwen2.5-14b  [dense]  [hf:Qwen/Qwen2.5-0.5B card family — 14B variant]

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064 — GQA, QKV bias.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen2.5-14b",
    family="dense",
    source="hf:Qwen/Qwen2.5-0.5B (14B card)",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab=152064,
    pattern=("attn",),
    n_pattern=48,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=False,
)
