"""minitron-8b  [dense]  [arXiv:2407.14679 (pruned Nemotron-4 15B)]

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000 — squared-ReLU
MLP and LayerNorm per the Nemotron family, untied embeddings.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="minitron-8b",
    family="dense",
    source="arXiv:2407.14679",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=256_000,
    pattern=("attn",),
    n_pattern=32,
    rope_theta=10_000.0,
    mlp="relu2",
    norm="layernorm",
    tie_embeddings=False,
)
