"""whisper-medium  [audio]  [arXiv:2212.04356]

24L (decoder) + 24L (encoder) d_model=1024 16H (kv=16) d_ff=4096
vocab=51865 — encoder-decoder; the mel+conv frontend is a STUB
(``input_specs`` provides 1500 precomputed frame embeddings).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-medium",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    pattern=("attn",),
    n_pattern=24,
    qkv_bias=True,
    mlp="gelu",
    norm="layernorm",
    tie_embeddings=True,
    n_frontend_tokens=1500,
    n_encoder_layers=24,
    # kv=16 divides the model axis: head-sharded cache + DUS decode is
    # already gather-free (see qwen2-moe note)
    masked_cache_update=False,
)
