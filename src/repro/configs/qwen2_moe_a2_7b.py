"""qwen2-moe-a2.7b  [moe]  [hf:Qwen/Qwen1.5-MoE-A2.7B]

24L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=151936,
MoE 60 routed experts top-4 + 4 shared experts (fused as one 4x-width
SwiGLU) behind a sigmoid shared-expert gate.
"""
from repro.models.config import ArchConfig, MoEArch

CONFIG = ArchConfig(
    arch_id="qwen2-moe-a2.7b",
    family="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=151936,
    pattern=("attn",),
    n_pattern=24,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    moe=MoEArch(n_experts=60, top_k=4, n_shared_experts=4,
                shared_expert_gate=True),
    # kv=16 divides the model axis: the head-sharded cache + DUS decode
    # is already gather-free; the masked/seq-sharded path would regress
    # it (EXPERIMENTS.md §Roofline-optimised)
    masked_cache_update=False,
)
