"""qwen3-0.6b  [dense]  [hf:Qwen/Qwen3-8B family — 0.6B variant]

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936 — qk_norm, GQA.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen3-0.6b",
    family="dense",
    source="hf:Qwen/Qwen3-8B (0.6B card)",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab=151936,
    pattern=("attn",),
    n_pattern=28,
    qk_norm=True,
    rope_theta=1_000_000.0,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
)
