"""Assigned architecture configs (one module per architecture).

Every config cites its source in ``ArchConfig.source``.  ``get_config``
accepts the dashed public arch id (``--arch qwen3-0.6b``).
"""
from __future__ import annotations

from repro.models.config import ArchConfig, SHAPES, ShapeConfig

from repro.configs.qwen3_0_6b import CONFIG as _qwen3_0_6b
from repro.configs.recurrentgemma_9b import CONFIG as _recurrentgemma_9b
from repro.configs.qwen2_5_14b import CONFIG as _qwen2_5_14b
from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4_scout
from repro.configs.mamba2_130m import CONFIG as _mamba2_130m
from repro.configs.whisper_medium import CONFIG as _whisper_medium
from repro.configs.minitron_8b import CONFIG as _minitron_8b
from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen2_moe
from repro.configs.llava_next_mistral_7b import CONFIG as _llava_next
from repro.configs.llama3_8b import CONFIG as _llama3_8b

ARCHS: dict[str, ArchConfig] = {
    c.arch_id: c
    for c in [
        _qwen3_0_6b, _recurrentgemma_9b, _qwen2_5_14b, _llama4_scout,
        _mamba2_130m, _whisper_medium, _minitron_8b, _qwen2_moe,
        _llava_next, _llama3_8b,
    ]
}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


__all__ = ["ARCHS", "SHAPES", "ShapeConfig", "ArchConfig", "get_config"]
