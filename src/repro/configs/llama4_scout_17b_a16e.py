"""llama4-scout-17b-a16e  [moe]  [hf:meta-llama/Llama-4-Scout-17B-16E]

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts
top-1 routing + 1 shared expert per layer (early-fusion multimodal in the
full model; the text backbone is what is assigned here).
"""
from repro.models.config import ArchConfig, MoEArch

CONFIG = ArchConfig(
    arch_id="llama4-scout-17b-a16e",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    pattern=("attn",),
    n_pattern=48,
    rope_theta=500_000.0,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=False,
    moe=MoEArch(n_experts=16, top_k=1, n_shared_experts=1),
)
