"""llama3-8b  [dense]  [arXiv:2407.21783]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256 — GQA, 128k vocab.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama3-8b",
    family="dense",
    source="arXiv:2407.21783",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    pattern=("attn",),
    n_pattern=32,
    rope_theta=500_000.0,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=False,
)
