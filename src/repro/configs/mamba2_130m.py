"""mamba2-130m  [ssm]  [arXiv:2405.21060 (SSD / state-space duality)]

24L d_model=768, attention-free, vocab=50280, ssm_state=128.
"""
from repro.models.config import ArchConfig, SSMArch

CONFIG = ArchConfig(
    arch_id="mamba2-130m",
    family="ssm",
    source="arXiv:2405.21060",
    n_layers=24,
    d_model=768,
    n_heads=1,        # unused (attention-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,           # no MLP — the SSM block is the mixer
    vocab=50280,
    pattern=("ssm",),
    n_pattern=24,
    mlp="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    ssm=SSMArch(d_state=128, head_dim=64, expand=2, n_groups=1,
                conv_width=4, chunk=256),
)
