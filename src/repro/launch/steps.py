"""Step-function builders: (arch x shape x mesh) -> jit-able step with
explicit in/out shardings, plus the abstract inputs to lower it with.

One bundle per shape kind:

  train_4k     -> train_step(params, opt_state, batch) (loss+grad+adam)
  prefill_32k  -> prefill_step(params, batch) -> last-position logits
  decode_32k / long_500k -> serve_step(params, token, caches, index)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.models import sharding as shd
from repro.models.config import ArchConfig
from repro.models.registry import (abstract_params, build_model,
                                   input_specs_for, long_ctx)
from repro.train.optimizer import Optimizer, OptState, adamw


@dataclasses.dataclass
class StepBundle:
    name: str
    fn: Callable
    args: tuple                 # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()

    def lower(self, mesh: Mesh):
        with mesh:
            jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                             out_shardings=self.out_shardings,
                             donate_argnums=self.donate_argnums)
            return jitted.lower(*self.args)


# overrides consumed by the step builder rather than ArchConfig
STEP_KEYS = ("microbatches", "param_mode")


def _apply_overrides(cfg: ArchConfig, overrides: Optional[dict]):
    if not overrides:
        return cfg, {}
    step_opts = {k: v for k, v in overrides.items() if k in STEP_KEYS}
    arch_over = {k: v for k, v in overrides.items() if k not in STEP_KEYS}
    return (dataclasses.replace(cfg, **arch_over) if arch_over else cfg,
            step_opts)


def _replicated_like(tree, mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def make_step(arch_id: str, shape_id: str, mesh: Mesh, *,
              overrides: Optional[dict] = None,
              optimizer: Optional[Optimizer] = None) -> StepBundle:
    shape = SHAPES[shape_id]
    if shape.kind == "train":
        return make_train_step(arch_id, shape_id, mesh, overrides=overrides,
                               optimizer=optimizer)
    if shape.kind == "prefill":
        return make_prefill_step(arch_id, shape_id, mesh,
                                 overrides=overrides)
    return make_decode_step(arch_id, shape_id, mesh, overrides=overrides)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def make_train_step(arch_id: str, shape_id: str, mesh: Mesh, *,
                    overrides: Optional[dict] = None,
                    optimizer: Optional[Optimizer] = None) -> StepBundle:
    cfg, step_opts = _apply_overrides(get_config(arch_id), overrides)
    model = build_model(cfg)
    optimizer = optimizer or adamw(3e-4, clip_norm=1.0)

    shape = SHAPES[shape_id]
    n_micro = int(step_opts.get("microbatches", 1))

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: model.loss(p, batch, remat=cfg.remat),
            has_aux=True)(params)

    def train_step(params, opt_state, batch):
        with shd.activation_sharding(
                mesh, shape.global_batch // max(n_micro, 1)):
            if n_micro <= 1:
                (loss, aux), grads = grads_of(params, batch)
            else:
                # §Perf: gradient accumulation — peak activation memory
                # scales with the microbatch, grads/optimizer unchanged
                micro = jax.tree.map(
                    lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                        + x.shape[1:]), batch)

                def acc(carry, mb):
                    (l, a), g = grads_of(params, mb)
                    return jax.tree.map(jnp.add, carry, ((l, a), g)), None

                zero = jax.eval_shape(lambda: grads_of(params, jax.tree.map(
                    lambda x: x[0], micro)))
                zero = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                    zero)
                ((loss, aux), grads), _ = jax.lax.scan(acc, zero, micro)
                scale = 1.0 / n_micro
                loss = loss * scale
                aux = jax.tree.map(lambda x: x * scale, aux)
                grads = jax.tree.map(lambda g: g * scale, grads)
            new_params, new_opt = optimizer.update(params, opt_state, grads)
        metrics = {"loss": loss, **{k: v for k, v in aux.items()}}
        return new_params, new_opt, metrics

    params_s = abstract_params(model)
    opt_s = jax.eval_shape(optimizer.init, params_s)
    batch_s = input_specs_for(cfg, shape)["batch"]

    pmode = step_opts.get("param_mode", "fsdp_tp")
    p_sh = shd.param_shardings(params_s, mesh, mode=pmode)
    o_sh = OptState(shd.replicated(mesh),
                    shd.param_shardings(opt_s.mu, mesh, mode=pmode),
                    shd.param_shardings(opt_s.nu, mesh, mode=pmode))
    b_sh = jax.tree.map(
        lambda x: NamedSharding(
            mesh, shd.data_spec(mesh, len(x.shape), x.shape[0])), batch_s)

    return StepBundle(
        name=f"train:{arch_id}:{shape_id}",
        fn=train_step,
        args=(params_s, opt_s, batch_s),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    )


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def make_prefill_step(arch_id: str, shape_id: str, mesh: Mesh, *,
                      overrides: Optional[dict] = None) -> StepBundle:
    cfg, step_opts = _apply_overrides(get_config(arch_id), overrides)
    model = build_model(cfg)

    shape = SHAPES[shape_id]

    def prefill_step(params, batch):
        with shd.activation_sharding(mesh, shape.global_batch):
            logits, _ = model.forward(
                params, batch.get("tokens"),
                frontend_embeds=batch.get("frontend_embeds"),
                remat=cfg.remat)
        return logits[:, -1]     # next-token logits; full (B,S,V) would be
                                 # a multi-hundred-GB output at 32k

    params_s = abstract_params(model)
    batch_s = input_specs_for(cfg, shape)["batch"]
    p_sh = shd.param_shardings(params_s, mesh,
                               mode=step_opts.get("param_mode", "fsdp_tp"))
    b_sh = jax.tree.map(
        lambda x: NamedSharding(
            mesh, shd.data_spec(mesh, len(x.shape), x.shape[0])), batch_s)

    return StepBundle(
        name=f"prefill:{arch_id}:{shape_id}",
        fn=prefill_step,
        args=(params_s, batch_s),
        in_shardings=(p_sh, b_sh),
        out_shardings=None,
    )


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def make_decode_step(arch_id: str, shape_id: str, mesh: Mesh, *,
                     overrides: Optional[dict] = None) -> StepBundle:
    cfg, step_opts = _apply_overrides(get_config(arch_id), overrides)
    model = build_model(cfg)
    shape = SHAPES[shape_id]
    lc = long_ctx(shape_id)

    def serve_step(params, token, caches, index):
        with shd.activation_sharding(mesh, shape.global_batch):
            logits, new_caches = model.decode_step(params, token, caches,
                                                   index, long_ctx=lc)
        return logits, new_caches

    params_s = abstract_params(model)
    spec = input_specs_for(cfg, shape)
    p_sh = shd.param_shardings(params_s, mesh,
                               mode=step_opts.get("param_mode", "fsdp_tp"))
    t_sh = NamedSharding(mesh, shd.data_spec(mesh, 2, shape.global_batch))
    c_sh = shd.cache_shardings(spec["caches"], mesh, shape.global_batch)
    i_sh = shd.replicated(mesh)

    return StepBundle(
        name=f"decode:{arch_id}:{shape_id}",
        fn=serve_step,
        args=(params_s, spec["token"], spec["caches"], spec["index"]),
        in_shardings=(p_sh, t_sh, c_sh, i_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(2,),
    )
