"""Loop-aware analysis of post-optimisation HLO text.

XLA's flat ``cost_analysis()`` counts every ``while`` body ONCE, so any
program built around ``lax.scan`` (stacked layers, chunked attention)
under-reports FLOPs, bytes, and collective traffic by the trip count.
This module re-derives the three roofline inputs from the compiled HLO
*with* loop multipliers:

  * computations are parsed into a call graph (while bodies, fusions,
    calls, conditionals), with a per-computation symbol table so operand
    shapes resolve even though the dump prints operands as bare names;
  * while trip counts are recovered from the canonical XLA loop form
    (condition compares the induction variable against a constant);
  * dot/convolution FLOPs, per-op HBM traffic (operands + results of
    top-level ops = post-fusion kernel boundaries), and collective operand
    bytes are accumulated over the graph, multiplying by trip counts.

Validated against ``cost_analysis()`` on loop-free programs and against
hand counts on scan programs (tests/test_hlo_analysis.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*"
    r"(?P<rtype>\([^=]*?\)|\S+)\s+"
    r"(?P<kind>[a-z][a-z0-9\-]*)\((?P<rest>.*)$")
_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((?P<params>.*)\)"
                     r"\s*->")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*((?:" + "|".join(_DTYPE_BYTES) +
                       r")\[[0-9,]*\])")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that move no HBM bytes of their own (meta / control / aliases)
_SKIP_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
                 "bitcast", "while", "call", "conditional", "after-all",
                 "iota", "partition-id", "replica-id", "domain",
                 "opt-barrier"}

# ops a TPU compiler fuses into neighbouring kernels: their top-level
# appearance in the CPU dump is a backend artifact, so they are excluded
# from the fusion-optimistic traffic figure (bytes_fused)
_ELEMENTWISE = {"add", "subtract", "multiply", "divide", "power", "negate",
                "exponential", "exponential-minus-one", "log", "log-plus-one",
                "tanh", "logistic", "sqrt", "rsqrt", "cbrt", "abs", "sign",
                "maximum", "minimum", "compare", "select", "and", "or",
                "not", "xor", "convert", "broadcast", "reshape", "clamp",
                "floor", "ceil", "round-nearest-afz", "round-nearest-even",
                "is-finite", "sine", "cosine", "concatenate", "pad", "slice",
                "reverse", "rem", "shift-left", "shift-right-logical",
                "shift-right-arithmetic", "reduce", "map", "atan2",
                "stochastic-convert", "real", "imag", "erf"}


def flat_cost_analysis(compiled) -> dict:
    """XLA's flat per-module cost analysis as ONE dict.

    ``Compiled.cost_analysis()`` returns a dict on current jax but a
    one-element list of dicts on older releases (0.4.x); normalise so
    callers (and the validation tests) can index properties directly.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def _elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    return sum(_elems(dims) * _DTYPE_BYTES[dt]
               for dt, dims in _SHAPE_RE.findall(type_str))


def _first_shape(type_str: str) -> Optional[tuple[str, list[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class OpInfo:
    name: str
    kind: str
    rtype: str
    operands: list[str]
    attrs: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[OpInfo]
    symbols: dict       # name -> result type str
    text: str


def parse_computations(hlo: str) -> tuple[dict[str, Computation],
                                          Optional[str]]:
    comps: dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        line = re.sub(r"/\*.*?\*/", "", line)  # strip /*index=N*/ comments
        hdr = _HDR_RE.match(line)
        if hdr and "{" in line and ("->" in line):
            cur = Computation(hdr.group(1), [], {}, "")
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = cur.name
            for pname, ptype in _PARAM_RE.findall(hdr.group("params")):
                cur.symbols[pname] = ptype
            continue
        if cur is None:
            continue
        cur.text += line + "\n"
        m = _OP_RE.match(line)
        if m:
            rest = m.group("rest")
            call_part = rest.split(")", 1)[0]
            operands = re.findall(r"%([\w\.\-]+)", call_part)
            if not operands:  # operands may be printed without '%'
                operands = [t.strip() for t in call_part.split(",")
                            if t.strip() and "=" not in t]
            attrs = rest[len(call_part):]
            op = OpInfo(m.group("name"), m.group("kind"), m.group("rtype"),
                        operands, attrs, line.strip())
            cur.ops.append(op)
            cur.symbols[op.name] = op.rtype
        if line.strip() == "}":
            cur = None
    return comps, entry


def trip_count(cond: Computation) -> int:
    consts: dict[str, int] = {}
    for mm in re.finditer(
            r"%?([\w\.\-]+)\s*=\s*[su]\d+\[\]\s*constant\((-?\d+)\)",
            cond.text):
        consts[mm.group(1)] = int(mm.group(2))
    for op in cond.ops:
        if op.kind != "compare":
            continue
        vals = [consts[n] for n in op.operands if n in consts]
        dm = re.search(r"direction=(\w+)", op.line)
        if vals:
            v = max(vals)
            if dm and dm.group(1) in ("LE", "GE"):
                v += 1
            return max(v, 1)
    if consts:
        return max(max(consts.values()), 1)
    return 1


def _dot_flops(op: OpInfo, symbols: dict) -> float:
    res = _first_shape(op.rtype)
    lhs_t = symbols.get(op.operands[0]) if op.operands else None
    lhs = _first_shape(lhs_t) if lhs_t else None
    if res is None or lhs is None:
        return 0.0
    res_elems = 1
    for d in res[1]:
        res_elems *= d
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    contract = 1
    if cm and cm.group(1):
        for i in cm.group(1).split(","):
            contract *= lhs[1][int(i)]
    return 2.0 * res_elems * contract


def _conv_flops(op: OpInfo, symbols: dict) -> float:
    res = _first_shape(op.rtype)
    ker_t = symbols.get(op.operands[1]) if len(op.operands) > 1 else None
    ker = _first_shape(ker_t) if ker_t else None
    if res is None or ker is None:
        return 0.0
    res_elems = 1
    for d in res[1]:
        res_elems *= d
    k_elems = 1
    for d in ker[1]:
        k_elems *= d
    out_feat = ker[1][-1] if ker[1] else 1
    return 2.0 * res_elems * (k_elems / max(out_feat, 1))


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes_accessed: float = 0.0      # upper bound (CPU fusion level)
    bytes_fused: float = 0.0         # TPU-fusion-optimistic lower bound
    collective_bytes: float = 0.0
    coll_breakdown: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def add(self, other: "Totals", mult: float = 1.0, *,
            bytes_too: bool = True):
        self.flops += other.flops * mult
        if bytes_too:
            self.bytes_accessed += other.bytes_accessed * mult
            self.bytes_fused += other.bytes_fused * mult
        self.collective_bytes += other.collective_bytes * mult
        for k in COLLECTIVES:
            self.coll_breakdown[k] += other.coll_breakdown[k] * mult


def analyse_hlo(hlo: str) -> Totals:
    comps, entry = parse_computations(hlo)
    if entry is None:
        entry = list(comps)[-1]

    memo: dict[str, Totals] = {}

    def visit(name: str) -> Totals:
        if name in memo:
            return memo[name]
        memo[name] = Totals()          # break cycles defensively
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        t = Totals()
        for op in comp.ops:
            base = op.kind.removesuffix("-start").removesuffix("-done")
            if op.kind.endswith("-done"):
                continue
            if base == "dot":
                t.flops += _dot_flops(op, comp.symbols)
            elif base == "convolution":
                t.flops += _conv_flops(op, comp.symbols)
            if base in COLLECTIVES:
                b = sum(_type_bytes(comp.symbols.get(o, ""))
                        for o in op.operands)
                t.collective_bytes += b
                t.coll_breakdown[base] += b
            if base == "dynamic-update-slice":
                # in-place update: traffic = the update slice (read+write),
                # not the full buffer (XLA aliases the big operand)
                upd = op.operands[1] if len(op.operands) > 1 else None
                b = 2 * _type_bytes(comp.symbols.get(upd, "")) if upd else 0
                t.bytes_accessed += b
                t.bytes_fused += b
            elif base == "dynamic-slice":
                t.bytes_accessed += 2 * _type_bytes(op.rtype)
                t.bytes_fused += 2 * _type_bytes(op.rtype)
            elif base not in _SKIP_TRAFFIC:
                b = _type_bytes(op.rtype)
                b += sum(_type_bytes(comp.symbols.get(o, ""))
                         for o in op.operands)
                if "dynamic-update-slice" in op.name or \
                        "dynamic_update_slice" in op.line:
                    # in-place accumulator fusion: the big buffer operand is
                    # aliased with the result; real traffic is the update
                    rbytes = _type_bytes(op.rtype)
                    alias = max((_type_bytes(comp.symbols.get(o, ""))
                                 for o in op.operands), default=0)
                    if alias and abs(alias - rbytes) <= rbytes * 0.01:
                        b -= alias + rbytes
                t.bytes_accessed += b
                if base not in _ELEMENTWISE:
                    t.bytes_fused += b
            if base == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", op.line)
                cm = re.search(r"condition=%?([\w\.\-]+)", op.line)
                # XLA annotates statically-known trip counts directly
                km = re.search(r'known_trip_count[^0-9]*(\d+)', op.line)
                if km:
                    trips = int(km.group(1))
                elif cm and cm.group(1) in comps:
                    trips = trip_count(comps[cm.group(1)])
                else:
                    trips = 1
                if bm:
                    t.add(visit(bm.group(1)), trips)
            elif base in ("fusion", "call", "conditional", "custom-call",
                          "map", "reduce", "reduce-window", "scatter",
                          "select-and-scatter", "sort", "async-start"):
                for cname in re.findall(
                        r"(?:calls|to_apply|branch_computations=\{)"
                        r"=?%?([\w\.\-]+)", op.attrs):
                    sub = visit(cname)
                    # fusion interior traffic is on-chip: flops and
                    # collectives propagate, bytes do not
                    t.add(sub, 1.0, bytes_too=(base in
                                               ("call", "conditional")))
        memo[name] = t
        return t

    return visit(entry)
