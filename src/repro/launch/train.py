"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 100 --batch 4 --seq 128

``--reduced`` trains the CPU-scale variant of the arch family (the full
configs are exercised via the dry-run); on a real TPU cluster the same
entrypoint builds the production mesh and shards with the path rules.
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.configs import ARCHS
from repro.data import frontend_batches, lm_batches
from repro.models.registry import get_model
from repro.train.trainer import TrainConfig, Trainer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    cfg, model = get_model(args.arch, reduced=args.reduced)
    tcfg = TrainConfig(batch=args.batch, steps=args.steps, lr=args.lr,
                       ckpt_dir=args.ckpt)
    trainer = Trainer(cfg, tcfg)

    tokens = lm_batches(cfg.vocab, args.batch, args.seq)
    if cfg.family in ("vlm", "audio"):
        fronts = frontend_batches(args.batch, cfg.n_frontend_tokens,
                                  cfg.d_model)
        data = ({"tokens": next(tokens)["tokens"],
                 "frontend_embeds": next(fronts)} for _ in iter(int, 1))
    else:
        data = tokens

    print(f"training {args.arch} (reduced={args.reduced}) "
          f"on {jax.devices()} for {args.steps} steps")
    _, _, history = trainer.run(
        data, hook=lambda i, m: print(
            f"  step {i:>5} loss {m['loss']:.4f} wall {m['wall_s']:.1f}s"))
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f}")
    return 0 if last < first else 1


if __name__ == "__main__":
    sys.exit(main())
