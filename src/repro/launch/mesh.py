"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax
import; tests see the default single device).
"""
from __future__ import annotations

import math

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` kwarg for ``jax.make_mesh`` when this jax has it.

    ``jax.sharding.AxisType`` (and the matching ``make_mesh`` kwarg)
    landed after 0.4.37; on the pinned jax every mesh axis is already
    Auto by default, so omitting the kwarg is behaviour-identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False) -> "jax.sharding.Mesh":
    """16x16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} exist; "
            "the dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax")
    return jax.make_mesh(
        shape, axes, devices=devices[:n], **_axis_type_kwargs(len(axes)))


def make_host_mesh(shape=(1, 1), axes=("data", "model")) -> \
        "jax.sharding.Mesh":
    """Tiny mesh over whatever devices exist (tests / smoke runs)."""
    n = math.prod(shape)
    return jax.make_mesh(
        shape, axes, devices=jax.devices()[:n], **_axis_type_kwargs(len(axes)))
