"""Split-policy serving launcher (the paper's pipeline on an assigned LLM).

Partitions a transformer at a super-block boundary, quantises the
boundary activation with a wire codec, and measures end-to-end decision
latency for split vs server-only execution across a bandwidth sweep —
the paper's Table 5 protocol with the model as the workload.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --edge-segments 1 --codec uint8 --bandwidths 10,25,50,100
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core.wire import get_codec
from repro.models.registry import get_model
from repro.serving.client import DecisionLoop, EdgeClient
from repro.serving.netsim import shaped
from repro.serving.server import PolicyServer


def build_split(arch: str, *, reduced: bool, edge_segments: int,
                codec_name: str, batch: int, seq: int):
    cfg, model = get_model(arch, reduced=reduced)
    if cfg.family == "audio":
        raise SystemExit("use the whisper enc/dec split example instead")
    params = model.init(jax.random.PRNGKey(0))
    edge_p, server_p = model.split_params(params, edge_segments)
    codec = get_codec(codec_name)

    @jax.jit
    def edge_fn(tokens):
        h = model.edge_forward(edge_p, tokens)
        return codec.encode(h)

    @jax.jit
    def server_fn(payload):
        h = codec.decode(payload, dtype=cfg.jnp_dtype)
        return model.server_forward(server_p, h)

    @jax.jit
    def monolith_fn(tokens):
        logits, _ = model.forward(params, tokens)
        return logits

    tokens = jnp.zeros((batch, seq), jnp.int32)
    hidden_shape = (batch, seq, cfg.d_model)
    wire = codec.wire_bytes(hidden_shape)
    raw = batch * seq * 4     # server-only sends raw token ids (4B each)
    # NOTE: for LLM serving the "raw observation" is tiny (token ids), so
    # the interesting split trade-off is the *reverse* of the RL case at
    # the first boundary; the pod-boundary use (DESIGN.md §2) transmits
    # hidden states because the server half holds the heavy weights.
    return (cfg, edge_fn, server_fn, monolith_fn, tokens, wire, raw)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--edge-segments", type=int, default=1)
    ap.add_argument("--codec", default="uint8")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--bandwidths", default="10,25,50,100")
    args = ap.parse_args(argv)

    (cfg, edge_fn, server_fn, monolith_fn, tokens, wire_bytes,
     raw_bytes) = build_split(
        args.arch, reduced=args.reduced, edge_segments=args.edge_segments,
        codec_name=args.codec, batch=args.batch, seq=args.seq)

    client = EdgeClient(encode_fn=edge_fn, wire_bytes=wire_bytes)
    j = client.measure(tokens)
    payload = edge_fn(tokens)
    server = PolicyServer(serve_fn=server_fn)
    s_split = server.measure(payload)
    mono = PolicyServer(serve_fn=monolith_fn)
    s_mono = mono.measure(tokens)

    print(f"{args.arch} split@{args.edge_segments} codec={args.codec}: "
          f"edge {j*1e3:.1f}ms server {s_split*1e3:.1f}ms "
          f"monolith {s_mono*1e3:.1f}ms wire {wire_bytes}B raw {raw_bytes}B")
    print(f"{'Mb/s':>8} {'server-only(ms)':>16} {'split(ms)':>11}")
    for mbps in [float(x) for x in args.bandwidths.split(",")]:
        so = DecisionLoop(link=shaped(mbps), server_time_s=s_mono,
                          split=False, payload_bytes=raw_bytes)
        sp = DecisionLoop(link=shaped(mbps), server_time_s=s_split,
                          split=True, edge_time_s=j,
                          payload_bytes=wire_bytes)
        print(f"{mbps:>8.0f} {so.median_latency(100)*1e3:>16.1f} "
              f"{sp.median_latency(100)*1e3:>11.1f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
