import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
on the production meshes, print memory/cost analysis, and emit the
roofline table rows (EXPERIMENTS.md §Dry-run / §Roofline read this).

The XLA_FLAGS line above MUST precede any jax import: jax locks the device
count at first init.  Do not set this flag globally — smoke tests and
benchmarks should see one device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.jsonl
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import HEADER, analyse, fmt_row
from repro.launch.steps import make_step


def run_one(arch: str, shape_id: str, mesh_name: str, *,
            overrides=None, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.devices.size
    bundle = make_step(arch, shape_id, mesh, overrides=overrides)
    t0 = time.time()
    lowered = bundle.lower(mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    if verbose:
        print(f"[{bundle.name} mesh={mesh_name}] lower {t1-t0:.1f}s "
              f"compile {t2-t1:.1f}s")
        print(f"  memory_analysis: {mem}")
    r = analyse(compiled, arch=arch, shape_cfg=SHAPES[shape_id],
                mesh_name=mesh_name, chips=chips, cfg=get_config(arch))
    if verbose:
        print(f"  cost_analysis: flops/chip={r.flops_per_chip:.3e} "
              f"bytes/chip={r.bytes_per_chip:.3e}")
        coll = {k: v for k, v in r.coll_breakdown.items() if v}
        print(f"  collectives/chip: {coll}")
        print("  " + fmt_row(r))
    d = r.to_dict()
    d["lower_s"] = t1 - t0
    d["compile_s"] = t2 - t1
    if overrides:
        d["overrides"] = {k: str(v) for k, v in overrides.items()}
    return d


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) combination")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    ap.add_argument("--override", action="append", default=[],
                    help="perf override key=value (repeatable)")
    args = ap.parse_args(argv)

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        if v in ("true", "false"):
            overrides[k] = v == "true"
        else:
            try:
                overrides[k] = json.loads(v)
            except json.JSONDecodeError:
                overrides[k] = v          # plain string (e.g. tp_only)

    archs = sorted(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = sorted(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    print(HEADER)
    failures = []
    for arch in archs:
        for shape_id in shapes:
            for mesh_name in meshes:
                try:
                    d = run_one(arch, shape_id, mesh_name,
                                overrides=overrides or None)
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(json.dumps(d) + "\n")
                except Exception as e:  # repro: allow(broad-except) -- a failure here IS the sharding bug under test; record the cell and keep sweeping
                    traceback.print_exc()
                    failures.append((arch, shape_id, mesh_name, repr(e)))
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(json.dumps({
                                "arch": arch, "shape": shape_id,
                                "mesh": mesh_name, "error": repr(e)}) + "\n")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        return 1
    print("\nall dry-runs lowered + compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
