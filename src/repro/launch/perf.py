"""Reproduce the §Perf hillclimb (EXPERIMENTS.md): baseline + winning
configuration for each of the three optimised (arch x shape) pairs.

  PYTHONPATH=src python -m repro.launch.perf [--pair A|B|C|all]
"""
import argparse
import sys

PAIRS = {
    # (arch, shape, baseline overrides, optimised overrides)
    "A": ("qwen2-moe-a2.7b", "train_4k", {},
          {"moe_dispatch_bf16": True, "moe_pad_experts": True,
           "moe_expert_parallel": True, "param_mode": "ep_model",
           "microbatches": 4}),
    "B": ("llama4-scout-17b-a16e", "train_4k", {},
          {"moe_dispatch_bf16": True, "moe_expert_parallel": True,
           "param_mode": "ep_model", "microbatches": 8}),
    # C's winning config is the default (masked_cache_update=True);
    # the paper-faithful baseline is the DUS + head-sharded path
    "C": ("qwen3-0.6b", "decode_32k",
          {"masked_cache_update": False}, {}),
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pair", choices=[*PAIRS, "all"], default="all")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from repro.launch.dryrun import run_one  # sets XLA_FLAGS on import
    import json

    pairs = PAIRS.items() if args.pair == "all" \
        else [(args.pair, PAIRS[args.pair])]
    for name, (arch, shape, base_over, opt_over) in pairs:
        print(f"\n=== pair {name}: {arch} x {shape} ===")
        for label, over in (("baseline", base_over), ("optimised",
                                                      opt_over)):
            print(f"--- {label} overrides={over}")
            d = run_one(arch, shape, args.mesh, overrides=over or None)
            d["pair"] = name
            d["label"] = label
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(d) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
