"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch, shape, mesh):

  compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory term     = HLO_bytes_per_chip / HBM_bw
  collective term = collective_bytes_per_chip / link_bw

``compiled.cost_analysis()`` reports the *per-device* (SPMD-partitioned)
program, so the terms above are already per-chip seconds; multiplying the
FLOPs back by chip count gives the global figure used for the
MODEL_FLOPS utilisation ratio.

collective_bytes is not in cost_analysis: we parse the post-optimisation
HLO (``compiled.as_text()``) and sum operand sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute ops.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any, Optional

# TPU v5e hardware constants (per the brief)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "u4": 1, "s4": 1,
}

# e.g. "bf16[8,128,1024]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+[a-z0-9]*|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum operand bytes per collective-op kind in post-opt HLO text."""
    out = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z0-9-]+)(?:-start|-done)?\(",
                      stripped)
        if not m:
            continue
        op = m.group(1)
        base = op.removesuffix("-start").removesuffix("-done")
        if base not in COLLECTIVE_OPS or op.endswith("-done"):
            continue
        # operand shapes are the dtype[shape] tokens after the '(' of the
        # op call; the result type(s) come before '='
        call = stripped.split("(", 1)[1] if "(" in stripped else ""
        shapes = _SHAPE_RE.findall(call.split("),")[0] if ")," in call
                                   else call)
        out[base] += sum(_shape_bytes(d, s) for d, s in shapes)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float            # TPU-fusion-optimistic HBM traffic
    coll_bytes_per_chip: float
    coll_breakdown: dict[str, int]
    model_flops: float               # 6·N·D (train) / 2·N·D (inference)
    bytes_upper_per_chip: float = 0  # CPU-fusion-level upper bound
    bytes_floor_per_chip: float = 0  # analytic perfect-fusion floor
    peak_memory_bytes: Optional[int] = None   # from memory_analysis

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def hlo_flops_global(self) -> float:
        return self.flops_per_chip * self.chips

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        if self.hlo_flops_global <= 0:
            return float("nan")
        return self.model_flops / self.hlo_flops_global

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "bytes_upper_per_chip": self.bytes_upper_per_chip,
            "bytes_floor_per_chip": self.bytes_floor_per_chip,
            "memory_floor_s": self.bytes_floor_per_chip / HBM_BW,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_breakdown": self.coll_breakdown,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "peak_memory_bytes": self.peak_memory_bytes,
        }


def model_flops(cfg, shape) -> float:
    """6·N·D for training (fwd+bwd), 2·N·D for inference steps, with
    N = active params (MoE counts routed top-k + shared only)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch * 1     # decode: one token per request
    return 2.0 * n * tokens


def hbm_floor_bytes(cfg, shape, chips: int) -> float:
    """Analytic per-chip HBM-traffic floor: weights + boundary activations
    + KV caches, assuming perfect fusion (flash attention keeps score
    tiles in VMEM).  The gap between this and the measured ``bytes_fused``
    is the fusion-quality headroom the §Perf loop works on."""
    P = cfg.param_count()
    D, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    B, S = shape.global_batch, shape.seq_len
    tp = 16  # model axis
    if shape.kind == "train":
        weights = P * 2.0 * 3 / tp          # fwd + bwd + remat reads (bf16)
        opt = P * 4.0 * 4 / chips           # adam m,v read+write (f32, FSDP)
        acts = L * B * S * D * 2.0 * 4 / chips
        logits = 3 * B * S * V * 2.0 / chips
        return weights + opt + acts + logits
    if shape.kind == "prefill":
        weights = P * 2.0 / tp
        acts = L * B * S * D * 2.0 * 2 / chips
        return weights + acts
    # decode: every cached byte is read once per token
    kv = 0.0
    for b in cfg.blocks():
        if b == "attn":
            kv += B * S * cfg.n_kv_heads * cfg.head_dim * 2 * 2.0
        elif b == "swa":
            w = min(cfg.sliding_window or S, S)
            kv += B * w * cfg.n_kv_heads * cfg.head_dim * 2 * 2.0
        elif b == "ssm":
            s = cfg.ssm
            kv += B * (cfg.d_model * s.expand // s.head_dim) \
                * s.head_dim * s.d_state * 4.0 * 2
        elif b == "rec":
            kv += B * (cfg.rnn_width or D) * 4.0 * 2
    weights = cfg.active_param_count() * 2.0 / tp
    return weights + kv / chips


def analyse(compiled, *, arch: str, shape_cfg, mesh_name: str, chips: int,
            cfg) -> Roofline:
    """Roofline terms from the compiled artifact.

    FLOPs/bytes/collectives come from the loop-aware HLO walk
    (repro.launch.hlo_analysis) because XLA's flat cost_analysis counts
    while bodies once; cost_analysis is kept as a cross-check field.
    """
    from repro.launch.hlo_analysis import analyse_hlo
    t = analyse_hlo(compiled.as_text())
    flops = t.flops
    byts = t.bytes_fused
    coll = {k: int(v) for k, v in t.coll_breakdown.items()}
    mem = compiled.memory_analysis()
    peak = None
    if mem is not None:
        peak = int(getattr(mem, "temp_size_in_bytes", 0)
                   + getattr(mem, "argument_size_in_bytes", 0)
                   + getattr(mem, "output_size_in_bytes", 0)
                   - getattr(mem, "alias_size_in_bytes", 0))
    return Roofline(
        arch=arch, shape=shape_cfg.name, mesh=mesh_name, chips=chips,
        flops_per_chip=flops, bytes_per_chip=byts,
        coll_bytes_per_chip=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops=model_flops(cfg, shape_cfg),
        bytes_upper_per_chip=t.bytes_accessed,
        bytes_floor_per_chip=hbm_floor_bytes(cfg, shape_cfg, chips),
        peak_memory_bytes=peak,
    )


def fmt_row(r: Roofline) -> str:
    return (f"{r.arch:<24} {r.shape:<12} {r.mesh:<6} "
            f"{r.compute_s:>10.4f} {r.memory_s:>10.4f} "
            f"{r.collective_s:>12.6f} {r.bottleneck:<10} "
            f"{r.useful_flops_ratio:>7.3f} "
            f"{(r.peak_memory_bytes or 0)/2**30:>8.2f}GiB")


HEADER = (f"{'arch':<24} {'shape':<12} {'mesh':<6} "
          f"{'compute_s':>10} {'memory_s':>10} {'collective_s':>12} "
          f"{'bottleneck':<10} {'useful':>7} {'peak/dev':>11}")
