"""Pallas TPU kernel for one MiniConv "shader pass".

A fragment-shader pass computes each output pixel by sampling a k x k
neighbourhood of <= 8 bound textures (4 channels each) and writes one RGBA
(4-channel) output texture.  The TPU adaptation keeps the pass structure but
re-tiles it for VMEM/MXU:

* grid = (batch, out_row, kernel_row): each grid step loads ONE input row
  (the analogue of one row of texture samples), multiplies it against one
  kernel row, and accumulates into the output row's VMEM scratch.  The
  kernel-row grid dimension is sequential ("arbitrary"), so the output block
  is revisited and accumulated in fp32, exactly like the shader's running
  sum over its sampling budget.
* the inner product per kernel column is a (W_out, C_in) @ (C_in, 4) matmul
  — C_in <= 32 by the shader budget, so the whole pass working set
  (one input row + one kernel + one output row) stays far below VMEM.

Stride-2 passes subsample the input row grid, mirroring the shader's
half-resolution render target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pass_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, stride: int,
                 kw: int, w_out: int):
    """One (batch, out_row, kernel_row) grid step.

    x_ref: (1, 1, W_in, C_in) — the input row sampled by this step
    w_ref: (kh, kw, C_in, 4) — full pass weights (constant across grid)
    b_ref: (1, 4)            — bias
    o_ref: (1, 1, W_out, 4)  — output row (written on the last kernel row)
    acc_ref: (W_out, 4) fp32 scratch
    """
    i = pl.program_id(2)          # kernel row index
    kh = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.broadcast_to(b_ref[0].astype(jnp.float32),
                                        acc_ref.shape)

    x = x_ref[0, 0].astype(jnp.float32)      # (W_in, C_in)
    w = w_ref[i].astype(jnp.float32)         # (kw, C_in, 4)

    acc = acc_ref[...]
    for j in range(kw):                       # the shader's column samples
        cols = jax.lax.slice(x, (j, 0),
                             (j + (w_out - 1) * stride + 1, x.shape[1]),
                             (stride, 1))     # (W_out, C_in)
        acc = acc + cols @ w[j]               # MXU: (W_out,C_in)@(C_in,4)
    acc_ref[...] = acc

    @pl.when(i == kh - 1)
    def _flush():
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("stride", "interpret"))
def miniconv_pass(x, w, b, *, stride: int = 1, interpret: bool = True):
    """One shader pass on a pre-padded input (VALID convolution).

    x: (B, H_in, W_in, C_in); w: (kh, kw, C_in, 4); b: (4,).
    Returns (B, H_out, W_out, 4) with
    H_out = (H_in - kh)//stride + 1, W_out = (W_in - kw)//stride + 1.
    """
    B, h_in, w_in, c_in = x.shape
    kh, kw, c_in_w, c_out = w.shape
    assert c_in == c_in_w and c_out == 4, (x.shape, w.shape)
    h_out = (h_in - kh) // stride + 1
    w_out = (w_in - kw) // stride + 1

    grid = (B, h_out, kh)
    return pl.pallas_call(
        functools.partial(_pass_kernel, stride=stride, kw=kw, w_out=w_out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, w_in, c_in),
                         lambda b_, q, i: (b_, q * stride + i, 0, 0)),
            pl.BlockSpec((kh, kw, c_in, 4), lambda b_, q, i: (0, 0, 0, 0)),
            pl.BlockSpec((1, 4), lambda b_, q, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, w_out, 4),
                               lambda b_, q, i: (b_, q, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, h_out, w_out, 4), x.dtype),
        scratch_shapes=[pltpu.VMEM((w_out, 4), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, b.reshape(1, 4))
