"""Pallas TPU kernels for MiniConv shader passes — three execution tiers.

A fragment-shader pass computes each output pixel by sampling a k x k
neighbourhood of <= 8 bound textures (4 channels each) and writes one RGBA
(4-channel) output texture.  The TPU adaptation keeps the pass structure but
re-tiles it for VMEM/MXU.  This module provides the pass schedule's three
execution tiers (see ``repro.core.passplan`` for the schedule itself):

1. :func:`miniconv_pass` — the legacy reference: ONE pallas_call per
   :class:`~repro.core.passplan.ShaderPass`.  grid = (batch, out_row,
   kernel_row); each step loads one input row, multiplies it against one
   kernel row and accumulates into fp32 VMEM scratch.  This is the oracle
   the fused paths are tested against.

2. :func:`miniconv_layer_grouped` — one pallas_call per LAYER.  The
   output-group becomes a grid dimension (innermost), so consecutive grid
   steps share the same input-row block: the row is loaded into VMEM once
   and reused across all ceil(c_out/4) groups instead of once per pass.
   The per-group fp32 accumulator lives in a (n_groups, W_out, 4) VMEM
   scratch.

3. :func:`miniconv_encoder` — one pallas_call for the WHOLE encoder
   (the fused analogue of the paper's full pass sequence).  grid =
   (batch, out_row_tile); layer intermediates never leave the chip:
   layers 0..L-2 are computed once per batch element (on the first tile
   step) and the SAME-padded input of the final layer is parked in a VMEM
   scratch, from which every grid step computes ``tile_h`` rows of the
   final feature map (multi-row output tiling).  All output groups of a
   layer are produced by a single (H*W, C_in) @ (C_in, C_out) matmul.
   Channel counts are zero-padded to multiples of 4 (RGBA packing), so
   specs with c_out % 4 != 0 execute correctly; the wrapper slices the
   result back to the true channel count.

Stride-2 passes subsample the input rows/cols, mirroring the shader's
half-resolution render target.  On very large inputs the fused kernel keeps
the full input image plus the last intermediate in VMEM (~a few MB at
X=400); for bigger frames lower ``tile_h`` does not help — split the spec
or fall back to the per-layer kernels.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.miniconv import _ACTS
from repro.kernels.pallas_compat import tpu_compiler_params


# ---------------------------------------------------------------------------
# Tier 1: legacy single-pass kernel (the reference oracle)
# ---------------------------------------------------------------------------

def _pass_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, stride: int,
                 kw: int, w_out: int):
    """One (batch, out_row, kernel_row) grid step.

    x_ref: (1, 1, W_in, C_in) — the input row sampled by this step
    w_ref: (kh, kw, C_in, 4) — full pass weights (constant across grid)
    b_ref: (1, 4)            — bias
    o_ref: (1, 1, W_out, 4)  — output row (written on the last kernel row)
    acc_ref: (W_out, 4) fp32 scratch
    """
    i = pl.program_id(2)          # kernel row index
    kh = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.broadcast_to(b_ref[0].astype(jnp.float32),
                                        acc_ref.shape)

    x = x_ref[0, 0].astype(jnp.float32)      # (W_in, C_in)
    w = w_ref[i].astype(jnp.float32)         # (kw, C_in, 4)

    acc = acc_ref[...]
    for j in range(kw):                       # the shader's column samples
        cols = jax.lax.slice(x, (j, 0),
                             (j + (w_out - 1) * stride + 1, x.shape[1]),
                             (stride, 1))     # (W_out, C_in)
        acc = acc + cols @ w[j]               # MXU: (W_out,C_in)@(C_in,4)
    acc_ref[...] = acc

    @pl.when(i == kh - 1)
    def _flush():
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("stride", "interpret"))
def miniconv_pass(x, w, b, *, stride: int = 1, interpret: bool = True):
    """One shader pass on a pre-padded input (VALID convolution).

    x: (B, H_in, W_in, C_in); w: (kh, kw, C_in, 4); b: (4,).
    Returns (B, H_out, W_out, 4) with
    H_out = (H_in - kh)//stride + 1, W_out = (W_in - kw)//stride + 1.
    """
    B, h_in, w_in, c_in = x.shape
    kh, kw, c_in_w, c_out = w.shape
    assert c_in == c_in_w and c_out == 4, (x.shape, w.shape)
    h_out = (h_in - kh) // stride + 1
    w_out = (w_in - kw) // stride + 1

    grid = (B, h_out, kh)
    return pl.pallas_call(
        functools.partial(_pass_kernel, stride=stride, kw=kw, w_out=w_out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, w_in, c_in),
                         lambda b_, q, i: (b_, q * stride + i, 0, 0)),
            pl.BlockSpec((kh, kw, c_in, 4), lambda b_, q, i: (0, 0, 0, 0)),
            pl.BlockSpec((1, 4), lambda b_, q, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, w_out, 4),
                               lambda b_, q, i: (b_, q, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, h_out, w_out, 4), x.dtype),
        scratch_shapes=[pltpu.VMEM((w_out, 4), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, b.reshape(1, 4))


# ---------------------------------------------------------------------------
# Tier 2: one pallas_call per layer, output-group as a grid dimension
# ---------------------------------------------------------------------------

def _layer_group_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, stride: int,
                        kw: int, w_out: int):
    """One (batch, out_row, kernel_row, group) grid step.

    The group dimension is innermost, so the input-row block index is
    constant across the group sweep — Pallas keeps the row resident in VMEM
    and only the (kw, C_in, 4) weight slice and (1, 4) bias change per step.

    x_ref: (1, 1, W_in, C_in); w_ref: (kh, kw, C_in, 4) group slice;
    b_ref: (1, 4) group slice; o_ref: (1, 1, W_out, 4) group output;
    acc_ref: (n_groups, W_out, 4) fp32 scratch (one accumulator per group).
    """
    i = pl.program_id(2)          # kernel row index
    g = pl.program_id(3)          # output-group index
    kh = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[pl.ds(g, 1)] = jnp.broadcast_to(
            b_ref[0].astype(jnp.float32), (1, w_out, 4))

    x = x_ref[0, 0].astype(jnp.float32)      # (W_in, C_in)
    w = w_ref[i].astype(jnp.float32)         # (kw, C_in, 4)

    acc = acc_ref[pl.ds(g, 1)][0]
    for j in range(kw):
        cols = jax.lax.slice(x, (j, 0),
                             (j + (w_out - 1) * stride + 1, x.shape[1]),
                             (stride, 1))     # (W_out, C_in)
        acc = acc + cols @ w[j]
    acc_ref[pl.ds(g, 1)] = acc[None]

    @pl.when(i == kh - 1)
    def _flush():
        o_ref[0, 0] = acc_ref[pl.ds(g, 1)][0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "interpret"))
def miniconv_layer_grouped(x, w, b, *, stride: int = 1,
                           interpret: bool = True):
    """All output groups of one layer in a single pallas_call (VALID conv).

    x: (B, H_in, W_in, C_in); w: (kh, kw, C_in, C_out) with C_out % 4 == 0
    (callers pad; see ``repro.kernels.ops.miniconv_layer``); b: (C_out,).
    """
    B, h_in, w_in, c_in = x.shape
    kh, kw, c_in_w, c_out = w.shape
    assert c_in == c_in_w and c_out % 4 == 0, (x.shape, w.shape)
    n_groups = c_out // 4
    h_out = (h_in - kh) // stride + 1
    w_out = (w_in - kw) // stride + 1

    grid = (B, h_out, kh, n_groups)
    return pl.pallas_call(
        functools.partial(_layer_group_kernel, stride=stride, kw=kw,
                          w_out=w_out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, w_in, c_in),
                         lambda b_, q, i, g: (b_, q * stride + i, 0, 0)),
            pl.BlockSpec((kh, kw, c_in, 4),
                         lambda b_, q, i, g: (0, 0, 0, g)),
            pl.BlockSpec((1, 4), lambda b_, q, i, g: (g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, w_out, 4),
                               lambda b_, q, i, g: (b_, q, 0, g)),
        out_shape=jax.ShapeDtypeStruct((B, h_out, w_out, c_out), x.dtype),
        scratch_shapes=[pltpu.VMEM((n_groups, w_out, 4), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w, b.reshape(n_groups, 4))


# ---------------------------------------------------------------------------
# Tier 3: the whole encoder as ONE fused kernel
# ---------------------------------------------------------------------------

def _conv_from_padded(xp, w, b, *, out_h: int, out_w: int, stride: int,
                      kernel: int):
    """SAME conv of a pre-padded fp32 image held in VMEM.

    xp: (H_pad, W_pad, C_in); w: (k, k, C_in, C_out); b: (C_out,).
    Returns (out_h, out_w, C_out) fp32.  Each (i, j) tap is one
    (out_h*out_w, C_in) @ (C_in, C_out) MXU matmul — all output groups of
    the layer in a single contraction.
    """
    c_in = xp.shape[-1]
    c_out = w.shape[-1]
    acc = jnp.broadcast_to(b, (out_h, out_w, c_out)).astype(jnp.float32)
    for i in range(kernel):
        for j in range(kernel):
            win = jax.lax.slice(
                xp, (i, j, 0),
                (i + (out_h - 1) * stride + 1,
                 j + (out_w - 1) * stride + 1, c_in),
                (stride, stride, 1))              # (out_h, out_w, C_in)
            tap = win.reshape(out_h * out_w, c_in) @ w[i, j]
            acc = acc + tap.reshape(out_h, out_w, c_out)
    return acc


def _encoder_kernel(*refs, plan, tile_h: int, scratch_rows: int):
    """One (batch, out_row_tile) grid step of the fused encoder.

    refs layout: x_ref, w_0..w_{L-1}, b_0..b_{L-1}, o_ref[, p_scr].
    ``p_scr`` (absent when L == 1) holds the SAME-padded input of the final
    layer for the current batch element: (scratch_rows, W_pad, C_in_pad)
    fp32, built once on the first tile step and reused by every tile.
    """
    layers = plan.layers
    L = len(layers)
    x_ref = refs[0]
    w_refs = refs[1:1 + L]
    b_refs = refs[1 + L:1 + 2 * L]
    o_ref = refs[1 + 2 * L]
    p_scr = refs[1 + 2 * L + 1] if L > 1 else None
    t = pl.program_id(1)
    last = layers[-1]

    if L > 1:
        @pl.when(t == 0)
        def _chain_front_layers():
            # Layers 0..L-2 run once per batch element; intermediates stay
            # on-chip and the final layer's padded input is parked in VMEM.
            y = x_ref[0].astype(jnp.float32)          # padded layer-0 input
            for l in range(L - 1):
                m = layers[l]
                y = _conv_from_padded(
                    y, w_refs[l][...].astype(jnp.float32),
                    b_refs[l][0].astype(jnp.float32),
                    out_h=m.out_h, out_w=m.out_w, stride=m.stride,
                    kernel=m.kernel)
                y = _ACTS[m.activation](y)
                nxt = layers[l + 1]
                pad = jnp.zeros((scratch_rows if l == L - 2
                                 else nxt.padded_in_h,
                                 nxt.padded_in_w, nxt.c_in_pad), jnp.float32)
                y = jax.lax.dynamic_update_slice(
                    pad, y, (nxt.pad_top, nxt.pad_left, 0))
            p_scr[...] = y

        src_ref = p_scr
    else:
        src_ref = None

    # Final layer: tile_h output rows per grid step.
    rows_need = (tile_h - 1) * last.stride + last.kernel
    row0 = t * tile_h * last.stride
    if L > 1:
        xp = src_ref[pl.ds(row0, rows_need)]
    else:
        xp = x_ref[0, pl.ds(row0, rows_need)].astype(jnp.float32)
    acc = _conv_from_padded(
        xp, w_refs[-1][...].astype(jnp.float32),
        b_refs[-1][0].astype(jnp.float32),
        out_h=tile_h, out_w=last.out_w, stride=last.stride,
        kernel=last.kernel)
    o_ref[0] = _ACTS[last.activation](acc).astype(o_ref.dtype)


def miniconv_encoder(x, weights, biases, plan, *, tile_h: int = 8,
                     interpret=None):
    """Execute a whole :class:`~repro.core.passplan.PassPlan` as ONE kernel.

    x: (B, H, W, C_in) with (H, W) == (plan.in_h, plan.in_w);
    weights/biases: per-layer lists matching ``plan.spec.layers``.
    Returns (B, plan.out_h, plan.out_w, plan.k_out) in x.dtype — bitwise
    semantics match the per-pass path (SAME padding, fp32 accumulation,
    per-layer activation) within float tolerance.
    """
    # resolve the env-dependent default OUTSIDE the jit cache so flipping
    # REPRO_PALLAS_COMPILE between calls is honoured
    if interpret is None:
        interpret = (not os.environ.get("REPRO_PALLAS_COMPILE")
                     and jax.default_backend() != "tpu")
    return _miniconv_encoder(x, weights, biases, plan, tile_h=tile_h,
                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("plan", "tile_h", "interpret"))
def _miniconv_encoder(x, weights, biases, plan, *, tile_h: int,
                      interpret: bool):
    layers = plan.layers
    L = len(layers)
    B, h, w_sz, c_in = x.shape
    assert (h, w_sz) == (plan.in_h, plan.in_w), (x.shape, plan.in_h,
                                                 plan.in_w)
    assert c_in == layers[0].c_in and len(weights) == L == len(biases)

    tile_h = max(1, min(tile_h, plan.out_h))
    n_tiles = -(-plan.out_h // tile_h)
    last = layers[-1]
    # Rows the last tile may read past the exact padded input: over-allocate
    # zero rows at the bottom so every pl.ds stays in bounds.
    rows_need_max = (n_tiles * tile_h - 1) * last.stride + last.kernel
    scratch_rows = max(last.padded_in_h, rows_need_max)

    # Zero-pad channels to RGBA multiples and bake in layer-0 SAME padding.
    first = layers[0]
    x0_rows = scratch_rows if L == 1 else first.padded_in_h
    xp = jnp.zeros((B, x0_rows, first.padded_in_w, first.c_in_pad), x.dtype)
    xp = jax.lax.dynamic_update_slice(
        xp, x, (0, first.pad_top, first.pad_left, 0))
    ws, bs = [], []
    for l, (wt, bi) in enumerate(zip(weights, biases)):
        m = layers[l]
        wp = jnp.zeros((m.kernel, m.kernel, m.c_in_pad, m.c_out_pad),
                       wt.dtype)
        wp = jax.lax.dynamic_update_slice(wp, wt, (0, 0, 0, 0))
        bp = jnp.zeros((1, m.c_out_pad), bi.dtype)
        bp = jax.lax.dynamic_update_slice(bp, bi[None], (0, 0))
        ws.append(wp)
        bs.append(bp)

    in_specs = [pl.BlockSpec((1, x0_rows, first.padded_in_w, first.c_in_pad),
                             lambda b_, t: (b_, 0, 0, 0))]
    for l in range(L):
        m = layers[l]
        in_specs.append(pl.BlockSpec(
            (m.kernel, m.kernel, m.c_in_pad, m.c_out_pad),
            lambda b_, t: (0, 0, 0, 0)))
    for l in range(L):
        m = layers[l]
        in_specs.append(pl.BlockSpec((1, m.c_out_pad),
                                     lambda b_, t: (0, 0)))
    scratch_shapes = []
    if L > 1:
        scratch_shapes.append(pltpu.VMEM(
            (scratch_rows, last.padded_in_w, last.c_in_pad), jnp.float32))

    out = pl.pallas_call(
        functools.partial(_encoder_kernel, plan=plan, tile_h=tile_h,
                          scratch_rows=scratch_rows),
        grid=(B, n_tiles),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, tile_h, last.out_w, last.c_out_pad),
                               lambda b_, t: (b_, t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (B, n_tiles * tile_h, last.out_w, last.c_out_pad), x.dtype),
        scratch_shapes=scratch_shapes,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xp, *ws, *bs)
    return out[:, :plan.out_h, :, :plan.k_out]


__all__ = ["miniconv_pass", "miniconv_layer_grouped", "miniconv_encoder"]
