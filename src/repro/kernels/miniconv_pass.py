"""Pallas TPU kernels for MiniConv shader passes — three execution tiers.

A fragment-shader pass computes each output pixel by sampling a k x k
neighbourhood of <= 8 bound textures (4 channels each) and writes one RGBA
(4-channel) output texture.  The TPU adaptation keeps the pass structure but
re-tiles it for VMEM/MXU.  This module provides the pass schedule's three
execution tiers (see ``repro.core.passplan`` for the schedule itself):

1. :func:`miniconv_pass` — the legacy reference: ONE pallas_call per
   :class:`~repro.core.passplan.ShaderPass`.  grid = (batch, out_row,
   kernel_row); each step loads one input row, multiplies it against one
   kernel row and accumulates into fp32 VMEM scratch.  This is the oracle
   the fused paths are tested against.

2. :func:`miniconv_layer_grouped` — one pallas_call per LAYER.  The
   output-group becomes a grid dimension (innermost), so consecutive grid
   steps share the same input-row block: the row is loaded into VMEM once
   and reused across all ceil(c_out/4) groups instead of once per pass.
   The per-group fp32 accumulator lives in a (n_groups, W_out, 4) VMEM
   scratch.

3. :func:`miniconv_encoder` — one pallas_call for the WHOLE encoder
   (the fused analogue of the paper's full pass sequence).  grid =
   (batch, out_row_tile); layer intermediates never leave the chip:
   layers 0..L-2 are computed once per batch element (on the first tile
   step) and the SAME-padded input of the final layer is parked in a VMEM
   scratch, from which every grid step computes ``tile_h`` rows of the
   final feature map (multi-row output tiling).  All output groups of a
   layer are produced by a single (H*W, C_in) @ (C_in, C_out) matmul.
   Channel counts are zero-padded to multiples of 4 (RGBA packing), so
   specs with c_out % 4 != 0 execute correctly; the wrapper slices the
   result back to the true channel count.

   The batch dimension is the OUTER grid dimension, so a (B, H, W, C)
   input is a single kernel launch: weight padding, dispatch, and the
   interpreter setup are paid once for the whole micro-batch instead of
   once per frame (the batched-serving path; see
   ``repro.serving.server.BatchingPolicyServer``).

   Optionally the server-side linear projection (the ``rl.networks``
   flatten + dense head) is FUSED into the kernel epilogue: each tile's
   activated rows are immediately contracted against the matching row
   slice of the head weight and accumulated in a (1, D) VMEM scratch, so
   the (B, D) projection leaves the kernel without the feature map ever
   being re-read from HBM.  Head-weight rows beyond ``plan.out_h`` and
   channels beyond ``plan.k_out`` are zero-padded, which cancels the
   contributions of the over-allocated tile rows and RGBA padding
   channels; the projection width D is lane-padded to a multiple of 128
   so the epilogue matmul fills whole MXU lanes (the zero columns are
   sliced off the returned projection).

4. :func:`miniconv_encoder_stream` — the fused encoder pipelined over
   BATCH CHUNKS, lifting the batch-must-fit-VMEM rule
   (``PassPlan.max_safe_batch``).  The micro-batch is split into
   ``chunk_b``-frame chunks; on compiled TPU a single pallas_call with a
   (chunk, batch, tile) grid fetches each chunk's input block HBM->VMEM
   per grid step (Pallas double-buffers the next chunk's fetch behind the
   current chunk's compute), while the portable fallback issues one fused
   launch per chunk (automatic multi-launch splitting).  When the batch
   divides into whole chunks both strategies are bitwise equal to calling
   :func:`miniconv_encoder` chunk-by-chunk and concatenating, so
   arbitrarily large micro-batches stream through one server (see
   :func:`miniconv_encoder_stream` for the ragged-remainder contract).
   Registered as the ``fused+stream`` execution backend
   (``repro.core.backends``).

Stride-2 passes subsample the input rows/cols, mirroring the shader's
half-resolution render target.  On very large inputs the fused kernel keeps
the full input image plus the last intermediate in VMEM (~a few MB at
X=400); for bigger frames lower ``tile_h`` does not help — split the spec
or stream the batch (:func:`miniconv_encoder_stream`).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.miniconv import _ACTS
from repro.kernels.pallas_compat import tpu_compiler_params


# ---------------------------------------------------------------------------
# Tier 1: legacy single-pass kernel (the reference oracle)
# ---------------------------------------------------------------------------

def _pass_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, stride: int,
                 kw: int, w_out: int):
    """One (batch, out_row, kernel_row) grid step.

    x_ref: (1, 1, W_in, C_in) — the input row sampled by this step
    w_ref: (kh, kw, C_in, 4) — full pass weights (constant across grid)
    b_ref: (1, 4)            — bias
    o_ref: (1, 1, W_out, 4)  — output row (written on the last kernel row)
    acc_ref: (W_out, 4) fp32 scratch
    """
    i = pl.program_id(2)          # kernel row index
    kh = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.broadcast_to(b_ref[0].astype(jnp.float32),
                                        acc_ref.shape)

    x = x_ref[0, 0].astype(jnp.float32)      # (W_in, C_in)
    w = w_ref[i].astype(jnp.float32)         # (kw, C_in, 4)

    acc = acc_ref[...]
    for j in range(kw):                       # the shader's column samples
        cols = jax.lax.slice(x, (j, 0),
                             (j + (w_out - 1) * stride + 1, x.shape[1]),
                             (stride, 1))     # (W_out, C_in)
        acc = acc + cols @ w[j]               # MXU: (W_out,C_in)@(C_in,4)
    acc_ref[...] = acc

    @pl.when(i == kh - 1)
    def _flush():
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("stride", "interpret"))
def miniconv_pass(x, w, b, *, stride: int = 1, interpret: bool = True):
    """One shader pass on a pre-padded input (VALID convolution).

    x: (B, H_in, W_in, C_in); w: (kh, kw, C_in, 4); b: (4,).
    Returns (B, H_out, W_out, 4) with
    H_out = (H_in - kh)//stride + 1, W_out = (W_in - kw)//stride + 1.
    """
    B, h_in, w_in, c_in = x.shape
    kh, kw, c_in_w, c_out = w.shape
    assert c_in == c_in_w and c_out == 4, (x.shape, w.shape)
    h_out = (h_in - kh) // stride + 1
    w_out = (w_in - kw) // stride + 1

    grid = (B, h_out, kh)
    return pl.pallas_call(
        functools.partial(_pass_kernel, stride=stride, kw=kw, w_out=w_out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, w_in, c_in),
                         lambda b_, q, i: (b_, q * stride + i, 0, 0)),
            pl.BlockSpec((kh, kw, c_in, 4), lambda b_, q, i: (0, 0, 0, 0)),
            pl.BlockSpec((1, 4), lambda b_, q, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, w_out, 4),
                               lambda b_, q, i: (b_, q, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, h_out, w_out, 4), x.dtype),
        scratch_shapes=[pltpu.VMEM((w_out, 4), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, b.reshape(1, 4))


# ---------------------------------------------------------------------------
# Tier 2: one pallas_call per layer, output-group as a grid dimension
# ---------------------------------------------------------------------------

def _layer_group_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, stride: int,
                        kw: int, w_out: int):
    """One (batch, out_row, kernel_row, group) grid step.

    The group dimension is innermost, so the input-row block index is
    constant across the group sweep — Pallas keeps the row resident in VMEM
    and only the (kw, C_in, 4) weight slice and (1, 4) bias change per step.

    x_ref: (1, 1, W_in, C_in); w_ref: (kh, kw, C_in, 4) group slice;
    b_ref: (1, 4) group slice; o_ref: (1, 1, W_out, 4) group output;
    acc_ref: (n_groups, W_out, 4) fp32 scratch (one accumulator per group).
    """
    i = pl.program_id(2)          # kernel row index
    g = pl.program_id(3)          # output-group index
    kh = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[pl.ds(g, 1)] = jnp.broadcast_to(
            b_ref[0].astype(jnp.float32), (1, w_out, 4))

    x = x_ref[0, 0].astype(jnp.float32)      # (W_in, C_in)
    w = w_ref[i].astype(jnp.float32)         # (kw, C_in, 4)

    acc = acc_ref[pl.ds(g, 1)][0]
    for j in range(kw):
        cols = jax.lax.slice(x, (j, 0),
                             (j + (w_out - 1) * stride + 1, x.shape[1]),
                             (stride, 1))     # (W_out, C_in)
        acc = acc + cols @ w[j]
    acc_ref[pl.ds(g, 1)] = acc[None]

    @pl.when(i == kh - 1)
    def _flush():
        o_ref[0, 0] = acc_ref[pl.ds(g, 1)][0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "interpret"))
def miniconv_layer_grouped(x, w, b, *, stride: int = 1,
                           interpret: bool = True):
    """All output groups of one layer in a single pallas_call (VALID conv).

    x: (B, H_in, W_in, C_in); w: (kh, kw, C_in, C_out) with C_out % 4 == 0
    (callers pad; see ``repro.kernels.ops.miniconv_layer``); b: (C_out,).
    """
    B, h_in, w_in, c_in = x.shape
    kh, kw, c_in_w, c_out = w.shape
    assert c_in == c_in_w and c_out % 4 == 0, (x.shape, w.shape)
    n_groups = c_out // 4
    h_out = (h_in - kh) // stride + 1
    w_out = (w_in - kw) // stride + 1

    grid = (B, h_out, kh, n_groups)
    return pl.pallas_call(
        functools.partial(_layer_group_kernel, stride=stride, kw=kw,
                          w_out=w_out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, w_in, c_in),
                         lambda b_, q, i, g: (b_, q * stride + i, 0, 0)),
            pl.BlockSpec((kh, kw, c_in, 4),
                         lambda b_, q, i, g: (0, 0, 0, g)),
            pl.BlockSpec((1, 4), lambda b_, q, i, g: (g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, w_out, 4),
                               lambda b_, q, i, g: (b_, q, 0, g)),
        out_shape=jax.ShapeDtypeStruct((B, h_out, w_out, c_out), x.dtype),
        scratch_shapes=[pltpu.VMEM((n_groups, w_out, 4), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w, b.reshape(n_groups, 4))


# ---------------------------------------------------------------------------
# Tier 3: the whole encoder as ONE fused kernel
# ---------------------------------------------------------------------------

def _conv_from_padded(xp, w, b, *, out_h: int, out_w: int, stride: int,
                      kernel: int):
    """SAME conv of a pre-padded fp32 image held in VMEM.

    xp: (H_pad, W_pad, C_in); w: (k, k, C_in, C_out); b: (C_out,).
    Returns (out_h, out_w, C_out) fp32.  Each (i, j) tap is one
    (out_h*out_w, C_in) @ (C_in, C_out) MXU matmul — all output groups of
    the layer in a single contraction.
    """
    c_in = xp.shape[-1]
    c_out = w.shape[-1]
    acc = jnp.broadcast_to(b, (out_h, out_w, c_out)).astype(jnp.float32)
    for i in range(kernel):
        for j in range(kernel):
            win = jax.lax.slice(
                xp, (i, j, 0),
                (i + (out_h - 1) * stride + 1,
                 j + (out_w - 1) * stride + 1, c_in),
                (stride, stride, 1))              # (out_h, out_w, C_in)
            tap = win.reshape(out_h * out_w, c_in) @ w[i, j]
            acc = acc + tap.reshape(out_h, out_w, c_out)
    return acc


def _encoder_kernel(*refs, plan, tile_h: int, scratch_rows: int,
                    has_head: bool, head_act: str, streamed: bool = False):
    """One (batch, out_row_tile) grid step of the fused encoder.

    refs layout: x_ref, w_0..w_{L-1}, b_0..b_{L-1}[, hw_ref, hb_ref],
    o_ref[, z_ref][, p_scr][, z_scr].
    ``p_scr`` (absent when L == 1) holds the SAME-padded input of the final
    layer for the current batch element: (scratch_rows, W_pad, C_in_pad)
    fp32, built once on the first tile step and reused by every tile.
    With a fused head, ``hw_ref`` is the FULL (n_tiles, tile_h*W_out*
    C_out_pad, D) tiled head weight, ``z_scr`` the (1, D) fp32 projection
    accumulator and ``z_ref`` the (1, D) projection output block.

    ``x_ref`` and ``hw_ref`` are whole-array blocks (constant index maps);
    the kernel slices out the (batch, tile) pieces it needs with pl.ds.
    Per-step sub-array BlockSpec fetches are pathologically slow in
    interpret mode (~1 ms/MB, re-fetched every grid step) and the x block
    is only consumed on the first tile step anyway; whole-array blocks
    skip the copy entirely.  Compiled-TPU consequence: the whole
    micro-batch input must fit VMEM (~1 MB at the serving scale B=8,
    X=84; stream the batch above that — see ``streamed``).

    With ``streamed=True`` the grid gains a leading batch-CHUNK dimension,
    ``x_ref`` is one chunk's input block (re-fetched HBM->VMEM when the
    chunk index advances; Pallas double-buffers that fetch behind the
    previous chunk's compute on compiled TPU) and ``b_i`` indexes WITHIN
    the chunk — so only ``chunk_b`` frames are VMEM-resident at a time.
    """
    layers = plan.layers
    L = len(layers)
    n_in = 1 + 2 * L + (2 if has_head else 0)
    x_ref = refs[0]
    w_refs = refs[1:1 + L]
    b_refs = refs[1 + L:1 + 2 * L]
    if has_head:
        hw_ref, hb_ref = refs[1 + 2 * L], refs[2 + 2 * L]
    o_ref = refs[n_in]
    z_ref = refs[n_in + 1] if has_head else None
    scr = refs[n_in + (2 if has_head else 1):]
    p_scr = scr[0] if L > 1 else None
    z_scr = scr[-1] if has_head else None
    b_i = pl.program_id(1 if streamed else 0)
    t = pl.program_id(2 if streamed else 1)
    tile_dim = 2 if streamed else 1
    last = layers[-1]

    if L > 1:
        @pl.when(t == 0)
        def _chain_front_layers():
            # Layers 0..L-2 run once per batch element; intermediates stay
            # on-chip and the final layer's padded input is parked in VMEM.
            y = x_ref[pl.ds(b_i, 1)][0].astype(jnp.float32)  # padded input
            for l in range(L - 1):
                m = layers[l]
                y = _conv_from_padded(
                    y, w_refs[l][...].astype(jnp.float32),
                    b_refs[l][0].astype(jnp.float32),
                    out_h=m.out_h, out_w=m.out_w, stride=m.stride,
                    kernel=m.kernel)
                y = _ACTS[m.activation](y)
                nxt = layers[l + 1]
                pad = jnp.zeros((scratch_rows if l == L - 2
                                 else nxt.padded_in_h,
                                 nxt.padded_in_w, nxt.c_in_pad), jnp.float32)
                y = jax.lax.dynamic_update_slice(
                    pad, y, (nxt.pad_top, nxt.pad_left, 0))
            p_scr[...] = y

        src_ref = p_scr
    else:
        src_ref = None

    # Final layer: tile_h output rows per grid step.
    rows_need = (tile_h - 1) * last.stride + last.kernel
    row0 = t * tile_h * last.stride
    if L > 1:
        xp = src_ref[pl.ds(row0, rows_need)]
    else:
        xp = x_ref[pl.ds(b_i, 1),
                   pl.ds(row0, rows_need)][0].astype(jnp.float32)
    acc = _conv_from_padded(
        xp, w_refs[-1][...].astype(jnp.float32),
        b_refs[-1][0].astype(jnp.float32),
        out_h=tile_h, out_w=last.out_w, stride=last.stride,
        kernel=last.kernel)
    y = _ACTS[last.activation](acc)
    o_ref[0] = y.astype(o_ref.dtype)

    if has_head:
        # Fused projection epilogue: contract this tile's activated rows
        # against the matching head-weight rows.  Zero-padded weight rows
        # (beyond plan.out_h) and channels (beyond plan.k_out) null the
        # over-allocated tile rows and RGBA padding.
        @pl.when(t == 0)
        def _z_init():
            z_scr[...] = jnp.broadcast_to(
                hb_ref[0].astype(jnp.float32), z_scr.shape)

        z_scr[...] = z_scr[...] + (
            y.reshape(1, -1) @ hw_ref[pl.ds(t, 1)][0].astype(jnp.float32))

        @pl.when(t == pl.num_programs(tile_dim) - 1)
        def _z_flush():
            z_ref[0] = _ACTS[head_act](z_scr[...])[0].astype(z_ref.dtype)


def _tile_head(head_w, plan, *, tile_h: int, n_tiles: int):
    """Lay a (plan.flat_features, D) head weight out on the kernel's tiled
    feature order: (n_tiles, tile_h*W_out*C_out_pad, D), zero rows beyond
    plan.out_h / channels beyond plan.k_out (they cancel the final tile's
    over-allocated rows and the RGBA padding)."""
    last = plan.layers[-1]
    flat = plan.out_h * plan.out_w * plan.k_out
    assert head_w.shape[0] == flat, (head_w.shape, flat)
    d_out = head_w.shape[1]
    hw = head_w.reshape(plan.out_h, plan.out_w, plan.k_out, d_out)
    hw_pad = jnp.zeros((n_tiles * tile_h, last.out_w, last.c_out_pad,
                        d_out), head_w.dtype)
    hw_pad = jax.lax.dynamic_update_slice(hw_pad, hw, (0, 0, 0, 0))
    return hw_pad.reshape(n_tiles, tile_h * last.out_w * last.c_out_pad,
                          d_out)


@functools.partial(jax.jit, static_argnames=("plan", "tile_h"))
def prepare_fused_head(head_w, plan, *, tile_h: int = 8):
    """Pre-tile a (plan.flat_features, D) head weight for the fused-head
    epilogue.  :func:`miniconv_encoder` tiles a 2-D ``head_w`` per call
    (inside the launch, a multi-MB zeros+copy); hot serving paths should
    call this ONCE per head and pass the 3-D result instead."""
    tile_h = max(1, min(tile_h, plan.out_h))
    n_tiles = -(-plan.out_h // tile_h)
    return _tile_head(head_w, plan, tile_h=tile_h, n_tiles=n_tiles)


def miniconv_encoder(x, weights, biases, plan, *, tile_h: int = 8,
                     head_w=None, head_b=None, head_act: str = "relu",
                     interpret=None):
    """Execute a whole :class:`~repro.core.passplan.PassPlan` as ONE kernel.

    x: (B, H, W, C_in) with (H, W) == (plan.in_h, plan.in_w); batch is the
    outer grid dimension, so a micro-batch of frames is a single launch.
    weights/biases: per-layer lists matching ``plan.spec.layers``.
    Returns (B, plan.out_h, plan.out_w, plan.k_out) in x.dtype — bitwise
    semantics match the per-pass path (SAME padding, fp32 accumulation,
    per-layer activation) within float tolerance.

    ``head_w`` ((plan.flat_features, D), optional) fuses the server-side
    linear projection into the kernel epilogue: the return value becomes
    ``(features, head_act(features.reshape(B, -1) @ head_w + head_b))``
    with the (B, D) projection accumulated tile-by-tile inside the kernel.
    A 3-D ``head_w`` is taken as already tiled by :func:`prepare_fused_head`
    (with the SAME ``tile_h``), skipping the per-call tiling copy.
    """
    # resolve the env-dependent default OUTSIDE the jit cache so flipping
    # REPRO_PALLAS_COMPILE between calls is honoured
    if interpret is None:
        interpret = (not os.environ.get("REPRO_PALLAS_COMPILE")
                     and jax.default_backend() != "tpu")
    return _miniconv_encoder(x, weights, biases, plan, tile_h=tile_h,
                             head_w=head_w, head_b=head_b,
                             head_act=head_act, interpret=interpret)


def _prep_fused_inputs(x, weights, biases, plan, *, tile_h: int,
                       head_w, head_b):
    """Shared argument preparation for the fused / streamed encoders.

    Pads the input batch to RGBA channel multiples with layer-0 SAME
    padding baked in, zero-pads per-layer weights/biases, tiles and
    lane-pads the optional head weight, and derives every static dimension
    both launch shapes need.  Returns a plain dict so the single-launch
    and batch-streamed callers build their own grids/BlockSpecs over
    IDENTICAL kernel operands (this is what makes them bitwise-equal).
    """
    layers = plan.layers
    L = len(layers)
    B, h, w_sz, c_in = x.shape
    assert (h, w_sz) == (plan.in_h, plan.in_w), (x.shape, plan.in_h,
                                                 plan.in_w)
    assert c_in == layers[0].c_in and len(weights) == L == len(biases)
    has_head = head_w is not None

    tile_h = max(1, min(tile_h, plan.out_h))
    n_tiles = -(-plan.out_h // tile_h)
    last = layers[-1]
    # Rows the last tile may read past the exact padded input: over-allocate
    # zero rows at the bottom so every pl.ds stays in bounds.
    rows_need_max = (n_tiles * tile_h - 1) * last.stride + last.kernel
    scratch_rows = max(last.padded_in_h, rows_need_max)

    # Zero-pad channels to RGBA multiples and bake in layer-0 SAME padding.
    first = layers[0]
    x0_rows = scratch_rows if L == 1 else first.padded_in_h
    xp = jnp.zeros((B, x0_rows, first.padded_in_w, first.c_in_pad), x.dtype)
    xp = jax.lax.dynamic_update_slice(
        xp, x, (0, first.pad_top, first.pad_left, 0))
    ws, bs = [], []
    for l, (wt, bi) in enumerate(zip(weights, biases)):
        m = layers[l]
        wp = jnp.zeros((m.kernel, m.kernel, m.c_in_pad, m.c_out_pad),
                       wt.dtype)
        wp = jax.lax.dynamic_update_slice(wp, wt, (0, 0, 0, 0))
        bp = jnp.zeros((1, m.c_out_pad), bi.dtype)
        bp = jax.lax.dynamic_update_slice(bp, bi[None], (0, 0))
        ws.append(wp)
        bs.append(bp)

    hw_pad = hb = None
    d_out = d_pad = 0
    tile_flat = tile_h * last.out_w * last.c_out_pad
    if has_head:
        if head_w.ndim == 3:              # pre-tiled by prepare_fused_head
            assert head_w.shape[:2] == (n_tiles, tile_flat), \
                (head_w.shape, n_tiles, tile_flat)
            hw_pad = head_w
        else:
            hw_pad = _tile_head(head_w, plan, tile_h=tile_h,
                                n_tiles=n_tiles)
        # Lane-pad the projection width to a multiple of 128 so the
        # epilogue matmul fills whole MXU lanes (D=512 is already aligned;
        # ragged widths gain zero columns that are sliced off below).
        d_out = hw_pad.shape[-1]
        d_pad = -(-d_out // 128) * 128
        if d_pad != d_out:
            hw_pad = jnp.pad(hw_pad, ((0, 0), (0, 0), (0, d_pad - d_out)))
        hb = (jnp.zeros((d_out,), hw_pad.dtype) if head_b is None
              else head_b)
        if d_pad != d_out:
            hb = jnp.pad(hb, ((0, d_pad - d_out),))
        hb = hb.reshape(1, d_pad)

    scratch_shapes = []
    if L > 1:
        scratch_shapes.append(pltpu.VMEM(
            (scratch_rows, last.padded_in_w, last.c_in_pad), jnp.float32))
    if has_head:
        scratch_shapes.append(pltpu.VMEM((1, d_pad), jnp.float32))

    return dict(xp=xp, ws=ws, bs=bs, hw_pad=hw_pad, hb=hb,
                has_head=has_head, tile_h=tile_h, n_tiles=n_tiles,
                tile_flat=tile_flat, scratch_rows=scratch_rows,
                x0_rows=x0_rows, d_out=d_out, d_pad=d_pad,
                scratch_shapes=scratch_shapes, B=B, L=L,
                first=first, last=last)


@functools.partial(jax.jit, static_argnames=("plan", "tile_h", "head_act",
                                             "interpret"))
def _miniconv_encoder(x, weights, biases, plan, *, tile_h: int,
                      head_w, head_b, head_act: str, interpret: bool):
    p = _prep_fused_inputs(x, weights, biases, plan, tile_h=tile_h,
                           head_w=head_w, head_b=head_b)
    B, L, first, last = p["B"], p["L"], p["first"], p["last"]
    tile_h, n_tiles = p["tile_h"], p["n_tiles"]

    # Whole-array block (constant index map): the kernel slices out the
    # batch element itself — see the interpret-mode fetch note in
    # _encoder_kernel's docstring.
    in_specs = [pl.BlockSpec(
        (B, p["x0_rows"], first.padded_in_w, first.c_in_pad),
        lambda b_, t: (0, 0, 0, 0))]
    for l in range(L):
        m = plan.layers[l]
        in_specs.append(pl.BlockSpec(
            (m.kernel, m.kernel, m.c_in_pad, m.c_out_pad),
            lambda b_, t: (0, 0, 0, 0)))
    for l in range(L):
        m = plan.layers[l]
        in_specs.append(pl.BlockSpec((1, m.c_out_pad),
                                     lambda b_, t: (0, 0)))

    args = [p["xp"], *p["ws"], *p["bs"]]
    out_specs = [pl.BlockSpec((1, tile_h, last.out_w, last.c_out_pad),
                              lambda b_, t: (b_, t, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct(
        (B, n_tiles * tile_h, last.out_w, last.c_out_pad), x.dtype)]
    if p["has_head"]:
        d_pad = p["d_pad"]
        in_specs.append(pl.BlockSpec((n_tiles, p["tile_flat"], d_pad),
                                     lambda b_, t: (0, 0, 0)))
        in_specs.append(pl.BlockSpec((1, d_pad), lambda b_, t: (0, 0)))
        args += [p["hw_pad"], p["hb"]]
        out_specs.append(pl.BlockSpec((1, d_pad), lambda b_, t: (b_, 0)))
        out_shape.append(jax.ShapeDtypeStruct((B, d_pad), x.dtype))

    out = pl.pallas_call(
        functools.partial(_encoder_kernel, plan=plan, tile_h=tile_h,
                          scratch_rows=p["scratch_rows"],
                          has_head=p["has_head"], head_act=head_act),
        grid=(B, n_tiles),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=p["scratch_shapes"],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
    feats = out[0][:, :plan.out_h, :, :plan.k_out]
    return (feats, out[1][:, :p["d_out"]]) if p["has_head"] else feats


# ---------------------------------------------------------------------------
# Tier 4: large-batch streaming (the batch no longer has to fit VMEM)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("plan", "chunk_b", "tile_h",
                                             "head_act", "interpret"))
def _miniconv_encoder_pipelined(x, weights, biases, plan, *, chunk_b: int,
                                tile_h: int, head_w, head_b, head_act: str,
                                interpret: bool):
    """ONE pallas_call over a (n_chunks, chunk_b, n_tiles) grid.

    The input BlockSpec covers one ``chunk_b``-frame chunk and its index
    map advances with the chunk grid dimension, so only one chunk's input
    block is VMEM-resident at a time; on compiled TPU, Pallas's revolving
    block buffers fetch chunk c+1 HBM->VMEM while chunk c computes (the
    double-buffered pipeline).  The batch is zero-padded up to a whole
    number of chunks; padded frames compute garbage that is sliced off
    (each batch element is independent, so real frames are bitwise
    unaffected).
    """
    B = x.shape[0]
    n_chunks = -(-B // chunk_b)
    b_pad = n_chunks * chunk_b
    if b_pad != B:
        x = jnp.pad(x, ((0, b_pad - B), (0, 0), (0, 0), (0, 0)))
    p = _prep_fused_inputs(x, weights, biases, plan, tile_h=tile_h,
                           head_w=head_w, head_b=head_b)
    L, first, last = p["L"], p["first"], p["last"]
    tile_h, n_tiles = p["tile_h"], p["n_tiles"]

    # Per-chunk input block: fetched when the chunk index advances.
    in_specs = [pl.BlockSpec(
        (chunk_b, p["x0_rows"], first.padded_in_w, first.c_in_pad),
        lambda c, b_, t: (c, 0, 0, 0))]
    for l in range(L):
        m = plan.layers[l]
        in_specs.append(pl.BlockSpec(
            (m.kernel, m.kernel, m.c_in_pad, m.c_out_pad),
            lambda c, b_, t: (0, 0, 0, 0)))
    for l in range(L):
        m = plan.layers[l]
        in_specs.append(pl.BlockSpec((1, m.c_out_pad),
                                     lambda c, b_, t: (0, 0)))

    args = [p["xp"], *p["ws"], *p["bs"]]
    out_specs = [pl.BlockSpec(
        (1, tile_h, last.out_w, last.c_out_pad),
        lambda c, b_, t: (c * chunk_b + b_, t, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct(
        (b_pad, n_tiles * tile_h, last.out_w, last.c_out_pad), x.dtype)]
    if p["has_head"]:
        d_pad = p["d_pad"]
        in_specs.append(pl.BlockSpec((n_tiles, p["tile_flat"], d_pad),
                                     lambda c, b_, t: (0, 0, 0)))
        in_specs.append(pl.BlockSpec((1, d_pad), lambda c, b_, t: (0, 0)))
        args += [p["hw_pad"], p["hb"]]
        out_specs.append(pl.BlockSpec((1, d_pad),
                                      lambda c, b_, t: (c * chunk_b + b_, 0)))
        out_shape.append(jax.ShapeDtypeStruct((b_pad, d_pad), x.dtype))

    out = pl.pallas_call(
        functools.partial(_encoder_kernel, plan=plan, tile_h=tile_h,
                          scratch_rows=p["scratch_rows"],
                          has_head=p["has_head"], head_act=head_act,
                          streamed=True),
        grid=(n_chunks, chunk_b, n_tiles),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=p["scratch_shapes"],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
    feats = out[0][:B, :plan.out_h, :, :plan.k_out]
    return (feats, out[1][:B, :p["d_out"]]) if p["has_head"] else feats


def miniconv_encoder_stream(x, weights, biases, plan, *, chunk_b: int,
                            tile_h: int = 8, head_w=None, head_b=None,
                            head_act: str = "relu", interpret=None,
                            pipelined=None):
    """Fused encoder over a micro-batch LARGER than the VMEM budget allows.

    Splits the (B, H, W, C) batch into ``chunk_b``-frame chunks so only one
    chunk's input is VMEM-resident at a time (``chunk_b`` should come from
    ``PassPlan.max_safe_batch``).  Two execution strategies:

    * ``pipelined=True`` — ONE pallas_call whose grid iterates chunks;
      per-chunk input BlockSpecs give the double-buffered HBM->VMEM fetch
      on compiled TPU.  Default on compiled TPU.  Bitwise equal to the
      single whole-batch fused launch.
    * ``pipelined=False`` — automatic multi-launch splitting: one fused
      launch per chunk (at most two compiled programs: the full chunk and
      the remainder).  The portable fallback; default everywhere else
      (per-step block fetches are pathologically slow in interpret mode).
      Bitwise equal to running :func:`miniconv_encoder` chunk-by-chunk and
      concatenating — by construction.

    When ``B % chunk_b == 0`` the two strategies are themselves bitwise
    identical (every chunk launch has the same grid shape as the streamed
    grid's inner steps).  A ragged remainder chunk may differ from the
    whole-batch launch by float-associativity ulps in the head projection
    (XLA schedules a size-1 grid differently); features are always
    bitwise.

    Returns the same (features[, projection]) as :func:`miniconv_encoder`.
    """
    if chunk_b < 1:
        raise ValueError(f"chunk_b must be >= 1, got {chunk_b}")
    if interpret is None:
        interpret = (not os.environ.get("REPRO_PALLAS_COMPILE")
                     and jax.default_backend() != "tpu")
    B = x.shape[0]
    if B <= chunk_b:                      # fits one launch: nothing to stream
        return _miniconv_encoder(x, weights, biases, plan, tile_h=tile_h,
                                 head_w=head_w, head_b=head_b,
                                 head_act=head_act, interpret=interpret)
    if pipelined is None:
        pipelined = not interpret and jax.default_backend() == "tpu"
    if pipelined:
        return _miniconv_encoder_pipelined(
            x, weights, biases, plan, chunk_b=chunk_b, tile_h=tile_h,
            head_w=head_w, head_b=head_b, head_act=head_act,
            interpret=interpret)
    # Multi-launch splitting: tile the head ONCE (not per chunk).
    if head_w is not None and head_w.ndim == 2:
        head_w = prepare_fused_head(head_w, plan, tile_h=tile_h)
    chunks = [
        _miniconv_encoder(x[i:i + chunk_b], weights, biases, plan,
                          tile_h=tile_h, head_w=head_w, head_b=head_b,
                          head_act=head_act, interpret=interpret)
        for i in range(0, B, chunk_b)]
    if head_w is not None:
        return (jnp.concatenate([c[0] for c in chunks]),
                jnp.concatenate([c[1] for c in chunks]))
    return jnp.concatenate(chunks)


__all__ = ["miniconv_pass", "miniconv_layer_grouped", "miniconv_encoder",
           "miniconv_encoder_stream", "prepare_fused_head"]
