"""Blocked (flash) attention Pallas kernel for prefill.

Grid = (batch, head, q_block, kv_block) with the kv dimension sequential:
each step streams one (block_k, d) K/V tile through VMEM, maintaining the
online-softmax running max / normaliser / accumulator in fp32 scratch.
Causal and sliding-window masks are applied per block; fully-masked blocks
contribute nothing (their running-max update is a no-op).

Block sizes default to (128, 128): MXU-aligned on both matmul dims.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int,
                  causal: bool, window: Optional[int]):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                # (bk, d)

    s = q @ k.T                                        # (bq, bk)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]                                # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                             # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)

    l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "sliding_window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    sliding_window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q, k, v: (B, H, S, D) -> (B, H, S, D).  GQA handled by the caller
    (repeat K/V heads before the call)."""
    B, H, S, D = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    grid = (B, H, S // block_q, S // block_k)
    scale = D ** -0.5

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=sliding_window)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
