"""Pallas kernels for the MiniConv shader-pass schedule.

Module map
----------
``miniconv_pass``
    The execution tiers behind the ``repro.core.backends`` registry:
    :func:`~repro.kernels.miniconv_pass.miniconv_pass` (per-pass oracle,
    backend ``reference``), :func:`~repro.kernels.miniconv_pass.
    miniconv_layer_grouped` (``grouped``), :func:`~repro.kernels.
    miniconv_pass.miniconv_encoder` (``fused`` / ``fused+head`` — the
    whole encoder, optionally with the projection epilogue, as ONE
    pallas_call) and :func:`~repro.kernels.miniconv_pass.
    miniconv_encoder_stream` (``fused+stream`` — the fused kernel
    pipelined over batch chunks, lifting the batch-must-fit-VMEM cap).
``ops``
    Public jit'd wrappers (``miniconv_layer``) used by the per-pass and
    grouped tiers.
``ref``
    Pure-jnp oracles every kernel here is parity-tested against.
``flash_attention``
    Blocked (flash) attention prefill kernel for the baselines.
``pallas_compat``
    Pallas API version shims plus ``compiled_pallas_supported()``, the
    probe gating the ``REPRO_PALLAS_COMPILE=1`` compiled-path tier.
"""
