"""Version/platform compatibility helpers for the Pallas TPU API.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in newer
JAX releases; this repo runs on both.  ``compiled_pallas_supported`` probes
whether THIS host can execute a non-interpret ``pallas_call`` at all — the
gate for the ``REPRO_PALLAS_COMPILE=1`` test/bench tier (most CPU-only JAX
builds raise "Only interpret mode is supported on CPU backend").
"""
from __future__ import annotations

import functools

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:                       # older JAX (<= 0.4.x)
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


@functools.lru_cache(maxsize=1)
def compiled_pallas_supported() -> bool:
    """True when a compiled (non-interpret) pallas_call can run here.

    TPU hosts always qualify; elsewhere a trivial kernel is attempted once
    and the result cached, so the ``REPRO_PALLAS_COMPILE=1`` tier can skip
    with an explicit marker instead of erroring mid-suite.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    if jax.default_backend() == "tpu":
        return True
    try:
        def _k(x_ref, o_ref):
            o_ref[...] = x_ref[...] + 1.0

        out = pl.pallas_call(
            _k, out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
            interpret=False)(jnp.zeros((8, 128), jnp.float32))
        jax.block_until_ready(out)
        return True
    except Exception:  # repro: allow(broad-except) -- compat probe: ANY failure means "compiled pallas unsupported here"
        return False


__all__ = ["compiled_pallas_supported", "tpu_compiler_params"]
