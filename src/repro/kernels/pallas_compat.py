"""Version compatibility helpers for the Pallas TPU API.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in newer
JAX releases; this repo runs on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:                       # older JAX (<= 0.4.x)
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


__all__ = ["tpu_compiler_params"]
