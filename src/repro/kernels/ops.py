"""Public jit'd wrappers around the Pallas kernels.

On this CPU container the kernels run in interpret mode (the kernel body is
executed in Python for correctness); on TPU set ``REPRO_PALLAS_COMPILE=1``
or pass interpret=False explicitly.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.miniconv_pass import (miniconv_encoder,
                                         miniconv_layer_grouped,
                                         miniconv_pass)


def _default_interpret() -> bool:
    if os.environ.get("REPRO_PALLAS_COMPILE"):
        return False
    return jax.default_backend() != "tpu"


def same_pad(x, kernel: int, stride: int):
    """SAME padding for a square kernel so the Pallas pass (VALID) matches
    XLA's SAME conv."""
    _, h, w, _ = x.shape
    out_h = -(-h // stride)
    out_w = -(-w // stride)
    pad_h = max((out_h - 1) * stride + kernel - h, 0)
    pad_w = max((out_w - 1) * stride + kernel - w, 0)
    return jnp.pad(x, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                       (pad_w // 2, pad_w - pad_w // 2), (0, 0)))


def _pad_groups(kernel, bias):
    """Zero-pad the output channels to a multiple of 4 (RGBA packing).

    ``LayerSpec.n_passes = ceil(c_out/4)`` admits c_out % 4 != 0; the final
    output group then renders a partially-used RGBA target.  The kernels
    always write full 4-channel groups, so we pad the weights/bias with
    zero channels and the caller slices the result back.
    """
    c_out = kernel.shape[-1]
    pad = (-c_out) % 4
    if pad:
        kernel = jnp.pad(kernel, ((0, 0), (0, 0), (0, 0), (0, pad)))
        bias = jnp.pad(bias, ((0, pad),))
    return kernel, bias, c_out


def miniconv_layer(x, kernel, bias, *, stride: int = 1,
                   interpret: Optional[bool] = None,
                   fused_groups: bool = False):
    """One MiniConv layer = ceil(c_out/4) shader passes (SAME padding).

    x: (B,H,W,C_in); kernel: (kh,kw,C_in,C_out); bias: (C_out,).
    ``fused_groups=True`` executes all output groups in a single
    pallas_call (output-group as a grid dimension); the default runs one
    pallas_call per pass — the legacy reference path.
    """
    interpret = _default_interpret() if interpret is None else interpret
    kh = kernel.shape[0]
    kernel, bias, c_out = _pad_groups(kernel, bias)
    xp = same_pad(x, kh, stride)
    if fused_groups:
        out = miniconv_layer_grouped(xp, kernel, bias, stride=stride,
                                     interpret=interpret)
    else:
        outs = [miniconv_pass(xp, kernel[..., g:g + 4], bias[g:g + 4],
                              stride=stride, interpret=interpret)
                for g in range(0, kernel.shape[-1], 4)]
        out = jnp.concatenate(outs, axis=-1) if len(outs) > 1 else outs[0]
    return out[..., :c_out]


def causal_attention(q, k, v, *, sliding_window: Optional[int] = None,
                     block_q: int = 128, block_k: int = 128,
                     interpret: Optional[bool] = None):
    """(B, H, S, D) flash attention wrapper (causal)."""
    interpret = _default_interpret() if interpret is None else interpret
    return flash_attention(q, k, v, causal=True,
                           sliding_window=sliding_window,
                           block_q=block_q, block_k=block_k,
                           interpret=interpret)


__all__ = ["miniconv_layer", "causal_attention", "miniconv_pass",
           "miniconv_layer_grouped", "miniconv_encoder", "flash_attention",
           "same_pad"]
