"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def miniconv_pass_ref(x, w, b, *, stride: int = 1):
    """VALID conv oracle matching kernels.miniconv_pass."""
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return (y + b.astype(jnp.float32)).astype(x.dtype)


def attention_ref(q, k, v, *, causal: bool = True,
                  sliding_window: Optional[int] = None, scale=None):
    """Oracle for kernels.flash_attention.  q,k,v: (B, H, S, D)."""
    B, H, S, D = q.shape
    scale = scale if scale is not None else D ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= k_pos <= q_pos
    if sliding_window is not None:
        mask &= k_pos > q_pos - sliding_window
    logits = jnp.where(mask[None, None], logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)
