"""Unified split-policy RL trainer reproducing the paper's pairings:

  Walker2d  + PPO   (Table 2)
  Hopper    + SAC   (Table 3)
  Pendulum  + DDPG  (Table 4)

Each condition swaps ONLY the observation encoder (Full-CNN vs MiniConv
K=4 / K=16), exactly as in the paper; the downstream heads, algorithm and
hyperparameters are held fixed within a task.

Reports Best / Mean / Final (mean over last 100 episodes) per the paper's
summary statistics.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.envs import make_pixel_env
from repro.nn.module import KeyGen
from repro.rl.buffers import ReplayBuffer
from repro.rl.ddpg import DDPGConfig, init_ddpg, make_ddpg_update
from repro.rl.networks import make_encoder
from repro.rl.ppo import PPOConfig, make_ppo_step
from repro.rl.sac import SACConfig, init_sac, make_sac_update

TASK_ALGO = {"walker": "ppo", "hopper": "sac", "pendulum": "ddpg"}


def _pipeline_encoder(encoder_name: str, c_in: int, *,
                      deploy_config: "Optional[DeploymentConfig]" = None):
    """Every trainer constructs its encoder pipeline via Deployment.build.

    Training runs the differentiable ``xla`` backend; the SAME
    DeploymentConfig (with the deployment backend swapped in) later serves
    the trained parameters, so train and deploy can never disagree on the
    spec, plan, or head.  ``full_cnn`` — the paper's server-only baseline —
    has no split pipeline and bypasses Deployment.
    """
    # lazy: repro.deploy composes rl.networks primitives, so the trainer
    # imports it per call to keep the package import acyclic
    from repro.deploy import Deployment, DeploymentConfig
    if deploy_config is not None:
        return Deployment.build(deploy_config).encoder
    if encoder_name == "full_cnn":
        return make_encoder(encoder_name, c_in=c_in)
    cfg = DeploymentConfig.from_encoder_name(encoder_name, c_in=c_in,
                                             backend="xla")
    return Deployment.build(cfg).encoder


@dataclasses.dataclass
class TrainResult:
    task: str
    algo: str
    encoder: str
    episode_returns: list[float]
    wall_time_s: float

    @property
    def best(self) -> float:
        return max(self.episode_returns) if self.episode_returns else float("nan")

    @property
    def mean(self) -> float:
        return float(np.mean(self.episode_returns)) if self.episode_returns \
            else float("nan")

    @property
    def final(self) -> float:
        """Mean episodic return over the final 100 episodes (paper metric)."""
        if not self.episode_returns:
            return float("nan")
        return float(np.mean(self.episode_returns[-100:]))

    def summary(self) -> dict:
        return {"task": self.task, "algo": self.algo, "encoder": self.encoder,
                "best": self.best, "final": self.final, "mean": self.mean,
                "episodes": len(self.episode_returns)}


def _track_episodes(returns_buf, ep_ret, rewards, dones):
    """Accumulate per-env episodic returns from (T, N) reward/done arrays."""
    rewards = np.asarray(rewards)
    dones = np.asarray(dones)
    for t in range(rewards.shape[0]):
        ep_ret += rewards[t]
        for i in np.nonzero(dones[t])[0]:
            returns_buf.append(float(ep_ret[i]))
            ep_ret[i] = 0.0
    return ep_ret


def train_ppo(task: str, encoder_name: str, *, total_steps: int = 20_000,
              seed: int = 0, cfg: Optional[PPOConfig] = None,
              log_every: int = 10, verbose: bool = False,
              deploy_config: Optional[DeploymentConfig] = None) -> TrainResult:
    cfg = cfg or PPOConfig()
    env = make_pixel_env(task, train=True)
    encoder = _pipeline_encoder(encoder_name, env.obs_shape[-1],
                                deploy_config=deploy_config)
    step_fn, init_carry = make_ppo_step(env, encoder, cfg)
    params, opt_state, env_states, obs = init_carry(jax.random.PRNGKey(seed))

    returns: list[float] = []
    ep_ret = np.zeros(cfg.n_envs)
    t0 = time.time()
    n_iters = max(total_steps // (cfg.n_steps * cfg.n_envs), 1)
    key = jax.random.PRNGKey(seed + 1)
    for it in range(n_iters):
        key, sub = jax.random.split(key)
        params, opt_state, env_states, obs, metrics, traj = step_fn(
            params, opt_state, env_states, obs, sub)
        ep_ret = _track_episodes(returns, ep_ret, traj["reward"],
                                 traj["done"])
        if verbose and it % log_every == 0:
            print(f"  [ppo {encoder_name}] iter {it} "
                  f"mean_r={float(metrics['mean_reward']):.3f} "
                  f"episodes={len(returns)}")
    return TrainResult(task, "ppo", encoder_name, returns,
                       time.time() - t0)


def _train_offpolicy(task: str, encoder_name: str, algo: str, *,
                     total_steps: int, seed: int,
                     cfg, verbose: bool = False,
                     deploy_config: Optional[DeploymentConfig] = None
                     ) -> TrainResult:
    env = make_pixel_env(task, train=True)
    encoder = _pipeline_encoder(encoder_name, env.obs_shape[-1],
                                deploy_config=deploy_config)
    kg = KeyGen(jax.random.PRNGKey(seed))

    if algo == "sac":
        params, target = init_sac(kg(), encoder, env.action_dim)
        update, act, opt = make_sac_update(encoder, env.action_dim, cfg)
    else:
        params, target = init_ddpg(kg(), encoder, env.action_dim)
        update, act, opt = make_ddpg_update(encoder, env.action_dim, cfg)
    opt_state = opt.init(params)

    buf = ReplayBuffer(cfg.buffer_size, env.obs_shape, env.action_dim, seed)
    reset_jit = jax.jit(env.reset)
    step_jit = jax.jit(env.step)

    state, obs = reset_jit(kg())
    returns: list[float] = []
    ep_ret = 0.0
    t0 = time.time()
    for t in range(total_steps):
        if t < cfg.learning_starts:
            action = np.random.default_rng(seed + t).uniform(
                -1, 1, env.action_dim).astype(np.float32)
            action = jnp.asarray(action)
        else:
            if algo == "sac":
                action, _ = act(params, obs[None], kg())
            else:
                action, _ = act(params, obs[None], kg())
            action = action[0]
        new_state, next_obs, reward, done = step_jit(state, action)
        buf.add_batch(np.asarray(obs)[None], np.asarray(action)[None],
                      np.asarray(reward)[None], np.asarray(next_obs)[None],
                      np.asarray(done)[None])
        ep_ret += float(reward)
        if bool(done):
            returns.append(ep_ret)
            ep_ret = 0.0
        state, obs = new_state, next_obs

        if t >= cfg.learning_starts and len(buf) >= cfg.batch_size:
            batch = jax.tree.map(jnp.asarray, buf.sample(cfg.batch_size))
            if algo == "sac":
                params, target, opt_state, m = update(
                    params, target, opt_state, batch, kg())
            else:
                params, target, opt_state, m = update(
                    params, target, opt_state, batch)
            if verbose and t % 500 == 0:
                print(f"  [{algo} {encoder_name}] step {t} "
                      + " ".join(f"{k}={float(v):.3f}" for k, v in m.items())
                      + f" episodes={len(returns)}")
    return TrainResult(task, algo, encoder_name, returns, time.time() - t0)


def train(task: str, encoder_name: str, *, total_steps: int = 20_000,
          seed: int = 0, verbose: bool = False,
          deploy_config: Optional[DeploymentConfig] = None) -> TrainResult:
    """Train the paper's (task, algorithm) pairing with a given encoder.

    ``deploy_config`` (optional) trains against an explicit
    :class:`repro.deploy.DeploymentConfig` instead of the named encoder's
    default, so a serialised deployment manifest can drive training too.
    """
    algo = TASK_ALGO[task]
    if algo == "ppo":
        return train_ppo(task, encoder_name, total_steps=total_steps,
                         seed=seed, verbose=verbose,
                         deploy_config=deploy_config)
    if algo == "sac":
        return _train_offpolicy(task, encoder_name, "sac",
                                total_steps=total_steps, seed=seed,
                                cfg=SACConfig(), verbose=verbose,
                                deploy_config=deploy_config)
    return _train_offpolicy(task, encoder_name, "ddpg",
                            total_steps=total_steps, seed=seed,
                            cfg=DDPGConfig(), verbose=verbose,
                            deploy_config=deploy_config)
