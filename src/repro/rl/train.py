"""Unified split-policy RL trainer reproducing the paper's pairings:

  Walker2d  + PPO   (Table 2)
  Hopper    + SAC   (Table 3)
  Pendulum  + DDPG  (Table 4)

Each condition swaps ONLY the observation encoder (Full-CNN vs MiniConv
K=4 / K=16), exactly as in the paper; the downstream heads, algorithm and
hyperparameters are held fixed within a task.

ONE generic driver: the algorithm is a frozen
:class:`~repro.rl.agent.Agent` bundle and the loop is a compiled
:class:`~repro.rl.rollout.Engine` — the driver never branches on the
algorithm.  All three algorithms train vectorised over ``cfg.n_envs``
parallel envs; off-policy training (SAC/DDPG) runs entirely on device
(rollout + replay + gradient steps fused in one scan), so only per-chunk
``(T, N)`` reward/done arrays cross to the host for episode tracking.

Reports Best / Mean / Final (mean over last 100 episodes) per the paper's
summary statistics.  Episodes truncated by the end of training are
counted explicitly (``truncated_returns``) instead of being silently
dropped, so episode counts are consistent across engines and ``n_envs``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.envs import make_pixel_env
from repro.rl.agent import Agent, make_agent
from repro.rl.rollout import make_engine

TASK_ALGO = {"walker": "ppo", "hopper": "sac", "pendulum": "ddpg"}


def _pipeline_encoder(encoder_name: str, c_in: int, *,
                      deploy_config: "Optional[DeploymentConfig]" = None):
    """Every trainer constructs its encoder pipeline via Deployment.build.

    Training runs the differentiable ``xla`` backend; the SAME
    DeploymentConfig (with the deployment backend swapped in) later serves
    the trained parameters, so train and deploy can never disagree on the
    spec, plan, or head.  ``full_cnn`` — the paper's server-only baseline —
    has no split pipeline and bypasses Deployment.
    """
    # lazy: repro.deploy composes rl.networks primitives, so the trainer
    # imports it per call to keep the package import acyclic
    from repro.deploy import Deployment, DeploymentConfig
    from repro.rl.networks import make_encoder
    if deploy_config is not None:
        return Deployment.build(deploy_config).encoder
    if encoder_name == "full_cnn":
        return make_encoder(encoder_name, c_in=c_in)
    cfg = DeploymentConfig.from_encoder_name(encoder_name, c_in=c_in,
                                             backend="xla")
    return Deployment.build(cfg).encoder


@dataclasses.dataclass
class TrainResult:
    task: str
    algo: str
    encoder: str
    episode_returns: list[float]
    wall_time_s: float
    truncated_returns: list[float] = dataclasses.field(default_factory=list)
    env_steps: int = 0
    params: Any = None            # trained parameter pytree (TrainState.params)
    # compile/steady split: the FIRST call of each distinct engine phase
    # shape pays XLA compile (minutes on CPU hosts); repeated shapes run
    # the cached program.  Reporting one blended steps/sec made the perf
    # trajectory compile-dominated, so the driver records both.
    compile_s: float = 0.0        # wall spent in first-call (compiling) phases
    steady_env_steps: int = 0     # env steps from repeated (cached) phases
    steady_wall_s: float = 0.0    # wall spent in repeated (cached) phases

    @property
    def all_returns(self) -> list[float]:
        """Completed episodes followed by the end-of-training truncated
        partials (the paper reports per-episode returns; dropping the
        final partial silently skewed episode counts between engines)."""
        return self.episode_returns + self.truncated_returns

    @property
    def _stat_returns(self) -> list[float]:
        """Best/Mean/Final are the paper's per-EPISODE statistics, so they
        use completed episodes whenever any exist — a short truncated
        partial must not become "Best" on a negative-reward task.  Only
        when a run is too short to complete a single episode (smoke
        scale) do the truncated partials stand in, keeping the stats
        finite."""
        return self.episode_returns or self.truncated_returns

    @property
    def best(self) -> float:
        r = self._stat_returns
        return max(r) if r else float("nan")

    @property
    def mean(self) -> float:
        r = self._stat_returns
        return float(np.mean(r)) if r else float("nan")

    @property
    def final(self) -> float:
        """Mean episodic return over the final 100 episodes (paper metric)."""
        r = self._stat_returns
        if not r:
            return float("nan")
        return float(np.mean(r[-100:]))

    @property
    def steps_per_sec(self) -> float:
        """End-to-end throughput (compile included) — the cost of running
        this condition once from scratch."""
        return self.env_steps / self.wall_time_s if self.wall_time_s > 0 \
            else float("nan")

    @property
    def steady_steps_per_sec(self) -> float:
        """Throughput of the cached (already-compiled) phases only; NaN
        when the run was too short for any phase shape to repeat."""
        if self.steady_wall_s > 0 and self.steady_env_steps > 0:
            return self.steady_env_steps / self.steady_wall_s
        return float("nan")

    def summary(self) -> dict:
        return {"task": self.task, "algo": self.algo, "encoder": self.encoder,
                "best": self.best, "final": self.final, "mean": self.mean,
                "episodes": len(self.all_returns),
                "episodes_completed": len(self.episode_returns),
                "episodes_truncated": len(self.truncated_returns),
                "env_steps": self.env_steps,
                "steps_per_sec": self.steps_per_sec,
                "compile_s": self.compile_s,
                # null (not NaN) in JSON artifacts when no phase repeated
                "steady_steps_per_sec": (
                    self.steady_steps_per_sec
                    if np.isfinite(self.steady_steps_per_sec) else None)}


def _track_episodes(returns_buf, ep_ret, ep_len, rewards, dones):
    """Accumulate per-env episodic returns from (T, N) reward/done arrays.

    ``ep_len`` counts steps since each env's last completed episode so the
    driver can flush genuinely-started partial episodes at the end of
    training (:func:`_flush_truncated`) instead of dropping them.
    """
    rewards = np.asarray(rewards)
    dones = np.asarray(dones)
    for t in range(rewards.shape[0]):
        ep_ret += rewards[t]
        ep_len += 1
        for i in np.nonzero(dones[t])[0]:
            returns_buf.append(float(ep_ret[i]))
            ep_ret[i] = 0.0
            ep_len[i] = 0
    return ep_ret, ep_len


def _flush_truncated(ep_ret, ep_len) -> list[float]:
    """Partial returns of episodes cut off by the end of training — one per
    env that has taken at least one step since its last done."""
    return [float(ep_ret[i]) for i in np.nonzero(ep_len > 0)[0]]


def train(task: str, encoder_name: str, *, total_steps: int = 20_000,
          seed: int = 0, verbose: bool = False, log_every: int = 10,
          cfg: Any = None, n_envs: Optional[int] = None,
          deploy_config: "Optional[DeploymentConfig]" = None) -> TrainResult:
    """Train the paper's (task, algorithm) pairing with a given encoder.

    ``deploy_config`` (optional) trains against an explicit
    :class:`repro.deploy.DeploymentConfig` instead of the named encoder's
    default, so a serialised deployment manifest can drive training too.
    ``cfg`` overrides the algorithm config; ``n_envs`` overrides just the
    parallel-env count.  The returned :class:`TrainResult` carries the
    trained parameters (``result.params``), ready to serve through
    ``Deployment.serving_pair``.
    """
    algo = TASK_ALGO[task]
    env = make_pixel_env(task, train=True)
    encoder = _pipeline_encoder(encoder_name, env.obs_shape[-1],
                                deploy_config=deploy_config)
    agent = make_agent(algo, encoder, env.action_dim, cfg=cfg, n_envs=n_envs)
    engine = make_engine(env, agent, total_steps)

    key = jax.random.PRNGKey(seed)
    k_init, key = jax.random.split(key)
    carry = engine.init(k_init)

    returns: list[float] = []
    ep_ret = np.zeros(engine.n_envs)
    ep_len = np.zeros(engine.n_envs, np.int64)
    env_steps = 0
    compile_s = 0.0
    steady_steps = 0
    steady_s = 0.0
    seen_shapes: set = set()
    t0 = time.time()
    for it, phase in enumerate(engine.plan()):
        key, sub = jax.random.split(key)
        t_call = time.time()
        carry, rewards, dones, metrics = engine.run(carry, sub, phase)
        rewards = np.asarray(rewards)        # blocks on the chunk
        dt = time.time() - t_call
        ep_ret, ep_len = _track_episodes(returns, ep_ret, ep_len,
                                         rewards, dones)
        chunk_steps = int(rewards.size)
        env_steps += chunk_steps
        # first call of a phase shape compiles a fresh XLA program;
        # repeats run the cached one — split the wall accordingly
        if phase in seen_shapes:
            steady_steps += chunk_steps
            steady_s += dt
        else:
            seen_shapes.add(phase)
            compile_s += dt
        if verbose and it % log_every == 0:
            shown = " ".join(f"{k}={float(v):.3f}"
                             for k, v in sorted(metrics.items()))
            print(f"  [{algo} {encoder_name}] {phase[0]} {it} {shown} "
                  f"episodes={len(returns)}")
    truncated = _flush_truncated(ep_ret, ep_len)
    return TrainResult(task, algo, encoder_name, returns,
                       time.time() - t0, truncated_returns=truncated,
                       env_steps=env_steps, params=carry.state.params,
                       compile_s=compile_s, steady_env_steps=steady_steps,
                       steady_wall_s=steady_s)


def train_population(spec, **kwargs):
    """Population driver — P members in one jitted program per static
    shape.  Thin re-export; see :func:`repro.rl.population.train_population`
    (imported lazily: population composes this module's helpers)."""
    from repro.rl.population import train_population as _train_population
    return _train_population(spec, **kwargs)
