"""Replay buffers for the off-policy algorithms (SAC/DDPG).

Two implementations with matching semantics:

* :class:`ReplayBuffer` — the original host-side numpy buffer.  Kept as
  the PARITY REFERENCE: the hypothesis property tests assert the device
  buffer's insert / wraparound / sampling behaviour against it.
* :class:`DeviceReplayBuffer` — a device-resident pytree ring buffer.
  Storage lives in ``jnp`` arrays (uint8 pixels, like the numpy buffer),
  inserts are ``lax.dynamic_update_slice`` writes and sampling happens
  INSIDE jit, so the fully-compiled off-policy engine
  (``repro.rl.rollout``) never round-trips transitions through the host.
  The buffer rides in the engine's donated scan carry, so updates are
  in-place on device.

The device ring is fixed-width: every ``add`` call inserts the same
number of rows ``n_add`` (the engine's ``n_envs``), and ``capacity`` must
be a multiple of it.  That invariant keeps the write cursor aligned —
an insert never straddles the wrap boundary — which is what makes the
single ``dynamic_update_slice`` exact (and cheap) under jit.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


class ReplayBuffer:
    """Host-side numpy buffer with uint8 pixel storage (the reference)."""

    def __init__(self, capacity: int, obs_shape: tuple, action_dim: int,
                 seed: int = 0):
        self.capacity = capacity
        self.obs = np.zeros((capacity,) + obs_shape, np.uint8)
        self.next_obs = np.zeros((capacity,) + obs_shape, np.uint8)
        self.actions = np.zeros((capacity, action_dim), np.float32)
        self.rewards = np.zeros((capacity,), np.float32)
        self.dones = np.zeros((capacity,), np.float32)
        self.idx = 0
        self.full = False
        self.rng = np.random.default_rng(seed)

    def __len__(self):
        return self.capacity if self.full else self.idx

    @staticmethod
    def _quantize(obs):
        return np.clip(np.round(np.asarray(obs) * 255), 0, 255).astype(np.uint8)

    def add_batch(self, obs, action, reward, next_obs, done):
        """Vectorised add: leading dim = n_envs."""
        n = obs.shape[0]
        idxs = (self.idx + np.arange(n)) % self.capacity
        self.obs[idxs] = self._quantize(obs)
        self.next_obs[idxs] = self._quantize(next_obs)
        self.actions[idxs] = np.asarray(action)
        self.rewards[idxs] = np.asarray(reward)
        self.dones[idxs] = np.asarray(done, np.float32)
        self.idx = int((self.idx + n) % self.capacity)
        self.full = self.full or self.idx < n or len(self) == self.capacity
        if not self.full and self.idx == 0:
            self.full = True

    def sample(self, batch: int, *, encode_fn=None):
        """Draw a minibatch; optionally encode observations in ONE call.

        ``encode_fn`` (e.g. the fused batched MiniConv encoder) is applied
        to obs and next_obs stacked into a single (2*batch, ...) array, so
        the whole minibatch costs one kernel launch instead of 2*batch
        per-frame launches; the features come back under ``obs_feats`` /
        ``next_obs_feats`` alongside the raw pixels.
        """
        idxs = self.rng.integers(0, len(self), size=batch)
        out = {
            "obs": self.obs[idxs].astype(np.float32) / 255.0,
            "next_obs": self.next_obs[idxs].astype(np.float32) / 255.0,
            "actions": self.actions[idxs],
            "rewards": self.rewards[idxs],
            "dones": self.dones[idxs],
        }
        if encode_fn is not None:
            stacked = np.concatenate([out["obs"], out["next_obs"]])
            feats = np.asarray(encode_fn(stacked))
            out["obs_feats"], out["next_obs_feats"] = \
                feats[:batch], feats[batch:]
        return out


# ---------------------------------------------------------------------------
# Device-resident pytree ring buffer
# ---------------------------------------------------------------------------

def _register(cls):
    return jax.tree_util.register_dataclass(
        cls,
        data_fields=["obs", "next_obs", "actions", "rewards", "dones",
                     "idx", "size"],
        meta_fields=["n_add"])


@_register
@dataclasses.dataclass(frozen=True)
class DeviceReplayBuffer:
    """jnp ring buffer; a pytree, so it scans/donates through jit.

    ``n_add`` (static metadata) is the fixed insert width; ``idx`` /
    ``size`` are traced scalars.  Construct with :func:`device_buffer`.
    """

    obs: Any                      # (capacity, *obs_shape) uint8
    next_obs: Any                 # (capacity, *obs_shape) uint8
    actions: Any                  # (capacity, action_dim) float32
    rewards: Any                  # (capacity,) float32
    dones: Any                    # (capacity,) float32
    idx: Any                      # () int32 — next write cursor
    size: Any                     # () int32 — filled rows
    n_add: int                    # static fixed insert width

    @property
    def capacity(self) -> int:
        return self.obs.shape[0]


def device_buffer(capacity: int, obs_shape: tuple, action_dim: int, *,
                  n_add: int = 1) -> DeviceReplayBuffer:
    """Allocate an empty device ring accepting ``n_add``-row inserts."""
    if capacity % n_add != 0:
        raise ValueError(f"capacity {capacity} must be a multiple of the "
                         f"insert width n_add={n_add} (keeps the write "
                         f"cursor slice-aligned)")
    return DeviceReplayBuffer(
        obs=jnp.zeros((capacity,) + tuple(obs_shape), jnp.uint8),
        next_obs=jnp.zeros((capacity,) + tuple(obs_shape), jnp.uint8),
        actions=jnp.zeros((capacity, action_dim), jnp.float32),
        rewards=jnp.zeros((capacity,), jnp.float32),
        dones=jnp.zeros((capacity,), jnp.float32),
        idx=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
        n_add=n_add)


def quantize_obs(obs):
    """Float [0,1] pixels -> uint8 ring storage (matches the numpy
    reference's ``ReplayBuffer._quantize``)."""
    return jnp.clip(jnp.round(obs * 255), 0, 255).astype(jnp.uint8)


def buffer_add(buf: DeviceReplayBuffer, obs, action, reward, next_obs,
               done) -> DeviceReplayBuffer:
    """Insert ``n_add`` float-pixel transitions at the ring cursor
    (jit-safe); quantises obs/next_obs to uint8 like the numpy reference.
    """
    return buffer_add_u8(buf, quantize_obs(obs), action, reward,
                         quantize_obs(next_obs), done)


def buffer_add_u8(buf: DeviceReplayBuffer, obs_u8, action, reward,
                  next_obs_u8, done) -> DeviceReplayBuffer:
    """Insert pre-quantised (uint8) observations.

    The hot path for the compiled engine: consecutive env steps share a
    frame (``next_obs`` at t IS ``obs`` at t+1), so the engine quantises
    each frame ONCE and threads the uint8 copy through its carry instead
    of re-quantising both sides of every transition.

    Because every insert is ``n_add`` rows and capacity is a multiple of
    ``n_add``, the cursor is always slice-aligned: one
    ``lax.dynamic_update_slice`` per tensor, never straddling the wrap.
    """
    n = obs_u8.shape[0]
    if n != buf.n_add:
        raise ValueError(f"insert width {n} != buffer's fixed n_add "
                         f"{buf.n_add}")

    def put(store, rows):
        start = (buf.idx,) + (0,) * (store.ndim - 1)
        return lax.dynamic_update_slice(store, rows.astype(store.dtype),
                                        start)

    cap = buf.capacity
    return dataclasses.replace(
        buf,
        obs=put(buf.obs, obs_u8),
        next_obs=put(buf.next_obs, next_obs_u8),
        actions=put(buf.actions, action),
        rewards=put(buf.rewards, reward.reshape(n)),
        dones=put(buf.dones, done.astype(jnp.float32).reshape(n)),
        idx=(buf.idx + n) % cap,
        size=jnp.minimum(buf.size + n, cap))


def buffer_sample(buf: DeviceReplayBuffer, batch: int, key) -> dict:
    """Uniform minibatch over the filled region, entirely inside jit.

    Returns the same dict layout as :meth:`ReplayBuffer.sample` (pixels
    dequantised to float32 in [0, 1]).

    Caveat vs the numpy reference: sampling an EMPTY buffer cannot raise
    under jit — ``sample_indices`` clamps the range to 1 and the batch
    comes back all-zero.  Callers must gate sampling on having inserted
    at least one minibatch (the engine's warmup plan guarantees it).
    """
    idxs = sample_indices(key, batch, buf.size)
    return {
        "obs": buf.obs[idxs].astype("float32") / 255.0,
        "next_obs": buf.next_obs[idxs].astype("float32") / 255.0,
        "actions": buf.actions[idxs],
        "rewards": buf.rewards[idxs],
        "dones": buf.dones[idxs],
    }


def sample_indices(key, batch: int, size):
    """Uniform indices in [0, size) with a traced ``size`` (jit-safe)."""
    return jax.random.randint(key, (batch,), 0, jnp.maximum(size, 1))


__all__ = ["ReplayBuffer", "DeviceReplayBuffer", "device_buffer",
           "buffer_add", "buffer_add_u8", "buffer_sample", "quantize_obs",
           "sample_indices"]
