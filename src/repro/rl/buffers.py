"""Replay buffer (SAC/DDPG) with uint8 pixel storage (host-side numpy)."""
from __future__ import annotations

import numpy as np


class ReplayBuffer:
    def __init__(self, capacity: int, obs_shape: tuple, action_dim: int,
                 seed: int = 0):
        self.capacity = capacity
        self.obs = np.zeros((capacity,) + obs_shape, np.uint8)
        self.next_obs = np.zeros((capacity,) + obs_shape, np.uint8)
        self.actions = np.zeros((capacity, action_dim), np.float32)
        self.rewards = np.zeros((capacity,), np.float32)
        self.dones = np.zeros((capacity,), np.float32)
        self.idx = 0
        self.full = False
        self.rng = np.random.default_rng(seed)

    def __len__(self):
        return self.capacity if self.full else self.idx

    @staticmethod
    def _quantize(obs):
        return np.clip(np.round(np.asarray(obs) * 255), 0, 255).astype(np.uint8)

    def add_batch(self, obs, action, reward, next_obs, done):
        """Vectorised add: leading dim = n_envs."""
        n = obs.shape[0]
        idxs = (self.idx + np.arange(n)) % self.capacity
        self.obs[idxs] = self._quantize(obs)
        self.next_obs[idxs] = self._quantize(next_obs)
        self.actions[idxs] = np.asarray(action)
        self.rewards[idxs] = np.asarray(reward)
        self.dones[idxs] = np.asarray(done, np.float32)
        self.idx = int((self.idx + n) % self.capacity)
        self.full = self.full or self.idx < n or len(self) == self.capacity
        if not self.full and self.idx == 0:
            self.full = True

    def sample(self, batch: int, *, encode_fn=None):
        """Draw a minibatch; optionally encode observations in ONE call.

        ``encode_fn`` (e.g. the fused batched MiniConv encoder) is applied
        to obs and next_obs stacked into a single (2*batch, ...) array, so
        the whole minibatch costs one kernel launch instead of 2*batch
        per-frame launches; the features come back under ``obs_feats`` /
        ``next_obs_feats`` alongside the raw pixels.
        """
        idxs = self.rng.integers(0, len(self), size=batch)
        out = {
            "obs": self.obs[idxs].astype(np.float32) / 255.0,
            "next_obs": self.next_obs[idxs].astype(np.float32) / 255.0,
            "actions": self.actions[idxs],
            "rewards": self.rewards[idxs],
            "dones": self.dones[idxs],
        }
        if encode_fn is not None:
            stacked = np.concatenate([out["obs"], out["next_obs"]])
            feats = np.asarray(encode_fn(stacked))
            out["obs_feats"], out["next_obs_feats"] = \
                feats[:batch], feats[batch:]
        return out
