"""Policy/value networks for the learning experiments (§4.1).

Encoders:
* ``full_cnn``   — the SB3 NatureCNN default CnnPolicy feature extractor
                   (the paper's Full-CNN baseline): conv 8x8/4x32,
                   4x4/2x64, 3x3/1x64, flatten, dense 512 + ReLU.
* ``miniconv``   — the paper's on-device encoder (K in {4, 16}); the conv
                   stack is the *edge* half, the flatten+dense(512) belongs
                   to the *server* half, so the wire tensor is exactly the
                   K-channel feature map the paper transmits.

Heads (downstream policy/value networks are identical across encoders, as
in the paper): Gaussian actor (PPO), squashed-Gaussian actor + twin Q
critics (SAC), deterministic actor + Q critic (DDPG).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.miniconv import (MiniConvSpec, miniconv_apply,
                                 miniconv_init, standard_spec)
from repro.nn.layers import conv2d, conv2d_init, dense, dense_init
from repro.nn.module import KeyGen, orthogonal_init

FEATURE_DIM = 512


# ---------------------------------------------------------------------------
# Encoders
# ---------------------------------------------------------------------------

def full_cnn_init(key, c_in: int, *, h: int = 84, w: int = 84):
    kg = KeyGen(key)
    # NatureCNN spatial sizes for 84x84 (VALID padding as in SB3/torch)
    h1, w1 = (h - 8) // 4 + 1, (w - 8) // 4 + 1       # 20
    h2, w2 = (h1 - 4) // 2 + 1, (w1 - 4) // 2 + 1     # 9
    h3, w3 = h2 - 3 + 1, w2 - 3 + 1                   # 7
    flat = h3 * w3 * 64
    return {
        "conv1": conv2d_init(kg(), 8, 8, c_in, 32),
        "conv2": conv2d_init(kg(), 4, 4, 32, 64),
        "conv3": conv2d_init(kg(), 3, 3, 64, 64),
        "proj": dense_init(kg(), flat, FEATURE_DIM, use_bias=True),
    }


def full_cnn_apply(params, obs):
    """obs: (B, 84, 84, C) in [0,1] -> (B, 512)."""
    x = jax.nn.relu(conv2d(params["conv1"], obs, stride=4, padding="VALID"))
    x = jax.nn.relu(conv2d(params["conv2"], x, stride=2, padding="VALID"))
    x = jax.nn.relu(conv2d(params["conv3"], x, stride=1, padding="VALID"))
    x = x.reshape(x.shape[0], -1)
    return jax.nn.relu(dense(params["proj"], x))


def miniconv_encoder_init(key, spec: MiniConvSpec, *, h: int = 84,
                          w: int = 84, feature_dim: int = FEATURE_DIM):
    """Edge (conv passes) + server (projection) halves, kept separate so
    the deployment split is a dict split.  The projection width comes from
    the compiled PassPlan — the single source of truth for the edge
    feature shape."""
    kg = KeyGen(key)
    fh, fw, k = spec.plan(h, w).feature_shape
    return {
        "edge": miniconv_init(kg(), spec),
        "server": {"proj": dense_init(kg(), fh * fw * k, feature_dim,
                                      use_bias=True)},
    }


def miniconv_edge_apply(params, spec: MiniConvSpec, obs, *,
                        use_kernel=False):
    """On-device half.  ``use_kernel`` selects the execution tier:
    False (XLA, training), "per_pass", "grouped", or "fused" (one Pallas
    kernel for the whole pass plan — the deployment path)."""
    return miniconv_apply(params, spec, obs, use_kernel=use_kernel)


def miniconv_server_apply(params, feats):
    x = feats.reshape(feats.shape[0], -1)
    return jax.nn.relu(dense(params["proj"], x))


@dataclasses.dataclass(frozen=True)
class Encoder:
    """Uniform encoder interface for the RL algorithms."""

    name: str
    init: Any
    apply: Any                      # (params, obs) -> (B, 512)
    spec: MiniConvSpec | None = None

    def plan(self, h: int = 84, w: int = 84):
        """Compiled pass plan of the edge half (None for full_cnn)."""
        return None if self.spec is None else self.spec.plan(h, w)


def make_encoder(name: str, c_in: int = 9, *, use_kernel=False,
                 fused_head: bool = False) -> Encoder:
    """name in {"full_cnn", "miniconv4", "miniconv16"}.

    .. deprecated::
        For MiniConv encoders this is a thin shim over
        :meth:`repro.deploy.Deployment.build` — the one canonical pipeline
        constructor.  ``use_kernel`` maps to the execution-backend registry
        (``repro.core.backends``) and ``fused_head=True`` to
        ``head_placement="fused"``.  New code should build a
        :class:`repro.deploy.DeploymentConfig` directly; ``full_cnn`` (the
        paper's server-only baseline) has no split pipeline and stays
        here.
    """
    if name == "full_cnn":
        return Encoder("full_cnn",
                       lambda key: full_cnn_init(key, c_in),
                       full_cnn_apply)
    if name.startswith("miniconv"):
        from repro.deploy import Deployment, DeploymentConfig
        cfg = DeploymentConfig.from_encoder_name(
            name, c_in=c_in, backend=use_kernel,
            head_placement="fused" if fused_head else "server")
        return Deployment.build(cfg).encoder
    raise ValueError(f"unknown encoder {name}")


# ---------------------------------------------------------------------------
# Heads
# ---------------------------------------------------------------------------

def mlp_init(key, sizes: list[int], *, use_bias=True, final_scale=0.01):
    kg = KeyGen(key)
    params = {}
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        scale = final_scale if i == len(sizes) - 2 else math.sqrt(2.0)
        params[f"fc{i}"] = dense_init(kg(), a, b, use_bias=use_bias,
                                      init=orthogonal_init(scale))
    return params


def mlp_apply(params, x, *, final_act=None):
    n = len(params)
    for i in range(n):
        x = dense(params[f"fc{i}"], x)
        if i < n - 1:
            x = jax.nn.relu(x)
    return final_act(x) if final_act is not None else x


def gaussian_actor_init(key, feat_dim: int, action_dim: int):
    kg = KeyGen(key)
    return {"mlp": mlp_init(kg(), [feat_dim, 256, action_dim]),
            "log_std": jnp.zeros((action_dim,))}


def gaussian_actor(params, feats):
    mean = mlp_apply(params["mlp"], feats)
    log_std = jnp.clip(params["log_std"], -5.0, 2.0)
    return mean, jnp.broadcast_to(log_std, mean.shape)


def squashed_actor_init(key, feat_dim: int, action_dim: int):
    return {"mlp": mlp_init(key, [feat_dim, 256, 2 * action_dim],
                            final_scale=0.01)}


def squashed_actor_mode(params, feats):
    """Deterministic action — tanh of the pre-squash mean.  The policy a
    deployment serves (``Agent.policy_head``) and the ``det`` output of
    :func:`squashed_actor_sample`."""
    mean, _ = jnp.split(mlp_apply(params["mlp"], feats), 2, axis=-1)
    return jnp.tanh(mean)


def squashed_actor_sample(params, feats, key):
    out = mlp_apply(params["mlp"], feats)
    mean, log_std = jnp.split(out, 2, axis=-1)
    log_std = jnp.clip(log_std, -10.0, 2.0)
    std = jnp.exp(log_std)
    eps = jax.random.normal(key, mean.shape)
    pre = mean + std * eps
    action = jnp.tanh(pre)
    # log prob with tanh correction
    logp = (-0.5 * (eps ** 2 + 2 * log_std + math.log(2 * math.pi))).sum(-1)
    logp -= jnp.sum(2 * (math.log(2.0) - pre - jax.nn.softplus(-2 * pre)), -1)
    return action, logp, jnp.tanh(mean)


def q_critic_init(key, feat_dim: int, action_dim: int):
    return {"mlp": mlp_init(key, [feat_dim + action_dim, 256, 1],
                            final_scale=1.0)}


def q_critic(params, feats, action):
    return mlp_apply(params["mlp"],
                     jnp.concatenate([feats, action], -1))[..., 0]


def v_critic_init(key, feat_dim: int):
    return {"mlp": mlp_init(key, [feat_dim, 256, 1], final_scale=1.0)}


def v_critic(params, feats):
    return mlp_apply(params["mlp"], feats)[..., 0]


def det_actor_init(key, feat_dim: int, action_dim: int):
    return {"mlp": mlp_init(key, [feat_dim, 256, action_dim],
                            final_scale=0.01)}


def det_actor(params, feats):
    return jnp.tanh(mlp_apply(params["mlp"], feats))
