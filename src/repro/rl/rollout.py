"""Device-resident rollout engines: the fully-jitted training loops.

One engine per agent family, both driven identically by
``repro.rl.train``:

* **On-policy** (PPO): one jitted call per iteration — ``lax.scan`` over
  ``n_steps`` env steps vmapped across ``n_envs`` envs, then the agent's
  whole GAE + epoch/minibatch update, all in one XLA program (this is the
  engine PPO always had, generalised to any on-policy ``Agent``).
* **Off-policy** (SAC/DDPG): the RLtools-style compiled loop.  One jitted
  ``run_chunk`` scans K vectorised env steps, and EVERY step interleaves
  ``train_freq * n_envs`` gradient updates sampled from the device-resident
  :class:`~repro.rl.buffers.DeviceReplayBuffer` riding in the scan carry —
  rollout, replay and learning never leave the device.  Warmup uses a jax
  PRNG stream (uniform actions) inside the same scan, compiled separately
  (no per-step host RNG construction).  The carry is donated, so the
  multi-hundred-MB replay storage is updated in place.

Only the per-chunk ``(T, N)`` reward/done arrays return to the host —
exactly what episode tracking needs.

Engines expose a uniform driver protocol::

    engine = make_engine(env, agent, total_steps)
    carry = engine.init(key)
    for phase in engine.plan():    # ("warmup"|"train"|"iter", n_vec_steps)
        carry, rewards, dones, metrics = engine.run(carry, key, phase)
    trained = carry.state          # TrainState

``plan`` splits the construction-time ``total_steps`` budget into
fixed-shape chunks so at most three XLA programs are compiled per run
(warmup, full chunk, tail chunk); the budget is baked in at build time
because the off-policy ring buffer is sized from it.

The loop *bodies* are exposed as pure builders (``offpolicy_chunk_fn``,
``offpolicy_init_fn``, ``onpolicy_iter_fn``, ...) separate from the
``make_*_engine`` wrappers that jit them.  ``repro.rl.population`` vmaps
the same pure functions over a member axis, so a population member and a
single-run engine execute literally the same traced program body — the
basis of the member-0 bitwise-parity guarantee.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.wrappers import PixelEnv
from repro.rl.agent import Agent, TrainState
from repro.rl.buffers import (DeviceReplayBuffer, buffer_add_u8,
                              buffer_sample, device_buffer, quantize_obs)

CHUNK = 128          # max vectorised steps per off-policy run_chunk call


class OffPolicyCarry(NamedTuple):
    state: TrainState
    buf: DeviceReplayBuffer
    env_states: Any
    obs: jnp.ndarray
    obs_u8: jnp.ndarray          # quantised copy of obs: each frame is
                                 # quantised ONCE and reused as the next
                                 # transition's stored observation


class OnPolicyCarry(NamedTuple):
    state: TrainState
    env_states: Any
    obs: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Engine:
    """A compiled training loop behind the uniform driver protocol."""

    agent: Agent
    n_envs: int
    init: Callable               # (key) -> carry
    plan: Callable               # () -> [(kind, n_vec_steps)]
    run: Callable                # (carry, key, phase) -> (carry, r, d, metrics)


def make_engine(env: PixelEnv, agent: Agent, total_steps: int) -> Engine:
    """The matching engine for ``agent`` (dispatches on ``on_policy``)."""
    if agent.on_policy:
        return make_onpolicy_engine(env, agent, total_steps)
    return make_offpolicy_engine(env, agent, total_steps)


# ---------------------------------------------------------------------------
# On-policy: scan-rollout + whole-trajectory update per jitted call
# ---------------------------------------------------------------------------

def onpolicy_init_fn(env: PixelEnv, agent: Agent) -> Callable:
    """Pure ``(key) -> OnPolicyCarry`` — agent params + N reset envs."""
    N = agent.cfg.n_envs

    def init(key) -> OnPolicyCarry:
        k_agent, k_env = jax.random.split(key)
        state = agent.init(k_agent)
        env_states, obs = env.reset_batch(jax.random.split(k_env, N))
        return OnPolicyCarry(state, env_states, obs)

    return init


def onpolicy_iter_fn(env: PixelEnv, agent: Agent) -> Callable:
    """Pure ``(carry, key) -> (carry, rewards, dones, metrics)`` body of
    one on-policy iteration (rollout scan + whole-trajectory update)."""
    T = agent.cfg.n_steps

    def run_iter(carry: OnPolicyCarry, key):
        state, env_states, obs = carry
        k_roll, k_upd = jax.random.split(key)

        def step(c, k):
            env_states, obs = c
            action, extras = agent.act(state.params, obs, k)
            env_states, next_obs, reward, done = env.step_batch(
                env_states, jnp.clip(action, -1.0, 1.0))
            out = dict(obs=obs, action=action, reward=reward, done=done,
                       **extras)
            return (env_states, next_obs), out

        (env_states, obs), traj = jax.lax.scan(
            step, (env_states, obs), jax.random.split(k_roll, T))
        state, metrics = agent.update(
            state, {"traj": traj, "last_obs": obs}, k_upd)
        state = agent.target_update(state)
        return (OnPolicyCarry(state, env_states, obs),
                traj["reward"], traj["done"], metrics)

    return run_iter


def onpolicy_plan(cfg, total_steps: int) -> list[tuple[str, int]]:
    return [("iter", cfg.n_steps)] * max(
        total_steps // (cfg.n_steps * cfg.n_envs), 1)


def make_onpolicy_engine(env: PixelEnv, agent: Agent,
                         total_steps: int) -> Engine:
    cfg = agent.cfg
    init = onpolicy_init_fn(env, agent)
    run_iter = jax.jit(onpolicy_iter_fn(env, agent), donate_argnums=(0,))

    def plan():
        return onpolicy_plan(cfg, total_steps)

    def run(carry, key, phase):
        return run_iter(carry, key)

    return Engine(agent=agent, n_envs=cfg.n_envs, init=init, plan=plan,
                  run=run)


# ---------------------------------------------------------------------------
# Off-policy: device ring buffer + interleaved updates inside one scan
# ---------------------------------------------------------------------------

def offpolicy_capacity(cfg, total_steps: int) -> int:
    """Ring capacity for a run: sized to the budget (never more than
    ``cfg.buffer_size``), rounded up to the fixed ``n_envs`` insert width
    the ring requires."""
    N = cfg.n_envs
    total_vec = -(-total_steps // N)
    cap = min(cfg.buffer_size, total_vec * N)
    cap = max(cap, cfg.batch_size, N)
    return -(-cap // N) * N


def offpolicy_plan(cfg, total_steps: int) -> list[tuple[str, int]]:
    """Warmup + fixed-shape train chunks covering ``total_steps``.

    Random warmup must bank at least one minibatch before updates start.
    """
    N = cfg.n_envs
    warmup_vec = -(-max(cfg.learning_starts, cfg.batch_size) // N)
    total_vec = -(-total_steps // N)
    warm = min(warmup_vec, total_vec)
    remaining = max(total_vec - warm, 0)
    phases = [("warmup", warm)] if warm else []
    phases += [("train", CHUNK)] * (remaining // CHUNK)
    if remaining % CHUNK:
        phases.append(("train", remaining % CHUNK))
    return phases


def offpolicy_init_fn(env: PixelEnv, agent: Agent, cap: int) -> Callable:
    """Pure ``(key) -> OffPolicyCarry`` — params, N reset envs, and a
    zeroed ring of ``cap`` transitions riding in the carry."""
    N = agent.cfg.n_envs

    def init(key) -> OffPolicyCarry:
        k_agent, k_env = jax.random.split(key)
        state = agent.init(k_agent)
        env_states, obs = env.reset_batch(jax.random.split(k_env, N))
        buf = device_buffer(cap, env.obs_shape, agent.action_dim, n_add=N)
        return OffPolicyCarry(state, buf, env_states, obs,
                              quantize_obs(obs))

    return init


def offpolicy_chunk_fn(env: PixelEnv, agent: Agent) -> Callable:
    """Pure ``(carry, key, *, n_steps, warmup) -> (carry, r, d, metrics)``
    body of one off-policy chunk: ``n_steps`` vectorised env steps, each
    interleaving ``train_freq * n_envs`` sampled gradient updates."""
    cfg = agent.cfg
    N = cfg.n_envs
    n_updates = cfg.train_freq * N   # keep the seed loop's 1 update/env-step

    def run_chunk(carry: OffPolicyCarry, key, *, n_steps: int,
                  warmup: bool):
        def step(carry, k):
            state, buf, env_states, obs, obs_u8 = carry
            k_act, k_upd = jax.random.split(k)
            if warmup:
                action = jax.random.uniform(
                    k_act, (N, agent.action_dim), minval=-1.0, maxval=1.0)
            else:
                action, _ = agent.act(state.params, obs, k_act)
            env_states, next_obs, reward, done = env.step_batch(
                env_states, jnp.clip(action, -1.0, 1.0))
            # each frame is quantised once: this step's next_obs IS the
            # next step's stored obs
            next_u8 = quantize_obs(next_obs)
            buf = buffer_add_u8(buf, obs_u8, action, reward, next_u8, done)
            metrics = {}
            if not warmup:
                def upd(state, ku):
                    k_s, k_u = jax.random.split(ku)
                    batch = buffer_sample(buf, cfg.batch_size, k_s)
                    state, m = agent.update(state, batch, k_u)
                    return agent.target_update(state), m

                state, metrics = jax.lax.scan(
                    upd, state, jax.random.split(k_upd, n_updates))
            return (OffPolicyCarry(state, buf, env_states, next_obs,
                                   next_u8),
                    (reward, done, metrics))

        carry, (rewards, dones, metrics) = jax.lax.scan(
            step, carry, jax.random.split(key, n_steps))
        return carry, rewards, dones, jax.tree.map(
            lambda x: x.mean(), metrics)

    return run_chunk


def make_offpolicy_engine(env: PixelEnv, agent: Agent,
                          total_steps: int) -> Engine:
    cfg = agent.cfg
    # the construction-time budget: warmup sizing and the ring capacity
    # are derived from it, so plan cannot take a different one without
    # silently shrinking replay coverage
    cap = offpolicy_capacity(cfg, total_steps)
    init = offpolicy_init_fn(env, agent, cap)
    run_chunk = jax.jit(offpolicy_chunk_fn(env, agent),
                        static_argnames=("n_steps", "warmup"),
                        donate_argnums=(0,))

    def plan():
        return offpolicy_plan(cfg, total_steps)

    def run(carry, key, phase):
        kind, n_steps = phase
        return run_chunk(carry, key, n_steps=n_steps,
                         warmup=(kind == "warmup"))

    return Engine(agent=agent, n_envs=cfg.n_envs, init=init, plan=plan,
                  run=run)


__all__ = ["CHUNK", "Engine", "OffPolicyCarry", "OnPolicyCarry",
           "make_engine", "make_onpolicy_engine", "make_offpolicy_engine",
           "onpolicy_init_fn", "onpolicy_iter_fn", "onpolicy_plan",
           "offpolicy_capacity", "offpolicy_chunk_fn", "offpolicy_init_fn",
           "offpolicy_plan"]
