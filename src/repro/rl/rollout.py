"""Device-resident rollout engines: the fully-jitted training loops.

One engine per agent family, both driven identically by
``repro.rl.train``:

* **On-policy** (PPO): one jitted call per iteration — ``lax.scan`` over
  ``n_steps`` env steps vmapped across ``n_envs`` envs, then the agent's
  whole GAE + epoch/minibatch update, all in one XLA program (this is the
  engine PPO always had, generalised to any on-policy ``Agent``).
* **Off-policy** (SAC/DDPG): the RLtools-style compiled loop.  One jitted
  ``run_chunk`` scans K vectorised env steps, and EVERY step interleaves
  ``train_freq * n_envs`` gradient updates sampled from the device-resident
  :class:`~repro.rl.buffers.DeviceReplayBuffer` riding in the scan carry —
  rollout, replay and learning never leave the device.  Warmup uses a jax
  PRNG stream (uniform actions) inside the same scan, compiled separately
  (no per-step host RNG construction).  The carry is donated, so the
  multi-hundred-MB replay storage is updated in place.

Only the per-chunk ``(T, N)`` reward/done arrays return to the host —
exactly what episode tracking needs.

Engines expose a uniform driver protocol::

    engine = make_engine(env, agent, total_steps)
    carry = engine.init(key)
    for phase in engine.plan():    # ("warmup"|"train"|"iter", n_vec_steps)
        carry, rewards, dones, metrics = engine.run(carry, key, phase)
    trained = carry.state          # TrainState

``plan`` splits the construction-time ``total_steps`` budget into
fixed-shape chunks so at most three XLA programs are compiled per run
(warmup, full chunk, tail chunk); the budget is baked in at build time
because the off-policy ring buffer is sized from it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.wrappers import PixelEnv
from repro.rl.agent import Agent, TrainState
from repro.rl.buffers import (DeviceReplayBuffer, buffer_add_u8,
                              buffer_sample, device_buffer, quantize_obs)

CHUNK = 128          # max vectorised steps per off-policy run_chunk call


class OffPolicyCarry(NamedTuple):
    state: TrainState
    buf: DeviceReplayBuffer
    env_states: Any
    obs: jnp.ndarray
    obs_u8: jnp.ndarray          # quantised copy of obs: each frame is
                                 # quantised ONCE and reused as the next
                                 # transition's stored observation


class OnPolicyCarry(NamedTuple):
    state: TrainState
    env_states: Any
    obs: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Engine:
    """A compiled training loop behind the uniform driver protocol."""

    agent: Agent
    n_envs: int
    init: Callable               # (key) -> carry
    plan: Callable               # () -> [(kind, n_vec_steps)]
    run: Callable                # (carry, key, phase) -> (carry, r, d, metrics)


def make_engine(env: PixelEnv, agent: Agent, total_steps: int) -> Engine:
    """The matching engine for ``agent`` (dispatches on ``on_policy``)."""
    if agent.on_policy:
        return make_onpolicy_engine(env, agent, total_steps)
    return make_offpolicy_engine(env, agent, total_steps)


# ---------------------------------------------------------------------------
# On-policy: scan-rollout + whole-trajectory update per jitted call
# ---------------------------------------------------------------------------

def make_onpolicy_engine(env: PixelEnv, agent: Agent,
                         total_steps: int) -> Engine:
    cfg = agent.cfg
    N, T = cfg.n_envs, cfg.n_steps

    def init(key) -> OnPolicyCarry:
        k_agent, k_env = jax.random.split(key)
        state = agent.init(k_agent)
        env_states, obs = env.reset_batch(jax.random.split(k_env, N))
        return OnPolicyCarry(state, env_states, obs)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def run_iter(carry: OnPolicyCarry, key):
        state, env_states, obs = carry
        k_roll, k_upd = jax.random.split(key)

        def step(c, k):
            env_states, obs = c
            action, extras = agent.act(state.params, obs, k)
            env_states, next_obs, reward, done = env.step_batch(
                env_states, jnp.clip(action, -1.0, 1.0))
            out = dict(obs=obs, action=action, reward=reward, done=done,
                       **extras)
            return (env_states, next_obs), out

        (env_states, obs), traj = jax.lax.scan(
            step, (env_states, obs), jax.random.split(k_roll, T))
        state, metrics = agent.update(
            state, {"traj": traj, "last_obs": obs}, k_upd)
        state = agent.target_update(state)
        return (OnPolicyCarry(state, env_states, obs),
                traj["reward"], traj["done"], metrics)

    def plan():
        return [("iter", T)] * max(total_steps // (T * N), 1)

    def run(carry, key, phase):
        return run_iter(carry, key)

    return Engine(agent=agent, n_envs=N, init=init, plan=plan, run=run)


# ---------------------------------------------------------------------------
# Off-policy: device ring buffer + interleaved updates inside one scan
# ---------------------------------------------------------------------------

def make_offpolicy_engine(env: PixelEnv, agent: Agent,
                          total_steps: int) -> Engine:
    cfg = agent.cfg
    N = cfg.n_envs
    n_updates = cfg.train_freq * N   # keep the seed loop's 1 update/env-step
    # Random warmup must bank at least one minibatch before updates start.
    warmup_vec = -(-max(cfg.learning_starts, cfg.batch_size) // N)
    total_vec = -(-total_steps // N)
    # Ring sized to the run (never more than cfg.buffer_size), rounded up
    # to the fixed n_envs insert width the ring requires.
    cap = min(cfg.buffer_size, total_vec * N)
    cap = max(cap, cfg.batch_size, N)
    cap = -(-cap // N) * N

    def init(key) -> OffPolicyCarry:
        k_agent, k_env = jax.random.split(key)
        state = agent.init(k_agent)
        env_states, obs = env.reset_batch(jax.random.split(k_env, N))
        buf = device_buffer(cap, env.obs_shape, agent.action_dim, n_add=N)
        return OffPolicyCarry(state, buf, env_states, obs,
                              quantize_obs(obs))

    @functools.partial(jax.jit, static_argnames=("n_steps", "warmup"),
                       donate_argnums=(0,))
    def run_chunk(carry: OffPolicyCarry, key, *, n_steps: int,
                  warmup: bool):
        def step(carry, k):
            state, buf, env_states, obs, obs_u8 = carry
            k_act, k_upd = jax.random.split(k)
            if warmup:
                action = jax.random.uniform(
                    k_act, (N, agent.action_dim), minval=-1.0, maxval=1.0)
            else:
                action, _ = agent.act(state.params, obs, k_act)
            env_states, next_obs, reward, done = env.step_batch(
                env_states, jnp.clip(action, -1.0, 1.0))
            # each frame is quantised once: this step's next_obs IS the
            # next step's stored obs
            next_u8 = quantize_obs(next_obs)
            buf = buffer_add_u8(buf, obs_u8, action, reward, next_u8, done)
            metrics = {}
            if not warmup:
                def upd(state, ku):
                    k_s, k_u = jax.random.split(ku)
                    batch = buffer_sample(buf, cfg.batch_size, k_s)
                    state, m = agent.update(state, batch, k_u)
                    return agent.target_update(state), m

                state, metrics = jax.lax.scan(
                    upd, state, jax.random.split(k_upd, n_updates))
            return (OffPolicyCarry(state, buf, env_states, next_obs,
                                   next_u8),
                    (reward, done, metrics))

        carry, (rewards, dones, metrics) = jax.lax.scan(
            step, carry, jax.random.split(key, n_steps))
        return carry, rewards, dones, jax.tree.map(
            lambda x: x.mean(), metrics)

    def plan():
        # the construction-time budget: warmup sizing and the ring
        # capacity are derived from it, so plan cannot take a different
        # one without silently shrinking replay coverage
        warm = min(warmup_vec, total_vec)
        remaining = max(total_vec - warm, 0)
        phases = [("warmup", warm)] if warm else []
        phases += [("train", CHUNK)] * (remaining // CHUNK)
        if remaining % CHUNK:
            phases.append(("train", remaining % CHUNK))
        return phases

    def run(carry, key, phase):
        kind, n_steps = phase
        return run_chunk(carry, key, n_steps=n_steps,
                         warmup=(kind == "warmup"))

    return Engine(agent=agent, n_envs=N, init=init, plan=plan, run=run)


__all__ = ["CHUNK", "Engine", "OffPolicyCarry", "OnPolicyCarry",
           "make_engine", "make_onpolicy_engine", "make_offpolicy_engine"]
