"""PPO (Schulman et al., 2017) — the paper's Walker2d algorithm.

Clipped surrogate updates with GAE over fully-jitted vectorised rollouts
(the rollout scan itself lives in ``repro.rl.rollout``; this module is
the algorithm only).  Hyperparameters follow SB3 defaults unless
overridden (the paper: "Unless otherwise stated, these settings follow the
Stable-Baselines3 defaults").

Exposed as a frozen :class:`~repro.rl.agent.Agent` bundle
(:func:`make_ppo_agent`): ``act`` returns the sampled action plus the
``logp``/``value`` extras the trajectory stores; ``update`` consumes the
whole scanned trajectory (``{"traj": ..., "last_obs": ...}``) and runs
GAE + the epoch/minibatch scan on device.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, FrozenSet

import jax
import jax.numpy as jnp

from repro.nn.module import KeyGen
from repro.rl.agent import Agent, TrainState
from repro.rl.networks import (Encoder, gaussian_actor, gaussian_actor_init,
                               mlp_apply, v_critic, v_critic_init,
                               FEATURE_DIM)
from repro.train.optimizer import adam


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    n_envs: int = 8
    n_steps: int = 128           # rollout horizon per env
    n_epochs: int = 4
    n_minibatches: int = 8
    gamma: float = 0.99
    gae_lambda: float = 0.95
    clip_eps: float = 0.2
    vf_coef: float = 0.5
    ent_coef: float = 0.0
    lr: float = 3e-4
    max_grad_norm: float = 0.5
    quantize_wire: bool = False  # straight-through uint8 wire in training

    # Fields that only feed traced arithmetic (never array shapes, scan
    # lengths or buffer sizes), so repro.rl.population may stack them
    # across population members and vmap over them.
    VMAPPABLE: ClassVar[FrozenSet[str]] = frozenset(
        {"gamma", "gae_lambda", "clip_eps", "vf_coef", "ent_coef", "lr",
         "max_grad_norm"})


def init_ppo(key, encoder: Encoder, action_dim: int):
    kg = KeyGen(key)
    return {
        "encoder": encoder.init(kg()),
        "actor": gaussian_actor_init(kg(), FEATURE_DIM, action_dim),
        "critic": v_critic_init(kg(), FEATURE_DIM),
    }


def _policy(params, encoder: Encoder, obs):
    feats = encoder.apply(params["encoder"], obs)
    mean, log_std = gaussian_actor(params["actor"], feats)
    value = v_critic(params["critic"], feats)
    return mean, log_std, value


def _logp(mean, log_std, action):
    var = jnp.exp(2 * log_std)
    return (-0.5 * ((action - mean) ** 2 / var + 2 * log_std
                    + jnp.log(2 * jnp.pi))).sum(-1)


def make_ppo_agent(encoder: Encoder, action_dim: int,
                   cfg: PPOConfig) -> Agent:
    """PPO behind the uniform :class:`~repro.rl.agent.Agent` protocol."""
    opt = adam(cfg.lr, clip_norm=cfg.max_grad_norm)

    def init(key) -> TrainState:
        params = init_ppo(key, encoder, action_dim)
        return TrainState(params, {}, opt.init(params))

    def act(params, obs, key):
        mean, log_std, value = _policy(params, encoder, obs)
        action = mean + jnp.exp(log_std) * jax.random.normal(key, mean.shape)
        return action, {"logp": _logp(mean, log_std, action), "value": value}

    def gae(traj, last_value):
        def back(carry, t):
            adv_next, v_next = carry
            nonterm = 1.0 - t["done"].astype(jnp.float32)
            delta = t["reward"] + cfg.gamma * v_next * nonterm - t["value"]
            adv = delta + cfg.gamma * cfg.gae_lambda * nonterm * adv_next
            return (adv, t["value"]), adv

        (_, _), advs = jax.lax.scan(
            back, (jnp.zeros_like(last_value), last_value), traj,
            reverse=True)
        returns = advs + traj["value"]
        return advs, returns

    def loss_fn(params, batch):
        mean, log_std, value = _policy(params, encoder, batch["obs"])
        logp = _logp(mean, log_std, batch["action"])
        ratio = jnp.exp(logp - batch["logp"])
        adv = batch["adv"]
        adv = (adv - adv.mean()) / (adv.std() + 1e-8)
        pg1 = ratio * adv
        pg2 = jnp.clip(ratio, 1 - cfg.clip_eps, 1 + cfg.clip_eps) * adv
        pg_loss = -jnp.minimum(pg1, pg2).mean()
        v_loss = 0.5 * jnp.square(value - batch["ret"]).mean()
        entropy = (log_std + 0.5 * jnp.log(2 * jnp.pi * jnp.e)).sum(-1).mean()
        loss = pg_loss + cfg.vf_coef * v_loss - cfg.ent_coef * entropy
        return loss, {"pg_loss": pg_loss, "v_loss": v_loss,
                      "entropy": entropy,
                      "approx_kl": ((ratio - 1) - jnp.log(ratio)).mean()}

    def update(state: TrainState, data, key):
        params, _, opt_state = state
        traj, last_obs = data["traj"], data["last_obs"]
        _, _, last_value = _policy(params, encoder, last_obs)
        advs, returns = gae(traj, last_value)
        T, N = cfg.n_steps, cfg.n_envs
        flat = {
            "obs": traj["obs"].reshape(T * N, *traj["obs"].shape[2:]),
            "action": traj["action"].reshape(T * N, -1),
            "logp": traj["logp"].reshape(T * N),
            "adv": advs.reshape(T * N),
            "ret": returns.reshape(T * N),
        }
        mb = T * N // cfg.n_minibatches

        def epoch(carry, k):
            params, opt_state = carry
            perm = jax.random.permutation(k, T * N)

            def minibatch(carry, idx):
                params, opt_state = carry
                batch = jax.tree.map(lambda x: x[idx], flat)
                (_, aux), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
                params, opt_state = opt.update(params, opt_state, grads)
                return (params, opt_state), aux

            idxs = perm.reshape(cfg.n_minibatches, mb)
            (params, opt_state), auxs = jax.lax.scan(
                minibatch, (params, opt_state), idxs)
            return (params, opt_state), auxs

        keys = jax.random.split(key, cfg.n_epochs)
        (params, opt_state), auxs = jax.lax.scan(
            epoch, (params, opt_state), keys)
        metrics = jax.tree.map(lambda x: x.mean(), auxs)
        metrics["mean_reward"] = traj["reward"].mean()
        return TrainState(params, {}, opt_state), metrics

    def act_greedy_head(params):
        actor = params["actor"]
        return lambda feats: jnp.clip(mlp_apply(actor["mlp"], feats), -1, 1)

    return Agent(name="ppo", cfg=cfg, encoder=encoder,
                 action_dim=action_dim, on_policy=True, init=init, act=act,
                 update=update, target_update=lambda state: state,
                 policy_head=act_greedy_head)
