"""DDPG (Lillicrap et al., 2015) — the paper's Pendulum algorithm.

Deterministic actor with Gaussian exploration noise, single Q critic,
Polyak target updates — SB3 defaults.  Encoder trained by the critic loss
(actor gradients stop at the features), as in repro.rl.sac.

Exposed as a frozen :class:`~repro.rl.agent.Agent` bundle
(:func:`make_ddpg_agent`) for the device-resident off-policy engine.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, FrozenSet

import jax
import jax.numpy as jnp

from repro.nn.module import KeyGen
from repro.rl.agent import Agent, TrainState
from repro.rl.networks import (Encoder, FEATURE_DIM, det_actor,
                               det_actor_init, q_critic, q_critic_init)
from repro.train.optimizer import adam, ema_update


@dataclasses.dataclass(frozen=True)
class DDPGConfig:
    gamma: float = 0.99
    tau: float = 0.005
    lr: float = 1e-3
    batch_size: int = 64
    buffer_size: int = 20_000
    learning_starts: int = 300
    train_freq: int = 1           # gradient steps per env step (per env)
    action_noise: float = 0.1
    # parallel envs in the vectorised engine.  Pendulum episodes are a
    # fixed 200 steps, so smoke-scale runs (512 steps) over many envs
    # would truncate every episode; 2 envs completes one per env while
    # still exercising the vectorised path (raise freely at paper scale).
    n_envs: int = 2

    # Fields that only feed traced arithmetic (never array shapes, scan
    # lengths or buffer sizes), so repro.rl.population may stack them
    # across population members and vmap over them.
    VMAPPABLE: ClassVar[FrozenSet[str]] = frozenset(
        {"gamma", "tau", "lr", "action_noise"})


def init_ddpg(key, encoder: Encoder, action_dim: int):
    kg = KeyGen(key)
    params = {
        "encoder": encoder.init(kg()),
        "actor": det_actor_init(kg(), FEATURE_DIM, action_dim),
        "q": q_critic_init(kg(), FEATURE_DIM, action_dim),
    }
    target = jax.tree.map(jnp.copy, params)
    return params, target


def make_ddpg_agent(encoder: Encoder, action_dim: int,
                    cfg: DDPGConfig) -> Agent:
    """DDPG behind the uniform :class:`~repro.rl.agent.Agent` protocol."""
    opt = adam(cfg.lr, clip_norm=10.0)

    def init(key) -> TrainState:
        params, target = init_ddpg(key, encoder, action_dim)
        return TrainState(params, target, opt.init(params))

    def critic_loss(params, target, batch):
        feats = encoder.apply(params["encoder"], batch["obs"])
        tfeats = encoder.apply(target["encoder"], batch["next_obs"])
        next_a = det_actor(target["actor"], tfeats)
        tq = q_critic(target["q"], tfeats, next_a)
        y = jax.lax.stop_gradient(
            batch["rewards"] + cfg.gamma * (1 - batch["dones"]) * tq)
        q = q_critic(params["q"], feats, batch["actions"])
        return jnp.square(q - y).mean()

    def actor_loss(params, batch):
        feats = jax.lax.stop_gradient(
            encoder.apply(params["encoder"], batch["obs"]))
        a = det_actor(params["actor"], feats)
        return -q_critic(params["q"], feats, a).mean()

    def update(state: TrainState, batch, key):
        params, target, opt_state = state
        closs, cgrads = jax.value_and_grad(critic_loss)(params, target, batch)
        aloss, agrads = jax.value_and_grad(actor_loss)(params, batch)
        grads = jax.tree.map(lambda a, b: a + b, cgrads, agrads)
        params, opt_state = opt.update(params, opt_state, grads)
        metrics = {"critic_loss": closs, "actor_loss": aloss}
        return TrainState(params, target, opt_state), metrics

    def target_update(state: TrainState) -> TrainState:
        return state._replace(target=ema_update(state.target, state.params,
                                                cfg.tau))

    def act(params, obs, key):
        feats = encoder.apply(params["encoder"], obs)
        a = det_actor(params["actor"], feats)
        noise = cfg.action_noise * jax.random.normal(key, a.shape)
        return jnp.clip(a + noise, -1, 1), {}

    def policy_head(params):
        actor = params["actor"]
        return lambda feats: det_actor(actor, feats)

    return Agent(name="ddpg", cfg=cfg, encoder=encoder,
                 action_dim=action_dim, on_policy=False, init=init, act=act,
                 update=update, target_update=target_update,
                 policy_head=policy_head)
