"""DDPG (Lillicrap et al., 2015) — the paper's Pendulum algorithm.

Deterministic actor with Gaussian exploration noise, single Q critic,
Polyak target updates — SB3 defaults.  Encoder trained by the critic loss
(actor gradients stop at the features), as in repro.rl.sac.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.module import KeyGen
from repro.rl.networks import (Encoder, FEATURE_DIM, det_actor,
                               det_actor_init, q_critic, q_critic_init)
from repro.train.optimizer import adam, ema_update


@dataclasses.dataclass(frozen=True)
class DDPGConfig:
    gamma: float = 0.99
    tau: float = 0.005
    lr: float = 1e-3
    batch_size: int = 64
    buffer_size: int = 20_000
    learning_starts: int = 300
    action_noise: float = 0.1


def init_ddpg(key, encoder: Encoder, action_dim: int):
    kg = KeyGen(key)
    params = {
        "encoder": encoder.init(kg()),
        "actor": det_actor_init(kg(), FEATURE_DIM, action_dim),
        "q": q_critic_init(kg(), FEATURE_DIM, action_dim),
    }
    target = jax.tree.map(jnp.copy, params)
    return params, target


def make_ddpg_update(encoder: Encoder, action_dim: int, cfg: DDPGConfig):
    opt = adam(cfg.lr, clip_norm=10.0)

    def critic_loss(params, target, batch):
        feats = encoder.apply(params["encoder"], batch["obs"])
        tfeats = encoder.apply(target["encoder"], batch["next_obs"])
        next_a = det_actor(target["actor"], tfeats)
        tq = q_critic(target["q"], tfeats, next_a)
        y = jax.lax.stop_gradient(
            batch["rewards"] + cfg.gamma * (1 - batch["dones"]) * tq)
        q = q_critic(params["q"], feats, batch["actions"])
        return jnp.square(q - y).mean()

    def actor_loss(params, batch):
        feats = jax.lax.stop_gradient(
            encoder.apply(params["encoder"], batch["obs"]))
        a = det_actor(params["actor"], feats)
        return -q_critic(params["q"], feats, a).mean()

    @jax.jit
    def update(params, target, opt_state, batch):
        closs, cgrads = jax.value_and_grad(critic_loss)(params, target, batch)
        aloss, agrads = jax.value_and_grad(actor_loss)(params, batch)
        grads = jax.tree.map(lambda a, b: a + b, cgrads, agrads)
        params, opt_state = opt.update(params, opt_state, grads)
        new_target = ema_update(target, params, cfg.tau)
        return params, new_target, opt_state, {
            "critic_loss": closs, "actor_loss": aloss}

    @jax.jit
    def act(params, obs, key):
        feats = encoder.apply(params["encoder"], obs)
        a = det_actor(params["actor"], feats)
        noise = cfg.action_noise * jax.random.normal(key, a.shape)
        return jnp.clip(a + noise, -1, 1), a

    return update, act, opt
