"""The unified Agent interface: ONE protocol for PPO / SAC / DDPG.

Every algorithm in the RL stack is packaged as a frozen :class:`Agent`
bundle — ``init`` / ``act`` / ``update`` / ``target_update`` plus its
config — so the training driver (``repro.rl.train``), the rollout engines
(``repro.rl.rollout``) and the deployment path (``repro.deploy``) never
branch on the algorithm name.  The same ``act``/``policy_head`` pair that
drives training rollouts serves the trained policy from a deployment
manifest, which is what keeps the train and serve paths from drifting
apart (the LExCI-style "one agent interface" argument).

Contract
--------
``init(key) -> TrainState``
    Fresh parameters, target parameters (``{}`` for on-policy agents) and
    optimizer state.
``act(params, obs, key) -> (action, extras)``
    The EXPLORATION policy, batched over a leading env axis: actions for a
    ``(N, H, W, C)`` observation stack.  ``extras`` is an algo-specific
    dict of per-step quantities an on-policy update needs stored in the
    trajectory (PPO: ``logp``/``value``); off-policy agents return ``{}``.
``update(state, data, key) -> (state, metrics)``
    One learning step.  Off-policy: ``data`` is a replay minibatch
    (``obs``/``actions``/``rewards``/``next_obs``/``dones``).  On-policy:
    ``data`` is ``{"traj": ..., "last_obs": ...}`` — the whole scanned
    rollout.  Pure (jit-safe): the engines scan it on device.
``target_update(state) -> state``
    Polyak/EMA target step, identity for agents without targets.
``policy_head(params) -> (feats -> action)``
    The deterministic serving-time policy applied AFTER the encoder —
    exactly the ``head`` a :class:`repro.deploy.Deployment` server mounts
    behind the projection, so a trained ``TrainState`` serves from a
    manifest with no algorithm-specific glue.

All three bundles are constructed by :func:`make_agent`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

from repro.rl.networks import Encoder


class TrainState(NamedTuple):
    """The complete learnable state — a pytree the engines carry on device.

    ``target`` is ``{}`` for agents without target networks (PPO).
    """

    params: Any
    target: Any
    opt_state: Any


@dataclasses.dataclass(frozen=True)
class Agent:
    """Frozen bundle of one RL algorithm behind the uniform protocol."""

    name: str                     # "ppo" | "sac" | "ddpg"
    cfg: Any                      # the algorithm's config dataclass
    encoder: Encoder
    action_dim: int
    on_policy: bool
    init: Callable                # (key) -> TrainState
    act: Callable                 # (params, obs, key) -> (action, extras)
    update: Callable              # (state, data, key) -> (state, metrics)
    target_update: Callable       # (state) -> state
    policy_head: Callable         # (params) -> (feats -> action)

    @property
    def n_envs(self) -> int:
        return self.cfg.n_envs


def _algorithms() -> dict:
    """algo name -> (ConfigCls, agent factory).  Imported lazily so
    agent.py stays free of the algorithm modules until one is used."""
    from repro.rl.ddpg import DDPGConfig, make_ddpg_agent
    from repro.rl.ppo import PPOConfig, make_ppo_agent
    from repro.rl.sac import SACConfig, make_sac_agent
    return {"ppo": (PPOConfig, make_ppo_agent),
            "sac": (SACConfig, make_sac_agent),
            "ddpg": (DDPGConfig, make_ddpg_agent)}


def make_agent(algo: str, encoder: Encoder, action_dim: int, *,
               cfg: Any = None, n_envs: int | None = None) -> Agent:
    """Construct the :class:`Agent` bundle for ``algo``.

    ``cfg`` overrides the algorithm's default config; ``n_envs`` (when
    given) overrides just the parallel-env count on top of whichever
    config is in effect.
    """
    algorithms = _algorithms()
    if algo not in algorithms:
        raise ValueError(f"unknown algorithm {algo!r}; one of: "
                         f"{', '.join(algorithms)}")
    config_cls, factory = algorithms[algo]
    cfg = cfg or config_cls()
    if n_envs is not None:
        cfg = dataclasses.replace(cfg, n_envs=n_envs)
    return factory(encoder, action_dim, cfg)


__all__ = ["Agent", "TrainState", "make_agent"]
