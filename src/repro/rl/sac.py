"""SAC (Haarnoja et al., 2018) — the paper's Hopper algorithm.

Twin Q critics, squashed-Gaussian actor, automatic entropy tuning (target
entropy = -|A|), Polyak target updates.  Pixel convention (DrQ-style, which
matches SB3's shared feature extractor): the encoder is trained by the
critic loss; actor gradients stop at the features.

Exposed as a frozen :class:`~repro.rl.agent.Agent` bundle
(:func:`make_sac_agent`); the device-resident off-policy engine in
``repro.rl.rollout`` scans its ``update`` on device.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, FrozenSet

import jax
import jax.numpy as jnp

from repro.nn.module import KeyGen
from repro.rl.agent import Agent, TrainState
from repro.rl.networks import (Encoder, FEATURE_DIM, q_critic,
                               q_critic_init, squashed_actor_init,
                               squashed_actor_mode, squashed_actor_sample)
from repro.train.optimizer import adam, ema_update


@dataclasses.dataclass(frozen=True)
class SACConfig:
    gamma: float = 0.99
    tau: float = 0.005
    lr: float = 3e-4
    batch_size: int = 64
    buffer_size: int = 20_000
    learning_starts: int = 500
    train_freq: int = 1           # gradient steps per env step (per env)
    init_alpha: float = 0.1
    n_envs: int = 4               # parallel envs in the vectorised engine

    # Fields that only feed traced arithmetic (never array shapes, scan
    # lengths or buffer sizes), so repro.rl.population may stack them
    # across population members and vmap over them.
    VMAPPABLE: ClassVar[FrozenSet[str]] = frozenset(
        {"gamma", "tau", "lr", "init_alpha"})


def init_sac(key, encoder: Encoder, action_dim: int,
             init_alpha: float = SACConfig.init_alpha):
    kg = KeyGen(key)
    params = {
        "encoder": encoder.init(kg()),
        "actor": squashed_actor_init(kg(), FEATURE_DIM, action_dim),
        "q1": q_critic_init(kg(), FEATURE_DIM, action_dim),
        "q2": q_critic_init(kg(), FEATURE_DIM, action_dim),
        "log_alpha": jnp.log(jnp.asarray(init_alpha)),
    }
    target = {"encoder": params["encoder"], "q1": params["q1"],
              "q2": params["q2"]}
    return params, jax.tree.map(jnp.copy, target)


def make_sac_agent(encoder: Encoder, action_dim: int,
                   cfg: SACConfig) -> Agent:
    """SAC behind the uniform :class:`~repro.rl.agent.Agent` protocol."""
    opt = adam(cfg.lr, clip_norm=10.0)
    target_entropy = -float(action_dim)

    def init(key) -> TrainState:
        # cfg.init_alpha, not the class default: per-member population
        # variants must actually reach the initial temperature
        params, target = init_sac(key, encoder, action_dim,
                                  init_alpha=cfg.init_alpha)
        return TrainState(params, target, opt.init(params))

    def critic_loss(params, target, batch, key):
        feats = encoder.apply(params["encoder"], batch["obs"])
        tfeats = encoder.apply(target["encoder"], batch["next_obs"])
        next_a, next_logp, _ = squashed_actor_sample(
            params["actor"], jax.lax.stop_gradient(tfeats), key)
        tq1 = q_critic(target["q1"], tfeats, next_a)
        tq2 = q_critic(target["q2"], tfeats, next_a)
        alpha = jnp.exp(params["log_alpha"])
        tq = jnp.minimum(tq1, tq2) - alpha * next_logp
        y = batch["rewards"] + cfg.gamma * (1 - batch["dones"]) * tq
        y = jax.lax.stop_gradient(y)
        q1 = q_critic(params["q1"], feats, batch["actions"])
        q2 = q_critic(params["q2"], feats, batch["actions"])
        return jnp.square(q1 - y).mean() + jnp.square(q2 - y).mean()

    def actor_alpha_loss(params, batch, key):
        feats = jax.lax.stop_gradient(
            encoder.apply(params["encoder"], batch["obs"]))
        a, logp, _ = squashed_actor_sample(params["actor"], feats, key)
        alpha = jnp.exp(params["log_alpha"])
        q = jnp.minimum(q_critic(params["q1"], feats, a),
                        q_critic(params["q2"], feats, a))
        actor_loss = (jax.lax.stop_gradient(alpha) * logp - q).mean()
        alpha_loss = -(params["log_alpha"]
                       * jax.lax.stop_gradient(logp + target_entropy)).mean()
        return actor_loss + alpha_loss, (actor_loss, alpha_loss)

    def update(state: TrainState, batch, key):
        params, target, opt_state = state
        k1, k2 = jax.random.split(key)
        closs, cgrads = jax.value_and_grad(critic_loss)(
            params, target, batch, k1)
        # critic grads touch encoder + q1 + q2 (+ log_alpha has zero grad)
        (_, (aloss, _)), agrads = jax.value_and_grad(
            actor_alpha_loss, has_aux=True)(params, batch, k2)
        grads = jax.tree.map(lambda a, b: a + b, cgrads, agrads)
        params, opt_state = opt.update(params, opt_state, grads)
        metrics = {"critic_loss": closs, "actor_loss": aloss,
                   "alpha": jnp.exp(params["log_alpha"])}
        return TrainState(params, target, opt_state), metrics

    def target_update(state: TrainState) -> TrainState:
        new_target = ema_update(
            state.target,
            {"encoder": state.params["encoder"], "q1": state.params["q1"],
             "q2": state.params["q2"]},
            cfg.tau)
        return state._replace(target=new_target)

    def act(params, obs, key):
        feats = encoder.apply(params["encoder"], obs)
        a, _, det = squashed_actor_sample(params["actor"], feats, key)
        return a, {}

    def policy_head(params):
        actor = params["actor"]
        return lambda feats: squashed_actor_mode(actor, feats)

    return Agent(name="sac", cfg=cfg, encoder=encoder,
                 action_dim=action_dim, on_policy=False, init=init, act=act,
                 update=update, target_update=target_update,
                 policy_head=policy_head)
