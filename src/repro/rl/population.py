"""Population training: seeds × hyperparameter variants × tasks as ONE
jitted program, plus the paper's final-100-episode eval protocol.

The single-run engines in ``repro.rl.rollout`` already fuse rollout,
replay and learning into one XLA program — but they train one agent at a
time, so P candidate runs pay P compiles and P program launches (minutes
of XLA compile each on CPU hosts, per ``BENCH_learning.json``).  This
module vmaps the SAME pure loop bodies over a leading member axis:

* the agent ``TrainState`` pytree, the vectorised env states, each
  member's :class:`~repro.rl.buffers.DeviceReplayBuffer` ring and each
  member's PRNG stream all gain a ``(P, ...)`` axis;
* hyperparameters that only feed traced arithmetic (each config's
  ``VMAPPABLE`` set) are stacked into ``(P,)`` arrays and rebuilt into a
  per-member config *inside* the trace, so one program trains P distinct
  hyperparameter settings;
* members whose configs differ in a *static* field (shapes, scan lengths,
  buffer sizes) cannot share a program — :meth:`PopulationSpec.programs`
  groups members so each group is jointly jittable, and tasks always get
  their own program (different envs/action spaces).

Two lane modes map the member axis (``lane_mode``):

* ``"exact"`` (default) — ``lax.map``, i.e. a ``lax.scan`` over the
  stacked member pytrees.  Each lane executes the IDENTICAL unbatched
  ops as the single-run engine, so member 0 of a population is
  bitwise-equal to ``train()`` at the same seed (the driver mirrors its
  PRNG chain per member) — ``benchmarks/population.py --smoke`` gates on
  exactly that.  Lanes run back-to-back on device, and the dominant
  single-run cost on CPU hosts — XLA compile — is paid once for P
  members.
* ``"vmap"`` — batched lanes for accelerator throughput.  Forward math
  is lane-exact, but XLA lowers *batched* gradient matmuls (and the
  batched QR in orthogonal init) differently from their unbatched
  forms, so lanes drift from single runs at the float32-ulp level
  (~1e-7 per update on this host); use it when wall-clock beats bitwise
  reproducibility.

Evaluation follows the paper's protocol ("mean over the final 100
episodes"): :func:`make_evaluator` builds a deterministic eval-mode
rollout — ``Agent.policy_head`` (no exploration noise) through
``reset_batch``/``step_batch`` on a ``train=False`` env (centre crop) —
returning per-episode returns that replay bitwise at a fixed seed.
:func:`evaluate_population` scores every member on the SAME episode seeds
so :meth:`PopulationResult.best_member` is an apples-to-apples pick, and
``Deployment.export_best`` serves the winner's params straight from a
manifest like the single-run path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.envs import make_pixel_env
from repro.envs.wrappers import PixelEnv
from repro.rl.agent import Agent, _algorithms, make_agent
from repro.rl.rollout import (Engine, offpolicy_capacity, offpolicy_chunk_fn,
                              offpolicy_init_fn, offpolicy_plan,
                              onpolicy_init_fn, onpolicy_iter_fn,
                              onpolicy_plan)
from repro.rl.train import (TASK_ALGO, _flush_truncated, _pipeline_encoder,
                            _track_episodes)
from repro.schema import check_version

SPEC_VERSION = 1


# ---------------------------------------------------------------------------
# Spec: which members exist, and which programs they compile into
# ---------------------------------------------------------------------------

def _canon_pairs(overrides) -> tuple:
    """Canonicalise a ``{field: value}`` mapping (dict or key/value pairs)
    into a sorted tuple of pairs, so two specs naming the same overrides in
    a different order are equal (and hashable inside the frozen spec)."""
    items = overrides.items() if isinstance(overrides, dict) \
        else (tuple(p) for p in overrides)
    return tuple(sorted((str(k), v) for k, v in items))


@dataclasses.dataclass(frozen=True)
class PopulationSpec:
    """P = tasks × variants × seeds members of one encoder family.

    ``variants`` is a sequence of per-member config overrides (dicts or
    key/value pairs); ``cfg_overrides`` applies to every member first.
    Overrides of a config's ``VMAPPABLE`` fields stack into one program;
    any other (static) override splits the program.  Member order is
    task-major, then variant, then seed — :meth:`members` is the single
    source of truth.
    """

    tasks: tuple
    seeds: tuple
    variants: tuple = ((),)
    encoder: str = "miniconv4"
    total_steps: int = 512
    cfg_overrides: tuple = ()

    def __post_init__(self):
        tasks = (self.tasks,) if isinstance(self.tasks, str) else self.tasks
        object.__setattr__(self, "tasks", tuple(tasks))
        object.__setattr__(self, "seeds",
                           tuple(int(s) for s in self.seeds))
        variants = tuple(_canon_pairs(v) for v in self.variants) or ((),)
        object.__setattr__(self, "variants", variants)
        object.__setattr__(self, "cfg_overrides",
                           _canon_pairs(self.cfg_overrides))
        if not self.tasks:
            raise ValueError("PopulationSpec needs at least one task")
        if not self.seeds:
            raise ValueError("PopulationSpec needs at least one seed")
        for task in self.tasks:
            if task not in TASK_ALGO:
                raise ValueError(f"unknown task {task!r}; one of: "
                                 f"{', '.join(TASK_ALGO)}")

    @property
    def n_members(self) -> int:
        return len(self.tasks) * len(self.variants) * len(self.seeds)

    def members(self) -> list["Member"]:
        out: list[Member] = []
        for task in self.tasks:
            for vi, variant in enumerate(self.variants):
                for seed in self.seeds:
                    out.append(Member(index=len(out), task=task,
                                      algo=TASK_ALGO[task], seed=seed,
                                      variant_index=vi,
                                      overrides=dict(variant)))
        return out

    def programs(self) -> list["Program"]:
        """Members grouped into jointly-jittable programs.

        Each group shares (task, static config); vmappable overrides
        become per-member hyperparameter columns, missing entries filled
        from the group's static config so every column is stackable.
        """
        algos = _algorithms()
        groups: dict = {}
        order: list = []
        for m in self.members():
            config_cls = algos[m.algo][0]
            field_names = {f.name for f in dataclasses.fields(config_cls)}
            vmappable = getattr(config_cls, "VMAPPABLE", frozenset())
            for k in list(dict(self.cfg_overrides)) + list(m.overrides):
                if k not in field_names:
                    raise ValueError(
                        f"{config_cls.__name__} has no field {k!r} "
                        f"(member {m.index}, task {m.task!r})")
            base = config_cls(**dict(self.cfg_overrides))
            static = {k: v for k, v in m.overrides.items()
                      if k not in vmappable}
            hyper = {k: v for k, v in m.overrides.items() if k in vmappable}
            static_cfg = dataclasses.replace(base, **static)
            gkey = (m.task, static_cfg)
            if gkey not in groups:
                groups[gkey] = Program(task=m.task, algo=m.algo,
                                       static_cfg=static_cfg, members=[],
                                       hyper_fields=())
                order.append(gkey)
            prog = groups[gkey]
            prog.members.append(m)
            prog.hyper_fields = tuple(sorted(set(prog.hyper_fields)
                                             | set(hyper)))
        return [groups[k] for k in order]

    def to_dict(self) -> dict:
        return {"version": SPEC_VERSION,
                "tasks": list(self.tasks),
                "seeds": list(self.seeds),
                "variants": [[list(p) for p in v] for v in self.variants],
                "encoder": self.encoder,
                "total_steps": self.total_steps,
                "cfg_overrides": [list(p) for p in self.cfg_overrides]}

    @classmethod
    def from_dict(cls, d: dict) -> "PopulationSpec":
        d = dict(d)
        check_version("PopulationSpec", d.pop("version", None),
                      (SPEC_VERSION,))
        return cls(tasks=tuple(d["tasks"]), seeds=tuple(d["seeds"]),
                   variants=tuple(tuple(tuple(p) for p in v)
                                  for v in d.get("variants", [[]])),
                   encoder=d.get("encoder", "miniconv4"),
                   total_steps=int(d.get("total_steps", 512)),
                   cfg_overrides=tuple(tuple(p) for p in
                                       d.get("cfg_overrides", [])))


@dataclasses.dataclass
class Member:
    """One population member: identity, then results once trained."""

    index: int
    task: str
    algo: str
    seed: int
    variant_index: int
    overrides: dict

    episode_returns: list = dataclasses.field(default_factory=list)
    truncated_returns: list = dataclasses.field(default_factory=list)
    env_steps: int = 0
    params: Any = None           # trained TrainState.params pytree
    eval_returns: Optional[np.ndarray] = None   # protocol eval episodes

    @property
    def final_100_mean(self) -> float:
        """Mean return over the final 100 eval episodes (paper metric);
        falls back to training episodes when the member wasn't evaluated."""
        if self.eval_returns is not None:
            return final_100_mean(self.eval_returns)
        return final_100_mean(self.episode_returns
                              or self.truncated_returns)

    def summary(self) -> dict:
        return {"member": self.index, "task": self.task, "algo": self.algo,
                "seed": self.seed, "variant": self.variant_index,
                "overrides": dict(self.overrides),
                "episodes_completed": len(self.episode_returns),
                "env_steps": self.env_steps,
                "final_100_mean": self.final_100_mean}


@dataclasses.dataclass
class Program:
    """A jointly-jittable group of members (shared task + static config)."""

    task: str
    algo: str
    static_cfg: Any
    members: list
    hyper_fields: tuple

    def hyper_arrays(self) -> dict:
        """``{field: (P,) float32}`` columns, member order, gaps filled
        from the static config so heterogeneous variants still stack."""
        return {k: jnp.asarray(
                    [m.overrides.get(k, getattr(self.static_cfg, k))
                     for m in self.members], jnp.float32)
                for k in self.hyper_fields}


def final_100_mean(returns) -> float:
    """The paper's summary statistic: mean over the last 100 episodes."""
    r = np.asarray(list(returns), dtype=np.float64).ravel()
    return float(np.mean(r[-100:])) if r.size else float("nan")


def split_member_keys(keys):
    """Per-member ``jax.random.split``: ``(P, 2)`` keys -> two ``(P, 2)``
    key arrays, row p being exactly ``jax.random.split(keys[p])`` — the
    population mirror of the single-run driver's ``a, b = split(key)``."""
    pair = jax.vmap(jax.random.split)(keys)
    return pair[:, 0], pair[:, 1]


# ---------------------------------------------------------------------------
# The population engine: jit(vmap(pure single-run bodies))
# ---------------------------------------------------------------------------

LANE_MODES = ("exact", "vmap")


def make_population_engine(env: PixelEnv, algo: str, encoder, action_dim: int,
                           static_cfg: Any, hyper: dict, n_members: int,
                           total_steps: int,
                           lane_mode: str = "exact") -> Engine:
    """An :class:`~repro.rl.rollout.Engine` whose carry/keys carry a
    leading ``(P,)`` member axis.  ``hyper`` maps VMAPPABLE config fields
    to ``(P,)`` arrays; the per-member config is rebuilt *inside* the
    trace (``dataclasses.replace`` with tracer leaves), so the agent
    factories close over traced hyperparameters with no protocol change.

    ``lane_mode="exact"`` maps members with ``lax.map`` (bitwise-equal
    lanes, the default); ``"vmap"`` batches them (accelerator mode, see
    module docstring).  ``init`` runs the single-run init eagerly per
    member and stacks — init is once-per-run, and the eager path keeps
    even the orthogonal-init QR bitwise-identical to ``train()``.
    """
    if lane_mode not in LANE_MODES:
        raise ValueError(f"lane_mode {lane_mode!r}; one of: "
                         f"{', '.join(LANE_MODES)}")
    base_agent = make_agent(algo, encoder, action_dim, cfg=static_cfg)

    def member_agent(hyper_m: dict) -> Agent:
        if not hyper_m:
            return base_agent
        return make_agent(algo, encoder, action_dim,
                          cfg=dataclasses.replace(static_cfg, **hyper_m))

    def lane_map(fn: Callable) -> Callable:
        """Lift ``fn(carry, key, hyper_m)`` over the member axis."""
        if lane_mode == "vmap":
            return lambda carry, keys: jax.vmap(fn)(carry, keys, hyper)
        return lambda carry, keys: jax.lax.map(
            lambda xs: fn(*xs), (carry, keys, hyper))

    if base_agent.on_policy:
        single_init = lambda agent: onpolicy_init_fn(env, agent)

        def iter_m(carry, key, hyper_m):
            return onpolicy_iter_fn(env, member_agent(hyper_m))(carry, key)

        run_iter = jax.jit(lane_map(iter_m), donate_argnums=(0,))

        def plan():
            return onpolicy_plan(static_cfg, total_steps)

        def run(carry, keys, phase):
            return run_iter(carry, keys)
    else:
        cap = offpolicy_capacity(static_cfg, total_steps)
        single_init = lambda agent: offpolicy_init_fn(env, agent, cap)

        def chunk_m(carry, key, hyper_m, *, n_steps, warmup):
            return offpolicy_chunk_fn(env, member_agent(hyper_m))(
                carry, key, n_steps=n_steps, warmup=warmup)

        def pop_chunk(carry, keys, *, n_steps, warmup):
            body = lambda c, k, h: chunk_m(c, k, h, n_steps=n_steps,
                                           warmup=warmup)
            return lane_map(body)(carry, keys)

        run_chunk = jax.jit(pop_chunk,
                            static_argnames=("n_steps", "warmup"),
                            donate_argnums=(0,))

        def plan():
            return offpolicy_plan(static_cfg, total_steps)

        def run(carry, keys, phase):
            kind, n_steps = phase
            return run_chunk(carry, keys, n_steps=n_steps,
                             warmup=(kind == "warmup"))

    def init(keys):
        hyper_host = {k: np.asarray(v) for k, v in hyper.items()}
        carries = []
        for p in range(n_members):
            hyper_m = {k: float(v[p]) for k, v in hyper_host.items()}
            carries.append(single_init(member_agent(hyper_m))(keys[p]))
        return jax.tree.map(lambda *xs: jnp.stack(xs), *carries)

    return Engine(agent=base_agent, n_envs=static_cfg.n_envs, init=init,
                  plan=plan, run=run)


# ---------------------------------------------------------------------------
# Deterministic eval: the paper's final-100-episode protocol
# ---------------------------------------------------------------------------

def _episode_returns_fn(env: PixelEnv, agent: Agent, n_episodes: int,
                        max_steps: Optional[int]) -> Callable:
    """Pure ``(params, key) -> (n_episodes,) returns``: E parallel
    episodes under the deterministic serving policy, no exploration."""
    E = int(n_episodes)
    T = int(max_steps if max_steps is not None else env.env.max_steps)

    def episode_returns(params, key):
        env_states, obs = env.reset_batch(jax.random.split(key, E))
        head = agent.policy_head(params)

        def step(carry, _):
            env_states, obs, ret, alive = carry
            feats = agent.encoder.apply(params["encoder"], obs)
            action = jnp.clip(head(feats), -1.0, 1.0)
            env_states, obs, reward, done = env.step_batch(env_states,
                                                           action)
            # sum rewards only until each episode's first done: the
            # auto-reset wrapper keeps stepping, the protocol does not
            ret = ret + reward * alive
            alive = alive * (1.0 - done.astype(jnp.float32))
            return (env_states, obs, ret, alive), None

        (_, _, ret, _), _ = jax.lax.scan(
            step, (env_states, obs, jnp.zeros(E), jnp.ones(E)), None,
            length=T)
        return ret

    return episode_returns


def make_evaluator(env: PixelEnv, agent: Agent, n_episodes: int = 100, *,
                   max_steps: Optional[int] = None) -> Callable:
    """Jitted ``(params, key) -> (n_episodes,) returns`` — deterministic:
    the same (params, key) replays bitwise."""
    return jax.jit(_episode_returns_fn(env, agent, n_episodes, max_steps))


def make_population_evaluator(env: PixelEnv, agent: Agent,
                              n_episodes: int = 100, *,
                              max_steps: Optional[int] = None,
                              lane_mode: str = "exact") -> Callable:
    """Jitted ``(stacked params, key) -> (P, n_episodes) returns``.

    One shared ``key``: every member is scored on the SAME episode seeds,
    so member comparisons are paired, and permuting members permutes the
    rows bitwise (lanes never interact).  In ``"exact"`` lane mode each
    row is additionally bitwise what :func:`make_evaluator` returns for
    that member alone.
    """
    if lane_mode not in LANE_MODES:
        raise ValueError(f"lane_mode {lane_mode!r}; one of: "
                         f"{', '.join(LANE_MODES)}")
    fn = _episode_returns_fn(env, agent, n_episodes, max_steps)
    if lane_mode == "vmap":
        return jax.jit(jax.vmap(fn, in_axes=(0, None)))
    return jax.jit(lambda params, key: jax.lax.map(
        lambda p: fn(p, key), params))


def evaluate(agent: Agent, params, n_episodes: int = 100, *,
             env: Optional[PixelEnv] = None, task: Optional[str] = None,
             seed: int = 0, max_steps: Optional[int] = None) -> np.ndarray:
    """The paper's eval protocol in one call: ``n_episodes`` deterministic
    episodes (default 100 — "mean over the final 100 episodes") of
    ``agent.policy_head`` on a ``train=False`` (centre-crop) env.
    Returns the per-episode returns; reduce with :func:`final_100_mean`.
    Deterministic in ``seed``: repeated calls are bitwise identical.
    """
    if env is None:
        if task is None:
            raise ValueError("evaluate() needs env= or task=")
        env = make_pixel_env(task, train=False)
    fn = make_evaluator(env, agent, n_episodes, max_steps=max_steps)
    return np.asarray(fn(params, jax.random.PRNGKey(seed)))


# ---------------------------------------------------------------------------
# Driver: train every program, eval every member, pick the winner
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PopulationResult:
    spec: PopulationSpec
    members: list
    program_stats: list
    wall_time_s: float

    @property
    def aggregate_steps_per_sec(self) -> float:
        total = sum(m.env_steps for m in self.members)
        return total / self.wall_time_s if self.wall_time_s > 0 \
            else float("nan")

    def best_member(self) -> Member:
        """Winner under the paper's metric (``final_100_mean``); ties and
        all-NaN populations fall back to the lowest member index."""
        scored = [m for m in self.members
                  if np.isfinite(m.final_100_mean)]
        if not scored:
            return self.members[0]
        return max(scored, key=lambda m: m.final_100_mean)

    def best_params(self):
        return self.best_member().params

    def summary(self) -> dict:
        best = self.best_member()
        return {"n_members": len(self.members),
                "n_programs": len(self.program_stats),
                "wall_time_s": self.wall_time_s,
                "aggregate_steps_per_sec": self.aggregate_steps_per_sec,
                "best_member": best.index,
                "best_final_100_mean": best.final_100_mean,
                "members": [m.summary() for m in self.members],
                "programs": list(self.program_stats)}


def train_population(spec: PopulationSpec, *, eval_episodes: int = 100,
                     eval_seed: int = 0,
                     eval_max_steps: Optional[int] = None,
                     deploy_config=None, lane_mode: str = "exact",
                     verbose: bool = False) -> PopulationResult:
    """Train every member of ``spec`` — one jitted program per
    (task, static-config) group — then score each with the deterministic
    eval protocol (``eval_episodes=0`` skips eval; ``eval_max_steps``
    shortens the episode window for smoke-scale runs).

    Per member, the PRNG chain is exactly ``train()``'s: seed ->
    ``k_init, key = split`` -> per-phase ``key, sub = split``.  With the
    default ``lane_mode="exact"`` every member therefore reproduces a
    single ``train()`` run at its seed bitwise.  Member results land on
    the returned :class:`PopulationResult.members` in spec order.
    """
    t_start = time.time()
    stats: list = []
    all_members: list = []
    for prog in spec.programs():
        env = make_pixel_env(prog.task, train=True)
        encoder = _pipeline_encoder(spec.encoder, env.obs_shape[-1],
                                    deploy_config=deploy_config)
        P = len(prog.members)
        engine = make_population_engine(
            env, prog.algo, encoder, env.action_dim, prog.static_cfg,
            prog.hyper_arrays(), P, spec.total_steps, lane_mode=lane_mode)

        keys = jnp.stack([jax.random.PRNGKey(m.seed) for m in prog.members])
        k_init, keys = split_member_keys(keys)
        t0 = time.time()
        carry = engine.init(k_init)

        N = engine.n_envs
        returns: list[list[float]] = [[] for _ in range(P)]
        ep_ret = np.zeros((P, N))
        ep_len = np.zeros((P, N), np.int64)
        env_steps = 0
        compile_s = 0.0
        seen: set = set()
        for it, phase in enumerate(engine.plan()):
            keys, subs = split_member_keys(keys)
            t_call = time.time()
            carry, rewards, dones, metrics = engine.run(carry, subs, phase)
            rewards = np.asarray(rewards)       # (P, T, N); blocks
            dones = np.asarray(dones)
            if phase not in seen:
                seen.add(phase)
                compile_s += time.time() - t_call
            for p in range(P):
                ep_ret[p], ep_len[p] = _track_episodes(
                    returns[p], ep_ret[p], ep_len[p], rewards[p], dones[p])
            env_steps += int(rewards[0].size)
            if verbose:
                print(f"  [population {prog.task}/{prog.algo} P={P}] "
                      f"{phase[0]} {it} episodes="
                      f"{sum(len(r) for r in returns)}")

        state = carry.state
        for p, m in enumerate(prog.members):
            m.episode_returns = returns[p]
            m.truncated_returns = _flush_truncated(ep_ret[p], ep_len[p])
            m.env_steps = env_steps
            m.params = jax.tree.map(lambda x: x[p], state.params)

        if eval_episodes:
            eval_env = make_pixel_env(prog.task, train=False)
            eval_agent = make_agent(prog.algo, encoder, env.action_dim,
                                    cfg=prog.static_cfg)
            evaluator = make_population_evaluator(
                eval_env, eval_agent, eval_episodes,
                max_steps=eval_max_steps, lane_mode=lane_mode)
            rets = np.asarray(evaluator(state.params,
                                        jax.random.PRNGKey(eval_seed)))
            for p, m in enumerate(prog.members):
                m.eval_returns = rets[p]

        stats.append({"task": prog.task, "algo": prog.algo, "n_members": P,
                      "hyper_fields": list(prog.hyper_fields),
                      "env_steps_per_member": env_steps,
                      "wall_s": time.time() - t0, "compile_s": compile_s})
        all_members.extend(prog.members)

    all_members.sort(key=lambda m: m.index)
    return PopulationResult(spec=spec, members=all_members,
                            program_stats=stats,
                            wall_time_s=time.time() - t_start)


__all__ = ["SPEC_VERSION", "LANE_MODES", "PopulationSpec", "Member",
           "Program", "PopulationResult", "final_100_mean",
           "split_member_keys", "make_population_engine", "make_evaluator",
           "make_population_evaluator", "evaluate", "train_population"]
