"""RL substrate: PPO / SAC / DDPG with swappable observation encoders."""

from repro.rl.train import TASK_ALGO, TrainResult, train

__all__ = ["train", "TrainResult", "TASK_ALGO"]
