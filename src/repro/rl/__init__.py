"""RL substrate: PPO / SAC / DDPG with swappable observation encoders.

One protocol, one driver: every algorithm is a frozen
:class:`~repro.rl.agent.Agent` bundle (``init`` / ``act`` / ``update`` /
``target_update`` + config), executed by a compiled
:class:`~repro.rl.rollout.Engine`, driven by the single generic
:func:`~repro.rl.train.train` loop — the paper's three (task, algorithm)
pairings differ only in which bundle ``make_agent`` returns::

    from repro.rl import train
    res = train("hopper", "miniconv4", total_steps=20_000)   # SAC, 4 envs
    res.params                       # trained pytree, ready to serve
    res.summary()                    # best/mean/final + steps/sec

Module map
----------
``agent``
    The uniform protocol: :class:`Agent` (frozen bundle), ``TrainState``
    (params / target / opt_state pytree) and :func:`make_agent` dispatch.
``ppo`` / ``sac`` / ``ddpg``
    The three algorithms as ``Agent`` factories (``make_ppo_agent``, ...).
    Losses and update math only — no training loops here.
``rollout``
    The compiled engines.  On-policy: scan-rollout + whole-trajectory
    update per jitted call.  Off-policy: ``run_chunk`` scans vectorised
    env steps with replay inserts and ``train_freq * n_envs`` gradient
    updates interleaved ON DEVICE, donated carry, jax-PRNG warmup; only
    (T, N) reward/done arrays come back to the host.
``buffers``
    :class:`DeviceReplayBuffer` — pytree ring buffer (uint8 storage,
    ``lax.dynamic_update_slice`` insert, uniform sampling inside jit) —
    plus the host-side numpy :class:`ReplayBuffer` kept as the parity
    reference for the property tests.
``networks``
    Encoders (Full-CNN baseline, MiniConv via ``Deployment.build``) and
    the shared actor/critic heads.
``train``
    The generic driver: ``TASK_ALGO`` pairings, episode tracking with
    explicit end-of-training truncation counting, and
    :class:`TrainResult` (best/mean/final, throughput with the
    compile/steady split, trained params).
``population``
    P = seeds × hyperparameter variants × tasks trained as ONE jitted
    program per static shape (vmapped TrainState / env / replay / PRNG
    axes, tracer hyperparameters), plus the paper's deterministic
    final-100-episode eval protocol (``evaluate`` / ``final_100_mean``)
    and ``best_member()`` selection feeding ``Deployment.export_best``.
"""

from repro.rl.agent import Agent, TrainState, make_agent
from repro.rl.train import TASK_ALGO, TrainResult, train, train_population

__all__ = ["train", "train_population", "TrainResult", "TASK_ALGO",
           "Agent", "TrainState", "make_agent"]
