"""Findings baseline: `--strict` gates NEW findings, not the backlog.

The baseline file (``analysis_baseline.json`` at the repo root) records
the fingerprints of known findings plus every live suppression comment.
Fingerprints are content-based (rule + path + hash of the stripped source
line + occurrence index), so unrelated line-number drift does not
invalidate entries; editing the flagged line does, which is the point —
touched code must come clean.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Sequence, Tuple

from .core import Finding, Suppression

BASELINE_VERSION = 1

__all__ = [
    "BASELINE_VERSION",
    "load_baseline",
    "save_baseline",
    "diff_against_baseline",
    "baseline_problems",
]


def load_baseline(path: Path) -> dict:
    if not path.exists():
        return {"version": BASELINE_VERSION, "findings": [], "suppressions": []}
    data = json.loads(path.read_text())
    version = data.get("version")
    if version != BASELINE_VERSION:
        raise ValueError(
            f"baseline {path} has version {version!r}; this tool reads "
            f"version {BASELINE_VERSION} — regenerate with --write-baseline"
        )
    return data


def save_baseline(
    path: Path, findings: Sequence[Finding], suppressions: Sequence[Suppression]
) -> None:
    data = {
        "version": BASELINE_VERSION,
        "findings": [
            dict(fingerprint=f.fingerprint, **f.to_dict())
            for f in findings
            if not f.suppressed
        ],
        "suppressions": [
            {
                "path": s.path,
                "line": s.line,
                "rules": list(s.rules),
                "justification": s.justification,
            }
            for s in suppressions
        ],
    }
    path.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")


def diff_against_baseline(
    findings: Sequence[Finding], baseline: dict
) -> Tuple[List[Finding], List[Finding], List[str]]:
    """Split live unsuppressed findings into (new, known); also return the
    stale baseline fingerprints that no longer fire (candidates for a
    baseline regeneration)."""
    known_fps = {f["fingerprint"] for f in baseline.get("findings", [])}
    live = [f for f in findings if not f.suppressed]
    new = [f for f in live if f.fingerprint not in known_fps]
    known = [f for f in live if f.fingerprint in known_fps]
    live_fps = {f.fingerprint for f in live}
    stale = sorted(known_fps - live_fps)
    return new, known, stale


def baseline_problems(baseline: dict) -> List[str]:
    """CI gate: a baseline may not carry unjustified suppressions."""
    problems = []
    for s in baseline.get("suppressions", []):
        if not str(s.get("justification", "")).strip():
            problems.append(
                f"{s.get('path')}:{s.get('line')} baseline suppression for "
                f"{s.get('rules')} has no justification string"
            )
    return problems
