"""CLI: ``python -m repro.analysis [paths...] [--strict] [...]``.

Exit codes::

    0  clean (or, with --strict, nothing beyond the committed baseline)
    1  findings (default mode)
    2  --strict: NEW findings, or unjustified suppressions (live or
       baselined)

Run from the repo root; default scan roots are ``src/repro``,
``benchmarks`` and ``examples`` (tests intentionally excluded — fixtures
contain deliberate violations).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from .baseline import (
    baseline_problems,
    diff_against_baseline,
    load_baseline,
    save_baseline,
)
from .core import RULES, load_context, rule_names, run_rules

DEFAULT_PATHS = ("src/repro", "benchmarks", "examples")
DEFAULT_BASELINE = "analysis_baseline.json"


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro static analysis: the repo's bug taxonomy as rules",
    )
    parser.add_argument(
        "paths",
        nargs="*",
        default=list(DEFAULT_PATHS),
        help=f"files/dirs to scan (default: {' '.join(DEFAULT_PATHS)})",
    )
    parser.add_argument(
        "--strict",
        action="store_true",
        help="gate against the baseline: exit 2 on new findings or "
        "unjustified suppressions, 0 otherwise",
    )
    parser.add_argument(
        "--baseline",
        default=DEFAULT_BASELINE,
        help=f"baseline file (default: {DEFAULT_BASELINE})",
    )
    parser.add_argument(
        "--write-baseline",
        action="store_true",
        help="write the current findings + suppressions as the new baseline",
    )
    parser.add_argument(
        "--rules",
        default=None,
        help="comma-separated rule subset (default: all)",
    )
    parser.add_argument(
        "--list-rules", action="store_true", help="list rules and exit"
    )
    parser.add_argument(
        "--json", action="store_true", help="emit findings as JSON"
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for name in rule_names():
            r = RULES[name]
            print(f"{name:28s} [{r.family}] {r.description}")
        return 0

    root = Path.cwd()
    paths = [p for p in args.paths if (root / p).exists()]
    ctx = load_context(paths, root)
    selected = args.rules.split(",") if args.rules else None
    findings = run_rules(ctx, rules=selected)

    suppressions = []
    for f in ctx.files:
        suppressions.extend(f.suppressions())

    unsuppressed = [f for f in findings if not f.suppressed]
    n_sup = len(findings) - len(unsuppressed)

    if args.write_baseline:
        save_baseline(Path(args.baseline), findings, suppressions)
        print(
            f"wrote {args.baseline}: {len(unsuppressed)} finding(s), "
            f"{len(suppressions)} suppression(s)"
        )
        return 0

    if args.json:
        print(
            json.dumps(
                [dict(fingerprint=f.fingerprint, **f.to_dict())
                 for f in unsuppressed],
                indent=2,
            )
        )

    if not args.strict:
        if not args.json:
            for f in unsuppressed:
                print(f.render())
        print(
            f"{len(unsuppressed)} finding(s) "
            f"({n_sup} suppressed with justification)"
        )
        return 1 if unsuppressed else 0

    # --strict: compare against the committed baseline
    baseline = load_baseline(Path(args.baseline))
    problems = baseline_problems(baseline)
    new, known, stale = diff_against_baseline(findings, baseline)
    if not args.json:
        for f in new:
            print(f"NEW {f.render()}")
    for p in problems:
        print(f"BASELINE {p}")
    for fp in stale:
        print(f"stale baseline entry (no longer fires): {fp}")
    print(
        f"strict: {len(new)} new, {len(known)} baselined, {n_sup} "
        f"suppressed, {len(stale)} stale"
    )
    return 2 if (new or problems) else 0


if __name__ == "__main__":
    sys.exit(main())
