"""Concurrency / socket-lifecycle rules.

The real fleet (``serving/realfleet.py``) taught us two invariants the
hard way: a TCP socket closed without a prior ``shutdown(SHUT_RDWR)``
leaves the peer's reader thread blocked in ``recv`` until its timeout,
and a spawned worker process without a join/terminate on every exit path
is a leaked process the CI gate will catch minutes later.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from .core import (
    Context,
    Finding,
    Rule,
    dotted_name,
    iter_functions,
    register_rule,
)

_SOCKET_CTORS = {"socket.socket", "socket.create_connection"}


def _unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except (ValueError, RecursionError):
        return ""


def _is_socket_ctor(node: ast.AST) -> bool:
    if isinstance(node, ast.Call):
        name = dotted_name(node.func)
        if name in _SOCKET_CTORS:
            return True
        if name.endswith(".accept"):
            return True
    return False


def _socket_targets(assign: ast.Assign) -> List[str]:
    """Names bound to a socket by this assignment.

    ``conn, addr = listener.accept()`` binds the socket to the first
    element of the tuple target.
    """
    value = assign.value
    if not _is_socket_ctor(value):
        return []
    out = []
    for t in assign.targets:
        if isinstance(t, ast.Tuple) and t.elts:
            out.append(_unparse(t.elts[0]))
        else:
            out.append(_unparse(t))
    return [o for o in out if o]


def _check_socket_shutdown(ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    for f in ctx.files:
        if f.tree is None:
            continue
        # receivers of .bind()/.listen() anywhere in the module are
        # listener sockets: shutdown() is invalid on them, close() is fine
        listeners: Set[str] = set()
        for n in ast.walk(f.tree):
            if (
                isinstance(n, ast.Call)
                and isinstance(n.func, ast.Attribute)
                and n.func.attr in ("bind", "listen")
            ):
                listeners.add(_unparse(n.func.value))

        # self.X attributes assigned from socket ctors anywhere in a class
        class_sockets: Dict[ast.ClassDef, Set[str]] = {}
        for fn, cls in iter_functions(f.tree):
            if cls is None:
                continue
            for n in ast.walk(fn):
                if isinstance(n, ast.Assign):
                    for name in _socket_targets(n):
                        if name.startswith("self."):
                            class_sockets.setdefault(cls, set()).add(name)

        for fn, cls in iter_functions(f.tree):
            sockets: Set[str] = set(class_sockets.get(cls, set()))
            for n in ast.walk(fn):
                if isinstance(n, ast.Assign):
                    sockets.update(_socket_targets(n))
            if not sockets:
                continue
            shutdown_lines: Dict[str, int] = {}
            for n in ast.walk(fn):
                if (
                    isinstance(n, ast.Call)
                    and isinstance(n.func, ast.Attribute)
                    and n.func.attr == "shutdown"
                ):
                    recv = _unparse(n.func.value)
                    shutdown_lines[recv] = min(
                        shutdown_lines.get(recv, n.lineno), n.lineno
                    )
            for n in ast.walk(fn):
                if not (
                    isinstance(n, ast.Call)
                    and isinstance(n.func, ast.Attribute)
                    and n.func.attr == "close"
                ):
                    continue
                recv = _unparse(n.func.value)
                if recv not in sockets or recv in listeners:
                    continue
                if recv in shutdown_lines and shutdown_lines[recv] <= n.lineno:
                    continue
                findings.append(
                    Finding(
                        "socket-shutdown",
                        f.path,
                        n.lineno,
                        f"{recv}.close() without a prior "
                        f"{recv}.shutdown(socket.SHUT_RDWR) in "
                        f"{getattr(fn, 'name', '?')}(); without the FIN the "
                        "peer's reader blocks in recv until its timeout "
                        "(listener sockets are exempt)",
                    )
                )
    return findings


def _spawn_kind(node: ast.Call) -> Optional[str]:
    name = dotted_name(node.func)
    last = name.rsplit(".", 1)[-1]
    if last == "Thread":
        return "thread"
    if last == "Process":
        return "process"
    return None


def _is_daemon_true(node: ast.Call) -> bool:
    for k in node.keywords:
        if k.arg == "daemon" and isinstance(k.value, ast.Constant):
            return bool(k.value.value)
    return False


def _has_reap_call(scope: ast.AST) -> bool:
    for n in ast.walk(scope):
        if (
            isinstance(n, ast.Call)
            and isinstance(n.func, ast.Attribute)
            and n.func.attr in ("join", "terminate", "kill")
        ):
            return True
    return False


def _check_thread_lifecycle(ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    for f in ctx.files:
        if f.tree is None:
            continue
        for fn, cls in iter_functions(f.tree):
            for n in ast.walk(fn):
                if not isinstance(n, ast.Call):
                    continue
                kind = _spawn_kind(n)
                if kind is None:
                    continue
                # daemon threads die with the process; daemon *processes*
                # still need reaping (SIGKILL at exit loses their sockets)
                if kind == "thread" and _is_daemon_true(n):
                    continue
                reaped = _has_reap_call(fn) or (
                    cls is not None and _has_reap_call(cls)
                )
                if not reaped:
                    findings.append(
                        Finding(
                            "thread-lifecycle",
                            f.path,
                            n.lineno,
                            f"{kind} spawned in {getattr(fn, 'name', '?')}() "
                            "with no join/terminate/kill in the function or "
                            "its class; every exit path must reap it or the "
                            "leak check fails later",
                        )
                    )
    return findings


register_rule(
    Rule(
        name="socket-shutdown",
        family="concurrency",
        description=(
            "connected sockets must shutdown(SHUT_RDWR) before close() so "
            "peers unblock; listener sockets are exempt"
        ),
        check=_check_socket_shutdown,
    )
)

register_rule(
    Rule(
        name="thread-lifecycle",
        family="concurrency",
        description=(
            "spawned threads/processes need a join/terminate/kill in scope "
            "(daemon threads exempt; daemon processes are not)"
        ),
        check=_check_thread_lifecycle,
    )
)
