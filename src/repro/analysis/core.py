"""Core of the repro static-analysis engine.

The engine mirrors the repo's other registries (backends, routers, link
kinds, scenarios): rules are small objects registered by name into
``RULES`` via :func:`register_rule`, and the CLI / tests look them up the
same way callers look up an execution backend.

A rule is a callable ``(Context) -> list[Finding]``.  Most rules are pure
AST walks over the parsed files in the context; two "runtime" rules
additionally import the repro registries to cross-check the AST against
what actually registered (see ``rules_schema`` / ``rules_kernel``).

Suppression contract
--------------------
A finding on line L is suppressed by a comment on line L or L-1 of the
form::

    # repro: allow(rule-name) -- one-line justification

The justification is mandatory: an ``allow`` with no ``--`` justification
does NOT suppress anything and instead raises its own
``suppression-justification`` finding, so CI can require every waiver to
say why.
"""

from __future__ import annotations

import ast
import hashlib
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

__all__ = [
    "Finding",
    "SourceFile",
    "Suppression",
    "Context",
    "Rule",
    "RULES",
    "register_rule",
    "get_rule",
    "rule_names",
    "load_context",
    "run_rules",
    "analyze_source",
    "iter_functions",
    "function_body",
    "dotted_name",
]


# --------------------------------------------------------------------------
# findings

@dataclass
class Finding:
    """One rule violation at one source location.

    ``key`` is a content-based fingerprint component (hash of the stripped
    source line plus an occurrence index), so baseline entries survive
    unrelated line-number drift.
    """

    rule: str
    path: str
    line: int
    message: str
    key: str = ""
    suppressed: bool = False
    justification: str = ""

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}|{self.path}|{self.key}"

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "key": self.key,
        }

    def render(self) -> str:
        return f"{self.path}:{self.line} [{self.rule}] {self.message}"


_ALLOW_RE = re.compile(
    r"#\s*repro:\s*allow\(([a-z0-9_,\s-]+)\)(?:\s*--\s*(.*))?\s*$"
)


@dataclass
class Suppression:
    path: str
    line: int
    rules: Tuple[str, ...]
    justification: str

    def covers(self, finding: Finding) -> bool:
        if finding.path != self.path:
            return False
        if finding.line not in (self.line, self.line + 1):
            return False
        return finding.rule in self.rules or "*" in self.rules


@dataclass
class SourceFile:
    path: str  # repo-relative, forward slashes
    source: str
    tree: Optional[ast.AST]  # None when the file failed to parse
    parse_error: str = ""

    @property
    def lines(self) -> List[str]:
        return self.source.splitlines()

    def suppressions(self) -> List[Suppression]:
        out = []
        for line, text in self._comments():
            m = _ALLOW_RE.search(text)
            if m:
                rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
                out.append(
                    Suppression(self.path, line, rules, (m.group(2) or "").strip())
                )
        return out

    def _comments(self) -> List[Tuple[int, str]]:
        """(line, text) for real comment tokens — an allow() example inside
        a docstring or string literal must not count as a waiver."""
        import io
        import tokenize

        try:
            return [
                (tok.start[0], tok.string)
                for tok in tokenize.generate_tokens(
                    io.StringIO(self.source).readline
                )
                if tok.type == tokenize.COMMENT
            ]
        except (tokenize.TokenError, IndentationError, SyntaxError):
            # unparseable file: fall back to a line scan so a waiver next
            # to the syntax finding still works
            return list(enumerate(self.lines, start=1))


@dataclass
class Context:
    """Everything a rule may look at: parsed files plus the repo root.

    ``runtime`` gates the rules that import the repro registries; fixture
    tests run pure-AST rules with ``runtime=False`` so analysing a snippet
    never imports jax.
    """

    files: List[SourceFile]
    root: Path
    runtime: bool = True

    def file(self, path: str) -> Optional[SourceFile]:
        for f in self.files:
            if f.path == path:
                return f
        return None


# --------------------------------------------------------------------------
# rule registry (same shape as core.backends / serving.fleet routers)

@dataclass(frozen=True)
class Rule:
    name: str
    family: str  # timing | rng | concurrency | schema | kernel | core
    description: str
    check: Callable[[Context], List[Finding]]


RULES: Dict[str, Rule] = {}


def register_rule(rule: Rule) -> Rule:
    if rule.name in RULES:
        raise ValueError(f"duplicate rule name {rule.name!r}")
    RULES[rule.name] = rule
    return rule


def get_rule(name: str) -> Rule:
    try:
        return RULES[name]
    except KeyError:
        raise KeyError(
            f"unknown rule {name!r}; known: {', '.join(sorted(RULES))}"
        ) from None


def rule_names() -> List[str]:
    return sorted(RULES)


# --------------------------------------------------------------------------
# AST helpers shared by the rule modules

def dotted_name(node: ast.AST) -> str:
    """Best-effort dotted source text for a Name/Attribute chain."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    if isinstance(node, ast.Call):
        return dotted_name(node.func)
    return ""


def iter_functions(
    tree: ast.AST,
) -> Iterator[Tuple[ast.AST, Optional[ast.ClassDef]]]:
    """Yield (function_def, enclosing_class) pairs, innermost included."""

    def walk(node: ast.AST, cls: Optional[ast.ClassDef]) -> Iterator:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                yield from walk(child, child)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield child, cls
                yield from walk(child, cls)
            else:
                yield from walk(child, cls)

    yield from walk(tree, None)


def function_body(fn: ast.AST) -> List[ast.AST]:
    """All nodes in a function, excluding nested function/class bodies.

    Nested defs are analysed on their own by :func:`iter_functions`; a
    block call inside a helper closure must not satisfy the outer timing
    window.
    """
    out: List[ast.AST] = []

    def walk(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
            ):
                continue
            out.append(child)
            walk(child)

    walk(fn)
    return out


def _finding_key(rule: str, file: SourceFile, line: int, seen: Dict[str, int]) -> str:
    lines = file.lines
    text = lines[line - 1].strip() if 0 < line <= len(lines) else ""
    base = f"{rule}|{text}"
    idx = seen.get(base, 0)
    seen[base] = idx + 1
    return hashlib.sha1(f"{base}|{idx}".encode()).hexdigest()[:12]


# --------------------------------------------------------------------------
# driver

def load_context(
    paths: Sequence[str], root: Path, runtime: bool = True
) -> Context:
    files: List[SourceFile] = []
    for p in paths:
        full = (root / p).resolve()
        if full.is_dir():
            candidates = sorted(full.rglob("*.py"))
        elif full.suffix == ".py":
            candidates = [full]
        else:
            continue
        for c in candidates:
            rel = c.relative_to(root).as_posix()
            source = c.read_text()
            try:
                tree: Optional[ast.AST] = ast.parse(source, filename=rel)
                err = ""
            except SyntaxError as e:
                tree, err = None, f"line {e.lineno}: {e.msg}"
            files.append(SourceFile(rel, source, tree, err))
    return Context(files=files, root=root, runtime=runtime)


def run_rules(
    ctx: Context, rules: Optional[Sequence[str]] = None
) -> List[Finding]:
    """Run rules, fingerprint findings, and apply suppression comments."""
    selected = [get_rule(n) for n in (rules if rules is not None else rule_names())]
    findings: List[Finding] = []

    # unparseable files are findings, not crashes
    for f in ctx.files:
        if f.tree is None:
            findings.append(
                Finding("syntax", f.path, 1, f"file does not parse: {f.parse_error}")
            )

    for rule in selected:
        findings.extend(rule.check(ctx))

    suppressions: List[Suppression] = []
    for f in ctx.files:
        suppressions.extend(f.suppressions())

    for s in suppressions:
        if not s.justification:
            findings.append(
                Finding(
                    "suppression-justification",
                    s.path,
                    s.line,
                    "repro: allow(...) without a '-- justification'; "
                    "the waiver is ignored until it says why",
                )
            )

    for fi in findings:
        for s in suppressions:
            if s.justification and s.covers(fi):
                fi.suppressed = True
                fi.justification = s.justification
                break

    findings.sort(key=lambda fi: (fi.path, fi.line, fi.rule))
    seen: Dict[str, int] = {}
    by_path = {f.path: f for f in ctx.files}
    for fi in findings:
        src = by_path.get(fi.path)
        fi.key = (
            _finding_key(fi.rule, src, fi.line, seen)
            if src
            else hashlib.sha1(fi.fingerprint.encode()).hexdigest()[:12]
        )
    return findings


def analyze_source(
    source: str,
    path: str = "snippet.py",
    rules: Optional[Sequence[str]] = None,
) -> List[Finding]:
    """Analyse a single in-memory snippet (the fixture-test entry point).

    Runs with ``runtime=False`` so registry/VMEM audits only perform their
    AST cross-reference half.
    """
    try:
        tree: Optional[ast.AST] = ast.parse(source, filename=path)
        err = ""
    except SyntaxError as e:
        tree, err = None, f"line {e.lineno}: {e.msg}"
    ctx = Context(
        files=[SourceFile(path, source, tree, err)],
        root=Path("."),
        runtime=False,
    )
    return run_rules(ctx, rules=rules)


# two checks live in the driver itself (they apply to every run regardless
# of rule selection); registered here so --list-rules documents them
register_rule(
    Rule(
        name="syntax",
        family="core",
        description="every scanned file parses under the CI interpreter",
        check=lambda ctx: [],  # emitted by run_rules from parse errors
    )
)
register_rule(
    Rule(
        name="suppression-justification",
        family="core",
        description=(
            "every '# repro: allow(...)' waiver carries a '-- justification'"
        ),
        check=lambda ctx: [],  # emitted by run_rules from the comment scan
    )
)


# the registry ships full: importing repro.analysis pulls in every rule
# module (mirrors how serving.scenario registers its builtin scenarios on
# import)
def _register_builtin_rules() -> None:
    from . import (  # noqa: F401
        rules_timing,
        rules_rng,
        rules_concurrency,
        rules_schema,
        rules_kernel,
    )
