"""repro.analysis — the repo's bug taxonomy, machine-checked.

An AST-based static-analysis engine whose rules encode the invariants
PRs 5-8 kept re-discovering by hand: blocked warmups before clock reads,
offsets-from-t_start scheduling, RNG reconstruction in reset(),
shutdown-before-close sockets, reaped workers, versioned schemas,
live registries, and static VMEM budgets.

Run ``python -m repro.analysis`` from the repo root; ``--strict`` gates
new findings against ``analysis_baseline.json`` in CI.  Rules live in a
registry (``RULES`` / ``register_rule``) exactly like the execution
backends, routers and link kinds they audit.
"""

from .core import (
    Context,
    Finding,
    RULES,
    Rule,
    SourceFile,
    Suppression,
    analyze_source,
    get_rule,
    load_context,
    register_rule,
    rule_names,
    run_rules,
    _register_builtin_rules,
)
from .baseline import (
    BASELINE_VERSION,
    baseline_problems,
    diff_against_baseline,
    load_baseline,
    save_baseline,
)

_register_builtin_rules()

__all__ = [
    "Context",
    "Finding",
    "RULES",
    "Rule",
    "SourceFile",
    "Suppression",
    "analyze_source",
    "get_rule",
    "load_context",
    "register_rule",
    "rule_names",
    "run_rules",
    "BASELINE_VERSION",
    "baseline_problems",
    "diff_against_baseline",
    "load_baseline",
    "save_baseline",
]
