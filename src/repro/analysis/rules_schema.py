"""Schema / registry consistency rules.

Every frozen config dataclass that serialises (``to_dict``/``from_dict``)
must carry a schema version and refuse unknown versions — the manifests
(`DeploymentConfig` v2, `Scenario`, `TunedPlan`, `ShapingConfig`) are
long-lived JSON artifacts and silent field drops are how stale benchmark
baselines sneak in.  Separately, every name registered in source must
actually exist in the imported registry (a registration inside a failed
conditional is invisible at runtime), and every registry entry must be
constructible and JSON-round-trippable.
"""

from __future__ import annotations

import ast
import json
from typing import Callable, Dict, List, Optional, Tuple

from .core import Context, Finding, Rule, dotted_name, register_rule


# --------------------------------------------------------------------------
# schema-version: versioned to_dict/from_dict on frozen config dataclasses

def _is_frozen_dataclass(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        try:
            text = ast.unparse(dec)
        except (ValueError, RecursionError):
            continue
        if "dataclass" in text and "frozen=True" in text:
            return True
    return False


def _mentions_version(fn: ast.AST) -> bool:
    for n in ast.walk(fn):
        if isinstance(n, ast.Constant) and n.value == "version":
            return True
        if isinstance(n, ast.Name) and "VERSION" in n.id:
            return True
    return False


def _rejects_unknown_version(fn: ast.AST) -> bool:
    """from_dict must be able to refuse: a raise, or a call into a
    version-checking helper (e.g. repro.schema.check_version)."""
    for n in ast.walk(fn):
        if isinstance(n, ast.Raise):
            return True
        if isinstance(n, ast.Call) and "version" in dotted_name(n.func).lower():
            return True
    return False


def _check_schema_version(ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    for f in ctx.files:
        if f.tree is None:
            continue
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.ClassDef) or not _is_frozen_dataclass(
                node
            ):
                continue
            methods = {
                n.name: n
                for n in node.body
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            }
            to_dict, from_dict = methods.get("to_dict"), methods.get("from_dict")
            if to_dict is None or from_dict is None:
                continue  # not a serialised schema (or one-way export)
            # a `version` dataclass field serialises through asdict()
            has_version_field = any(
                isinstance(n, ast.AnnAssign)
                and isinstance(n.target, ast.Name)
                and n.target.id == "version"
                for n in node.body
            )
            problems = []
            if not (_mentions_version(to_dict) or has_version_field):
                problems.append("to_dict() does not write a 'version' field")
            if not (_mentions_version(from_dict) and _rejects_unknown_version(from_dict)):
                problems.append(
                    "from_dict() does not check the version and raise on "
                    "unknown ones"
                )
            if problems:
                findings.append(
                    Finding(
                        "schema-version",
                        f.path,
                        node.lineno,
                        f"frozen config dataclass {node.name} serialises "
                        f"without schema versioning: {'; '.join(problems)} "
                        "(see repro.schema.check_version)",
                    )
                )
    return findings


# --------------------------------------------------------------------------
# registry-roundtrip: AST-registered names must exist in the imported
# registries, and registry entries must survive a JSON round-trip

# register function -> (module, registry accessor returning {name: entry})
_REGISTRIES: Dict[str, Tuple[str, Callable]] = {
    "register_router": ("repro.serving.fleet", lambda m: m.ROUTERS),
    "register_link_kind": ("repro.serving.netsim", lambda m: m.LINK_KINDS),
    "register_scenario": ("repro.serving.scenario", lambda m: m.SCENARIOS),
    "register_adaptation": ("repro.serving.scenario", lambda m: m.ADAPTATIONS),
    "register_profile": ("repro.serving.profiles", lambda m: m.DEVICE_PROFILES),
    "register_backend": (
        "repro.core.backends",
        lambda m: {n: m.get_backend(n) for n in m.backend_names()},
    ),
}


def _registered_name(call: ast.Call) -> Optional[str]:
    """Literal name a register_*() call registers, or None if dynamic."""
    if call.args and isinstance(call.args[0], ast.Constant):
        if isinstance(call.args[0].value, str):
            return call.args[0].value
    if call.args and isinstance(call.args[0], ast.Call):
        ctor = call.args[0]
        for k in ctor.keywords:
            if (
                k.arg == "name"
                and isinstance(k.value, ast.Constant)
                and isinstance(k.value.value, str)
            ):
                return k.value.value
        if ctor.args and isinstance(ctor.args[0], ast.Constant):
            if isinstance(ctor.args[0].value, str):
                return ctor.args[0].value
    return None


def _check_registry_roundtrip(ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    import importlib

    # AST half: cross-reference literal register_*() names against the
    # live registries (runs for fixtures too — only the named registry's
    # module is imported)
    for f in ctx.files:
        if f.tree is None:
            continue
        for n in ast.walk(f.tree):
            if not isinstance(n, ast.Call):
                continue
            fn_name = dotted_name(n.func).rsplit(".", 1)[-1]
            if fn_name not in _REGISTRIES:
                continue
            name = _registered_name(n)
            if name is None:
                continue
            module_name, accessor = _REGISTRIES[fn_name]
            try:
                registry = accessor(importlib.import_module(module_name))
            except Exception as e:  # repro: allow(broad-except) -- audit must report, not crash on, a registry import failure
                findings.append(
                    Finding(
                        "registry-roundtrip",
                        f.path,
                        n.lineno,
                        f"cannot import {module_name} to verify "
                        f"{fn_name}({name!r}): {e!r}",
                    )
                )
                continue
            if name not in registry:
                findings.append(
                    Finding(
                        "registry-roundtrip",
                        f.path,
                        n.lineno,
                        f"{fn_name}({name!r}) appears in source but "
                        f"{name!r} is missing from the live "
                        f"{module_name} registry — registration is dead "
                        "code or conditional",
                    )
                )

    if ctx.runtime:
        findings.extend(check_registries())
    return findings


def check_registries() -> List[Finding]:
    """Runtime half: construct + JSON-round-trip every registry entry."""
    findings: List[Finding] = []

    def report(path: str, msg: str) -> None:
        findings.append(Finding("registry-roundtrip", path, 1, msg))

    try:
        from repro.core.backends import backend_names, get_backend
        from repro.serving.fleet import ROUTERS
        from repro.serving.netsim import LINK_KINDS
        from repro.serving.profiles import DEVICE_PROFILES
        from repro.serving.scenario import ADAPTATIONS, SCENARIOS, Scenario
        from repro.core.wire import CODECS
    except Exception as e:  # repro: allow(broad-except) -- audit must report, not crash on, a registry import failure
        report("src/repro/analysis/rules_schema.py", f"registry import failed: {e!r}")
        return findings

    for name in backend_names():
        b = get_backend(name)
        if b.name != name:
            report(
                "src/repro/core/backends.py",
                f"backend registered as {name!r} reports name {b.name!r}",
            )

    for name, fn in ROUTERS.items():
        if not callable(fn):
            report("src/repro/serving/fleet.py", f"router {name!r} is not callable")

    for name, builder in LINK_KINDS.items():
        if not callable(builder):
            report(
                "src/repro/serving/netsim.py",
                f"link kind {name!r} builder is not callable",
            )

    for name, codec in CODECS.items():
        if getattr(codec, "name", name) != name:
            report(
                "src/repro/core/wire.py",
                f"codec registered as {name!r} reports name "
                f"{getattr(codec, 'name', None)!r}",
            )

    for name, p in DEVICE_PROFILES.items():
        if p.name != name:
            report(
                "src/repro/serving/profiles.py",
                f"profile registered as {name!r} reports name {p.name!r}",
            )

    for name, factory in ADAPTATIONS.items():
        if not callable(factory):
            report(
                "src/repro/serving/scenario.py",
                f"adaptation {name!r} factory is not callable",
            )

    for name, sc in SCENARIOS.items():
        path = "src/repro/serving/scenario.py"
        if sc.name != name:
            report(path, f"scenario registered as {name!r} reports {sc.name!r}")
            continue
        try:
            wire = json.loads(json.dumps(sc.to_dict()))
            back = Scenario.from_dict(wire)
        except Exception as e:  # repro: allow(broad-except) -- audit must report, not crash on, a schema round-trip failure
            report(path, f"scenario {name!r} JSON round-trip raised: {e!r}")
            continue
        if back != sc:
            report(
                path,
                f"scenario {name!r} does not survive to_dict->json->"
                "from_dict bitwise",
            )
        try:
            sc.validate()
        except Exception as e:  # repro: allow(broad-except) -- audit must report, not crash on, a scenario validation failure
            report(path, f"scenario {name!r} fails validate(): {e!r}")

    return findings


register_rule(
    Rule(
        name="schema-version",
        family="schema",
        description=(
            "frozen config dataclasses with to_dict/from_dict must write a "
            "version and refuse unknown versions on load"
        ),
        check=_check_schema_version,
    )
)

register_rule(
    Rule(
        name="registry-roundtrip",
        family="schema",
        description=(
            "register_*() names in source must exist in the live registry; "
            "every registry entry constructs and JSON-round-trips"
        ),
        check=_check_registry_roundtrip,
    )
)
