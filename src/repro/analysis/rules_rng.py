"""Determinism / RNG rules.

The scenario engine's replayability contract (PR 8) is that ``reset()``
restores a link/sim to a bitwise-identical trajectory.  That only holds
when ``reset()`` reconstructs the RNG (``np.random.default_rng(self.seed)``)
rather than reusing the advanced generator, and when nothing in the
serving/sim path draws from unseeded or global RNG state.
"""

from __future__ import annotations

import ast
from typing import List, Optional

from .core import (
    Context,
    Finding,
    Rule,
    dotted_name,
    iter_functions,
    register_rule,
)

# unseeded-RNG scope: modules that feed seeded, replayable simulation
_RNG_SCOPES = ("src/repro/serving/",)

# numpy global-state draw functions (np.random.<fn> without a Generator)
_GLOBAL_DRAWS = {
    "uniform",
    "normal",
    "random",
    "randint",
    "rand",
    "randn",
    "choice",
    "shuffle",
    "permutation",
    "exponential",
    "poisson",
}


def _is_default_rng_call(node: ast.AST) -> bool:
    return isinstance(node, ast.Call) and dotted_name(node.func).endswith(
        "default_rng"
    )


def _rng_attrs_in(fn: ast.AST) -> List[str]:
    """self.X attributes assigned from default_rng(...) in this function."""
    out = []
    for n in ast.walk(fn):
        if isinstance(n, ast.Assign) and any(
            _is_default_rng_call(v) for v in ast.walk(n.value)
        ):
            for t in n.targets:
                if (
                    isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)
                    and t.value.id == "self"
                ):
                    out.append(t.attr)
    return out


def _check_rng_reset(ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    for f in ctx.files:
        if f.tree is None:
            continue
        classes = {}
        for fn, cls in iter_functions(f.tree):
            if cls is None:
                continue
            classes.setdefault(cls, {})[fn.name] = fn
        for cls, methods in classes.items():
            rng_attrs: List[str] = []
            for ctor in ("__init__", "__post_init__"):
                if ctor in methods:
                    rng_attrs.extend(_rng_attrs_in(methods[ctor]))
            reset = methods.get("reset")
            if not rng_attrs or reset is None:
                continue
            reconstructs = any(
                _is_default_rng_call(n) for n in ast.walk(reset)
            )
            restores = any(
                isinstance(n, ast.Assign)
                and any(
                    attr in ast.unparse(t)
                    for t in n.targets
                    for attr in rng_attrs
                )
                for n in ast.walk(reset)
            )
            if not (reconstructs or restores):
                findings.append(
                    Finding(
                        "rng-reset",
                        f.path,
                        reset.lineno,
                        f"{cls.name}.reset() does not reconstruct or restore "
                        f"the RNG state it seeds in __init__/__post_init__ "
                        f"(self.{', self.'.join(sorted(set(rng_attrs)))}); "
                        "reset must re-run np.random.default_rng(self.seed) "
                        "or the replayed trajectory diverges",
                    )
                )
    return findings


def _in_rng_scope(path: str) -> bool:
    return any(scope in path for scope in _RNG_SCOPES)


def _check_rng_unseeded(ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    for f in ctx.files:
        if f.tree is None or not _in_rng_scope(f.path):
            continue
        for n in ast.walk(f.tree):
            if not isinstance(n, ast.Call):
                continue
            name = dotted_name(n.func)
            if name.endswith("default_rng"):
                seeded = bool(n.args and not (
                    isinstance(n.args[0], ast.Constant)
                    and n.args[0].value is None
                )) or any(k.arg == "seed" for k in n.keywords)
                if not seeded:
                    findings.append(
                        Finding(
                            "rng-unseeded",
                            f.path,
                            n.lineno,
                            "np.random.default_rng() constructed without a "
                            "seed in a sim/link/scenario module; pass the "
                            "owning object's seed so runs replay",
                        )
                    )
            elif (
                (parts := name.split("."))[-1] in _GLOBAL_DRAWS
                and len(parts) >= 2
                and parts[-2] == "random"
            ):
                findings.append(
                    Finding(
                        "rng-unseeded",
                        f.path,
                        n.lineno,
                        f"global-state RNG draw {name}(...) in a "
                        "sim/link/scenario module; draw from a seeded "
                        "np.random.Generator instead",
                    )
                )
    return findings


register_rule(
    Rule(
        name="rng-reset",
        family="rng",
        description=(
            "classes that seed np.random.default_rng in __init__/"
            "__post_init__ must reconstruct or restore it in reset()"
        ),
        check=_check_rng_reset,
    )
)

register_rule(
    Rule(
        name="rng-unseeded",
        family="rng",
        description=(
            "no unseeded default_rng() or global np.random/random draws "
            "inside sim/link/scenario modules (src/repro/serving/)"
        ),
        check=_check_rng_unseeded,
    )
)
