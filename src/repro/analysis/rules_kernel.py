"""Kernel / VMEM budget rules, plus the broad-except sweep.

``kernel-interpret`` (AST): every ``pallas_call`` site must pass
``interpret=`` explicitly — the repo's compiled-vs-interpret stamping
(PR 6) only works because no call site inherits an ambient default.

``kernel-vmem`` (runtime, arithmetic only — nothing is executed): for the
paper's standard encoder configs, every fused pallas backend must admit at
least a batch-1 launch under the ``PassPlan`` VMEM budget.  A backend
whose batch-independent residency alone exceeds VMEM is unlaunchable and
streaming cannot help it.

``broad-except`` (AST): ``except Exception`` / bare ``except`` hides the
exact bug classes the rest of this engine looks for; outside allow-listed
compat probes each site needs a narrow type or a justified suppression.
"""

from __future__ import annotations

import ast
from typing import List

from .core import Context, Finding, Rule, dotted_name, register_rule


# --------------------------------------------------------------------------
# kernel-interpret

def _check_kernel_interpret(ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    for f in ctx.files:
        if f.tree is None:
            continue
        for n in ast.walk(f.tree):
            if not isinstance(n, ast.Call):
                continue
            # only the pallas_call(...) call itself, not the immediate
            # invocation pl.pallas_call(...)(x) whose func is that Call
            if not isinstance(n.func, (ast.Name, ast.Attribute)):
                continue
            if dotted_name(n.func).rsplit(".", 1)[-1] != "pallas_call":
                continue
            if not any(k.arg == "interpret" for k in n.keywords):
                findings.append(
                    Finding(
                        "kernel-interpret",
                        f.path,
                        n.lineno,
                        "pallas_call without an explicit interpret= kwarg; "
                        "the compiled/interpret mode stamp on BENCH "
                        "artifacts requires every site to choose explicitly",
                    )
                )
    return findings


# --------------------------------------------------------------------------
# kernel-vmem

# (c_in, input size) pairs covering the paper's standard encoder configs
_AUDIT_CONFIGS = ((12, 84), (4, 64), (4, 128), (4, 256), (4, 400))
_AUDIT_HEAD_DIM = 512
_AUDIT_TILE_H = 8


def audit_vmem_budgets(vmem_limit: int = 0) -> List[Finding]:
    """Static VMEM audit: PassPlan arithmetic only, no kernel launches."""
    findings: List[Finding] = []
    try:
        from repro.core.backends import backend_names, get_backend
        from repro.core.miniconv import standard_spec
        from repro.core.passplan import DEFAULT_VMEM_LIMIT, build_pass_plan
    except Exception as e:  # repro: allow(broad-except) -- audit must report, not crash on, an import failure
        return [
            Finding(
                "kernel-vmem",
                "src/repro/analysis/rules_kernel.py",
                1,
                f"cannot import PassPlan machinery for the VMEM audit: {e!r}",
            )
        ]
    limit = vmem_limit or DEFAULT_VMEM_LIMIT
    for c_in, size in _AUDIT_CONFIGS:
        spec = standard_spec(c_in=c_in)
        plan = build_pass_plan(spec, size, size)
        head = plan.head(_AUDIT_HEAD_DIM)
        for name in backend_names():
            b = get_backend(name)
            if not b.is_pallas or b.mode != "fused":
                continue  # per-pass/grouped launch one pass at a time
            safe = plan.max_safe_batch(
                head=head if b.fused_head else None,
                tile_h=_AUDIT_TILE_H,
                vmem_limit=limit,
            )
            if safe < 1:
                findings.append(
                    Finding(
                        "kernel-vmem",
                        "src/repro/core/backends.py",
                        1,
                        f"backend {name!r} cannot launch even batch=1 for "
                        f"c_in={c_in} {size}x{size} under the "
                        f"{limit / 2**20:.1f} MiB VMEM budget "
                        "(batch-independent residency already exceeds it; "
                        "streaming cannot help)",
                    )
                )
    return findings


def _check_kernel_vmem(ctx: Context) -> List[Finding]:
    if not ctx.runtime:
        return []
    return audit_vmem_budgets()


# --------------------------------------------------------------------------
# broad-except

def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    names = []
    if isinstance(t, ast.Tuple):
        names = [dotted_name(e) for e in t.elts]
    else:
        names = [dotted_name(t)]
    return any(n in ("Exception", "BaseException") for n in names)


def _reraises(handler: ast.ExceptHandler) -> bool:
    return any(
        isinstance(n, ast.Raise) and n.exc is None for n in ast.walk(handler)
    )


def _check_broad_except(ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    for f in ctx.files:
        if f.tree is None:
            continue
        for n in ast.walk(f.tree):
            if not isinstance(n, ast.ExceptHandler) or not _is_broad(n):
                continue
            if _reraises(n):
                continue  # catch-to-cleanup-and-reraise is fine
            findings.append(
                Finding(
                    "broad-except",
                    f.path,
                    n.lineno,
                    "broad except handler swallows every bug class this "
                    "engine checks for; catch the specific exceptions or "
                    "add '# repro: allow(broad-except) -- <why>'",
                )
            )
    return findings


register_rule(
    Rule(
        name="kernel-interpret",
        family="kernel",
        description="every pallas_call site passes interpret= explicitly",
        check=_check_kernel_interpret,
    )
)

register_rule(
    Rule(
        name="kernel-vmem",
        family="kernel",
        description=(
            "fused pallas backends must admit batch>=1 for the standard "
            "encoder configs under the PassPlan VMEM budget (arithmetic "
            "only, nothing executed)"
        ),
        check=_check_kernel_vmem,
    )
)

register_rule(
    Rule(
        name="broad-except",
        family="kernel",
        description=(
            "no bare/Exception-wide handlers without a justified "
            "suppression (re-raising handlers exempt)"
        ),
        check=_check_broad_except,
    )
)
