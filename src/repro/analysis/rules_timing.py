"""Timing-hygiene rules.

The repo's latency claims all come from ``time.perf_counter()`` windows
around jitted JAX calls.  Two bug classes kept reappearing (PRs 5-8):

* reading the clock while device work is still in flight — jax dispatch
  is async, so a window that isn't preceded by a warmup + block measures
  dispatch (microseconds) or compile (seconds), not the kernel;
* accumulating periods onto a raw monotonic clock value (``t += period``)
  instead of scheduling offsets from ``t_start`` — float error compounds
  and the schedule drifts (the PR 8 ``run_load`` flake).
"""

from __future__ import annotations

import ast
from typing import List

from .core import (
    Context,
    Finding,
    Rule,
    dotted_name,
    function_body,
    iter_functions,
    register_rule,
)

_CLOCKS = {"perf_counter", "monotonic", "time"}
_BLOCK_SUFFIXES = ("block_until_ready", "_block")


def _is_perf_counter_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = dotted_name(node.func)
    return name == "perf_counter" or name.endswith(".perf_counter")


def _is_block_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = dotted_name(node.func)
    last = name.rsplit(".", 1)[-1]
    return last == "block_until_ready" or last.endswith("_block")


def _check_warmup(ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    for f in ctx.files:
        if f.tree is None:
            continue
        for fn, _cls in iter_functions(f.tree):
            body = function_body(fn)
            pc_lines = sorted(
                n.lineno for n in body if _is_perf_counter_call(n)
            )
            if len(pc_lines) < 2:
                continue  # a single read is not a timing window
            # the timed region must contain something to measure
            first = pc_lines[0]
            timed_calls = [
                n
                for n in body
                if isinstance(n, ast.Call)
                and n.lineno >= first
                and not _is_perf_counter_call(n)
            ]
            if not timed_calls:
                continue
            block_lines = [n.lineno for n in body if _is_block_call(n)]
            if not any(b < first for b in block_lines):
                findings.append(
                    Finding(
                        "timing-warmup",
                        f.path,
                        first,
                        f"perf_counter window in {getattr(fn, 'name', '?')}() "
                        "with no preceding blocked warmup: call "
                        "jax.block_until_ready(...) (or _block(...)) on a "
                        "warmup result before the first clock read, or the "
                        "window times async dispatch/compile instead of the "
                        "work",
                    )
                )
    return findings


def _is_clock_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = dotted_name(node.func)
    parts = name.rsplit(".", 1)
    if len(parts) == 2:
        return parts[0].endswith("time") and parts[1] in _CLOCKS
    return False


def _contains_clock_call(node: ast.AST) -> bool:
    return any(_is_clock_call(n) for n in ast.walk(node))


def _target_text(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except (ValueError, RecursionError):  # unparse of odd targets
        return ""


def _check_monotonic_accum(ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    for f in ctx.files:
        if f.tree is None:
            continue
        for fn, _cls in iter_functions(f.tree):
            body = function_body(fn)
            clock_vars = {}  # target text -> first assignment line
            for n in body:
                if isinstance(n, ast.Assign) and _contains_clock_call(n.value):
                    for t in n.targets:
                        text = _target_text(t)
                        if text:
                            clock_vars.setdefault(text, n.lineno)
            if not clock_vars:
                continue
            for n in body:
                if isinstance(n, ast.AugAssign) and isinstance(
                    n.op, (ast.Add, ast.Sub)
                ):
                    text = _target_text(n.target)
                elif (
                    isinstance(n, ast.Assign)
                    and len(n.targets) == 1
                    and isinstance(n.value, ast.BinOp)
                    and isinstance(n.value.op, (ast.Add, ast.Sub))
                    and _target_text(n.targets[0])
                    in (
                        _target_text(n.value.left),
                        _target_text(n.value.right),
                    )
                ):
                    text = _target_text(n.targets[0])
                else:
                    continue
                if text in clock_vars and n.lineno > clock_vars[text]:
                    findings.append(
                        Finding(
                            "timing-monotonic-accum",
                            f.path,
                            n.lineno,
                            f"{text!r} accumulates onto a raw monotonic "
                            "clock value; schedule as offsets from t_start "
                            "(t_start + i * period) so float error cannot "
                            "compound into schedule drift",
                        )
                    )
    return findings


register_rule(
    Rule(
        name="timing-warmup",
        family="timing",
        description=(
            "perf_counter timing windows must be preceded by a warmup that "
            "blocks on device results (jax.block_until_ready / _block)"
        ),
        check=_check_warmup,
    )
)

register_rule(
    Rule(
        name="timing-monotonic-accum",
        family="timing",
        description=(
            "never accumulate periods onto a raw monotonic clock value; "
            "derive deadlines as offsets from a fixed t_start"
        ),
        check=_check_monotonic_accum,
    )
)
