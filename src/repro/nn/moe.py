"""Mixture-of-Experts layer with capacity-based dispatch.

Expert weights are stacked on a leading expert dimension which the sharding
rules place on the ``model`` mesh axis (expert parallelism); the dispatch /
combine einsums then lower to all-to-all style collectives under GSPMD.

Supports the two assigned MoE archs:
  * llama4-scout : 16 routed experts, top-1, + 1 shared expert (every layer)
  * qwen2-moe    : 60 routed experts, top-4, + 4 shared experts (fused as one
                   dense SwiGLU with 4x expert width) and a shared-expert gate
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.constrain import constrain
from repro.nn.layers import dense, dense_init, swiglu, swiglu_init
from repro.nn.module import KeyGen, fan_in_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff_expert: int
    n_experts: int
    top_k: int
    n_shared_experts: int = 0       # fused into one SwiGLU of n_shared * d_ff
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    shared_expert_gate: bool = False  # qwen2-moe gates the shared expert
    # tokens are grouped and capacity applied per group, which keeps the
    # dispatch/combine tensors linear in sequence length:
    #   (n_groups, G, E, C) with C = O(K * G / E)  =>  bytes ~ T * K * cf.
    # A global capacity would make them quadratic (C ~ T) and un-lowerable
    # at the assigned 1M-token training shape.
    group_size: int = 512
    # ---- §Perf knobs ------------------------------------------------------
    # pad the expert dimension to this count (0 = off) so it divides the
    # "data" mesh axis (e.g. qwen2-moe's 60 -> 64); padded experts get
    # -inf router logits and are never selected
    pad_experts_to: int = 0
    # constrain dispatch/combine so the expert dim shards over "data"
    # (expert parallelism -> all-to-all instead of all-reduce)
    expert_parallel: bool = False
    # run dispatch/combine einsums in the activation dtype instead of f32
    dispatch_bf16: bool = False

    @property
    def n_experts_padded(self) -> int:
        return max(self.pad_experts_to, self.n_experts)


def moe_init(key, cfg: MoEConfig, *, dtype=jnp.float32):
    kg = KeyGen(key)
    E, D, F = cfg.n_experts_padded, cfg.d_model, cfg.d_ff_expert

    def one_expert(k):
        return swiglu_init(k, D, F, dtype=dtype)

    p = {
        "router": dense_init(kg(), D, E, dtype=jnp.float32,
                             init=fan_in_init()),
        "experts": jax.vmap(one_expert)(kg.split(E)),
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = swiglu_init(kg(), D, F * cfg.n_shared_experts, dtype=dtype)
        if cfg.shared_expert_gate:
            p["shared_gate"] = dense_init(kg(), D, 1, dtype=dtype)
    return p


def _capacity(cfg: MoEConfig, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts)
    return max(cap, cfg.top_k)


def _group_size(cfg: MoEConfig, n_tokens: int) -> int:
    g = min(cfg.group_size, n_tokens)
    while n_tokens % g:  # group size must tile the token count
        g -= 1
    return g


def moe_apply(params, cfg: MoEConfig, x, *, deterministic: bool = True,
              rng: Optional[jax.Array] = None):
    """x: (B, S, D) -> (y, aux) where aux carries the load-balance loss.

    Dispatch is capacity-based per token *group* (Shazeer-style, applied in
    groups of ``cfg.group_size``).  All tensors stay linear in T; under
    GSPMD the group axis shards with the batch ("data") and the expert FFN
    width with "model", so the expert matmuls run expert- and tensor-
    parallel with no manual collectives.
    """
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts_padded, cfg.top_k
    G = _group_size(cfg, T)
    n_groups = T // G
    C = _capacity(cfg, G)
    xt = x.reshape(n_groups, G, D)
    if cfg.expert_parallel:
        # pin the group axis to "data": left to propagation, GSPMD splits
        # the intra-group token dim G over "model" and every dispatch
        # einsum becomes a partial-sum all-reduce of multi-GiB f32
        # tensors (§Perf A4; conditional because the same split is
        # profitable for top-1/E=16 under the default TP layout)
        xt = constrain(xt, ("data", None, None))

    logits = dense(params["router"], xt.astype(jnp.float32))  # (n,G,E)
    if not deterministic and cfg.router_jitter > 0 and rng is not None:
        logits = logits + jax.random.normal(rng, logits.shape) * cfg.router_jitter
    if E > cfg.n_experts:   # padded experts are unroutable
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask, -jnp.inf, logits)
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (n,G,K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- per-group capacity dispatch ---------------------------------------
    # the one-hot routing structure is piecewise-constant: autodiff would
    # otherwise drag multi-GiB f32 cotangents (and their model-axis
    # all-reduces) through the cumsum/one-hot chain for an identically-
    # zero gradient — the differentiable path is gate_vals only (§Perf)
    ddt = x.dtype if cfg.dispatch_bf16 else jnp.float32
    onehot = jax.nn.one_hot(expert_idx, E, dtype=ddt)          # (n,G,K,E)
    # position of each (token, k) within its expert queue, per group
    pos = jnp.cumsum(onehot.reshape(n_groups, G * K, E), axis=1) \
        .reshape(n_groups, G, K, E) - onehot
    keep = (pos < C) & (onehot > 0)
    pos = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)   # (n,G,K)
    pos_oh = jax.nn.one_hot(pos, C, dtype=ddt) \
        * keep.max(-1, keepdims=True)

    disp = jax.lax.stop_gradient(
        onehot[..., None] * pos_oh[..., None, :])            # (n,G,K,E,C)
    dispatch = disp.sum(2)                                    # (n,G,E,C)
    combine = (disp * gate_vals[..., None, None].astype(ddt)).sum(2)
    if cfg.expert_parallel:
        dispatch = constrain(dispatch, ("data", None, None, None))
        combine = constrain(combine, ("data", None, None, None))

    expert_in = jnp.einsum("ngec,ngd->necd", dispatch,
                           xt.astype(ddt)).astype(x.dtype)
    if cfg.expert_parallel:
        # expert parallelism over the "model" axis: each model shard owns
        # E/model_size (padded) experts, so the dispatch einsum computes
        # its expert slice locally — the only collective left is the
        # psum of the combine output over "model"
        expert_in = constrain(expert_in, ("data", "model", None, None))
    # vmap over experts (stacked weights), treating (n, C) as the batch
    expert_out = jax.vmap(swiglu, in_axes=(0, 1), out_axes=1)(
        params["experts"], expert_in)                         # (n,E,C,D)
    if cfg.expert_parallel:
        expert_out = constrain(expert_out, ("data", "model", None, None))
    y = jnp.einsum("ngec,necd->ngd", combine.astype(ddt),
                   expert_out.astype(ddt)).astype(x.dtype)

    if "shared" in params:
        shared = swiglu(params["shared"], xt)
        if "shared_gate" in params:
            g = jax.nn.sigmoid(dense(params["shared_gate"], xt))
            shared = shared * g
        y = y + shared

    # --- auxiliary load-balance loss (Switch-style) ------------------------
    frac_tokens = jnp.mean(onehot.sum(2), axis=(0, 1))        # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))                 # (E,)
    aux_loss = cfg.n_experts * jnp.sum(frac_tokens * frac_probs)

    return y.reshape(B, S, D), {"moe_aux_loss": aux_loss,
                                "router_entropy": -jnp.mean(
                                    jnp.sum(probs * jnp.log(probs + 1e-9), -1))}
