"""Neural-network substrate: functional layers over param pytrees."""
