"""Core layers: dense, embedding, norms, conv2d (NHWC), MLP blocks."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.constrain import constrain
from repro.nn.module import KeyGen, fan_in_init, normal_init


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, *, use_bias: bool = False,
               dtype=jnp.float32, init=None):
    init = init or fan_in_init()
    p = {"kernel": init(key, (in_dim, out_dim), dtype)}
    if use_bias:
        p["bias"] = jnp.zeros((out_dim,), dtype)
    return p


def dense(params, x):
    y = x @ params["kernel"]
    if "bias" in params:
        y = y + params["bias"]
    return y


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, dim: int, *, dtype=jnp.float32, stddev=0.02):
    return {"embedding": normal_init(stddev)(key, (vocab, dim), dtype)}


def embed(params, ids):
    return jnp.take(params["embedding"], ids, axis=0)


def unembed(params, x):
    """Tied logits projection (vocab-sharded on the model axis)."""
    return x @ params["embedding"].T


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, *, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params, x, *, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# Conv2D (NHWC, HWIO kernel) — used by MiniConv / Full-CNN RL encoders
# ---------------------------------------------------------------------------

def conv2d_init(key, kh: int, kw: int, c_in: int, c_out: int, *,
                use_bias: bool = True, dtype=jnp.float32, init=None):
    init = init or fan_in_init()
    kernel = init(key, (kh, kw, c_in, c_out), dtype)
    # fan-in for conv counts the receptive field
    kernel = kernel / jnp.sqrt(jnp.asarray(kh * kw, dtype))
    p = {"kernel": kernel}
    if use_bias:
        p["bias"] = jnp.zeros((c_out,), dtype)
    return p


def conv2d(params, x, *, stride: int = 1, padding: str = "SAME"):
    """x: (B, H, W, C_in) -> (B, H', W', C_out)."""
    y = jax.lax.conv_general_dilated(
        x,
        params["kernel"],
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if "bias" in params:
        y = y + params["bias"]
    return y


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU) and classic MLP
# ---------------------------------------------------------------------------

def swiglu_init(key, d_model: int, d_ff: int, *, dtype=jnp.float32):
    kg = KeyGen(key)
    return {
        "gate": dense_init(kg(), d_model, d_ff, dtype=dtype),
        "up": dense_init(kg(), d_model, d_ff, dtype=dtype),
        "down": dense_init(kg(), d_ff, d_model, dtype=dtype),
    }


def _hidden_dims(x):
    return ("batch",) + (None,) * (x.ndim - 2) + ("model",)


def swiglu(params, x):
    g = jax.nn.silu(dense(params["gate"], x))
    u = dense(params["up"], x)
    h = constrain(g * u, _hidden_dims(x))
    return dense(params["down"], h)


def gelu_mlp_init(key, d_model: int, d_ff: int, *, use_bias: bool = True,
                  dtype=jnp.float32):
    kg = KeyGen(key)
    return {
        "up": dense_init(kg(), d_model, d_ff, use_bias=use_bias, dtype=dtype),
        "down": dense_init(kg(), d_ff, d_model, use_bias=use_bias, dtype=dtype),
    }


def gelu_mlp(params, x):
    h = constrain(jax.nn.gelu(dense(params["up"], x)), _hidden_dims(x))
    return dense(params["down"], h)
