"""RG-LRU recurrent block (RecurrentGemma / Griffin).  [arXiv:2402.19427]

The recurrence h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t) is a
diagonal linear recurrence; training/prefill uses jax.lax.associative_scan,
decode is a single fused step.  The surrounding block follows Griffin's
recurrent block: in-proj -> causal conv1d(4) -> RG-LRU, gated by a GeLU
branch, then out-proj.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.layers import dense, dense_init
from repro.nn.module import KeyGen

_C = 8.0  # Griffin's fixed recurrence sharpness constant


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    d_rnn: int = 0              # recurrence width; 0 => d_model
    conv_width: int = 4
    n_blocks: int = 1           # block-diagonal gate projections (Griffin uses heads)

    @property
    def width(self) -> int:
        return self.d_rnn or self.d_model


def rglru_init(key, cfg: RGLRUConfig, *, dtype=jnp.float32):
    kg = KeyGen(key)
    W = cfg.width
    # Λ initialised so a^c = exp(-c·softplus(Λ)) spans (0.9, 0.999)
    u = jax.random.uniform(kg(), (W,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^{-1}(-log(u)/c)
    return {
        "in_x": dense_init(kg(), cfg.d_model, W, dtype=dtype),
        "in_gate": dense_init(kg(), cfg.d_model, W, dtype=dtype),
        "conv": {"kernel": (jax.random.normal(kg(), (cfg.conv_width, W)) * 0.1
                            ).astype(dtype),
                 "bias": jnp.zeros((W,), dtype)},
        "w_a": dense_init(kg(), W, W, use_bias=True, dtype=dtype),
        "w_i": dense_init(kg(), W, W, use_bias=True, dtype=dtype),
        "lambda": lam.astype(jnp.float32),
        "out": dense_init(kg(), W, cfg.d_model, dtype=dtype),
    }


def _gates(params, x):
    """x: (..., W) post-conv activations.  Returns (a, gated_input)."""
    r = jax.nn.sigmoid(dense(params["w_a"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(params["w_i"], x).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lambda"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i * x.astype(jnp.float32))


def _causal_conv(x, kernel, bias):
    W = kernel.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * kernel[i] for i in range(W))
    return out + bias


def rglru_scan(a, bx, h0=None):
    """Diagonal linear recurrence via associative scan along axis 1.

    a, bx: (B, S, W).  h_t = a_t h_{t-1} + bx_t.
    """
    if h0 is not None:
        bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, b_l * a_r + b_r

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def rglru_forward(params, cfg: RGLRUConfig, u, *, h0=None,
                  return_state: bool = False):
    """Griffin recurrent block, full sequence.  u: (B, S, d_model)."""
    gate = jax.nn.gelu(dense(params["in_gate"], u))
    x = dense(params["in_x"], u)
    x = _causal_conv(x, params["conv"]["kernel"], params["conv"]["bias"])
    a, bx = _gates(params, x)
    h = rglru_scan(a, bx, h0=h0)
    y = (h.astype(u.dtype)) * gate
    out = dense(params["out"], y)
    if return_state:
        return out, h[:, -1].astype(jnp.float32)
    return out


def rglru_init_state(cfg: RGLRUConfig, batch: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, cfg.width), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.width), dtype),
    }


def rglru_decode_step(params, cfg: RGLRUConfig, u, state):
    """One-token decode.  u: (B, 1, d_model)."""
    u0 = u[:, 0]
    gate = jax.nn.gelu(dense(params["in_gate"], u0))
    x = dense(params["in_x"], u0)
    conv_buf = jnp.concatenate([state["conv"], x[:, None, :]], axis=1)
    kernel, bias = params["conv"]["kernel"], params["conv"]["bias"]
    x = jnp.einsum("bwc,wc->bc", conv_buf, kernel) + bias
    a, bx = _gates(params, x)
    h = a * state["h"] + bx
    y = h.astype(u.dtype) * gate
    out = dense(params["out"], y)[:, None, :]
    return out, {"h": h.astype(state["h"].dtype), "conv": conv_buf[:, 1:]}
