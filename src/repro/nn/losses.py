"""Losses that stay sharded under GSPMD.

The naive cross-entropy (``take_along_axis`` over the vocab axis) forces
XLA to all-gather the full-vocab logits (observed: 37 GiB/device at the
train_4k shape).  ``softmax_cross_entropy`` keeps the vocab axis sharded:
reductions over a sharded axis lower to partial-reduce + all-reduce, and
the gold logit is extracted with a one-hot contraction instead of a
gather.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.constrain import constrain


def softmax_cross_entropy(logits, targets):
    """logits: (B, S, V) (any float dtype); targets: (B, S) int32.

    Returns per-token CE (B, S) in float32 without ever materialising an
    unsharded (B, S, V) tensor.
    """
    logits = constrain(logits, ("batch", None, "model"))
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    logz = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1))
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=lf.dtype)
    onehot = constrain(onehot, ("batch", None, "model"))
    gold = jnp.einsum("bsv,bsv->bs", lf, onehot)
    return logz - gold
