"""Multi-head attention with GQA, qk-norm, optional bias, sliding windows,
cross-attention, and a decode KV cache.

Shapes follow (B, S, H, D) convention internally; the public API takes
(B, S, d_model).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.constrain import constrain
from repro.nn.layers import dense, dense_init, rmsnorm, rmsnorm_init
from repro.nn.module import KeyGen
from repro.nn.rotary import apply_rope


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False          # qwen2.5 style
    qk_norm: bool = False           # qwen3 style (RMSNorm over head_dim)
    rope_theta: float = 10000.0
    use_rope: bool = True
    sliding_window: Optional[int] = None  # None => full causal
    causal: bool = True             # False for encoder self-attention
    attn_logit_softcap: Optional[float] = None
    # implementation knobs (not architecture):
    chunked_threshold: int = 2048   # S above which the online-softmax
                                    # chunked path replaces naive S^2 scores
    block_q: int = 512
    block_k: int = 512
    # perf (§Perf): decode with a sliding window gathers only the window
    # from the cache instead of masking the full S_max scores
    windowed_decode_gather: bool = False
    # perf (§Perf): skip fully-masked KV chunks in the chunked path
    # (causal upper triangle / outside the sliding-window band)
    skip_masked_blocks: bool = False
    # perf (§Perf): update the KV cache with a masked where() instead of
    # dynamic-update-slice — a DUS on a sharded sequence axis triggers
    # GSPMD "involuntary full rematerialization" (a full cache gather per
    # token); the masked form updates each shard locally
    masked_cache_update: bool = False


def attention_init(key, cfg: AttentionConfig, *, dtype=jnp.float32):
    kg = KeyGen(key)
    p = {
        "wq": dense_init(kg(), cfg.d_model, cfg.n_heads * cfg.head_dim,
                         use_bias=cfg.qkv_bias, dtype=dtype),
        "wk": dense_init(kg(), cfg.d_model, cfg.n_kv_heads * cfg.head_dim,
                         use_bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(kg(), cfg.d_model, cfg.n_kv_heads * cfg.head_dim,
                         use_bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(kg(), cfg.n_heads * cfg.head_dim, cfg.d_model,
                         use_bias=False, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(cfg.head_dim, dtype)
        p["k_norm"] = rmsnorm_init(cfg.head_dim, dtype)
    return p


def _project_qkv(params, cfg: AttentionConfig, x, positions):
    B, S, _ = x.shape
    q = dense(params["wq"], x).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = dense(params["wk"], x).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = dense(params["wv"], x).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if cfg.use_rope:
        q = apply_rope(q, positions, theta=cfg.rope_theta)
        k = apply_rope(k, positions, theta=cfg.rope_theta)
    bshd = ("batch", None, "model", None)
    return constrain(q, bshd), constrain(k, bshd), constrain(v, bshd)


def _repeat_kv(x, n_rep: int):
    if n_rep == 1:
        return x
    B, S, KV, D = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (B, S, KV, n_rep, D)).reshape(
        B, S, KV * n_rep, D)


def _scores_to_out(cfg, q, k, v, mask, *, seq_sharded: bool = False):
    """q: (B,Sq,H,D); k,v: (B,Skv,H,D); mask broadcastable to (B,H,Sq,Skv).

    ``seq_sharded`` pins the score matrix's KV axis to the "model" mesh
    axis (decode with a sequence-sharded cache): the softmax then lowers
    to a distributed reduction and the AV contraction to a small psum,
    instead of GSPMD regathering the full cache per token.
    """
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if seq_sharded:
        logits = constrain(logits, ("batch", None, None, "model"))
    if cfg.attn_logit_softcap is not None:
        c = cfg.attn_logit_softcap
        logits = c * jnp.tanh(logits / c)
    if mask is not None:
        logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    # explicit max-subtracted softmax: the reductions over the sharded KV
    # axis lower to tiny all-reduces of the (B,H,Sq) statistics
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    p = jnp.exp(logits - m)
    probs = (p / p.sum(axis=-1, keepdims=True)).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def make_attention_mask(cfg: AttentionConfig, q_len: int, kv_len: int,
                        q_offset: int = 0) -> Optional[jnp.ndarray]:
    """(1,1,q_len,kv_len) boolean mask: True = attend."""
    q_pos = q_offset + jnp.arange(q_len)[:, None]
    kv_pos = jnp.arange(kv_len)[None, :]
    mask = jnp.ones((q_len, kv_len), dtype=bool)
    if cfg.causal:
        mask &= kv_pos <= q_pos
    if cfg.sliding_window is not None:
        mask &= kv_pos > q_pos - cfg.sliding_window
    return mask[None, None]


def attention(params, cfg: AttentionConfig, x, *, positions=None,
              mask=None):
    """Full-sequence self-attention (training / prefill)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    q, k, v = _project_qkv(params, cfg, x, positions)
    k = _repeat_kv(k, cfg.n_heads // cfg.n_kv_heads)
    v = _repeat_kv(v, cfg.n_heads // cfg.n_kv_heads)
    if S > cfg.chunked_threshold and mask is None:
        out = chunked_attention(cfg, q, k, v)
    else:
        if mask is None:
            mask = make_attention_mask(cfg, S, S)
        out = _scores_to_out(cfg, q, k, v, mask)
    out = constrain(out, ("batch", None, "model", None))
    return dense(params["wo"], out.reshape(B, S, -1))


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (pure XLA "flash"): never materialises
# the (S, S) score matrix.  Used for training/prefill above
# ``chunked_threshold``; the Pallas kernel (repro.kernels.flash_attention)
# is the TPU fast path with identical semantics.
# ---------------------------------------------------------------------------

_NEG = -0.5 * float(jnp.finfo(jnp.float32).max)


def _chunk_q_block(cfg: AttentionConfig, q_blk, k, v, q_lo, kv_lo: int = 0):
    """One q-chunk against the given KV range with an online softmax.

    q_blk: (B, bq, H, D); k, v: (B, Skv', H, D) (a slice starting at global
    position ``kv_lo``); q_lo: first query position (may be traced).
    """
    B, bq, H, D = q_blk.shape
    Skv = k.shape[1]
    bk = min(cfg.block_k, Skv)
    n_k = Skv // bk
    scale = cfg.head_dim ** -0.5
    qf = q_blk.astype(jnp.float32) * scale
    q_pos = q_lo + jnp.arange(bq)

    def body(carry, ik):
        m, l, acc = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k, ik * bk, bk, 1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, ik * bk, bk, 1)
        logits = jnp.einsum("bqhd,bkhd->bhqk", qf,
                            k_blk.astype(jnp.float32))
        if cfg.attn_logit_softcap is not None:
            c = cfg.attn_logit_softcap
            logits = c * jnp.tanh(logits / c)
        kv_pos = kv_lo + ik * bk + jnp.arange(bk)
        msk = jnp.ones((bq, bk), bool)
        if cfg.causal:
            msk &= kv_pos[None, :] <= q_pos[:, None]
        if cfg.sliding_window is not None:
            msk &= kv_pos[None, :] > q_pos[:, None] - cfg.sliding_window
        logits = jnp.where(msk[None, None], logits, _NEG)
        new_m = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - new_m[..., None]) * msk[None, None]
        alpha = jnp.exp(m - new_m)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, v_blk.astype(jnp.float32))
        return (new_m, l, acc), None

    m0 = constrain(jnp.full((B, H, bq), _NEG, jnp.float32),
                   ("batch", "model", None))
    l0 = constrain(jnp.zeros((B, H, bq), jnp.float32),
                   ("batch", "model", None))
    a0 = constrain(jnp.zeros((B, H, bq, D), jnp.float32),
                   ("batch", "model", None, None))
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(n_k))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2)           # (B, bq, H, D)


def chunked_attention(cfg: AttentionConfig, q, k, v):
    """q, k, v: (B, S, H, D) (kv already GQA-repeated) -> (B, S, H, D).

    Baseline: lax.scan over q-chunks, every q-chunk visits every KV chunk
    (mask kills the upper triangle but the FLOPs are spent).  With
    ``cfg.skip_masked_blocks`` the q-loop is unrolled with *static* per-chunk
    KV bounds, so causal/sliding-window skipping shows up in the compiled
    FLOP count (§Perf).
    """
    B, S, H, D = q.shape
    bq = min(cfg.block_q, S)
    assert S % bq == 0, f"S={S} not tiled by block_q={bq}"
    bk = min(cfg.block_k, S)
    n_q = S // bq
    qc = q.reshape(B, n_q, bq, H, D)

    if cfg.skip_masked_blocks:
        outs = []
        for iq in range(n_q):
            q_lo = iq * bq
            lo = 0
            if cfg.sliding_window is not None:
                lo = max(q_lo - cfg.sliding_window + 1, 0) // bk
            hi = min((q_lo + bq - 1) // bk + 1, S // bk) if cfg.causal \
                else S // bk
            blk = jax.checkpoint(_chunk_q_block, static_argnums=(0, 5))
            outs.append(blk(cfg, qc[:, iq], k[:, lo * bk:hi * bk],
                            v[:, lo * bk:hi * bk], q_lo, lo * bk))
        out = jnp.stack(outs, axis=1).reshape(B, S, H, D)
        return out.astype(q.dtype)

    blk = jax.checkpoint(lambda qb, lo: _chunk_q_block(cfg, qb, k, v, lo))

    def body(_, iq):
        qb = jax.lax.dynamic_index_in_dim(qc, iq, 1, keepdims=False)
        return None, blk(qb, iq * bq)

    _, outs = jax.lax.scan(body, None, jnp.arange(n_q))  # (n_q,B,bq,H,D)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, D)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Cross attention (Whisper decoder)
# ---------------------------------------------------------------------------

def cross_attention(params, cfg: AttentionConfig, x, kv_src=None, *,
                    k=None, v=None):
    """kv_src: (B, S_enc, d_model) encoder output (no rope, no mask), or
    precomputed k/v (decode path reuses cached cross-KV)."""
    B, Sq, _ = x.shape
    q = dense(params["wq"], x).reshape(B, Sq, cfg.n_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
    if k is None:
        k, v = cross_kv(params, cfg, kv_src)
    k = _repeat_kv(k, cfg.n_heads // cfg.n_kv_heads)
    v = _repeat_kv(v, cfg.n_heads // cfg.n_kv_heads)
    out = _scores_to_out(cfg, q, k, v, None)
    return dense(params["wo"], out.reshape(B, Sq, -1))


def cross_kv(params, cfg: AttentionConfig, kv_src):
    B, Skv, _ = kv_src.shape
    k = dense(params["wk"], kv_src).reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim)
    v = dense(params["wv"], kv_src).reshape(B, Skv, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        k = rmsnorm(params["k_norm"], k)
    return k, v


# ---------------------------------------------------------------------------
# Decode path with KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: AttentionConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
    }


def decode_attention(params, cfg: AttentionConfig, x, cache, index):
    """One-token decode step.

    x: (B, 1, d_model); cache: {"k","v"} of (B, S_max, KV, D); index: scalar
    int32 position of the new token.  Returns (out, new_cache).
    """
    B, S1, _ = x.shape
    assert S1 == 1, "decode_attention processes exactly one new token"
    positions = jnp.broadcast_to(index[None, None], (B, 1)) \
        if jnp.ndim(index) == 0 else index.reshape(B, 1)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions.astype(jnp.int32))
    # at decode the per-token q/k/v are tiny: replicate them over "model"
    # so they compose with however the cache is sharded (head-dim-sharded
    # new entries meeting a sequence-sharded cache otherwise trigger a
    # full cache regather per token)
    rep = ("batch", None, None, None)
    q = constrain(q, rep)
    k_new = constrain(k_new, rep)
    v_new = constrain(v_new, rep)

    idx = jnp.asarray(index, jnp.int32).reshape(())
    if cfg.masked_cache_update:
        sel = (jnp.arange(cache["k"].shape[1]) == idx)[None, :, None, None]
        k_cache = jnp.where(sel, k_new.astype(cache["k"].dtype), cache["k"])
        v_cache = jnp.where(sel, v_new.astype(cache["v"].dtype), cache["v"])
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), idx, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), idx, axis=1)
    new_cache = {"k": k_cache, "v": v_cache}

    S_max = k_cache.shape[1]
    if (cfg.windowed_decode_gather and cfg.sliding_window is not None
            and S_max > cfg.sliding_window):
        # §Perf: read only the live window from the cache instead of
        # scoring (and masking) all S_max cached positions.
        W = cfg.sliding_window
        start = jnp.clip(idx - W + 1, 0, S_max - W)
        k_cmp = jax.lax.dynamic_slice_in_dim(k_cache, start, W, 1)
        v_cmp = jax.lax.dynamic_slice_in_dim(v_cache, start, W, 1)
        kv_pos = start + jnp.arange(W)
    else:
        k_cmp, v_cmp = k_cache, v_cache
        kv_pos = jnp.arange(S_max)
    valid = kv_pos <= idx
    if cfg.sliding_window is not None:
        valid &= kv_pos > idx - cfg.sliding_window
    mask = valid[None, None, None, :]  # (1,1,1,S_kv)

    k = _repeat_kv(k_cmp.astype(q.dtype), cfg.n_heads // cfg.n_kv_heads)
    v = _repeat_kv(v_cmp.astype(q.dtype), cfg.n_heads // cfg.n_kv_heads)
    out = _scores_to_out(cfg, q, k, v, mask,
                         seq_sharded=cfg.masked_cache_update)
    return dense(params["wo"], out.reshape(B, 1, -1)), new_cache
