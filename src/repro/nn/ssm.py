"""Mamba-2 (SSD — state-space duality) layer.  [arXiv:2405.21060]

Training/prefill uses the chunked SSD algorithm (within-chunk quadratic +
inter-chunk linear recurrence via lax.scan over chunk states); decode is the
O(1) recurrent update.  Pure JAX — the per-chunk matmuls are MXU-shaped by
construction (chunk length 256, head dim 64, state 128).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.layers import dense, dense_init, rmsnorm, rmsnorm_init
from repro.nn.module import KeyGen


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    head_dim: int = 64          # P
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def ssm_init(key, cfg: SSMConfig, *, dtype=jnp.float32):
    kg = KeyGen(key)
    d_in = cfg.d_inner
    G, N, H = cfg.n_groups, cfg.d_state, cfg.n_heads
    proj_out = 2 * d_in + 2 * G * N + H  # [z, x, B, C, dt]
    conv_dim = d_in + 2 * G * N
    return {
        "in_proj": dense_init(kg(), cfg.d_model, proj_out, dtype=dtype),
        "conv": {"kernel": (jax.random.normal(kg(), (cfg.conv_width, conv_dim))
                            * 0.1).astype(dtype),
                 "bias": jnp.zeros((conv_dim,), dtype)},
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": rmsnorm_init(d_in, dtype),
        "out_proj": dense_init(kg(), d_in, cfg.d_model, dtype=dtype),
    }


def _split_proj(cfg: SSMConfig, zxbcdt):
    d_in, G, N, H = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:d_in + d_in + 2 * G * N]
    dt = zxbcdt[..., -H:]
    return z, xBC, dt


def _causal_conv(xBC, kernel, bias):
    """Depthwise causal conv along sequence.  xBC: (B,S,Cc); kernel: (W,Cc)."""
    W = kernel.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * kernel[i] for i in range(W))
    return jax.nn.silu(out + bias)


def _segsum(x):
    """x: (..., L).  Returns seg[..., i, j] = sum_{k=j+1..i} x_k (lower-tri,
    -inf above the diagonal)."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(cfg: SSMConfig, x, dt, A, B, C, D, *, h0=None):
    """Chunked SSD scan.

    x: (b, S, H, P); dt: (b, S, H) (post softplus); A: (H,) negative;
    B, C: (b, S, G, N); D: (H,).  Returns (y, h_final) with
    h_final: (b, H, P, N).
    """
    b, S, H, P = x.shape
    G, N = B.shape[-2], B.shape[-1]
    Q = min(cfg.chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
    c = S // Q
    rep = H // G

    xc = x.reshape(b, c, Q, H, P)
    dtc = dt.reshape(b, c, Q, H)
    Bc = B.reshape(b, c, Q, G, N)
    Cc = C.reshape(b, c, Q, G, N)
    Bh = jnp.repeat(Bc, rep, axis=3)  # (b,c,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A[None, None, None, :]                    # (b,c,Q,H)
    dA_cs = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum

    # 1. within-chunk (quadratic) term
    Lmat = jnp.exp(_segsum(jnp.moveaxis(dA, 3, 2)))      # (b,c,H,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)
    y_diag = jnp.einsum("bchqk,bchqk,bckh,bckhp->bcqhp",
                        scores, Lmat, dtc, xc)

    # 2. per-chunk input states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (b,c,Q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqh,bcqhp->bchpn",
                        Bh, decay_states, dtc, xc)       # (b,c,H,P,N)

    # 3. inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])            # (b,c,H)
    if h0 is None:
        h0 = jnp.zeros((b, H, P, N), states.dtype)

    def step(h, inp):
        dec, s = inp                                      # dec: (b,H), s: (b,H,P,N)
        h = h * dec[:, :, None, None] + s
        return h, h

    decs = jnp.moveaxis(chunk_decay, 1, 0)               # (c,b,H)
    ss = jnp.moveaxis(states, 1, 0)                      # (c,b,H,P,N)
    h_final, h_all = jax.lax.scan(step, h0, (decs, ss))
    # states *entering* each chunk
    h_in = jnp.concatenate([h0[None], h_all[:-1]], axis=0)
    h_in = jnp.moveaxis(h_in, 0, 1)                      # (b,c,H,P,N)

    # 4. chunk-output from incoming states
    out_decay = jnp.exp(dA_cs)                           # (b,c,Q,H)
    y_off = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp", Ch, out_decay, h_in)

    y = (y_diag + y_off).reshape(b, S, H, P)
    y = y + x * D[None, None, :, None]
    return y, h_final


def ssm_forward(params, cfg: SSMConfig, u, *, h0=None, conv0=None,
                return_state: bool = False):
    """Full-sequence forward.  u: (B, S, d_model)."""
    B_, S, _ = u.shape
    G, N, H, P = cfg.n_groups, cfg.d_state, cfg.n_heads, cfg.head_dim
    zxbcdt = dense(params["in_proj"], u)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(xBC, params["conv"]["kernel"], params["conv"]["bias"])
    x = xBC[..., :cfg.d_inner].reshape(B_, S, H, P)
    Bm = xBC[..., cfg.d_inner:cfg.d_inner + G * N].reshape(B_, S, G, N)
    Cm = xBC[..., cfg.d_inner + G * N:].reshape(B_, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, h = ssd_chunked(cfg, x.astype(jnp.float32), dt, A,
                       Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                       params["D"], h0=h0)
    y = y.reshape(B_, S, cfg.d_inner).astype(u.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = dense(params["out_proj"], y)
    if return_state:
        return out, h
    return out


def ssm_init_state(cfg: SSMConfig, batch: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1,
                           cfg.d_inner + 2 * cfg.n_groups * cfg.d_state), dtype),
    }


def ssm_decode_step(params, cfg: SSMConfig, u, state):
    """One-token decode.  u: (B, 1, d_model).  Returns (out, new_state)."""
    B_, _, _ = u.shape
    G, N, H, P = cfg.n_groups, cfg.d_state, cfg.n_heads, cfg.head_dim
    zxbcdt = dense(params["in_proj"], u[:, 0])
    z, xBC, dt = _split_proj(cfg, zxbcdt)

    # rolling conv state
    conv_buf = jnp.concatenate([state["conv"], xBC[:, None, :]], axis=1)
    kernel, bias = params["conv"]["kernel"], params["conv"]["bias"]
    xBC = jax.nn.silu(jnp.einsum("bwc,wc->bc", conv_buf, kernel) + bias)
    new_conv = conv_buf[:, 1:]

    x = xBC[..., :cfg.d_inner].reshape(B_, H, P)
    Bm = xBC[..., cfg.d_inner:cfg.d_inner + G * N].reshape(B_, G, N)
    Cm = xBC[..., cfg.d_inner + G * N:].reshape(B_, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)   # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A[None, :])                                     # (B,H)

    h = state["h"] * dA[:, :, None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, x.astype(jnp.float32), Bh.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch.astype(jnp.float32))
    y = y + x.astype(jnp.float32) * params["D"][None, :, None]
    y = y.reshape(B_, cfg.d_inner).astype(u.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = dense(params["out_proj"], y)[:, None, :]
    return out, {"h": h.astype(state["h"].dtype), "conv": new_conv}
