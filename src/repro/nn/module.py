"""Minimal functional module substrate.

Parameters are plain nested dicts of jnp arrays (pytrees).  Every layer in
``repro.nn`` exposes ``init(key, ...) -> params`` and a pure ``apply`` (usually
just a function taking ``(params, x, ...)``).  Sharding is attached *outside*
the model code via path-based rules (see :mod:`repro.models.sharding`), which
keeps the model definitions mesh-agnostic.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree of arrays
Initializer = Callable[[jax.Array, tuple, Any], jax.Array]


class KeyGen:
    """Splittable PRNG key stream: ``kg = KeyGen(key); k1 = kg(); k2 = kg()``."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def split(self, n: int) -> jax.Array:
        self._key, *subs = jax.random.split(self._key, n + 1)
        return jnp.stack(subs)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def normal_init(stddev: float = 0.02) -> Initializer:
    def init(key, shape, dtype=jnp.float32):
        return (jax.random.normal(key, shape) * stddev).astype(dtype)

    return init


def fan_in_init(scale: float = 1.0, fan_axis: int = 0) -> Initializer:
    """LeCun-style fan-in scaled normal (default for projection matrices)."""

    def init(key, shape, dtype=jnp.float32):
        fan_in = shape[fan_axis] if shape else 1
        std = scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, shape) * std).astype(dtype)

    return init


def orthogonal_init(scale: float = 1.0) -> Initializer:
    def init(key, shape, dtype=jnp.float32):
        return jax.nn.initializers.orthogonal(scale)(key, shape, dtype)

    return init


def zeros_init() -> Initializer:
    def init(key, shape, dtype=jnp.float32):
        return jnp.zeros(shape, dtype)

    return init


def ones_init() -> Initializer:
    def init(key, shape, dtype=jnp.float32):
        return jnp.ones(shape, dtype)

    return init


# ---------------------------------------------------------------------------
# Pytree helpers
# ---------------------------------------------------------------------------

def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def param_bytes(params: Params) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(params))


def tree_paths(params: Params) -> Iterator[tuple[str, Any]]:
    """Yield ('a/b/c', leaf) pairs for a nested-dict pytree."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        keys = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                keys.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        yield "/".join(keys), leaf


def cast_tree(params: Params, dtype) -> Params:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params,
    )


def stack_init(init_fn: Callable[[jax.Array], Params], keys: jax.Array) -> Params:
    """vmap an init function over a stacked leading (layer) dimension."""
    return jax.vmap(init_fn)(keys)


@dataclasses.dataclass
class ShapeOnly:
    """Marker used by dry-run init: produce ShapeDtypeStructs, not arrays."""

    dtype: Any = jnp.float32


def abstract_init(init_fn: Callable[..., Params], *args, **kwargs) -> Params:
    """Run an init function under eval_shape (no FLOPs, no allocation)."""
    return jax.eval_shape(init_fn, *args, **kwargs)
