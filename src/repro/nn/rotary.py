"""Rotary position embeddings (RoPE), supporting arbitrary position offsets."""
from __future__ import annotations

import jax.numpy as jnp


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, *,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (D/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, dim: int) -> jnp.ndarray:
    """Classic transformer sinusoidal table (used by the Whisper encoder)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    half = dim // 2
    inv = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1)))
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
