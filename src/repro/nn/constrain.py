"""Activation sharding-constraint context (mesh-agnostic model code).

Launch code enters ``activation_sharding(mesh, global_batch)``; layer code
calls ``constrain(x, dims)`` with semantic dim names:

  "batch" -> the data axes, iff that dim equals the global batch and the
             axes divide it
  "model" -> the "model" axis, iff it divides the dim
  None    -> unconstrained

Outside the context every call is a no-op, so tests and single-device
runs never touch sharding machinery.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACT_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_act_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, global_batch: int):
    token = _ACT_CTX.set((mesh, global_batch))
    try:
        yield
    finally:
        _ACT_CTX.reset(token)


def _data_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def constrain(x, dims: Sequence[Optional[str]]):
    ctx = _ACT_CTX.get()
    if ctx is None or not hasattr(x, "ndim"):
        return x
    mesh, batch = ctx
    if x.ndim != len(dims):
        return x
    parts: list = []
    used: set[str] = set()
    for name, size in zip(dims, x.shape):
        part = None
        if name == "batch":
            axes = _data_axes(mesh)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            if size == batch and size % n == 0 and not (set(axes) & used):
                part = axes if len(axes) > 1 else axes[0]
                used.update(axes)
        elif name == "model":
            if size % mesh.shape["model"] == 0 and size > 0 \
                    and "model" not in used:
                part = "model"
                used.add("model")
        elif name == "data":
            # shard this dim over the data axis regardless of batch size
            # (expert-parallel MoE uses this on the expert dim)
            if size % mesh.shape["data"] == 0 and size > 0 \
                    and "data" not in used:
                part = "data"
                used.add("data")
        parts.append(part)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*parts)))


def constrain_act(x):
    """Batch-major hidden state: dim0 = batch, rest unconstrained."""
    ctx = _ACT_CTX.get()
    if ctx is None or not hasattr(x, "ndim") or x.ndim == 0:
        return x
    return constrain(x, ("batch",) + (None,) * (x.ndim - 1))
