"""Execution-mode and host stamps for perf artifacts.

Every number this repo records depends on HOW the kernels executed
(Pallas interpret vs compiled) and WHERE (host platform, accelerator,
core count).  Comparing a compiled-TPU artifact against an interpret-CPU
one is meaningless, and before this module nothing in the BENCH files
said which was which — the ROADMAP's standing "all numbers are
interpret-mode" ambiguity.

:func:`stamp` annotates a result dict with ``mode``, ``host`` and
(optionally) ``backend``; :func:`check_comparable` is the gate the CI
compare steps call before diffing two artifacts — it refuses to compare
across mismatched execution modes and warns on host mismatches via the
returned reason list.
"""
from __future__ import annotations

import os
import platform
from typing import Optional


def execution_mode(interpret: Optional[bool] = None) -> str:
    """``"interpret"`` or ``"compiled"`` — resolved exactly like the
    kernel layer resolves ``interpret=None`` (compiled on TPU or with
    ``REPRO_PALLAS_COMPILE=1``, interpret everywhere else)."""
    if interpret is None:
        import jax
        interpret = (not os.environ.get("REPRO_PALLAS_COMPILE")
                     and jax.default_backend() != "tpu")
    return "interpret" if interpret else "compiled"


def host_fingerprint() -> str:
    """``platform/machine/device-kind/cpu-count``, e.g.
    ``linux/x86_64/cpu/2``.  Coarse on purpose: enough to flag
    cross-host comparisons without leaking hostnames into artifacts."""
    try:
        import jax
        device = jax.devices()[0].device_kind.replace("/", "-")
    except (ImportError, IndexError, RuntimeError):
        # no jax, no devices, or backend init failed: stamp coarse-unknown
        device = "unknown"
    return "/".join([platform.system().lower(), platform.machine(),
                     device, str(os.cpu_count() or 0)])


def stamp(entry: dict, *, backend: Optional[str] = None,
          interpret: Optional[bool] = None,
          transport: Optional[str] = None) -> dict:
    """Return a copy of ``entry`` stamped with mode/host (+ backend,
    + transport).  ``transport`` distinguishes HOW a serving number was
    produced: ``"sim"`` (event-time queue simulation) vs ``"socket"``
    (wall-clock measured real fleet) — a sim-vs-real delta is a
    calibration result, never a regression signal, so transport
    mismatches are hard failures for :func:`check_comparable`."""
    out = dict(entry)
    out["mode"] = execution_mode(interpret)
    out["host"] = host_fingerprint()
    if backend is not None:
        out["backend"] = backend
    if transport is not None:
        out["transport"] = transport
    return out


def mismatches(a: dict, b: dict) -> list[str]:
    """Comparability defects between two stamped entries.

    ``mode`` mismatches (or a missing ``mode`` on either side) and
    ``transport`` mismatches (sim-vs-real: differing values, or stamped
    on only one side) are hard failures for :func:`check_comparable`;
    ``host``/``backend`` mismatches are reported so callers can surface
    them, but two runs on different hosts are still a meaningful
    (cross-host) comparison.
    """
    out = []
    ma, mb = a.get("mode"), b.get("mode")
    if ma is None or mb is None:
        out.append(f"mode missing (got {ma!r} vs {mb!r}; artifact predates "
                   "stamping — re-run the benchmark)")
    elif ma != mb:
        out.append(f"mode {ma!r} != {mb!r}")
    ta, tb = a.get("transport"), b.get("transport")
    if (ta is None) != (tb is None):
        out.append(f"transport stamped on one side only ({ta!r} vs {tb!r}; "
                   "sim-vs-real comparisons are calibration, not diffs)")
    elif ta is not None and ta != tb:
        out.append(f"transport {ta!r} != {tb!r}")
    for key in ("host", "backend"):
        va, vb = a.get(key), b.get(key)
        if va is not None and vb is not None and va != vb:
            out.append(f"{key} {va!r} != {vb!r}")
    return out


def check_comparable(a: dict, b: dict, *, what: str = "artifacts") -> None:
    """Raise ValueError when two stamped entries must not be compared
    (different or missing execution modes, or sim-vs-real transports —
    those deltas are noise or calibration, not regression signal)."""
    hard = [m for m in mismatches(a, b)
            if m.startswith(("mode", "transport"))]
    if hard:
        raise ValueError(
            f"refusing to compare {what} across execution modes: "
            + "; ".join(hard))


__all__ = ["execution_mode", "host_fingerprint", "stamp", "mismatches",
           "check_comparable"]
