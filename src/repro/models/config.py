"""Architecture and input-shape configuration.

Every assigned architecture is an :class:`ArchConfig`; the four assigned
input shapes are :class:`ShapeConfig` entries in ``SHAPES``.  A config is
pure data — models are built from it by ``repro.models.registry``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architectures
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEArch:
    n_experts: int
    top_k: int
    n_shared_experts: int = 0
    shared_expert_gate: bool = False
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMArch:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    source: str                  # citation (paper/model card)

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # block composition: ``pattern`` repeats ``n_pattern`` times, then
    # ``remainder``.  Block ids: attn | swa (sliding-window attn) | rec
    # (RG-LRU) | ssm (Mamba-2).  attn/swa blocks carry the MLP (or MoE).
    pattern: tuple = ("attn",)
    n_pattern: int = 0
    remainder: tuple = ()

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None    # window for "swa" blocks
    # long-context decode variant: dense archs run long_500k with this
    # window applied to ALL attn blocks (DESIGN.md §5)
    long_context_window: int = 4096

    # mlp
    mlp: str = "swiglu"          # swiglu | gelu | relu2 | geglu
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    tie_embeddings: bool = True
    logit_softcap: Optional[float] = None

    moe: Optional[MoEArch] = None
    ssm: Optional[SSMArch] = None
    rnn_width: int = 0           # RG-LRU width (hybrid)

    # modality frontend stubs
    n_frontend_tokens: int = 0   # vlm: patch tokens; audio: encoder frames
    n_encoder_layers: int = 0    # audio enc-dec: encoder depth

    dtype: str = "bfloat16"

    # ------- performance knobs (not architecture; §Perf iterates these) ---
    attn_block_q: int = 512
    attn_block_k: int = 512
    attn_skip_masked_blocks: bool = False   # static causal/window skipping
    windowed_decode_gather: bool = False    # gather-window decode for swa
    remat: bool = True                      # checkpoint each super-block
    moe_group_size: int = 512               # capacity group (tokens)
    moe_pad_experts: bool = False           # pad E to divide the data axis
    moe_expert_parallel: bool = False       # E over "data" (all-to-all)
    moe_dispatch_bf16: bool = False         # dispatch einsums in bf16
    # where() cache write + sequence-sharded decode scores.  Default ON:
    # with a sequence-sharded KV cache the DUS write and the gathered
    # softmax each trigger a full per-token cache regather (§Perf C2-C5:
    # 3.77 GB -> 9.7 MB all-gather per token on qwen3 decode_32k)
    masked_cache_update: bool = True

    # ---------------- derived -------------------------------------------
    def blocks(self) -> list[str]:
        seq = list(self.pattern) * self.n_pattern + list(self.remainder)
        assert len(seq) == self.n_layers, (self.arch_id, len(seq),
                                           self.n_layers)
        return seq

    @property
    def attention_free(self) -> bool:
        return all(b == "ssm" for b in self.blocks())

    @property
    def subquadratic(self) -> bool:
        """True if no block needs O(S) KV state growth at decode beyond a
        bounded window (SSM/rec states are O(1); swa windows are bounded)."""
        return all(b in ("ssm", "rec", "swa") for b in self.blocks())

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        qk = self.n_heads * self.head_dim
        kv = self.n_kv_heads * self.head_dim
        attn = D * qk + 2 * D * kv + qk * D
        mlp_mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        mlp = mlp_mult * D * F
        total = V * D  # embedding (tied)
        if not self.tie_embeddings:
            total += V * D
        for b in self.blocks():
            if b in ("attn", "swa"):
                total += attn
                if self.moe is not None:
                    e = self.moe
                    total += e.n_experts * mlp_mult * D * F + D * e.n_experts
                    if e.n_shared_experts:
                        total += mlp_mult * D * F * e.n_shared_experts
                else:
                    total += mlp
            elif b == "rec":
                W = self.rnn_width or D
                total += 2 * D * W + 2 * W * W + W * D + mlp
            elif b == "ssm":
                s = self.ssm or SSMArch()
                d_in = s.expand * D
                total += D * (2 * d_in + 2 * s.n_groups * s.d_state
                              + d_in // s.head_dim) + d_in * D
        if self.n_encoder_layers:  # whisper encoder (attn + mlp, layernorm)
            total += self.n_encoder_layers * (attn + mlp)
        return total

    def active_param_count(self) -> int:
        """Params active per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        D, F = self.d_model, self.d_ff
        mlp_mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        inactive = (e.n_experts - e.top_k) * mlp_mult * D * F
        n_moe_layers = sum(1 for b in self.blocks() if b in ("attn", "swa"))
        return self.param_count() - n_moe_layers * inactive

    def reduced(self) -> "ArchConfig":
        """2-layer, d_model<=512, <=4-expert variant for CPU smoke tests."""
        d = min(self.d_model, 256)
        hd = 32
        heads = max(min(self.n_heads, 4), 1)
        kv = max(min(self.n_kv_heads, heads), 1)
        pat = tuple(self.pattern)
        if len(pat) <= 2:
            reps, rem = 2 // len(pat), tuple(pat[: 2 % len(pat)])
        else:  # keep one block of each distinct kind (e.g. rec + swa)
            kinds = list(dict.fromkeys(pat))
            reps, rem = 0, tuple(kinds[:2])
        n_layers = reps * len(pat) + len(rem)
        moe = None
        if self.moe:
            moe = dataclasses.replace(self.moe, n_experts=4,
                                      top_k=min(self.moe.top_k, 2),
                                      n_shared_experts=min(
                                          self.moe.n_shared_experts, 1))
        ssm = dataclasses.replace(self.ssm, d_state=32, head_dim=16,
                                  chunk=8) if self.ssm else None
        return dataclasses.replace(
            self, n_layers=n_layers, d_model=d, n_heads=heads,
            n_kv_heads=kv, head_dim=hd, d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 1024), pattern=pat, n_pattern=reps,
            remainder=rem, moe=moe, ssm=ssm,
            rnn_width=min(self.rnn_width, d) if self.rnn_width else 0,
            sliding_window=min(self.sliding_window, 8)
            if self.sliding_window else None,
            n_frontend_tokens=min(self.n_frontend_tokens, 16),
            n_encoder_layers=min(self.n_encoder_layers, 2),
            dtype="float32")

    @property
    def jnp_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]
