"""Decoder-only model composed from ArchConfig block patterns.

Layer weights are stacked per super-block (one repetition of
``cfg.pattern``) and scanned with lax.scan — HLO size stays constant in
depth, which keeps the 80-config dry-run matrix compilable.  The remainder
blocks (e.g. recurrentgemma's trailing 2 rec blocks) are unrolled.

Supports tokens and/or frontend embeddings (VLM patch tokens prepended),
full-sequence forward (train/prefill) and one-token decode with stacked
caches.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.blocks import (block_apply, block_decode, block_init,
                                 block_init_cache, norm_apply, norm_init)
from repro.models.config import ArchConfig
from repro.models.sharding import constrain, constrain_act
from repro.nn.losses import softmax_cross_entropy
from repro.nn.layers import dense_init, embedding_init, embed, unembed, dense
from repro.nn.module import KeyGen


def _seg_key(i: int, kind: str) -> str:
    return f"b{i}_{kind}"


class DecoderModel:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.pattern = tuple(cfg.pattern)
        self.n_pattern = cfg.n_pattern
        self.remainder = tuple(cfg.remainder)

    # ------------------------------------------------------------------ init
    def init(self, key) -> Any:
        cfg = self.cfg
        dtype = cfg.jnp_dtype
        kg = KeyGen(key)

        def seg_init(k):
            kg2 = KeyGen(k)
            return {_seg_key(i, kind): block_init(kg2(), cfg, kind, dtype)
                    for i, kind in enumerate(self.pattern)}

        params = {"embed": embedding_init(kg(), cfg.vocab, cfg.d_model,
                                          dtype=dtype)}
        if self.n_pattern > 0:
            params["scan"] = jax.vmap(seg_init)(kg.split(self.n_pattern))
        for i, kind in enumerate(self.remainder):
            params[f"rem{i}_{kind}"] = block_init(kg(), cfg, kind, dtype)
        params["final_norm"] = norm_init(cfg, dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(kg(), cfg.d_model, cfg.vocab,
                                           dtype=dtype)
        return params

    # --------------------------------------------------------------- forward
    def _embed_inputs(self, params, tokens, frontend_embeds):
        parts = []
        if frontend_embeds is not None:
            parts.append(frontend_embeds.astype(self.cfg.jnp_dtype))
        if tokens is not None:
            parts.append(embed(params["embed"], tokens))
        return jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]

    def forward(self, params, tokens=None, *, frontend_embeds=None,
                long_ctx: bool = False, remat: bool = False):
        """Full-sequence forward.  Returns (logits, aux)."""
        cfg = self.cfg
        x = self._embed_inputs(params, tokens, frontend_embeds)

        x = constrain_act(x)

        def super_apply(x, seg_params):
            aux = jnp.zeros((), jnp.float32)
            for i, kind in enumerate(self.pattern):
                x, a = block_apply(seg_params[_seg_key(i, kind)], cfg, kind,
                                   x, long_ctx=long_ctx)
                x = constrain_act(x)
                if "moe_aux_loss" in a:
                    aux = aux + a["moe_aux_loss"]
            return x, aux

        body = jax.checkpoint(super_apply) if remat else super_apply
        if self.n_pattern > 0:
            x, auxs = jax.lax.scan(lambda c, p: body(c, p),
                                   x, params["scan"])
            aux_total = auxs.sum()
        else:
            aux_total = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(self.remainder):
            x, a = block_apply(params[f"rem{i}_{kind}"], cfg, kind, x,
                               long_ctx=long_ctx)
            if "moe_aux_loss" in a:
                aux_total = aux_total + a["moe_aux_loss"]

        x = norm_apply(cfg, params["final_norm"], x)
        if cfg.tie_embeddings:
            logits = unembed(params["embed"], x)
        else:
            logits = dense(params["lm_head"], x)
        if cfg.logit_softcap:
            c = cfg.logit_softcap
            logits = c * jnp.tanh(logits / c)
        logits = constrain(logits, ("batch", None, "model"))
        return logits, {"moe_aux_loss": aux_total}

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch, *, remat: bool = True):
        """Next-token cross-entropy.  batch: tokens (B,S) int32, optional
        frontend_embeds (B,T,D); loss over token positions only."""
        tokens = batch["tokens"]
        fe = batch.get("frontend_embeds")
        logits, aux = self.forward(params, tokens, frontend_embeds=fe,
                                   remat=remat)
        n_front = fe.shape[1] if fe is not None else 0
        # predict tokens[t+1] from sequence position n_front + t
        logits = logits[:, n_front:-1]
        targets = tokens[:, 1:]
        ce = softmax_cross_entropy(logits, targets).mean()
        total = ce + 0.01 * aux["moe_aux_loss"]
        return total, {"ce": ce, **aux}

    # ------------------------------------------------------------ split (§2)
    # The paper's technique: partition the network at a block boundary,
    # run the cheap half on the weak side of the link, transmit the
    # boundary activation (quantised by repro.core.wire).  For the
    # assigned LLMs the boundary is a super-block index; the stacked scan
    # params slice cleanly.

    def split_params(self, params, n_edge_segments: int):
        """-> (edge_params, server_params) at a super-block boundary."""
        k = n_edge_segments
        edge = {"embed": params["embed"],
                "scan": jax.tree.map(lambda x: x[:k], params["scan"])}
        server = {kk: v for kk, v in params.items()
                  if kk not in ("embed", "scan")}
        server["scan"] = jax.tree.map(lambda x: x[k:], params["scan"])
        if self.cfg.tie_embeddings:
            server["embed"] = params["embed"]
        return edge, server

    def edge_forward(self, params, tokens=None, *, frontend_embeds=None,
                     long_ctx: bool = False):
        """Embed + the first n_edge super-blocks -> boundary hidden."""
        cfg = self.cfg
        x = self._embed_inputs(params, tokens, frontend_embeds)

        def super_apply(x, seg_params):
            for i, kind in enumerate(self.pattern):
                x, _ = block_apply(seg_params[_seg_key(i, kind)], cfg, kind,
                                   x, long_ctx=long_ctx)
            return x, None

        x, _ = jax.lax.scan(super_apply, x, params["scan"])
        return x

    def server_forward(self, params, hidden, *, long_ctx: bool = False):
        """Remaining super-blocks + remainder + head <- boundary hidden."""
        cfg = self.cfg
        x = hidden.astype(cfg.jnp_dtype)

        def super_apply(x, seg_params):
            for i, kind in enumerate(self.pattern):
                x, _ = block_apply(seg_params[_seg_key(i, kind)], cfg, kind,
                                   x, long_ctx=long_ctx)
            return x, None

        if params["scan"] is not None:
            x, _ = jax.lax.scan(super_apply, x, params["scan"])
        for i, kind in enumerate(self.remainder):
            x, _ = block_apply(params[f"rem{i}_{kind}"], cfg, kind, x,
                               long_ctx=long_ctx)
        x = norm_apply(cfg, params["final_norm"], x)
        if cfg.tie_embeddings:
            return unembed(params["embed"], x)
        return dense(params["lm_head"], x)

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg

        def seg_cache():
            return {_seg_key(i, kind): block_init_cache(cfg, kind, batch,
                                                        max_len, dtype)
                    for i, kind in enumerate(self.pattern)}

        caches = {}
        if self.n_pattern > 0:
            proto = seg_cache()
            caches["scan"] = jax.tree.map(
                lambda x: jnp.zeros((self.n_pattern,) + x.shape, x.dtype),
                proto)
        for i, kind in enumerate(self.remainder):
            caches[f"rem{i}_{kind}"] = block_init_cache(cfg, kind, batch,
                                                        max_len, dtype)
        return caches

    # ----------------------------------------------------------------- decode
    def decode_step(self, params, token, caches, index, *,
                    long_ctx: bool = False):
        """token: (B, 1) int32; index: scalar int32 position.
        Returns (logits (B, 1, V), new_caches)."""
        cfg = self.cfg
        x = embed(params["embed"], token)

        x = constrain_act(x)

        def body(x, xs):
            seg_params, seg_cache = xs
            new_cache = {}
            for i, kind in enumerate(self.pattern):
                k = _seg_key(i, kind)
                x, c = block_decode(seg_params[k], cfg, kind, x,
                                    seg_cache[k], index, long_ctx=long_ctx)
                x = constrain_act(x)
                new_cache[k] = c
            return x, new_cache

        new_caches = {}
        if self.n_pattern > 0:
            x, new_caches["scan"] = jax.lax.scan(
                body, x, (params["scan"], caches["scan"]))
        for i, kind in enumerate(self.remainder):
            k = f"rem{i}_{kind}"
            x, c = block_decode(params[k], cfg, kind, x, caches[k], index,
                                long_ctx=long_ctx)
            new_caches[k] = c

        x = norm_apply(cfg, params["final_norm"], x)
        if cfg.tie_embeddings:
            logits = unembed(params["embed"], x)
        else:
            logits = dense(params["lm_head"], x)
        if cfg.logit_softcap:
            c = cfg.logit_softcap
            logits = c * jnp.tanh(logits / c)
        return logits, new_caches
