"""--arch <id> -> model instance; --shape <id> -> abstract input specs.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input of
the given (architecture, input-shape) pair: weak-type-correct, shardable,
and allocation-free, so the production mesh can be dry-run on any host.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Union

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.models.config import ArchConfig, ShapeConfig
from repro.models.transformer import DecoderModel
from repro.models.whisper import WhisperModel

Model = Union[DecoderModel, WhisperModel]


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family == "audio":
        return WhisperModel(cfg)
    return DecoderModel(cfg)


def get_model(arch_id: str, *, reduced: bool = False) -> tuple[ArchConfig,
                                                               Model]:
    cfg = get_config(arch_id)
    if reduced:
        cfg = cfg.reduced()
    return cfg, build_model(cfg)


def abstract_params(model: Model) -> Any:
    """Parameter ShapeDtypeStructs without allocating anything."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(model.init, key)


def text_len(cfg: ArchConfig, shape: ShapeConfig) -> int:
    """Token positions left for text once frontend tokens are prepended.

    VLM patch tokens share the sequence budget; the audio encoder's frames
    live in the encoder, so whisper keeps the full decoder length.
    """
    if cfg.family == "vlm":
        return shape.seq_len - cfg.n_frontend_tokens
    return shape.seq_len


def _frontend_spec(cfg: ArchConfig, batch: int):
    return jax.ShapeDtypeStruct((batch, cfg.n_frontend_tokens, cfg.d_model),
                                jnp.bfloat16)


def input_specs(arch_id: str, shape_id: str) -> dict[str, Any]:
    """Abstract inputs for the step the shape lowers.

    train/prefill: {"batch": {tokens[, frontend_embeds]}}
    decode:        {"token", "caches", "index"}
    """
    return input_specs_for(get_config(arch_id), SHAPES[shape_id])


def input_specs_for(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    B = shape.global_batch

    if shape.kind in ("train", "prefill"):
        batch: dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((B, text_len(cfg, shape)),
                                           jnp.int32)
        }
        if cfg.family in ("vlm", "audio"):
            batch["frontend_embeds"] = _frontend_spec(cfg, B)
        return {"batch": batch}

    # decode: one new token against a seq_len-deep cache
    decode_model = build_model(cfg)
    caches = jax.eval_shape(
        lambda: decode_model.init_cache(B, shape.seq_len, jnp.bfloat16))
    return {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "caches": caches,
        "index": jax.ShapeDtypeStruct((), jnp.int32),
    }


def long_ctx(shape_id: str) -> bool:
    return shape_id == "long_500k"


ARCH_IDS = tuple(sorted(
    __import__("repro.configs", fromlist=["ARCHS"]).ARCHS))
SHAPE_IDS = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
