"""Transformer block zoo: attn / swa / rec (RG-LRU) / ssm (Mamba-2) blocks
with a uniform (init, apply, decode, cache) interface, composed by
repro.models.transformer according to ArchConfig.pattern.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, SSMArch
from repro.nn.attention import (AttentionConfig, attention, attention_init,
                                decode_attention, init_kv_cache)
from repro.nn.layers import (dense, dense_init, gelu_mlp, gelu_mlp_init,
                             layernorm, layernorm_init, rmsnorm,
                             rmsnorm_init, swiglu, swiglu_init)
from repro.nn.module import KeyGen
from repro.nn.moe import MoEConfig, moe_apply, moe_init
from repro.nn.rglru import (RGLRUConfig, rglru_decode_step, rglru_forward,
                            rglru_init, rglru_init_state)
from repro.nn.ssm import (SSMConfig, ssm_decode_step, ssm_forward, ssm_init,
                          ssm_init_state)


# ---------------------------------------------------------------------------
# config adapters
# ---------------------------------------------------------------------------

def attn_config(cfg: ArchConfig, kind: str, *,
                long_ctx: bool = False) -> AttentionConfig:
    window = None
    if kind == "swa":
        window = cfg.sliding_window
    elif long_ctx:
        # dense archs run long_500k with a sliding-window variant
        window = cfg.long_context_window
    return AttentionConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
        rope_theta=cfg.rope_theta, sliding_window=window,
        attn_logit_softcap=cfg.logit_softcap,
        block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
        skip_masked_blocks=cfg.attn_skip_masked_blocks,
        windowed_decode_gather=cfg.windowed_decode_gather,
        masked_cache_update=cfg.masked_cache_update)


def moe_config(cfg: ArchConfig) -> MoEConfig:
    e = cfg.moe
    pad = 0
    if cfg.moe_pad_experts:
        pad = -(-e.n_experts // 16) * 16   # next multiple of the data axis
    return MoEConfig(d_model=cfg.d_model, d_ff_expert=cfg.d_ff,
                     n_experts=e.n_experts, top_k=e.top_k,
                     n_shared_experts=e.n_shared_experts,
                     shared_expert_gate=e.shared_expert_gate,
                     capacity_factor=e.capacity_factor,
                     group_size=cfg.moe_group_size,
                     pad_experts_to=pad,
                     expert_parallel=cfg.moe_expert_parallel,
                     dispatch_bf16=cfg.moe_dispatch_bf16)


def ssm_config(cfg: ArchConfig) -> SSMConfig:
    s = cfg.ssm or SSMArch()
    return SSMConfig(d_model=cfg.d_model, d_state=s.d_state,
                     head_dim=s.head_dim, expand=s.expand,
                     n_groups=s.n_groups, conv_width=s.conv_width,
                     chunk=s.chunk)


def rglru_config(cfg: ArchConfig) -> RGLRUConfig:
    return RGLRUConfig(d_model=cfg.d_model, d_rnn=cfg.rnn_width)


# ---------------------------------------------------------------------------
# norms / mlps
# ---------------------------------------------------------------------------

def norm_init(cfg: ArchConfig, dtype):
    return (rmsnorm_init(cfg.d_model, dtype) if cfg.norm == "rmsnorm"
            else layernorm_init(cfg.d_model, dtype))


def norm_apply(cfg: ArchConfig, p, x):
    return rmsnorm(p, x) if cfg.norm == "rmsnorm" else layernorm(p, x)


def mlp_init(key, cfg: ArchConfig, dtype):
    if cfg.mlp in ("swiglu", "geglu"):
        return swiglu_init(key, cfg.d_model, cfg.d_ff, dtype=dtype)
    return gelu_mlp_init(key, cfg.d_model, cfg.d_ff,
                         use_bias=cfg.mlp == "gelu", dtype=dtype)


def mlp_apply(cfg: ArchConfig, p, x):
    if cfg.mlp == "swiglu":
        return swiglu(p, x)
    if cfg.mlp == "geglu":
        g = jax.nn.gelu(dense(p["gate"], x))
        return dense(p["down"], g * dense(p["up"], x))
    if cfg.mlp == "relu2":  # minitron/nemotron: squared ReLU, no gate
        h = jax.nn.relu(dense(p["up"], x))
        return dense(p["down"], h * h)
    return gelu_mlp(p, x)


# ---------------------------------------------------------------------------
# block init / apply / decode / cache
# ---------------------------------------------------------------------------

def block_init(key, cfg: ArchConfig, kind: str, dtype):
    kg = KeyGen(key)
    if kind in ("attn", "swa"):
        p = {
            "norm1": norm_init(cfg, dtype),
            "attn": attention_init(kg(), attn_config(cfg, kind), dtype=dtype),
            "norm2": norm_init(cfg, dtype),
        }
        if cfg.moe is not None:
            p["moe"] = moe_init(kg(), moe_config(cfg), dtype=dtype)
        else:
            p["mlp"] = mlp_init(kg(), cfg, dtype)
        return p
    if kind == "rec":
        return {
            "norm1": norm_init(cfg, dtype),
            "rglru": rglru_init(kg(), rglru_config(cfg), dtype=dtype),
            "norm2": norm_init(cfg, dtype),
            "mlp": mlp_init(kg(), cfg, dtype),
        }
    if kind == "ssm":
        return {
            "norm": norm_init(cfg, dtype),
            "ssm": ssm_init(kg(), ssm_config(cfg), dtype=dtype),
        }
    raise ValueError(kind)


def block_apply(params, cfg: ArchConfig, kind: str, x, *,
                long_ctx: bool = False):
    """Full-sequence forward.  Returns (x, aux)."""
    aux = {}
    if kind in ("attn", "swa"):
        acfg = attn_config(cfg, kind, long_ctx=long_ctx)
        x = x + attention(params["attn"], acfg, norm_apply(cfg, params["norm1"], x))
        h = norm_apply(cfg, params["norm2"], x)
        if cfg.moe is not None:
            y, aux = moe_apply(params["moe"], moe_config(cfg), h)
        else:
            y = mlp_apply(cfg, params["mlp"], h)
        return x + y, aux
    if kind == "rec":
        rcfg = rglru_config(cfg)
        x = x + rglru_forward(params["rglru"], rcfg,
                              norm_apply(cfg, params["norm1"], x))
        y = mlp_apply(cfg, params["mlp"],
                      norm_apply(cfg, params["norm2"], x))
        return x + y, aux
    if kind == "ssm":
        scfg = ssm_config(cfg)
        return x + ssm_forward(params["ssm"], scfg,
                               norm_apply(cfg, params["norm"], x)), aux
    raise ValueError(kind)


def block_init_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                     dtype):
    if kind in ("attn", "swa"):
        return init_kv_cache(attn_config(cfg, kind), batch, max_len, dtype)
    if kind == "rec":
        return rglru_init_state(rglru_config(cfg), batch, jnp.float32)
    if kind == "ssm":
        return ssm_init_state(ssm_config(cfg), batch, jnp.float32)
    raise ValueError(kind)


def block_decode(params, cfg: ArchConfig, kind: str, x, cache, index, *,
                 long_ctx: bool = False):
    """One-token decode.  Returns (x, new_cache)."""
    if kind in ("attn", "swa"):
        acfg = attn_config(cfg, kind, long_ctx=long_ctx)
        h, cache = decode_attention(params["attn"], acfg,
                                    norm_apply(cfg, params["norm1"], x),
                                    cache, index)
        x = x + h
        hh = norm_apply(cfg, params["norm2"], x)
        if cfg.moe is not None:
            y, _ = moe_apply(params["moe"], moe_config(cfg), hh)
        else:
            y = mlp_apply(cfg, params["mlp"], hh)
        return x + y, cache
    if kind == "rec":
        rcfg = rglru_config(cfg)
        h, cache = rglru_decode_step(params["rglru"], rcfg,
                                     norm_apply(cfg, params["norm1"], x),
                                     cache)
        x = x + h
        y = mlp_apply(cfg, params["mlp"],
                      norm_apply(cfg, params["norm2"], x))
        return x + y, cache
    if kind == "ssm":
        scfg = ssm_config(cfg)
        h, cache = ssm_decode_step(params["ssm"], scfg,
                                   norm_apply(cfg, params["norm"], x), cache)
        return x + h, cache
    raise ValueError(kind)
