"""Whisper-style encoder–decoder backbone (audio).  [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor frontend is a STUB per the
brief: ``input_specs`` provides precomputed frame embeddings of shape
(B, n_frames, d_model); this module implements the transformer backbone
that consumes them — a bidirectional encoder (sinusoidal positions) and a
causal decoder with cross-attention (learned positions).

The enc-dec split is the most faithful LLM analogue of the paper's
split-policy architecture: the encoder is the "edge" half and the decoder
the "server" half, with the encoder output as the wire tensor
(DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.sharding import constrain_act
from repro.nn.losses import softmax_cross_entropy
from repro.nn.attention import (AttentionConfig, attention, attention_init,
                                cross_attention, cross_kv, decode_attention,
                                init_kv_cache, make_attention_mask)
from repro.nn.layers import (dense, dense_init, embed, embedding_init,
                             gelu_mlp, gelu_mlp_init, layernorm,
                             layernorm_init, unembed)
from repro.nn.module import KeyGen
from repro.nn.rotary import sinusoidal_positions


def _attn_cfg(cfg: ArchConfig, *, causal: bool, long_ctx: bool = False):
    window = cfg.long_context_window if (causal and long_ctx) else None
    return AttentionConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        qkv_bias=True, use_rope=False, causal=causal,
        sliding_window=window,
        block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
        skip_masked_blocks=cfg.attn_skip_masked_blocks,
        windowed_decode_gather=cfg.windowed_decode_gather)


class WhisperModel:
    """cfg.n_layers = decoder depth; cfg.n_encoder_layers = encoder depth;
    cfg.n_frontend_tokens = encoder frames (1500 for 30 s audio)."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.max_target_positions = 448  # whisper's decoder position table

    # ------------------------------------------------------------------ init
    def _enc_block_init(self, key, dtype):
        kg = KeyGen(key)
        return {
            "norm1": layernorm_init(self.cfg.d_model, dtype),
            "attn": attention_init(kg(), _attn_cfg(self.cfg, causal=False),
                                   dtype=dtype),
            "norm2": layernorm_init(self.cfg.d_model, dtype),
            "mlp": gelu_mlp_init(kg(), self.cfg.d_model, self.cfg.d_ff,
                                 dtype=dtype),
        }

    def _dec_block_init(self, key, dtype):
        kg = KeyGen(key)
        return {
            "norm1": layernorm_init(self.cfg.d_model, dtype),
            "self_attn": attention_init(kg(), _attn_cfg(self.cfg, causal=True),
                                        dtype=dtype),
            "norm2": layernorm_init(self.cfg.d_model, dtype),
            "cross_attn": attention_init(kg(),
                                         _attn_cfg(self.cfg, causal=False),
                                         dtype=dtype),
            "norm3": layernorm_init(self.cfg.d_model, dtype),
            "mlp": gelu_mlp_init(kg(), self.cfg.d_model, self.cfg.d_ff,
                                 dtype=dtype),
        }

    def init(self, key) -> Any:
        cfg = self.cfg
        dtype = cfg.jnp_dtype
        kg = KeyGen(key)
        return {
            "embed": embedding_init(kg(), cfg.vocab, cfg.d_model,
                                    dtype=dtype),
            "dec_pos": embedding_init(kg(), self.max_target_positions,
                                      cfg.d_model, dtype=dtype),
            "enc_scan": jax.vmap(lambda k: self._enc_block_init(k, dtype))(
                kg.split(cfg.n_encoder_layers)),
            "enc_norm": layernorm_init(cfg.d_model, dtype),
            "dec_scan": jax.vmap(lambda k: self._dec_block_init(k, dtype))(
                kg.split(cfg.n_layers)),
            "dec_norm": layernorm_init(cfg.d_model, dtype),
        }

    # --------------------------------------------------------------- encoder
    def encode(self, params, frame_embeds):
        """frame_embeds: (B, T, D) stub-frontend output -> (B, T, D)."""
        cfg = self.cfg
        T = frame_embeds.shape[1]
        x = frame_embeds.astype(cfg.jnp_dtype)
        x = x + sinusoidal_positions(T, cfg.d_model).astype(x.dtype)
        acfg = _attn_cfg(cfg, causal=False)

        def body(x, p):
            h = attention(p["attn"], acfg, layernorm(p["norm1"], x))
            x = x + h
            x = x + gelu_mlp(p["mlp"], layernorm(p["norm2"], x))
            return constrain_act(x), None

        x, _ = jax.lax.scan(body, constrain_act(x), params["enc_scan"])
        return layernorm(params["enc_norm"], x)

    # --------------------------------------------------------------- decoder
    def _dec_positions(self, params, start, length, batch):
        # decoder position table is 448 long; positions wrap for the
        # long-context dry-run shapes (documented deviation)
        pos = (start + jnp.arange(length)) % self.max_target_positions
        return embed(params["dec_pos"], jnp.broadcast_to(pos, (batch, length)))

    def decode_full(self, params, tokens, enc_out, *, long_ctx=False,
                    remat=False):
        """Teacher-forced decoder pass.  Returns (logits, aux)."""
        cfg = self.cfg
        B, S = tokens.shape
        x = embed(params["embed"], tokens)
        x = x + self._dec_positions(params, 0, S, B)
        acfg = _attn_cfg(cfg, causal=True, long_ctx=long_ctx)
        xcfg = _attn_cfg(cfg, causal=False)

        def body(x, p):
            x = x + attention(p["self_attn"], acfg,
                              layernorm(p["norm1"], x))
            x = x + cross_attention(p["cross_attn"], xcfg,
                                    layernorm(p["norm2"], x), enc_out)
            x = x + gelu_mlp(p["mlp"], layernorm(p["norm3"], x))
            return constrain_act(x), None

        fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(fn, constrain_act(x), params["dec_scan"])
        x = layernorm(params["dec_norm"], x)
        return unembed(params["embed"], x), {}

    def forward(self, params, tokens=None, *, frontend_embeds=None,
                long_ctx=False, remat=False):
        enc_out = self.encode(params, frontend_embeds)
        return self.decode_full(params, tokens, enc_out, long_ctx=long_ctx,
                                remat=remat)

    def loss(self, params, batch, *, remat: bool = True):
        logits, aux = self.forward(
            params, batch["tokens"],
            frontend_embeds=batch["frontend_embeds"], remat=remat)
        ce = softmax_cross_entropy(logits[:, :-1],
                                   batch["tokens"][:, 1:]).mean()
        return ce, {"ce": ce}

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        acfg = _attn_cfg(cfg, causal=True)
        L = cfg.n_layers
        self_kv = init_kv_cache(acfg, batch, max_len, dtype)
        T = cfg.n_frontend_tokens
        cross = {
            "k": jnp.zeros((batch, T, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, T, cfg.n_kv_heads, cfg.head_dim), dtype),
        }
        stack = lambda t: jax.tree.map(
            lambda x: jnp.zeros((L,) + x.shape, x.dtype), t)
        return {"self": stack(self_kv), "cross": stack(cross)}

    def prefill_cross_cache(self, params, enc_out, caches):
        """Populate the cross-attention KV cache from encoder output."""
        xcfg = _attn_cfg(self.cfg, causal=False)

        def body(_, p):
            k, v = cross_kv(p["cross_attn"], xcfg, enc_out)
            return None, {"k": k.astype(jnp.bfloat16),
                          "v": v.astype(jnp.bfloat16)}

        _, cross = jax.lax.scan(body, None, params["dec_scan"])
        return {"self": caches["self"], "cross": cross}

    def decode_step(self, params, token, caches, index, *, long_ctx=False):
        """One decoder token against cached self/cross KV."""
        cfg = self.cfg
        B = token.shape[0]
        x = embed(params["embed"], token)
        x = x + self._dec_positions(params, index, 1, B)
        acfg = _attn_cfg(cfg, causal=True, long_ctx=long_ctx)
        xcfg = _attn_cfg(cfg, causal=False)

        def body(x, xs):
            p, self_c, cross_c = xs
            h, self_c = decode_attention(p["self_attn"], acfg,
                                         layernorm(p["norm1"], x),
                                         self_c, index)
            x = x + h
            x = x + cross_attention(p["cross_attn"], xcfg,
                                    layernorm(p["norm2"], x),
                                    k=cross_c["k"].astype(x.dtype),
                                    v=cross_c["v"].astype(x.dtype))
            x = x + gelu_mlp(p["mlp"], layernorm(p["norm3"], x))
            return constrain_act(x), self_c

        x, new_self = jax.lax.scan(
            body, x, (params["dec_scan"], caches["self"], caches["cross"]))
        x = layernorm(params["dec_norm"], x)
        logits = unembed(params["embed"], x)
        return logits, {"self": new_self, "cross": caches["cross"]}
