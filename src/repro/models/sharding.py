"""Path-based sharding rules: FSDP ("data") + Megatron TP ("model").

Model code is mesh-agnostic; these rules attach a PartitionSpec to every
parameter / optimizer-state / cache leaf by matching its pytree path and
shape.  The engine is *divisibility-greedy*: each dimension lists candidate
mesh-axis groups in preference order and gets the first group that (a)
divides the dimension and (b) is not already used by another dimension of
the same leaf.  Architectures whose dimensions don't divide the mesh
(e.g. qwen2-moe's 60 experts, mamba2's 50280 vocab) degrade gracefully to
the next candidate or replication instead of failing to lower.

Scheme (single-pod ("data", "model") and multi-pod ("pod", "data", "model")):

* batch            -> ("pod", "data")      (DP across pods and data axis)
* parameters       -> FSDP over "data" on one dim, TP over "model" on the
                      other; the "pod" axis intentionally does NOT shard
                      parameters, so FSDP all-gathers stay on intra-pod ICI
                      and only gradient all-reduce crosses the slow DCN —
                      the paper's principle (small tensors on the slow link)
                      applied to training.
* KV caches        -> batch over ("pod","data"), kv-heads (or head_dim)
                      over "model"; long_500k (batch=1) shards the sequence
                      dimension over "data" instead.
"""
from __future__ import annotations

import fnmatch
import re
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn.module import tree_paths

# jax.tree.map_with_path is absent before jax 0.5; the tree_util spelling
# exists on every supported version
_tree_map_with_path = getattr(jax.tree, "map_with_path",
                              jax.tree_util.tree_map_with_path)

Axes = tuple[str, ...]            # one axis group, e.g. ("pod", "data")
DimPrefs = Sequence[Axes]         # candidates for one dim, in pref. order
Rule = Sequence[DimPrefs]         # one entry per *logical* dim of the leaf

# ---------------------------------------------------------------------------
# Parameter rules, matched right-to-left on the leaf path.  Leaves with more
# dims than the rule (scan-stacked layers, stacked experts) get leading None.
# ---------------------------------------------------------------------------

DATA = (("data",),)
MODEL = (("model",),)
NONE: DimPrefs = ()

PARAM_RULES: list[tuple[str, Rule]] = [
    # embeddings: vocab TP for the logits matmul, d_model FSDP
    ("*embed/embedding", (MODEL, DATA)),
    ("*dec_pos/embedding", (NONE, DATA)),
    ("*lm_head/kernel", (DATA, MODEL)),
    # attention
    ("*/wq/kernel", (DATA, MODEL)),
    ("*/wk/kernel", (DATA, MODEL)),
    ("*/wv/kernel", (DATA, MODEL)),
    ("*/wo/kernel", (MODEL, DATA)),
    ("*/wq/bias", (MODEL,)),
    ("*/wk/bias", (MODEL,)),
    ("*/wv/bias", (MODEL,)),
    # moe (BEFORE the dense-mlp rules: first match wins and the generic
    # "*/gate/kernel" would shadow the expert paths):
    # experts (E, D, F) — default: expert dim FSDP over "data" when E
    # divides, expert FFN width TP over "model".  param_mode="ep_model"
    # (used with moe_expert_parallel for MoE *training*, §Perf A5) flips
    # the expert dim to "model" so each model shard owns E/16 experts and
    # the dispatch einsums compute expert slices locally; left as the
    # default it regresses MoE *decode* (per-token expert-weight motion).
    ("*/experts/gate/kernel", (DATA, DATA, MODEL)),
    ("*/experts/up/kernel", (DATA, DATA, MODEL)),
    ("*/experts/down/kernel", (DATA, MODEL, DATA)),
    ("*/router/kernel", (NONE, NONE)),
    # dense mlp (also matches the fused shared-expert SwiGLU)
    ("*/gate/kernel", (DATA, MODEL)),
    ("*/up/kernel", (DATA, MODEL)),
    ("*/down/kernel", (MODEL, DATA)),
    # ssm
    ("*/ssm/in_proj/kernel", (DATA, MODEL)),
    ("*/ssm/out_proj/kernel", (MODEL, DATA)),
    # rg-lru
    ("*/rglru/in_x/kernel", (DATA, MODEL)),
    ("*/rglru/in_gate/kernel", (DATA, MODEL)),
    ("*/rglru/w_a/kernel", (DATA, MODEL)),
    ("*/rglru/w_i/kernel", (DATA, MODEL)),
    ("*/rglru/out/kernel", (MODEL, DATA)),
]


def _choose(shape: Sequence[int], rule: Rule, mesh: Mesh) -> P:
    """Greedy divisibility-checked assignment of axis groups to dims."""
    extra = len(shape) - len(rule)
    assert extra >= 0, (shape, rule)
    used: set[str] = set()
    parts: list[Any] = [None] * extra
    for dim, prefs in zip(shape[extra:], rule):
        pick = None
        for axes in prefs:
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if dim % size == 0 and not (set(axes) & used):
                pick = axes if len(axes) > 1 else axes[0]
                used.update(axes)
                break
        parts.append(pick)
    return P(*parts)


def _strip_data(rule: Rule) -> Rule:
    """tp_only mode: drop FSDP ("data") candidates — params replicate over
    the data axes.  Right for decode, where a per-step FSDP all-gather of
    the full parameter set dwarfs the one token's compute (§Perf)."""
    return tuple(tuple(axes for axes in prefs
                       if "data" not in axes) for prefs in rule)


def param_spec(path: str, shape: Sequence[int], mesh: Mesh, *,
               mode: str = "fsdp_tp") -> P:
    for pat, rule in PARAM_RULES:
        if fnmatch.fnmatch(path, pat):
            if len(shape) < len(rule):   # e.g. unexpected rank; replicate
                return P()
            if mode == "tp_only":
                rule = _strip_data(rule)
            elif mode == "ep_model" and "/experts/" in path:
                rule = (MODEL,) + tuple(rule[1:])
            return _choose(shape, rule, mesh)
    return P()  # norms, biases, scalars: replicated


def param_shardings(param_shapes: Any, mesh: Mesh, *,
                    mode: str = "fsdp_tp") -> Any:
    """ShapeDtypeStruct (or array) pytree -> NamedSharding pytree."""
    flat = dict(tree_paths(param_shapes))
    specs = {p: param_spec(p, v.shape, mesh, mode=mode)
             for p, v in flat.items()}
    return _tree_map_with_path(
        lambda kp, v: NamedSharding(mesh, specs[_path_str(kp)]),
        param_shapes)


def _path_str(key_path) -> str:
    keys = []
    for p in key_path:
        if isinstance(p, jax.tree_util.DictKey):
            keys.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            keys.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            keys.append(str(p.name))
        else:
            keys.append(str(p))
    return "/".join(keys)


# ---------------------------------------------------------------------------
# Batch / cache / state specs
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh) -> Axes:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def cache_spec(path: str, shape: Sequence[int], mesh: Mesh,
               batch: int) -> P:
    """KV caches (…, B, S, KV, D), SSM states (…, B, H, P, N), conv
    states, RG-LRU states (…, B, W).

    batch-shardable => dim holding ``batch`` gets the data axes; for
    batch=1 (long_500k) the sequence dim of KV caches gets "data".
    """
    daxes = batch_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]

    shape = tuple(shape)
    parts: list[Any] = [None] * len(shape)
    used: set[str] = set()

    # locate the batch dim: first dim equal to `batch` (skipping stacked
    # leading layer dims which equal n_pattern/L, usually != batch)
    b_dim = None
    for i, d in enumerate(shape):
        if d == batch:
            b_dim = i
            break
    if b_dim is not None and batch % dsize == 0 and batch >= dsize:
        parts[b_dim] = daxes if len(daxes) > 1 else daxes[0]
        used.update(daxes)

    is_kv = path.endswith("/k") or path.endswith("/v") \
        or re.search(r"/(k|v)$", path) is not None
    if is_kv and len(shape) >= 4:
        s_dim, kv_dim, hd_dim = len(shape) - 3, len(shape) - 2, len(shape) - 1
        # sequence over "data" only if batch didn't take it (long_500k)
        if "data" not in used and shape[s_dim] % mesh.shape["data"] == 0:
            parts[s_dim] = "data"
            used.add("data")
        if shape[kv_dim] % mesh.shape["model"] == 0:
            parts[kv_dim] = "model"
        elif parts[s_dim] is None and \
                shape[s_dim] % mesh.shape["model"] == 0:
            # GQA kv-head count doesn't divide the model axis: shard the
            # SEQUENCE over "model" instead.  Sharding head_dim forces a
            # full f32 cache all-gather per decoded token (§Perf: observed
            # 3.6 GB/step on qwen3 decode_32k); with the sequence sharded,
            # scores are computed locally and only the tiny AV partial
            # sum crosses the mesh.
            parts[s_dim] = "model"
        elif shape[hd_dim] % mesh.shape["model"] == 0:
            parts[hd_dim] = "model"
    else:
        # recurrent states: shard the widest trailing dim over "model"
        cand = max(range(1 if b_dim is None else b_dim + 1, len(shape)),
                   key=lambda i: shape[i], default=None) \
            if len(shape) > 1 else None
        if cand is not None and shape[cand] % mesh.shape["model"] == 0 \
                and shape[cand] >= mesh.shape["model"]:
            parts[cand] = "model"
    return P(*parts)


def cache_shardings(cache_shapes: Any, mesh: Mesh, batch: int) -> Any:
    flat = dict(tree_paths(cache_shapes))
    specs = {p: cache_spec(p, v.shape, mesh, batch) for p, v in flat.items()}
    return _tree_map_with_path(
        lambda kp, v: NamedSharding(mesh, specs[_path_str(kp)]),
        cache_shapes)


def data_spec(mesh: Mesh, rank: int, batch: Optional[int] = None) -> P:
    """Plain batch-major input: (B, ...), falling back to fewer (or no)
    axes when the batch does not divide (long_500k has batch=1)."""
    candidates: list[Axes] = [batch_axes(mesh), ("data",), ("pod",)]
    for ax in candidates:
        if not all(a in mesh.shape for a in ax):
            continue
        size = 1
        for a in ax:
            size *= mesh.shape[a]
        if batch is None or (batch % size == 0 and batch >= size):
            return P(ax if len(ax) > 1 else ax[0], *([None] * (rank - 1)))
    return P(*([None] * rank))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# Activation-sharding constraint machinery lives in repro.nn.constrain
# (kept import-cycle-free for layer code); re-exported here for launch code.
from repro.nn.constrain import (activation_sharding, constrain,  # noqa: F401
                                constrain_act)
