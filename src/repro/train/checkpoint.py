"""Checkpointing: pytree <-> directory of .npz shards + a JSON manifest.

No external deps (orbax is not installed offline); handles arbitrary
nested-dict pytrees of arrays, dtype-preserving (incl. bfloat16 via a
uint16 view), with atomic rename so a crashed save never corrupts the
latest checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.module import tree_paths

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


def _to_numpy(x) -> tuple[np.ndarray, str]:
    arr = np.asarray(x)
    if arr.dtype == jnp.bfloat16:
        return arr.view(np.uint16), "bfloat16"
    return arr, str(arr.dtype)


def save(path: str, tree: Any, *, step: Optional[int] = None) -> None:
    flat = dict(tree_paths(tree))
    arrays = {}
    dtypes = {}
    for k, v in flat.items():
        arrays[k], dtypes[k] = _to_numpy(v)
    tmp = tempfile.mkdtemp(dir=os.path.dirname(os.path.abspath(path))
                           or ".")
    try:
        np.savez(os.path.join(tmp, _ARRAYS), **arrays)
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump({"dtypes": dtypes, "step": step,
                       "keys": sorted(arrays)}, f)
        if os.path.isdir(path):
            shutil.rmtree(path)
        os.replace(tmp, path)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs)."""
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    loaded = np.load(os.path.join(path, _ARRAYS))
    flat = {}
    for k in manifest["keys"]:
        arr = loaded[k]
        if manifest["dtypes"][k] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        flat[k] = jnp.asarray(arr)

    paths = [p for p, _ in tree_paths(like)]
    leaves = [flat[p] for p in paths]
    treedef = jax.tree.structure(like)
    return jax.tree.unflatten(treedef, leaves)


def latest_step(path: str) -> Optional[int]:
    try:
        with open(os.path.join(path, _MANIFEST)) as f:
            return json.load(f).get("step")
    except FileNotFoundError:
        return None
