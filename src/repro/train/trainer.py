"""LM trainer: composes model, optimizer, data pipeline, checkpointing.

Runs on whatever devices exist (host CPU for the examples/smoke tests,
the production mesh on a real cluster); sharding comes from the same
path-based rules the dry-run uses, so the example driver exercises the
deployment configuration end to end.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.registry import build_model
from repro.train import checkpoint
from repro.train.optimizer import Optimizer, adamw, cosine_schedule


@dataclasses.dataclass
class TrainConfig:
    batch: int = 8
    steps: int = 200
    lr: float = 3e-4
    warmup: int = 20
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    remat: bool = False


class Trainer:
    def __init__(self, arch_cfg: ArchConfig, tcfg: TrainConfig, *,
                 optimizer: Optional[Optimizer] = None):
        self.cfg = arch_cfg
        self.tcfg = tcfg
        self.model = build_model(arch_cfg)
        self.optimizer = optimizer or adamw(
            cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.steps),
            clip_norm=1.0)

        def step(params, opt_state, batch):
            (loss, aux), grads = jax.value_and_grad(
                lambda p: self.model.loss(p, batch, remat=tcfg.remat),
                has_aux=True)(params)
            params, opt_state = self.optimizer.update(params, opt_state,
                                                      grads)
            return params, opt_state, {"loss": loss, **aux}

        self._step = jax.jit(step, donate_argnums=(0, 1))

    def init(self, seed: int = 0):
        params = self.model.init(jax.random.PRNGKey(seed))
        return params, self.optimizer.init(params)

    def run(self, data: Iterator[dict], *, params=None, opt_state=None,
            hook: Optional[Callable[[int, dict], None]] = None):
        if params is None:
            params, opt_state = self.init()
        history = []
        jax.block_until_ready(params)  # init off the clock; dispatch is async
        t0 = time.perf_counter()
        for i in range(self.tcfg.steps):
            batch = next(data)
            params, opt_state, metrics = self._step(params, opt_state,
                                                    batch)
            if i % self.tcfg.log_every == 0 or i == self.tcfg.steps - 1:
                jax.block_until_ready(metrics)  # wall_s covers finished work
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = i
                m["wall_s"] = time.perf_counter() - t0
                history.append(m)
                if hook:
                    hook(i, m)
            if (self.tcfg.ckpt_dir and self.tcfg.ckpt_every
                    and i and i % self.tcfg.ckpt_every == 0):
                checkpoint.save(self.tcfg.ckpt_dir,
                                {"params": params}, step=i)
        if self.tcfg.ckpt_dir:
            checkpoint.save(self.tcfg.ckpt_dir, {"params": params},
                            step=self.tcfg.steps)
        return params, opt_state, history
