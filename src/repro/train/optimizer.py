"""Optimizers (optax-style pure transforms, built in-repo: offline container).

Provides adam / adamw / sgd with optional global-norm clipping and LR
schedules.  State is a pytree mirroring the params, so it shards identically
to the params under pjit (the sharding rules in repro.models.sharding apply
verbatim to optimizer moments).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Params = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Params
    nu: Params


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], OptState]
    update: Callable[[Params, OptState, Params], tuple[Params, OptState]]


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor: float = 0.0) -> Schedule:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return sched


def global_norm(tree: Params) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree: Params, max_norm: float) -> Params:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: x * scale, tree)


def adam(lr: float | Schedule, *, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0,
         clip_norm: Optional[float] = None) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        zeros = lambda: jax.tree.map(
            lambda x: jnp.zeros_like(x, dtype=jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), zeros(), zeros())

    def update(params, state, grads):
        if clip_norm is not None:
            grads = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr_t = sched(step)
        b1c = 1 - b1 ** step.astype(jnp.float32)
        b2c = 1 - b2 ** step.astype(jnp.float32)

        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) *
                          g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) *
                          jnp.square(g.astype(jnp.float32)), state.nu, grads)

        def upd(p, m, v):
            mhat = m / b1c
            vhat = v / b2c
            delta = lr_t * mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                delta = delta + lr_t * weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, OptState(step, mu, nu)

    return Optimizer(init=init, update=update)


def adamw(lr, *, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def sgd(lr: float | Schedule, *, momentum: float = 0.0,
        clip_norm: Optional[float] = None) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        mu = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), mu, mu)

    def update(params, state, grads):
        if clip_norm is not None:
            grads = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr_t = sched(step)
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state.mu, grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr_t * m).astype(p.dtype),
            params, mu)
        return new_params, OptState(step, mu, state.nu)

    return Optimizer(init=init, update=update)


def ema_update(avg: Params, new: Params, tau: float) -> Params:
    """Polyak averaging for target networks: avg <- (1-tau) avg + tau new."""
    return jax.tree.map(lambda a, n: (1 - tau) * a + tau * n, avg, new)
