"""Walker2D — simplified planar biped.

Not MuJoCo-exact (DESIGN.md §4): a torso with two telescoping torque-swung
legs and spring-damper ground contact.  Preserves the experimental role of
Walker2d-v4: 6 continuous actions, pixel observations via a tracking
camera, reward = forward velocity + alive bonus - control cost,
termination when the torso falls or pitches over.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.base import Env
from repro.envs.rendering import (Camera, blank, draw_capsule,
                                  draw_checker_ground, draw_circle)

_DT = 0.02
_G = 9.8
_M = 1.2
_I = 0.12          # torso moment of inertia
_L0 = 0.5
_KC = 220.0        # contact spring
_DC = 9.0          # contact damping
MAX_STEPS = 400


class WalkerState(NamedTuple):
    x: jnp.ndarray
    z: jnp.ndarray
    pitch: jnp.ndarray
    vx: jnp.ndarray
    vz: jnp.ndarray
    vpitch: jnp.ndarray
    leg_angle: jnp.ndarray   # (2,) from vertical
    leg_len: jnp.ndarray     # (2,)
    t: jnp.ndarray


def reset(key) -> WalkerState:
    k1, k2 = jax.random.split(key)
    return WalkerState(
        x=jnp.zeros(()), z=jnp.asarray(_L0 + 0.12),
        pitch=jax.random.uniform(k1, (), minval=-0.03, maxval=0.03),
        vx=jnp.zeros(()), vz=jnp.zeros(()), vpitch=jnp.zeros(()),
        leg_angle=jnp.asarray([0.12, -0.12])
        + jax.random.uniform(k2, (2,), minval=-0.03, maxval=0.03),
        leg_len=jnp.full((2,), _L0),
        t=jnp.zeros((), jnp.int32),
    )


def _feet(state: WalkerState):
    fx = state.x + state.leg_len * jnp.sin(state.leg_angle)
    fz = state.z - state.leg_len * jnp.cos(state.leg_angle)
    return fx, fz


def step(state: WalkerState, action):
    action = jnp.clip(action, -1, 1)
    hip = action[:2] * 4.0       # swing rate per leg
    knee = action[2:4] * 0.8     # length rate per leg
    push = action[4:6] * 60.0    # extension force per leg (stance push-off)

    fx, fz = _feet(state)
    pen = jnp.maximum(-fz, 0.0)                       # ground penetration
    in_stance = pen > 0.0

    # contact force along each leg (spring-damper + actuated push)
    f_leg = jnp.where(in_stance,
                      _KC * pen - _DC * state.vz + jnp.maximum(push, 0.0),
                      0.0)
    f_leg = jnp.maximum(f_leg, 0.0)

    ax = jnp.sum(-f_leg * jnp.sin(state.leg_angle)) / _M
    az = jnp.sum(f_leg * jnp.cos(state.leg_angle)) / _M - _G
    # stance friction + hip reaction torque pitches the torso
    ax = ax - jnp.sum(jnp.where(in_stance, 0.6, 0.0)) * state.vx / _M
    torque = jnp.sum(jnp.where(in_stance, -0.15 * hip, 0.02 * hip))
    apitch = (torque - 2.2 * state.pitch - 0.5 * state.vpitch) / _I

    vx = state.vx + ax * _DT
    vz = state.vz + az * _DT
    vpitch = state.vpitch + apitch * _DT
    x = state.x + vx * _DT
    z = jnp.maximum(state.z + vz * _DT, 0.3 * _L0)
    pitch = state.pitch + vpitch * _DT

    leg_angle = jnp.clip(state.leg_angle
                         + hip * _DT * jnp.where(in_stance, 0.3, 1.0),
                         -0.8, 0.8)
    leg_len = jnp.clip(state.leg_len + knee * _DT, 0.55 * _L0, 1.2 * _L0)

    new = WalkerState(x, z, pitch, vx, vz, vpitch, leg_angle, leg_len,
                      state.t + 1)

    ctrl_cost = 1e-3 * jnp.sum(jnp.square(action))
    healthy = (z > 0.4) & (jnp.abs(pitch) < 1.0)
    reward = vx + 1.0 * healthy.astype(jnp.float32) - ctrl_cost
    done = (~healthy) | (new.t >= MAX_STEPS)
    return new, reward, done


def render(state: WalkerState):
    cam = Camera(center_x=state.x, center_y=0.6, half_extent=1.1)
    img = blank()
    img = draw_checker_ground(img, cam, 0.0)
    fx, fz = _feet(state)
    colors = [(0.85, 0.45, 0.2), (0.7, 0.25, 0.45)]
    for i in range(2):
        img = draw_capsule(img, cam, state.x, state.z, fx[i],
                           jnp.maximum(fz[i], 0.0), 0.05, colors[i])
        img = draw_circle(img, cam, fx[i], jnp.maximum(fz[i], 0.02), 0.055,
                          (0.15, 0.15, 0.15))
    # torso drawn as a tilted capsule
    tx = state.x + 0.35 * jnp.sin(state.pitch)
    tz = state.z + 0.35 * jnp.cos(state.pitch)
    img = draw_capsule(img, cam, state.x, state.z, tx, tz, 0.12,
                       (0.2, 0.3, 0.8))
    return img


ENV = Env(name="walker", reset=reset, step=step, render=render,
          action_dim=6, max_steps=MAX_STEPS)
