"""Software rasteriser in jnp: distance-field drawing onto a pixel grid.

Replaces MuJoCo's OpenGL renderer for pixel observations (DESIGN.md §4).
All draws are pure functions (B-free; vmap over batch outside).  World
coordinates are mapped through a camera (centre + half-extent) so tracking
cameras (Walker/Hopper) and static cameras (Pendulum) share one code path.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Camera:
    center_x: float | jnp.ndarray
    center_y: float | jnp.ndarray
    half_extent: float
    resolution: int = 100

    def grid(self):
        r = self.resolution
        ys = jnp.linspace(1.0, -1.0, r) * self.half_extent + self.center_y
        xs = jnp.linspace(-1.0, 1.0, r) * self.half_extent + self.center_x
        return jnp.meshgrid(xs, ys)  # (X, Y) each (r, r); row 0 = top


def blank(resolution: int = 100, color=(1.0, 1.0, 1.0)) -> jnp.ndarray:
    return jnp.ones((resolution, resolution, 3)) * jnp.asarray(color)


def _paint(img, mask, color):
    return jnp.where(mask[..., None], jnp.asarray(color), img)


def draw_circle(img, cam: Camera, cx, cy, radius, color):
    X, Y = cam.grid()
    mask = (X - cx) ** 2 + (Y - cy) ** 2 <= radius ** 2
    return _paint(img, mask, color)


def draw_capsule(img, cam: Camera, x1, y1, x2, y2, radius, color):
    """Filled segment with round caps (how MuJoCo draws geoms)."""
    X, Y = cam.grid()
    dx, dy = x2 - x1, y2 - y1
    len2 = dx * dx + dy * dy + 1e-12
    t = jnp.clip(((X - x1) * dx + (Y - y1) * dy) / len2, 0.0, 1.0)
    px, py = x1 + t * dx, y1 + t * dy
    mask = (X - px) ** 2 + (Y - py) ** 2 <= radius ** 2
    return _paint(img, mask, color)


def draw_ground(img, cam: Camera, ground_y, color=(0.55, 0.45, 0.35)):
    _, Y = cam.grid()
    return _paint(img, Y <= ground_y, color)


def draw_checker_ground(img, cam: Camera, ground_y, period: float = 0.5):
    """Checkered ground so forward motion is visible to a tracking camera."""
    X, Y = cam.grid()
    stripe = jnp.floor(X / period).astype(jnp.int32) % 2
    color_a = jnp.asarray((0.60, 0.50, 0.40))
    color_b = jnp.asarray((0.45, 0.37, 0.30))
    ground = jnp.where(stripe[..., None] == 0, color_a, color_b)
    return jnp.where((Y <= ground_y)[..., None], ground, img)


def to_uint8(img: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(jnp.round(img * 255.0), 0, 255).astype(jnp.uint8)
