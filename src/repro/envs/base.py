"""Functional environment interface (pure-JAX, vmap/scan friendly)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

State = Any


@dataclasses.dataclass(frozen=True)
class Env:
    """Bundle of pure functions defining one environment.

    reset(key) -> state
    step(state, action) -> (state, reward, done)   [action: (action_dim,)]
    render(state) -> (res, res, 3) float32 in [0, 1]
    """

    name: str
    reset: Callable
    step: Callable
    render: Callable
    action_dim: int
    max_steps: int
    resolution: int = 100
