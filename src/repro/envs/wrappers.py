"""Observation pipeline matching the paper's wrapper stack (§4.1):

render 100x100 RGB -> crop to 84x84 (random crop in training, centre crop
in eval) -> float in [0,1] -> FrameStack(3) -> (84, 84, 9) HWC tensor.
For deployment/bandwidth analyses an opaque alpha channel is appended at
the (simulated) OpenGL upload boundary; training uses RGB only.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.base import Env

RENDER_RES = 100
CROP = 84
STACK = 3


class PixelEnvState(NamedTuple):
    inner: object
    frames: jnp.ndarray          # (STACK, CROP, CROP, 3) float32
    key: jnp.ndarray
    episode_return: jnp.ndarray
    step_count: jnp.ndarray


def _crop(frame, key, *, train: bool):
    if train:
        ox = jax.random.randint(key, (), 0, RENDER_RES - CROP + 1)
        oy = jax.random.randint(jax.random.fold_in(key, 1), (),
                                0, RENDER_RES - CROP + 1)
    else:
        ox = oy = (RENDER_RES - CROP) // 2
    return jax.lax.dynamic_slice(frame, (oy, ox, 0), (CROP, CROP, 3))


def _obs(frames):
    """(STACK, H, W, 3) -> (H, W, 3*STACK) channel-stacked observation."""
    return jnp.concatenate(list(frames), axis=-1)


class PixelEnv:
    """Wraps a state-based Env into the paper's pixel pipeline."""

    def __init__(self, env: Env, *, train: bool = True):
        self.env = env
        self.train = train
        self.obs_shape = (CROP, CROP, 3 * STACK)
        self.action_dim = env.action_dim

    def reset(self, key):
        k_env, k_crop, k_next = jax.random.split(key, 3)
        inner = self.env.reset(k_env)
        frame = _crop(self.env.render(inner), k_crop, train=self.train)
        frames = jnp.broadcast_to(frame, (STACK,) + frame.shape)
        state = PixelEnvState(inner, frames, k_next,
                              jnp.zeros(()), jnp.zeros((), jnp.int32))
        return state, _obs(frames)

    def step(self, state: PixelEnvState, action):
        k_crop, k_reset, k_next = jax.random.split(state.key, 3)
        inner, reward, done = self.env.step(state.inner, action)
        frame = _crop(self.env.render(inner), k_crop, train=self.train)
        frames = jnp.concatenate([state.frames[1:], frame[None]], axis=0)

        # auto-reset on done (standard vectorised-env semantics)
        reset_inner = self.env.reset(k_reset)
        reset_frame = _crop(self.env.render(reset_inner), k_crop,
                            train=self.train)
        reset_frames = jnp.broadcast_to(reset_frame,
                                        (STACK,) + reset_frame.shape)
        inner = jax.tree.map(
            lambda a, b: jnp.where(done, a, b), reset_inner, inner)
        frames = jnp.where(done, reset_frames, frames)

        ep_ret = jnp.where(done, 0.0, state.episode_return + reward)
        steps = jnp.where(done, 0, state.step_count + 1)
        new = PixelEnvState(inner, frames, k_next, ep_ret, steps)
        return new, _obs(frames), reward, done

    # -- batched (vectorised-env) API ---------------------------------------
    def reset_batch(self, keys):
        """Vectorised reset: (N, 2) keys -> (states, (N, H, W, C) obs)."""
        return jax.vmap(self.reset)(keys)

    def step_batch(self, states, actions):
        """Vectorised step over the leading env axis (jit/scan friendly):
        (states, (N, A)) -> (states, (N, H, W, C) obs, (N,) r, (N,) done)."""
        return jax.vmap(self.step)(states, actions)

    # -- population-batched API ---------------------------------------------
    def reset_population(self, keys):
        """Population-batched reset: ``(P, N, 2)`` keys -> stacked states +
        ``(P, N, H, W, C)`` obs.  Row ``p`` is bitwise what
        ``reset_batch(keys[p])`` returns — population members are
        independent lanes, never coupled (``repro.rl.population`` relies
        on this for its member-0 parity guarantee)."""
        return jax.vmap(self.reset_batch)(keys)

    def step_population(self, states, actions):
        """Population-batched step over ``(member, env)`` axes:
        (states, (P, N, A)) -> (states, (P, N, H, W, C) obs, (P, N) r,
        (P, N) done)."""
        return jax.vmap(self.step_batch)(states, actions)

    # -- deployment boundary -------------------------------------------------
    @staticmethod
    def to_rgba_uint8(obs):
        """Simulated OpenGL upload: append opaque alpha, quantise to uint8.
        obs: (H, W, 3*STACK) float -> (H, W, 4*STACK) uint8."""
        h, w, c = obs.shape
        rgb = obs.reshape(h, w, STACK, 3)
        alpha = jnp.ones((h, w, STACK, 1))
        rgba = jnp.concatenate([rgb, alpha], axis=-1).reshape(h, w, 4 * STACK)
        return jnp.clip(jnp.round(rgba * 255), 0, 255).astype(jnp.uint8)


def make_pixel_env(name: str, *, train: bool = True) -> PixelEnv:
    from repro.envs import REGISTRY
    return PixelEnv(REGISTRY[name], train=train)
