"""Pendulum-v1 (Classic Control) — dynamics faithful to Gymnasium.

theta'' = 3g/(2l) sin(theta) + 3/(m l^2) u,  dt = 0.05, |u| <= 2,
reward = -(angle_norm^2 + 0.1 theta_dot^2 + 0.001 u^2), 200-step episodes.
Rendered with the default static camera: rod from the pivot, bob at the tip.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.base import Env
from repro.envs.rendering import (Camera, blank, draw_capsule, draw_circle,
                                  to_uint8)

_G, _M, _L, _DT = 10.0, 1.0, 1.0, 0.05
MAX_TORQUE = 2.0
MAX_SPEED = 8.0


class PendulumState(NamedTuple):
    theta: jnp.ndarray
    theta_dot: jnp.ndarray
    t: jnp.ndarray


def _angle_normalize(x):
    return ((x + jnp.pi) % (2 * jnp.pi)) - jnp.pi


def reset(key) -> PendulumState:
    k1, k2 = jax.random.split(key)
    theta = jax.random.uniform(k1, (), minval=-jnp.pi, maxval=jnp.pi)
    theta_dot = jax.random.uniform(k2, (), minval=-1.0, maxval=1.0)
    return PendulumState(theta, theta_dot, jnp.zeros((), jnp.int32))


def step(state: PendulumState, action):
    # policy actions live in [-1, 1]; scale to the torque limit
    u = jnp.clip(action[0] * MAX_TORQUE, -MAX_TORQUE, MAX_TORQUE)
    th, thdot = state.theta, state.theta_dot
    cost = (_angle_normalize(th) ** 2 + 0.1 * thdot ** 2 + 0.001 * u ** 2)
    newthdot = thdot + (3 * _G / (2 * _L) * jnp.sin(th)
                        + 3.0 / (_M * _L ** 2) * u) * _DT
    newthdot = jnp.clip(newthdot, -MAX_SPEED, MAX_SPEED)
    newth = th + newthdot * _DT
    new = PendulumState(newth, newthdot, state.t + 1)
    done = new.t >= 200
    return new, -cost, done


_CAM = Camera(center_x=0.0, center_y=0.0, half_extent=1.5)


def render(state: PendulumState):
    th = state.theta
    # Gym convention: theta=0 is upright
    tip_x = _L * jnp.sin(th)
    tip_y = _L * jnp.cos(th)
    img = blank()
    img = draw_capsule(img, _CAM, 0.0, 0.0, tip_x, tip_y, 0.09,
                       (0.8, 0.3, 0.3))
    img = draw_circle(img, _CAM, 0.0, 0.0, 0.06, (0.1, 0.1, 0.1))
    img = draw_circle(img, _CAM, tip_x, tip_y, 0.12, (0.2, 0.2, 0.7))
    return img


ENV = Env(name="pendulum", reset=reset, step=step, render=render,
          action_dim=1, max_steps=200)
