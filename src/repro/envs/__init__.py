"""Pure-JAX visual control suite (replaces MuJoCo/Gymnasium offline)."""

from repro.envs.base import Env
from repro.envs.hopper import ENV as HOPPER
from repro.envs.pendulum import ENV as PENDULUM
from repro.envs.walker import ENV as WALKER

REGISTRY: dict[str, Env] = {
    "pendulum": PENDULUM,
    "hopper": HOPPER,
    "walker": WALKER,
}

from repro.envs.wrappers import PixelEnv, make_pixel_env  # noqa: E402

__all__ = ["Env", "REGISTRY", "PixelEnv", "make_pixel_env",
           "PENDULUM", "HOPPER", "WALKER"]
