"""Hopper2D — simplified planar one-legged hopper (SLIP-style).

Not MuJoCo-exact (DESIGN.md §4): a spring-loaded-inverted-pendulum body with
actuated leg thrust, hip torque, and leg-length rate.  Preserves the
experimental role of Hopper-v4: continuous actions (3), pixel observations
via a tracking camera, reward = forward velocity + alive bonus - control
cost, termination on falling.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.base import Env
from repro.envs.rendering import (Camera, blank, draw_capsule,
                                  draw_checker_ground, draw_circle)

_DT = 0.02
_G = 9.8
_M = 1.0          # body mass
_L0 = 0.55        # rest leg length
_KSPRING = 140.0  # leg spring
_DAMP = 4.0
MAX_STEPS = 400


class HopperState(NamedTuple):
    x: jnp.ndarray        # body horizontal position
    z: jnp.ndarray        # body height
    vx: jnp.ndarray
    vz: jnp.ndarray
    leg_angle: jnp.ndarray   # from vertical, + = forward
    leg_len: jnp.ndarray
    t: jnp.ndarray


def reset(key) -> HopperState:
    k1, k2 = jax.random.split(key)
    return HopperState(
        x=jnp.zeros(()),
        z=_L0 + 0.25 + jax.random.uniform(k1, (), minval=0.0, maxval=0.05),
        vx=jnp.zeros(()),
        vz=jnp.zeros(()),
        leg_angle=jax.random.uniform(k2, (), minval=-0.05, maxval=0.05),
        leg_len=jnp.asarray(_L0),
        t=jnp.zeros((), jnp.int32),
    )


def _foot(state: HopperState):
    fx = state.x + state.leg_len * jnp.sin(state.leg_angle)
    fz = state.z - state.leg_len * jnp.cos(state.leg_angle)
    return fx, fz


def step(state: HopperState, action):
    thrust = jnp.clip(action[0], -1, 1) * 90.0      # spring pre-load
    hip = jnp.clip(action[1], -1, 1) * 3.0          # leg swing rate
    rate = jnp.clip(action[2], -1, 1) * 0.6         # leg length rate

    fx, fz = _foot(state)
    in_stance = fz <= 0.0

    # stance: spring force along the leg (plus thrust), acting on the body
    compression = jnp.maximum(_L0 - state.leg_len, 0.0)
    spring_f = jnp.where(in_stance,
                         _KSPRING * compression + jnp.maximum(thrust, 0.0)
                         - _DAMP * (-state.vz), 0.0)
    ax = spring_f * jnp.sin(state.leg_angle) / _M * (-1.0)
    az = spring_f * jnp.cos(state.leg_angle) / _M - _G

    # stance foot friction damps horizontal motion a little
    ax = ax - jnp.where(in_stance, 0.8 * state.vx, 0.0)

    vx = state.vx + ax * _DT
    vz = state.vz + az * _DT
    x = state.x + vx * _DT
    z = state.z + vz * _DT

    # leg control: swing in flight, compress/extend always
    leg_angle = state.leg_angle + hip * _DT * jnp.where(in_stance, 0.25, 1.0)
    leg_angle = jnp.clip(leg_angle, -0.7, 0.7)
    leg_len = jnp.clip(state.leg_len + rate * _DT
                       - jnp.where(in_stance, 0.5 * compression * _DT, 0.0),
                       0.6 * _L0, 1.15 * _L0)

    # stance constraint: keep body above ground through the leg
    z = jnp.maximum(z, 0.35 * _L0)

    new = HopperState(x, z, vx, vz, leg_angle, leg_len, state.t + 1)

    ctrl_cost = 1e-3 * jnp.sum(jnp.square(jnp.asarray(
        [action[0], action[1], action[2]])))
    healthy = (z > 0.45) & (jnp.abs(leg_angle) < 0.69)
    reward = vx + 1.0 * healthy.astype(jnp.float32) - ctrl_cost
    done = (~healthy) | (new.t >= MAX_STEPS)
    return new, reward, done


def render(state: HopperState):
    cam = Camera(center_x=state.x, center_y=0.6, half_extent=1.1)
    img = blank()
    img = draw_checker_ground(img, cam, 0.0)
    fx, fz = _foot(state)
    img = draw_capsule(img, cam, state.x, state.z, fx, jnp.maximum(fz, 0.0),
                       0.05, (0.85, 0.45, 0.2))
    img = draw_circle(img, cam, state.x, state.z, 0.16, (0.2, 0.3, 0.8))
    img = draw_circle(img, cam, fx, jnp.maximum(fz, 0.02), 0.06,
                      (0.15, 0.15, 0.15))
    return img


ENV = Env(name="hopper", reset=reset, step=step, render=render,
          action_dim=3, max_steps=MAX_STEPS)
