from repro.data.synthetic import (SyntheticLM, lm_batches, frontend_batches,
                                  zipf_tokens)

__all__ = ["SyntheticLM", "lm_batches", "frontend_batches", "zipf_tokens"]
