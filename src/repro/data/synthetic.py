"""Synthetic data pipeline (offline container: no downloads).

Produces deterministic, shardable token streams with LM-like statistics:

* Zipf-distributed unigrams (natural-language-like frequency profile);
* a Markov "template" layer so sequences have learnable structure —
  training losses actually decrease, which the example drivers and tests
  assert;
* document packing with BOS/EOS markers, fixed seq_len, host-prefetch
  iterator.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def zipf_tokens(key, shape, vocab: int, *, alpha: float = 1.2) -> jnp.ndarray:
    """Zipf-distributed token ids via inverse-CDF sampling."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** (-alpha)
    probs /= probs.sum()
    cdf = jnp.asarray(np.cumsum(probs), jnp.float32)
    u = jax.random.uniform(key, shape)
    return jnp.searchsorted(cdf, u).astype(jnp.int32)


@dataclasses.dataclass
class SyntheticLM:
    """Markov-structured synthetic corpus.

    Each document interleaves a persistent "topic" n-gram template with
    Zipf noise; next-token statistics are predictable enough that a small
    model's CE visibly drops within a few hundred steps.
    """

    vocab: int
    seq_len: int
    bos: int = 1
    eos: int = 2
    structure: float = 0.75     # fraction of positions from the template
    n_templates: int = 64
    template_len: int = 32

    def _templates(self, key) -> jnp.ndarray:
        return zipf_tokens(key, (self.n_templates, self.template_len),
                           self.vocab)

    def batch(self, key, batch_size: int) -> dict:
        k1, k2, k3, k4 = jax.random.split(key, 4)
        templates = self._templates(jax.random.PRNGKey(0))  # fixed corpus
        tids = jax.random.randint(k1, (batch_size, 1), 0, self.n_templates)
        reps = -(-self.seq_len // self.template_len)
        body = jnp.tile(templates[tids[:, 0]], (1, reps))[:, :self.seq_len]
        noise = zipf_tokens(k2, (batch_size, self.seq_len), self.vocab)
        use_template = jax.random.bernoulli(
            k3, self.structure, (batch_size, self.seq_len))
        tokens = jnp.where(use_template, body, noise)
        tokens = tokens.at[:, 0].set(self.bos)
        doc_end = jax.random.randint(k4, (batch_size,),
                                     self.seq_len // 2, self.seq_len)
        tokens = jnp.where(
            jnp.arange(self.seq_len)[None, :] == doc_end[:, None],
            self.eos, tokens)
        return {"tokens": tokens}


def lm_batches(vocab: int, batch: int, seq: int, *,
               seed: int = 0) -> Iterator[dict]:
    """Infinite deterministic batch iterator."""
    src = SyntheticLM(vocab=vocab, seq_len=seq)
    key = jax.random.PRNGKey(seed)
    step = 0
    while True:
        yield src.batch(jax.random.fold_in(key, step), batch)
        step += 1


def frontend_batches(batch: int, n_tokens: int, d_model: int, *,
                     seed: int = 0) -> Iterator[jnp.ndarray]:
    """Stub modality frontend: precomputed frame/patch embeddings (the
    brief's one allowed stub for [audio]/[vlm] architectures)."""
    key = jax.random.PRNGKey(seed)
    step = 0
    while True:
        k = jax.random.fold_in(key, step)
        yield (jax.random.normal(k, (batch, n_tokens, d_model))
               * 0.02).astype(jnp.bfloat16)
        step += 1
