"""repro.deploy — ONE declarative deployment API: MiniConvSpec -> served policy.

The paper's artifact is a deployment *pipeline*: a
:class:`~repro.core.miniconv.MiniConvSpec` is compiled into an ordered
shader-pass schedule (:class:`~repro.core.passplan.PassPlan`), executed by
a backend (fragment shaders on the Pi, Pallas kernels here), split at the
wire boundary (:class:`~repro.core.split.SplitModel` +
:class:`~repro.core.wire.WireCodec`) and served to clients
(:class:`~repro.serving.server.BatchingPolicyServer` /
:class:`~repro.serving.client.EdgeClient`).  This module makes that whole
pipeline ONE object:

* :class:`DeploymentConfig` — a frozen, JSON-serialisable manifest of the
  deployment: the spec, the concrete input size, the execution backend
  (``repro.core.backends`` registry), the micro-batching policy, the wire
  codec and the head placement.  ``to_dict``/``from_dict`` round-trip, so
  a manifest can ship to the device exactly like the paper's compiled
  shader bundles.
* :class:`Deployment` — the compiled form.  ``Deployment.build(config)``
  resolves the config ONCE into the budget-checked PassPlan (including
  the batch-size-aware VMEM check), parameter initialisers, the
  :class:`SplitModel`, the RL-facing :class:`~repro.rl.networks.Encoder`,
  the codec, and factories for a ready ``EdgeClient`` /
  ``BatchingPolicyServer`` pair.

Every entry point — training (``repro.rl.train``), serving, and the
benchmarks — constructs the pipeline through ``Deployment.build``; the
legacy constructors (``rl.networks.make_encoder``,
``core.split.make_miniconv_split``) survive as thin deprecation shims
over it.

Quick start::

    from repro.deploy import Deployment, DeploymentConfig

    cfg = DeploymentConfig.standard(k=4, c_in=12, h=84, backend="fused")
    dep = Deployment.build(cfg)
    params = dep.init(jax.random.PRNGKey(0))
    client = dep.client(params)            # EdgeClient: obs -> payload
    server = dep.server(params)            # BatchingPolicyServer
    actions = server.serve([client.encode_fn(obs)])

Run ``python -m repro.deploy`` to write (and round-trip-verify) a
deployment manifest.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.backends import ExecutionBackend, backend_names, get_backend
from repro.core.miniconv import (_ACTS, LayerSpec, MiniConvSpec,
                                 ShaderBudget, miniconv_apply, standard_spec)
from repro.core.passplan import HeadPlan, PassPlan, build_pass_plan
from repro.core.split import SplitModel
from repro.schema import check_version
from repro.core.tuning import TunedPlan
from repro.core.wire import CODECS, WireCodec, get_codec
from repro.nn.layers import dense
from repro.rl.networks import Encoder, miniconv_encoder_init
from repro.serving.client import EdgeClient
from repro.serving.fleet import ROUTERS, FleetQueueSim
from repro.serving.server import BatchingPolicyServer

# version 2 added the optional ``tuning`` block (a frozen TunedPlan);
# version-1 manifests load unchanged with ``tuning=None``.
CONFIG_VERSION = 2
_READABLE_VERSIONS = (1, 2)


# ---------------------------------------------------------------------------
# The manifest
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeploymentConfig:
    """Declarative, serialisable description of one split-policy deployment.

    Fields
    ------
    spec            : the MiniConv encoder architecture (budget-checked).
    in_h, in_w      : the concrete input size the edge device sees.
    backend         : execution-backend name (``repro.core.backends``):
                      ``xla`` | ``reference`` | ``grouped`` | ``fused`` |
                      ``fused+head``.
    interpret       : Pallas interpret (True) vs compiled (False) for the
                      kernel backends; ``None`` = auto (compiled on TPU or
                      with ``REPRO_PALLAS_COMPILE=1``).
    codec           : wire-codec name (``repro.core.wire.CODECS``).
    head_dim        : width of the server-side projection (paper: 512).
    head_act        : activation of the projection.
    head_placement  : ``"server"`` — projection runs as the server half of
                      the split (the paper's deployment); ``"fused"`` —
                      projection is fused with the encoder into one call
                      (one kernel launch under the ``fused`` backends; the
                      colocated training / replay-encoding hot path).
    max_batch       : server micro-batching cap (B frames per launch).
    max_wait_ms     : how long the server holds a batch open for
                      stragglers.
    tile_h          : fused-kernel output-row tile height.
    quantize_in_train : straight-through-quantise features during training
                      so training numerics match the deployed wire.
    n_servers       : fleet size — how many independent micro-batching
                      servers share the ingress (1 = the paper's Table 6
                      single server).
    router          : fleet routing policy (``repro.serving.fleet.ROUTERS``):
                      ``round_robin`` | ``least_loaded`` |
                      ``client_affinity`` (hash-pinned, keeps one client's
                      requests ordered).
    tuning          : optional frozen :class:`~repro.core.tuning.TunedPlan`
                      (``core.tuning.tune`` / ``python -m repro.deploy
                      --tune``).  When present, :meth:`Deployment.build`
                      executes with the tuned backend / ``tile_h`` /
                      micro-batch instead of the fields above — tune once,
                      freeze into the manifest, every entry point inherits
                      the tuned kernels.
    """

    spec: MiniConvSpec
    in_h: int
    in_w: int
    backend: str = "fused"
    interpret: Optional[bool] = None
    codec: str = "uint8"
    head_dim: int = 512
    head_act: str = "relu"
    head_placement: str = "server"
    max_batch: int = 8
    max_wait_ms: float = 0.0
    tile_h: int = 8
    quantize_in_train: bool = False
    n_servers: int = 1
    router: str = "round_robin"
    tuning: Optional[TunedPlan] = None

    def __post_init__(self):
        # canonicalise backend aliases (and the legacy use_kernel booleans)
        # at construction so equality and serialisation are name-stable
        object.__setattr__(self, "backend", get_backend(self.backend).name)
        if isinstance(self.tuning, dict):     # deserialised manifests
            object.__setattr__(self, "tuning",
                               TunedPlan.from_dict(self.tuning))

    # ---- construction helpers ---------------------------------------------
    @classmethod
    def standard(cls, *, k: int = 4, c_in: int = 12, h: int = 84,
                 w: Optional[int] = None, **overrides) -> "DeploymentConfig":
        """The paper's standard encoder family, deployed at (h, w)."""
        return cls(spec=standard_spec(c_in=c_in, k=k), in_h=h,
                   in_w=h if w is None else w, **overrides)

    @classmethod
    def from_encoder_name(cls, name: str, *, c_in: int, h: int = 84,
                          w: Optional[int] = None,
                          **overrides) -> "DeploymentConfig":
        """``miniconv<K>`` (the ``rl.networks.make_encoder`` names)."""
        if not name.startswith("miniconv"):
            raise ValueError(f"not a MiniConv deployment: {name!r} "
                             f"(full_cnn has no split pipeline)")
        k = int(name.replace("miniconv", ""))
        return cls.standard(k=k, c_in=c_in, h=h, w=w, **overrides)

    # ---- validation --------------------------------------------------------
    def validate(self) -> None:
        get_backend(self.backend)          # raises listing registered names
        if self.codec not in CODECS:
            raise ValueError(f"unknown codec {self.codec!r}; registered: "
                             f"{', '.join(CODECS)}")
        if self.head_placement not in ("server", "fused"):
            raise ValueError(f"head_placement must be 'server' or 'fused', "
                             f"got {self.head_placement!r}")
        if self.head_act not in _ACTS:
            raise ValueError(f"unknown head_act {self.head_act!r}; one of "
                             f"{', '.join(_ACTS)}")
        if self.in_h < 1 or self.in_w < 1:
            raise ValueError(f"input size must be positive, got "
                             f"{(self.in_h, self.in_w)}")
        if self.head_dim < 1:
            raise ValueError(f"head_dim must be positive: {self.head_dim}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1: {self.max_batch}")
        if self.max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0: {self.max_wait_ms}")
        if self.tile_h < 1:
            raise ValueError(f"tile_h must be >= 1: {self.tile_h}")
        if self.n_servers < 1:
            raise ValueError(f"n_servers must be >= 1: {self.n_servers}")
        if self.router not in ROUTERS:
            raise ValueError(f"unknown router {self.router!r}; registered: "
                             f"{', '.join(ROUTERS)}")
        if self.tuning is not None:
            get_backend(self.tuning.backend)   # raises listing names
            if self.tuning.tile_h < 1 or self.tuning.micro_batch < 1:
                raise ValueError(
                    f"tuning tile_h/micro_batch must be >= 1, got "
                    f"{self.tuning.tile_h}/{self.tuning.micro_batch}")
        self.spec.validate()

    # ---- serialisation -----------------------------------------------------
    def to_dict(self) -> dict:
        """JSON-safe manifest; inverse of :meth:`from_dict`."""
        d = dataclasses.asdict(self)
        d["spec"] = {
            "layers": [dataclasses.asdict(l) for l in self.spec.layers],
            "budget": dataclasses.asdict(self.spec.budget),
        }
        d["tuning"] = None if self.tuning is None else self.tuning.to_dict()
        d["version"] = CONFIG_VERSION
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "DeploymentConfig":
        d = dict(d)
        check_version("DeploymentConfig manifest",
                      d.pop("version", CONFIG_VERSION), _READABLE_VERSIONS)
        s = d.pop("spec")
        spec = MiniConvSpec(
            layers=tuple(LayerSpec(**l) for l in s["layers"]),
            budget=ShaderBudget(**s.get("budget", {})))
        # pre-tuning (version-1) manifests default cleanly to tuning=None;
        # __post_init__ revives a serialised TunedPlan dict
        return cls(spec=spec, **d)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "DeploymentConfig":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# The compiled deployment
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Deployment:
    """A resolved deployment: every pipeline stage, built once from config.

    Construct with :meth:`build`.  The object is cheap to carry around —
    parameters stay OUTSIDE (functional style), so one Deployment serves
    training, serving, and benchmarking with different parameter sets.
    """

    config: DeploymentConfig
    backend: ExecutionBackend
    plan: PassPlan
    head_plan: HeadPlan
    codec: WireCodec
    split: SplitModel
    encoder: Encoder
    max_safe_batch: int
    tile_h: int = 8
    stream_chunk: Optional[int] = None
    compiled: bool = False
    build_log: tuple = ()

    # ---- the compiler ------------------------------------------------------
    @classmethod
    def build(cls, config: DeploymentConfig) -> "Deployment":
        """Resolve ``config`` into the executable pipeline.

        The PassPlan is lowered and shader-budget-checked once, up front.
        A manifest ``tuning`` block overrides the executed backend /
        ``tile_h`` / micro-batch (tune once, serve everywhere).  When the
        resolved backend runs the fused Pallas kernel compiled
        (``interpret=False``, or ``interpret=None`` resolving to compiled
        on a TPU host / under ``REPRO_PALLAS_COMPILE=1``), the configured
        micro-batch is checked against the fused kernel's VMEM residency
        model — and an over-budget batch is no longer rejected: it is
        PIPELINED through :func:`~repro.kernels.miniconv_pass.
        miniconv_encoder_stream` in ``max_safe_batch``-frame chunks (the
        decision is recorded in ``build_log``).  Build still fails, with
        the computed ``max_safe_batch`` and the tuner's suggestion, when
        even a single frame exceeds the budget.
        """
        config.validate()
        backend = get_backend(config.backend)
        tile_h = config.tile_h
        tuning = config.tuning
        log: list[str] = []
        if tuning is not None:
            backend = get_backend(tuning.backend)
            tile_h = tuning.tile_h
            log.append(
                f"tuning: manifest TunedPlan -> backend={backend.name} "
                f"tile_h={tile_h} micro_batch={tuning.micro_batch} "
                f"(measured {tuning.mode} on {tuning.host or 'unknown'})")
        spec = config.spec
        plan = build_pass_plan(spec, config.in_h, config.in_w)
        head_plan = plan.head(config.head_dim, activation=config.head_act)
        fused_head = backend.fused_head or (config.head_placement == "fused"
                                            and backend.mode == "fused")
        vmem_head = head_plan if fused_head else None
        max_safe = plan.max_safe_batch(head=vmem_head, tile_h=tile_h)
        # The VMEM residency model describes the FUSED kernel (whole-batch
        # input resident on-chip); per-pass/grouped kernels stream row
        # blocks and are batch-size-indifferent.  interpret=None resolves
        # the same way the kernel layer does, so a default manifest built
        # on a TPU host (compiled) is still checked at build time.
        if config.interpret is None:
            compiled = bool(os.environ.get("REPRO_PALLAS_COMPILE")) \
                or jax.default_backend() == "tpu"
        else:
            compiled = not config.interpret
        stream_chunk: Optional[int] = None
        if backend.mode == "fused":
            if compiled and max_safe < 1:
                raise cls._unlaunchable(config, plan, vmem_head, tile_h)
            if backend.streamed:
                chunk = tuning.micro_batch if tuning is not None else 0
                if compiled:
                    chunk = min(chunk, max_safe) if chunk >= 1 else max_safe
                elif chunk < 1:
                    chunk = max_safe if max_safe >= 1 else config.max_batch
                stream_chunk = max(1, min(chunk, config.max_batch))
            elif compiled and config.max_batch > max_safe:
                # Over-budget micro-batch on the plain fused path: pipeline
                # it instead of rejecting the deployment.
                stream_chunk = max_safe
                log.append(cls._pipelining_note(config, max_safe, tile_h,
                                                stream_chunk))
        codec = get_codec(config.codec)
        mode, interpret = backend.mode, config.interpret
        head_act = config.head_act

        def edge_apply(edge_params, obs):
            # deployment path: the prebuilt plan is reused (and
            # size-checked) on every frame
            return miniconv_apply(edge_params, spec, obs, use_kernel=mode,
                                  plan=plan if mode == "fused" else None,
                                  tile_h=tile_h, interpret=interpret,
                                  stream_chunk=stream_chunk)

        def server_apply(server_params, feats):
            z = dense(server_params["proj"], feats.reshape(feats.shape[0], -1))
            return _ACTS[head_act](z)

        split = SplitModel(edge_apply=edge_apply, server_apply=server_apply,
                           codec=codec,
                           quantize_in_train=config.quantize_in_train,
                           plan=plan)

        def init(key):
            return miniconv_encoder_init(key, spec, h=config.in_h,
                                         w=config.in_w,
                                         feature_dim=config.head_dim)

        if config.head_placement == "fused" or backend.fused_head:
            def encoder_apply(params, obs):
                # encoder + projection in one call (one kernel launch
                # under the fused backends)
                p = plan if (mode == "fused"
                             and obs.shape[1:3] == (plan.in_h, plan.in_w)) \
                    else None
                _, z = miniconv_apply(params["edge"], spec, obs,
                                      use_kernel=mode, plan=p, tile_h=tile_h,
                                      head=params["server"]["proj"],
                                      head_act=head_act, interpret=interpret,
                                      stream_chunk=stream_chunk
                                      if p is not None else None)
                return z
        else:
            def encoder_apply(params, obs):
                # training path tolerates other input sizes: re-lower the
                # plan when the observation differs from the deployed size
                p = plan if (mode == "fused"
                             and obs.shape[1:3] == (plan.in_h, plan.in_w)) \
                    else None
                feats = miniconv_apply(params["edge"], spec, obs,
                                       use_kernel=mode, plan=p,
                                       tile_h=tile_h, interpret=interpret,
                                       stream_chunk=stream_chunk
                                       if p is not None else None)
                return server_apply(params["server"], feats)

        encoder = Encoder(name=f"miniconv{spec.k_out}", init=init,
                          apply=encoder_apply, spec=spec)
        return cls(config=config, backend=backend, plan=plan,
                   head_plan=head_plan, codec=codec, split=split,
                   encoder=encoder, max_safe_batch=max_safe, tile_h=tile_h,
                   stream_chunk=stream_chunk, compiled=compiled,
                   build_log=tuple(log))

    # ---- over-budget diagnostics ------------------------------------------
    @staticmethod
    def _suggestion(config) -> str:
        """The tuner's cost-model pick, formatted for diagnostics."""
        from repro.core.tuning import suggest_tuning
        try:
            s = suggest_tuning(config)
        except ValueError:
            return ""
        return (f"; tuner suggests backend={s.backend} tile_h={s.tile_h} "
                f"micro_batch={s.micro_batch} (python -m repro.deploy "
                f"--tune to measure and freeze)")

    @classmethod
    def _unlaunchable(cls, config, plan, vmem_head, tile_h) -> ValueError:
        need = plan.vmem_bytes(1, head=vmem_head, tile_h=tile_h)
        from repro.core.passplan import DEFAULT_VMEM_LIMIT
        return ValueError(
            f"compiled fused launch cannot fit VMEM at ANY batch size: one "
            f"{plan.in_h}x{plan.in_w} frame needs ~{need / 2**20:.2f} MiB "
            f"> budget {DEFAULT_VMEM_LIMIT / 2**20:.2f} MiB "
            f"(max_safe_batch=0, tile_h={tile_h}) — batch pipelining "
            f"cannot help; lower the input size or split the spec"
            + cls._suggestion(config))

    @classmethod
    def _pipelining_note(cls, config, max_safe, tile_h, chunk) -> str:
        return (f"pipelining: max_batch {config.max_batch} exceeds "
                f"max_safe_batch {max_safe} (tile_h={tile_h}) — streaming "
                f"the fused launch in {chunk}-frame chunks "
                f"(kernels.miniconv_pass.miniconv_encoder_stream)"
                + cls._suggestion(config))

    # ---- parameters --------------------------------------------------------
    def init(self, key):
        """{"edge": conv params, "server": {"proj": dense}} — the dict split
        IS the deployment split."""
        return self.encoder.init(key)

    # ---- accounting --------------------------------------------------------
    @property
    def spec(self) -> MiniConvSpec:
        return self.config.spec

    @property
    def wire_bytes(self) -> int:
        """Exact bytes of one request's payload on the link."""
        return self.split.wire_bytes()

    def wire_bytes_batch(self, batch: Optional[int] = None) -> int:
        return self.split.wire_bytes(
            batch=self.config.max_batch if batch is None else batch)

    @property
    def frame_bytes(self) -> int:
        """Bytes of the raw observation upload the server-only baseline
        transmits (RGBA-packed: 4 channels per texture)."""
        c = self.spec.layers[0].c_in
        return self.config.in_h * self.config.in_w * (-(-c // 4) * 4)

    # ---- served pipeline ---------------------------------------------------
    @staticmethod
    def _split_params(params):
        """Accept either the encoder split ({"edge", "server"}) or a full
        TRAINED parameter pytree (``TrainResult.params`` /
        ``TrainState.params``, whose ``"encoder"`` entry is that split) —
        so a training run serves from the manifest with no repacking."""
        if "edge" not in params and "encoder" in params:
            return params["encoder"]
        return params

    def edge_fn(self, params) -> Callable:
        """Jitted on-device half: obs -> wire payload."""
        edge_params = self._split_params(params)["edge"]
        return jax.jit(lambda obs: self.split.edge_step(edge_params, obs))

    def server_fn(self, params, head: Optional[Callable] = None) -> Callable:
        """Jitted remote half: payload -> features (or actions via
        ``head``, e.g. a policy MLP applied after the projection)."""
        server_params = self._split_params(params)["server"]

        def fn(payload):
            z = self.split.server_step(server_params, payload)
            return head(z) if head is not None else z
        return jax.jit(fn)

    def server_batch_fn(self, params,
                        head: Optional[Callable] = None) -> Callable:
        """Jitted micro-batched remote half: stacked payload -> actions."""
        server_params = self._split_params(params)["server"]

        def fn(payload_batch):
            z = self.split.server_step_batch(server_params, payload_batch)
            return head(z) if head is not None else z
        return jax.jit(fn)

    def client(self, params) -> EdgeClient:
        """Ready :class:`EdgeClient` for these parameters."""
        return EdgeClient(encode_fn=self.edge_fn(params),
                          wire_bytes=self.wire_bytes)

    def server(self, params,
               head: Optional[Callable] = None) -> BatchingPolicyServer:
        """Ready :class:`BatchingPolicyServer` under this config's
        batching policy (``max_batch`` / ``max_wait_ms``)."""
        return BatchingPolicyServer(
            serve_batch_fn=self.server_batch_fn(params, head),
            max_batch=self.config.max_batch,
            max_wait_s=self.config.max_wait_ms / 1e3)

    def serving_pair(self, params, head: Optional[Callable] = None
                     ) -> tuple[EdgeClient, BatchingPolicyServer]:
        """The paper's Figure-5 pipeline, ready to measure."""
        return self.client(params), self.server(params, head)

    def export_best(self, population, head: Optional[Callable] = None
                    ) -> tuple[EdgeClient, BatchingPolicyServer]:
        """Serving pair for a population run's winning member.

        ``population`` is a :class:`repro.rl.population.PopulationResult`;
        the winner is its ``best_member()`` — highest ``final_100_mean``
        under the deterministic eval protocol.  The member's trained
        params serve through THIS manifest exactly like the single-run
        path (:meth:`serving_pair` accepts ``TrainState.params``
        directly), so train-many / freeze-best / serve-on-fleet is one
        manifest round-trip.
        """
        return self.serving_pair(population.best_params(), head=head)

    def fleet_sim(self, service_model: Callable[[int], float], *, uplink,
                  rate_hz: float = 10.0, horizon_s: float = 5.0,
                  action_bytes: int = 64,
                  n_servers: Optional[int] = None,
                  router: Optional[str] = None,
                  max_batch: Optional[int] = None,
                  max_wait_s: Optional[float] = None) -> FleetQueueSim:
        """Fleet-scale queue simulator for THIS deployment.

        Payload bytes, micro-batching policy and fleet shape
        (``n_servers`` / ``router``) all come from the manifest —
        keyword overrides take precedence, so a benchmark sweeping the
        batching policy can keep the sim consistent with the policy it
        MEASURED t(B) under; ``service_model`` is that measured curve
        (``BatchingPolicyServer.service_model()``), charged by every
        server in the fleet.  At ``n_servers=1`` this is exactly the
        Table 6 batched simulation.
        """
        cfg = self.config
        return FleetQueueSim(
            service_time_s=service_model(1), uplink=uplink,
            payload_bytes=self.wire_bytes, action_bytes=action_bytes,
            rate_hz=rate_hz, horizon_s=horizon_s,
            max_batch=cfg.max_batch if max_batch is None else max_batch,
            max_wait_s=cfg.max_wait_ms / 1e3 if max_wait_s is None
            else max_wait_s,
            service_model=service_model,
            n_servers=cfg.n_servers if n_servers is None else n_servers,
            router=cfg.router if router is None else router)

    def scenario_sim(self, scenario, *,
                     n_servers: Optional[int] = None,
                     router: Optional[str] = None,
                     max_batch: Optional[int] = None,
                     max_wait_s: Optional[float] = None,
                     adaptation: str = "none",
                     service_model: Optional[Callable[[int], float]] = None):
        """This deployment under a named (or inline) :class:`Scenario`.

        The scenario supplies the serving CONDITION — its seeded link,
        its device zoo (one t(B) curve per server, cycled from the
        profile registry), client population/rate and adaptation-mode
        ladder — while the manifest supplies the deployment: payload
        bytes (``wire_bytes``), micro-batching policy and fleet shape,
        with the same keyword-override precedence as :meth:`fleet_sim`.
        ``adaptation`` picks the controller (``"none"``, ``"rule"``,
        ``"static:<i>"`` or anything registered via
        ``repro.serving.scenario.register_adaptation``); a measured
        ``service_model`` overrides the zoo on every server.  Returns a
        :class:`~repro.serving.scenario.ScenarioFleetSim` — call
        ``.report(n_clients)`` for latencies, uplink bytes and the
        delivered-return proxy.
        """
        from repro.serving.scenario import get_scenario
        sc = get_scenario(scenario)
        cfg = self.config
        ns = cfg.n_servers if n_servers is None else n_servers
        return sc.sim(
            self.wire_bytes, n_servers=ns,
            router=cfg.router if router is None else router,
            max_batch=cfg.max_batch if max_batch is None else max_batch,
            max_wait_s=cfg.max_wait_ms / 1e3 if max_wait_s is None
            else max_wait_s,
            adaptation=adaptation,
            service_models=None if service_model is None
            else (service_model,) * ns)

    def fleet(self, params, *, n_servers: Optional[int] = None,
              router: Optional[str] = None, max_batch: Optional[int] = None,
              service_model: Optional[Callable[[int], float]] = None,
              timeout_s: float = 10.0, retries: int = 2,
              precompile: bool = True, start: bool = True,
              shaping=None):
        """A REAL multi-process fleet for THIS deployment (localhost).

        The counterpart of :meth:`fleet_sim`: ``n_servers`` spawned
        worker processes (each rebuilding the jitted server half from
        this manifest), length-prefix-framed sockets carrying the wire
        codec's payloads, and the registered routing policy at the front
        door (``repro.serving.realfleet``).  Fleet shape defaults to the
        manifest (``n_servers`` / ``router`` / ``max_batch``), exactly
        like the simulator.

        When a measured ``service_model`` is given, worker admission is
        capped at its :attr:`~repro.serving.server.BatchServiceModel.
        max_measured_batch` — the real fleet never serves batch sizes the
        t(B) curve only extrapolates, so the sim-vs-real calibration
        compares measured numbers on both sides.

        ``shaping`` (a :class:`~repro.serving.realfleet.ShapingConfig`
        or its dict) token-bucket-shapes every worker's request ingress —
        the measured counterpart of the sims' shaped uplink.

        Returns a started :class:`~repro.serving.realfleet.RealFleet`
        (``start=False`` defers the spawn); always ``close()`` it — the
        returned leak list is the CI "no leaked workers" gate.
        """
        import numpy as np
        from repro.serving.realfleet import RealFleet
        cfg = self.config
        cap = cfg.max_batch if max_batch is None else max_batch
        if service_model is not None and hasattr(service_model,
                                                 "max_measured_batch"):
            cap = min(cap, service_model.max_measured_batch)
        params_np = jax.tree.map(np.asarray, self._split_params(params))
        fl = RealFleet(
            cfg.to_dict(), params_np,
            n_servers=cfg.n_servers if n_servers is None else n_servers,
            router=cfg.router if router is None else router,
            max_batch=max(1, cap), timeout_s=timeout_s, retries=retries,
            precompile=precompile, shaping=shaping)
        return fl.start() if start else fl


# ---------------------------------------------------------------------------
# Manifest CLI: python -m repro.deploy
# ---------------------------------------------------------------------------

def _verify_roundtrip(cfg: DeploymentConfig, *, seed: int = 0) -> None:
    """Assert a reloaded manifest reproduces identical encoder outputs and
    wire payloads (the ISSUE-3 acceptance criterion)."""
    import numpy as np
    cfg2 = DeploymentConfig.from_json(cfg.to_json())
    assert cfg2 == cfg, "manifest round-trip changed the config"
    dep, dep2 = Deployment.build(cfg), Deployment.build(cfg2)
    key = jax.random.PRNGKey(seed)
    params = dep.init(key)
    params2 = dep2.init(key)
    obs = jax.random.uniform(jax.random.PRNGKey(seed + 1),
                             (1, cfg.in_h, cfg.in_w,
                              cfg.spec.layers[0].c_in))
    np.testing.assert_array_equal(dep.encoder.apply(params, obs),
                                  dep2.encoder.apply(params2, obs))
    p1 = dep.split.edge_step(params["edge"], obs)
    p2 = dep2.split.edge_step(params2["edge"], obs)
    for k in p1:
        np.testing.assert_array_equal(p1[k], p2[k])


def _real_fleet_check(cfg: DeploymentConfig, *, n_requests: int = 8,
                      seed: int = 0) -> None:
    """Launch the manifest's real multi-process fleet on localhost, serve
    ``n_requests`` over sockets, and assert the actions are bitwise-equal
    to in-process serving — then shut down and assert no worker leaked."""
    import numpy as np
    dep = Deployment.build(cfg)
    params = dep.init(jax.random.PRNGKey(seed))
    client, server = dep.serving_pair(params)
    obs = jax.random.uniform(
        jax.random.PRNGKey(seed + 1),
        (n_requests, cfg.in_h, cfg.in_w, cfg.spec.layers[0].c_in))
    payloads = [client.encode_fn(obs[i:i + 1]) for i in range(n_requests)]
    want = [np.asarray(server.serve([p])[0]) for p in payloads]
    fleet = dep.fleet(params)
    try:
        got = [fleet.request(p, client=i) for i, p in enumerate(payloads)]
        per_server = list(fleet.stats["per_server"])
    finally:
        leaked = fleet.close()
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    assert not leaked, f"leaked worker processes: {leaked}"
    print(f"  real fleet: {cfg.n_servers} worker(s) via {cfg.router} served "
          f"{n_requests} requests over sockets (per-server {per_server}); "
          f"actions bitwise-equal to in-process serving; clean shutdown, "
          f"no leaked workers")


def _scenario_report(dep: "Deployment", name: str) -> None:
    """Run one registered scenario against this deployment and print the
    static-vs-adaptive scorecard (sim only — no processes spawned)."""
    from repro.serving.scenario import get_scenario
    sc = get_scenario(name)
    print(f"  scenario {sc.name}: link={sc.link_kind} seed={sc.seed} "
          f"devices={','.join(sc.devices)} N={sc.n_clients} "
          f"rate={sc.rate_hz}Hz horizon={sc.horizon_s}s "
          f"deadline={sc.deadline_s * 1e3:.0f}ms")
    policies = ([f"static:{i}" for i in range(len(sc.modes))]
                + (["rule"] if len(sc.modes) > 1 else []))
    for adapt in policies:
        rep = dep.scenario_sim(sc, adaptation=adapt).report(sc.n_clients)
        modes = " ".join(f"{k}={v}" for k, v in rep.mode_counts().items()
                         if v)
        print(f"    {adapt:<9} p95={rep.p95_s * 1e3:8.2f}ms "
              f"mean={rep.mean_s * 1e3:7.2f}ms "
              f"return={rep.delivered_return:.4f} "
              f"bytes={rep.total_uplink_bytes / 1e6:.3f}MB  [{modes}]")


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Build the standard deployment config, write its "
                    "manifest, reload it and verify the round-trip.")
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--c-in", type=int, default=12)
    ap.add_argument("--x", type=int, default=84, help="input H=W")
    ap.add_argument("--backend", default="fused",
                    help=f"one of: {', '.join(backend_names())}")
    ap.add_argument("--codec", default="uint8")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--n-servers", type=int, default=1,
                    help="fleet size for the sharded serving simulation")
    ap.add_argument("--router", default="round_robin",
                    help=f"fleet routing policy: {', '.join(ROUTERS)}")
    ap.add_argument("--out", default="deploy_manifest.json")
    ap.add_argument("--verify", action="store_true",
                    help="rebuild from the reloaded manifest and assert "
                         "identical encoder outputs and wire payloads")
    ap.add_argument("--tune", action="store_true",
                    help="autotune backend/tile_h/micro-batch for this "
                         "config (core.tuning) and freeze the winning "
                         "TunedPlan into the written manifest")
    ap.add_argument("--tune-iters", type=int, default=5,
                    help="timing repetitions per measured candidate")
    ap.add_argument("--real-fleet", action="store_true",
                    help="launch the manifest's REAL multi-process fleet "
                         "on localhost (n_servers worker processes behind "
                         "the configured router), verify socket-served "
                         "actions are bitwise-equal to in-process serving, "
                         "and shut down cleanly")
    ap.add_argument("--fleet-requests", type=int, default=8,
                    help="requests served during the --real-fleet check")
    ap.add_argument("--scenario", default=None,
                    help="run the manifest through a registered serving "
                         "scenario (repro.serving.scenario: seeded "
                         "adversarial link + device zoo) and print the "
                         "no-adaptation / per-static-mode / rule-"
                         "controller comparison")
    args = ap.parse_args(argv)

    cfg = DeploymentConfig.standard(k=args.k, c_in=args.c_in, h=args.x,
                                    backend=args.backend, codec=args.codec,
                                    max_batch=args.max_batch,
                                    n_servers=args.n_servers,
                                    router=args.router)
    if args.tune:
        from repro.core.tuning import tune
        print(f"  tuning {args.backend} X={args.x} "
              f"max_batch={args.max_batch} ...")
        tp = tune(cfg, iters=args.tune_iters, log=print)
        cfg = dataclasses.replace(cfg, tuning=tp)
        print(f"  tuned: backend={tp.backend} tile_h={tp.tile_h} "
              f"micro_batch={tp.micro_batch} "
              f"({tp.per_frame_s * 1e6:.1f} us/frame, mode={tp.mode}, "
              f"searched={tp.searched} pruned={tp.pruned})")
    dep = Deployment.build(cfg)
    for line in dep.build_log:
        print(f"  {line}")
    with open(args.out, "w") as f:
        f.write(cfg.to_json(indent=2))
    print(f"  wrote {args.out}")
    reloaded = DeploymentConfig.from_json(open(args.out).read())
    assert reloaded == cfg, "manifest on disk does not round-trip"
    print(f"  round-trip OK: backend={dep.backend.name} "
          f"plan={dep.plan.total_passes} passes "
          f"feature={dep.plan.feature_shape} wire={dep.wire_bytes}B "
          f"max_safe_batch={dep.max_safe_batch} "
          f"fleet={cfg.n_servers}x/{cfg.router}")
    if args.verify:
        _verify_roundtrip(cfg)
        print("  verified: reloaded manifest reproduces identical encoder "
              "outputs and wire payloads")
    if args.real_fleet:
        _real_fleet_check(reloaded, n_requests=args.fleet_requests)
    if args.scenario:
        _scenario_report(dep, args.scenario)


if __name__ == "__main__":
    main()


__all__ = ["CONFIG_VERSION", "Deployment", "DeploymentConfig"]
