"""Deterministic bandwidth-shaped link simulation.

Replaces the paper's ``tc netem``-shaped physical link: a serialising link
with finite bandwidth, fixed propagation delay, and (optional)
deterministic jitter.  Transfers are serialised FIFO — a transfer cannot
start before the previous one finished (token-bucket with depth one burst),
which is what bandwidth shaping does to a single TCP flow.

Jitter semantics (matching ``tc netem delay ... jitter``): jitter is extra
PROPAGATION delay on one transfer's arrival — it does NOT occupy the link,
so back-to-back transfers still serialise at exactly ``tx_time`` spacing.
The deterministic per-transfer pattern cycles 0.5x / 1.0x / 1.5x of
``jitter_s``, so the mean added delay is exactly ``jitter_s``.  Note that
with nonzero jitter, arrival order can differ from send order (as on a
real jittery link); the queue simulators all run jitter-free links.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class LinkTrace:
    start: float
    tx_done: float
    arrival: float
    payload_bytes: int


@dataclasses.dataclass
class ShapedLink:
    bandwidth_bps: float             # shaped bandwidth, bits/s
    propagation_s: float = 0.002     # one-way propagation delay
    jitter_s: float = 0.0            # deterministic per-transfer jitter
    _busy_until: float = 0.0
    _n: int = 0

    def tx_time(self, payload_bytes: int) -> float:
        return 8.0 * payload_bytes / self.bandwidth_bps

    def send(self, t: float, payload_bytes: int) -> LinkTrace:
        """Enqueue a transfer at time ``t``; returns timing trace.

        Jitter delays THIS transfer's arrival only — it never extends the
        link's busy window, so it cannot double-count into the
        serialisation of subsequent transfers.
        """
        start = max(t, self._busy_until)
        tx_done = start + self.tx_time(payload_bytes)
        self._busy_until = tx_done
        jitter = self.jitter_s * (0.5 + 0.5 * (self._n % 3))
        self._n += 1
        return LinkTrace(start=start, tx_done=tx_done,
                         arrival=tx_done + self.propagation_s + jitter,
                         payload_bytes=payload_bytes)

    def reset(self) -> None:
        self._busy_until = 0.0
        self._n = 0


MBPS = 1e6


def shaped(mbps: float, *, rtt_ms: float = 4.0) -> ShapedLink:
    return ShapedLink(bandwidth_bps=mbps * MBPS,
                      propagation_s=rtt_ms / 2000.0)
