"""Deterministic bandwidth-shaped link simulation.

Replaces the paper's ``tc netem``-shaped physical link: a serialising link
with finite bandwidth, fixed propagation delay, and (optional)
deterministic jitter.  Transfers are serialised FIFO — a transfer cannot
start before the previous one finished (token-bucket with depth one burst),
which is what bandwidth shaping does to a single TCP flow.

Jitter semantics (matching ``tc netem delay ... jitter``): jitter is extra
PROPAGATION delay on one transfer's arrival — it does NOT occupy the link,
so back-to-back transfers still serialise at exactly ``tx_time`` spacing.
The deterministic per-transfer pattern cycles 0.5x / 1.0x / 1.5x of
``jitter_s``, so the mean added delay is exactly ``jitter_s``.  Note that
with nonzero jitter, arrival order can differ from send order (as on a
real jittery link); the queue simulators all run jitter-free links.

Beyond the paper's single static uplink, this module carries the scenario
engine's adversarial link family (all ``ShapedLink``-compatible:
``send(t, payload_bytes) -> LinkTrace``, ``tx_time``, ``reset()``):

``TraceLink``
    Trace-driven piecewise-constant bandwidth schedule — transfers
    integrate bits across regime boundaries, so a payload straddling a
    dropout window pays for it exactly.
``MarkovLink``
    Seeded Markov regime-switching bandwidth (Wi-Fi rate-adaptation
    style): the link dwells in one of a few rate states and hops between
    them with a row-stochastic transition matrix every ``dwell_s``.
``LossyLink``
    Seeded Bernoulli loss with retransmit: a lost transfer re-occupies
    the link after an RTO gap (head-of-line blocking, as for one in-order
    TCP flow).
``StochasticJitterLink``
    ``ShapedLink`` whose per-transfer jitter draw is seeded-uniform on
    ``[0, 2 * jitter_s)`` (same ``jitter_s`` mean) instead of the
    deterministic 0.5x/1.0x/1.5x cycle.

Every stochastic link takes an explicit ``seed`` and ``reset()`` restores
the FULL initial state including the RNG — so one link instance re-used
across simulator runs or sizing sweeps replays the identical trace
(``QueueSim`` entry points call ``uplink.reset()`` for exactly this
reason).  ``LINK_KINDS`` / ``make_link`` is the registry the Scenario
schema uses to name link shapes in JSON.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np


@dataclasses.dataclass
class LinkTrace:
    start: float
    tx_done: float
    arrival: float
    payload_bytes: int


@dataclasses.dataclass
class ShapedLink:
    bandwidth_bps: float             # shaped bandwidth, bits/s
    propagation_s: float = 0.002     # one-way propagation delay
    jitter_s: float = 0.0            # deterministic per-transfer jitter
    _busy_until: float = 0.0
    _n: int = 0

    def tx_time(self, payload_bytes: int) -> float:
        return 8.0 * payload_bytes / self.bandwidth_bps

    def _jitter(self) -> float:
        """Per-transfer arrival jitter draw; mean is exactly ``jitter_s``."""
        return self.jitter_s * (0.5 + 0.5 * (self._n % 3))

    def send(self, t: float, payload_bytes: int) -> LinkTrace:
        """Enqueue a transfer at time ``t``; returns timing trace.

        Jitter delays THIS transfer's arrival only — it never extends the
        link's busy window, so it cannot double-count into the
        serialisation of subsequent transfers.
        """
        start = max(t, self._busy_until)
        tx_done = start + self.tx_time(payload_bytes)
        self._busy_until = tx_done
        jitter = self._jitter()
        self._n += 1
        return LinkTrace(start=start, tx_done=tx_done,
                         arrival=tx_done + self.propagation_s + jitter,
                         payload_bytes=payload_bytes)

    def reset(self) -> None:
        self._busy_until = 0.0
        self._n = 0


MBPS = 1e6


def shaped(mbps: float, *, rtt_ms: float = 4.0) -> ShapedLink:
    return ShapedLink(bandwidth_bps=mbps * MBPS,
                      propagation_s=rtt_ms / 2000.0)


@dataclasses.dataclass
class StochasticJitterLink(ShapedLink):
    """``ShapedLink`` with a seeded-uniform jitter draw on
    ``[0, 2 * jitter_s)`` — same ``jitter_s`` mean as the deterministic
    cycle, netem-style delay variation on arrival only."""
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def _jitter(self) -> float:
        return float(self._rng.uniform(0.0, 2.0 * self.jitter_s))

    def reset(self) -> None:
        super().reset()
        self._rng = np.random.default_rng(self.seed)


def _integrate_tx(bw_at: Callable[[float], float],
                  next_boundary: Callable[[float], float],
                  start: float, bits: float) -> float:
    """Finish time of a ``bits`` transfer starting at ``start`` under a
    piecewise-constant bandwidth ``bw_at(t)`` whose next regime boundary
    after ``t`` is ``next_boundary(t)`` (``inf`` for the final regime)."""
    t = start
    remaining = float(bits)
    while remaining > 0.0:
        bps = bw_at(t)
        bound = next_boundary(t)
        if bound == np.inf:
            if bps <= 0.0:
                raise ValueError("final link regime must have positive "
                                 "bandwidth (transfer would never finish)")
            return t + remaining / bps
        if bps > 0.0:
            capacity = bps * (bound - t)
            if capacity >= remaining:
                return t + remaining / bps
            remaining -= capacity
        t = bound
    return t


@dataclasses.dataclass
class TraceLink:
    """Trace-driven piecewise-constant bandwidth (dropouts, congestion
    windows).  ``schedule`` is ``((t_start_s, bandwidth_bps), ...)``,
    sorted, starting at t=0; the final segment extends forever.  Segments
    may have zero bandwidth (full outage) except the last.

    ``tx_time`` reports the transfer time at the NOMINAL (peak) rate —
    it is the downlink/action accounting hook, and the scenario engine
    deliberately applies the adversarial shaping to the uplink only,
    where the fat feature payloads flow.
    """
    schedule: tuple
    propagation_s: float = 0.002
    jitter_s: float = 0.0
    _busy_until: float = 0.0
    _n: int = 0

    def __post_init__(self):
        sched = tuple((float(t), float(b)) for t, b in self.schedule)
        if not sched:
            raise ValueError("TraceLink needs a non-empty schedule")
        if sched[0][0] != 0.0:
            raise ValueError("TraceLink schedule must start at t=0, got "
                             f"{sched[0][0]}")
        for (t0, _), (t1, _) in zip(sched, sched[1:]):
            if t1 <= t0:
                raise ValueError("TraceLink schedule times must be "
                                 f"strictly increasing, got {t0} -> {t1}")
        if any(b < 0.0 for _, b in sched):
            raise ValueError("TraceLink bandwidths must be >= 0")
        if sched[-1][1] <= 0.0:
            raise ValueError("TraceLink final segment must have positive "
                             "bandwidth")
        self.schedule = sched

    @property
    def nominal_bps(self) -> float:
        return max(b for _, b in self.schedule)

    def bandwidth_at(self, t: float) -> float:
        bps = self.schedule[0][1]
        for t0, b in self.schedule:
            if t0 > t:
                break
            bps = b
        return bps

    def _next_boundary(self, t: float) -> float:
        for t0, _ in self.schedule:
            if t0 > t:
                return t0
        return np.inf

    def tx_time(self, payload_bytes: int) -> float:
        return 8.0 * payload_bytes / self.nominal_bps

    def _jitter(self) -> float:
        return self.jitter_s * (0.5 + 0.5 * (self._n % 3))

    def send(self, t: float, payload_bytes: int) -> LinkTrace:
        start = max(t, self._busy_until)
        tx_done = _integrate_tx(self.bandwidth_at, self._next_boundary,
                                start, 8.0 * payload_bytes)
        self._busy_until = tx_done
        jitter = self._jitter()
        self._n += 1
        return LinkTrace(start=start, tx_done=tx_done,
                         arrival=tx_done + self.propagation_s + jitter,
                         payload_bytes=payload_bytes)

    def reset(self) -> None:
        self._busy_until = 0.0
        self._n = 0


@dataclasses.dataclass
class MarkovLink:
    """Seeded Markov regime-switching link (Wi-Fi rate-adaptation style).

    The link dwells ``dwell_s`` in one of ``states_bps`` and hops
    according to the row-stochastic ``transition`` matrix.  The state
    chain is generated lazily but strictly in chain order from one seeded
    generator, so the realised trace depends only on ``seed`` — never on
    the query pattern — and ``reset()`` replays it bitwise.
    """
    states_bps: tuple
    transition: tuple
    dwell_s: float = 0.25
    start_state: int = 0
    seed: int = 0
    propagation_s: float = 0.002
    jitter_s: float = 0.0

    def __post_init__(self):
        self.states_bps = tuple(float(b) for b in self.states_bps)
        if not self.states_bps or any(b <= 0.0 for b in self.states_bps):
            raise ValueError("MarkovLink states must all have positive "
                             "bandwidth (the lowest Wi-Fi MCS still moves "
                             "bits)")
        n = len(self.states_bps)
        rows = tuple(tuple(float(p) for p in row) for row in self.transition)
        if len(rows) != n or any(len(r) != n for r in rows):
            raise ValueError(f"transition must be {n}x{n}")
        for row in rows:
            if any(p < 0.0 for p in row) or abs(sum(row) - 1.0) > 1e-9:
                raise ValueError(f"transition rows must be stochastic: {row}")
        self.transition = rows
        if not 0 <= self.start_state < n:
            raise ValueError(f"start_state {self.start_state} out of range")
        if self.dwell_s <= 0.0:
            raise ValueError("dwell_s must be positive")
        self.reset()

    def reset(self) -> None:
        self._busy_until = 0.0
        self._n = 0
        self._rng = np.random.default_rng(self.seed)
        self._chain = [self.start_state]

    def _state_at(self, i: int) -> int:
        while len(self._chain) <= i:
            row = self.transition[self._chain[-1]]
            nxt = int(self._rng.choice(len(self.states_bps), p=row))
            self._chain.append(nxt)
        return self._chain[i]

    @property
    def nominal_bps(self) -> float:
        return max(self.states_bps)

    def bandwidth_at(self, t: float) -> float:
        return self.states_bps[self._state_at(max(0, int(t / self.dwell_s)))]

    def _next_boundary(self, t: float) -> float:
        return (int(t / self.dwell_s) + 1) * self.dwell_s

    def tx_time(self, payload_bytes: int) -> float:
        return 8.0 * payload_bytes / self.nominal_bps

    def _jitter(self) -> float:
        return self.jitter_s * (0.5 + 0.5 * (self._n % 3))

    def send(self, t: float, payload_bytes: int) -> LinkTrace:
        start = max(t, self._busy_until)
        tx_done = _integrate_tx(self.bandwidth_at, self._next_boundary,
                                start, 8.0 * payload_bytes)
        self._busy_until = tx_done
        jitter = self._jitter()
        self._n += 1
        return LinkTrace(start=start, tx_done=tx_done,
                         arrival=tx_done + self.propagation_s + jitter,
                         payload_bytes=payload_bytes)


@dataclasses.dataclass
class LossyLink:
    """Seeded Bernoulli loss with retransmit on a fixed-rate link.

    Each attempt occupies the link for the payload's ``tx_time``; a lost
    attempt waits ``rto_s`` and retransmits.  The link stays busy through
    the RTO gaps (head-of-line blocking: one in-order TCP flow).  After
    ``max_retries`` losses the transfer is delivered anyway — the sim
    models latency, not permanent failure.
    """
    bandwidth_bps: float
    loss_p: float = 0.0
    rto_s: float = 0.05
    max_retries: int = 8
    seed: int = 0
    propagation_s: float = 0.002

    def __post_init__(self):
        if not 0.0 <= self.loss_p < 1.0:
            raise ValueError(f"loss_p must be in [0, 1), got {self.loss_p}")
        self.reset()

    def reset(self) -> None:
        self._busy_until = 0.0
        self._n = 0
        self._rng = np.random.default_rng(self.seed)

    def tx_time(self, payload_bytes: int) -> float:
        return 8.0 * payload_bytes / self.bandwidth_bps

    def send(self, t: float, payload_bytes: int) -> LinkTrace:
        start = max(t, self._busy_until)
        tx = self.tx_time(payload_bytes)
        end = start + tx
        for _ in range(self.max_retries):
            if float(self._rng.random()) >= self.loss_p:
                break
            end = end + self.rto_s + tx    # retransmit after the RTO gap
        self._busy_until = end
        self._n += 1
        return LinkTrace(start=start, tx_done=end,
                         arrival=end + self.propagation_s,
                         payload_bytes=payload_bytes)


# --- link-kind registry (the Scenario schema names link shapes by kind) ---

LINK_KINDS: dict[str, Callable] = {}


def register_link_kind(name: str, builder: Callable) -> None:
    """``builder(seed, params: dict) -> link``; params are JSON-shaped."""
    LINK_KINDS[name] = builder


def make_link(kind: str, *, seed: int = 0, **params):
    """Build a registered link kind.  Seeded kinds receive ``seed`` unless
    ``params`` explicitly overrides it; static kinds ignore it."""
    if kind not in LINK_KINDS:
        raise KeyError(f"unknown link kind {kind!r}; registered: "
                       f"{sorted(LINK_KINDS)}")
    return LINK_KINDS[kind](seed, dict(params))


register_link_kind("static", lambda seed, p: ShapedLink(**p))
register_link_kind("trace", lambda seed, p: TraceLink(**p))
register_link_kind("markov",
                   lambda seed, p: MarkovLink(**{"seed": seed, **p}))
register_link_kind("lossy",
                   lambda seed, p: LossyLink(**{"seed": seed, **p}))
register_link_kind("jitter",
                   lambda seed, p: StochasticJitterLink(**{"seed": seed, **p}))
