"""Edge-client execution and the end-to-end decision loop.

``EdgeClient`` wraps the on-device half (a MiniConv encoder or the edge
stage of a split transformer) + wire codec.  ``DecisionLoop`` composes
client, link, and server into the paper's Figure-5 pipeline and measures
decision latency (observation available -> action received), either with
measured host wall-clock for the compute stages or with supplied stage
times.

Batched serving: each client still encodes and transmits ONE frame per
decision — micro-batching happens server-side across clients
(``repro.serving.server.BatchingPolicyServer``).  The batched encode path
(``EdgeClient.measure_batch``) is the trainer-side use of the same fused
kernel: replay minibatches run through one (B, H, W, C) launch instead of
B per-frame launches (see ``repro.rl.buffers.ReplayBuffer.sample``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.serving.netsim import ShapedLink
from repro.serving.server import PolicyServer, _block


@dataclasses.dataclass
class EdgeClient:
    """encode_fn(obs) -> payload dict; wire_bytes = bytes on the link."""

    encode_fn: Callable
    wire_bytes: int
    encode_time_s: Optional[float] = None

    def measure(self, example_obs, *, iters: int = 20,
                warmup: int = 2) -> float:
        # compile + warmup, blocked BEFORE the clock starts: jax dispatch
        # is async, so an unblocked warmup call would still be executing
        # inside the timed region and skew the per-frame time
        out = self.encode_fn(example_obs)
        for _ in range(warmup):
            out = self.encode_fn(example_obs)
        _block(out)
        t0 = time.perf_counter()
        for _ in range(iters):
            out = self.encode_fn(example_obs)
        _block(out)
        self.encode_time_s = (time.perf_counter() - t0) / iters
        return self.encode_time_s

    def measure_batch(self, example_obs, *, batch: int = 8,
                      iters: int = 10, warmup: int = 2) -> float:
        """Per-frame encode time when ``batch`` frames share one launch.

        ``example_obs`` is a single (1, H, W, C) observation; it is tiled
        along the leading axis, which the fused MiniConv kernel consumes as
        its outer grid dimension.  Returns seconds PER FRAME so the value
        is directly comparable to :meth:`measure`.
        """
        import jax.numpy as jnp
        obs = jnp.broadcast_to(example_obs[:1],
                               (batch,) + tuple(example_obs.shape[1:]))
        out = self.encode_fn(obs)
        for _ in range(warmup):
            out = self.encode_fn(obs)
        _block(out)
        t0 = time.perf_counter()
        for _ in range(iters):
            out = self.encode_fn(obs)
        _block(out)
        return (time.perf_counter() - t0) / (iters * batch)


@dataclasses.dataclass
class DecisionLoop:
    """One client against one server over a shaped link.

    ``split=True``  : obs -> edge encode -> tx(features) -> server head
    ``split=False`` : obs -> tx(raw frame) -> server (encoder + head)
    """

    link: ShapedLink
    server_time_s: float
    split: bool
    edge_time_s: float = 0.0
    payload_bytes: int = 0
    action_bytes: int = 64

    def decision_latency(self) -> float:
        t = 0.0
        if self.split:
            t += self.edge_time_s
        tr = self.link.send(t, self.payload_bytes)
        t = tr.arrival + self.server_time_s
        t += self.link.tx_time(self.action_bytes) + self.link.propagation_s
        return t

    def run(self, n_decisions: int = 1000) -> np.ndarray:
        """Sequential closed-loop decisions (the RL setting: the next
        observation exists only after the action returns)."""
        self.link.reset()
        lats = []
        for _ in range(n_decisions):
            lats.append(self.decision_latency())
            self.link.reset()   # closed loop: link idle between decisions
        return np.asarray(lats)

    def median_latency(self, n_decisions: int = 1000) -> float:
        return float(np.median(self.run(n_decisions)))
