"""Named device profiles: the heterogeneous hardware zoo.

The paper measures the split policy on three fixed edge devices — Jetson
Nano, Raspberry Pi 4B, Pi Zero 2W — each with its own batched service
curve t(B) and on-device encode time.  The scenario engine serves a
POPULATION of such devices: a :class:`DeviceProfile` names one hardware
class (its t(B) curve as :class:`~repro.serving.server.BatchServiceModel`
points plus its per-frame encode cost), ``DEVICE_PROFILES`` registers
them, and :func:`zoo` cycles named profiles across a fleet's servers so
``FleetQueueSim.service_models`` sees a heterogeneous fleet.

The shipped curves are paper-shaped reference values, not measurements
from this host: the Pi Zero 2W encode time matches the paper's ~0.1 s
MiniConv frame time at X=400 (see ``repro.core.latency
.paper_pi_zero_config``), the others scale by the devices' relative
compute, and every t(B) curve keeps the paper's qualitative shape —
near-flat batching gain on the GPU-backed Jetson, near-linear growth on
the CPU-bound Pis.  Re-measure with ``BatchingPolicyServer.measure`` and
:func:`register_profile` to pin real hardware.
"""
from __future__ import annotations

import dataclasses

from repro.serving.server import BatchServiceModel


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """One hardware class: batched service curve + on-device encode cost.

    ``service_points`` is the t(B) curve ((batch, seconds), ...) this
    device sustains when serving the remote half; ``encode_s`` is its
    per-frame on-device encoder time (what a client of this class pays
    before its payload hits the uplink).
    """
    name: str
    service_points: tuple
    encode_s: float
    notes: str = ""

    def __post_init__(self):
        object.__setattr__(self, "service_points",
                           tuple((int(b), float(t))
                                 for b, t in self.service_points))
        # constructor-validate the curve once, eagerly
        BatchServiceModel(self.service_points)
        if self.encode_s < 0.0:
            raise ValueError(f"encode_s must be >= 0: {self.encode_s}")

    def service_model(self, *, out_of_range: str = "extrapolate") \
            -> BatchServiceModel:
        return BatchServiceModel(self.service_points,
                                 out_of_range=out_of_range)


DEVICE_PROFILES: dict[str, DeviceProfile] = {}


def register_profile(profile: DeviceProfile) -> DeviceProfile:
    DEVICE_PROFILES[profile.name] = profile
    return profile


def get_profile(name: str) -> DeviceProfile:
    try:
        return DEVICE_PROFILES[name]
    except KeyError:
        raise KeyError(f"unknown device profile {name!r}; registered: "
                       f"{sorted(DEVICE_PROFILES)}") from None


def profile_names() -> tuple[str, ...]:
    return tuple(DEVICE_PROFILES)


def zoo(names, n_servers: int, *,
        out_of_range: str = "extrapolate") -> tuple:
    """Cycle named profiles across ``n_servers`` service models — the
    ``FleetQueueSim.service_models`` tuple for a heterogeneous fleet."""
    names = tuple(names)
    if not names:
        raise ValueError("zoo needs at least one profile name")
    profiles = [get_profile(n) for n in names]
    return tuple(profiles[s % len(profiles)]
                 .service_model(out_of_range=out_of_range)
                 for s in range(n_servers))


register_profile(DeviceProfile(
    name="jetson_nano",
    service_points=((1, 0.0040), (2, 0.0048), (4, 0.0062), (8, 0.0090)),
    encode_s=0.008,
    notes="GPU-backed: batching amortises launch overhead, t(B) near-flat"))

register_profile(DeviceProfile(
    name="pi_4b",
    service_points=((1, 0.0120), (2, 0.0190), (4, 0.0330), (8, 0.0610)),
    encode_s=0.033,
    notes="quad A72: moderate batching gain, then near-linear"))

register_profile(DeviceProfile(
    name="pi_zero_2w",
    service_points=((1, 0.0450), (2, 0.0850), (4, 0.1650), (8, 0.3250)),
    encode_s=0.100,
    notes="paper's ~0.1 s MiniConv frame time at X=400; t(B) near-linear"))

register_profile(DeviceProfile(
    name="workstation",
    service_points=((1, 0.0020), (2, 0.0022), (4, 0.0026), (8, 0.0034)),
    encode_s=0.002,
    notes="synthetic fast host: the near-ideal batching end of the zoo"))


__all__ = ["DeviceProfile", "DEVICE_PROFILES", "register_profile",
           "get_profile", "profile_names", "zoo"]
