"""Real multi-process serving fleet behind the router registry.

Everything fleet-shaped before this module was simulation:
:class:`~repro.serving.fleet.FleetQueueSim` *predicts* what ``n_servers``
micro-batching servers behind a router would do.  This module *runs* that
deployment on this host, so the sim's predictions can be validated against
wall-clock measurements (the DistrEdge-style sim-to-real calibration in
``benchmarks/realfleet.py``):

* :class:`WorkerServer` — one micro-batching policy server: a localhost
  TCP listener whose admission loop does CONTINUOUS batching (admit every
  request that arrived while the previous micro-batch was in service, up
  to ``max_batch`` — no fixed ``max_wait_ms`` hold; the running batch's
  service time IS the batching window).  Runs in-process for tests, or as
  the body of a spawned worker process (:func:`_worker_main`, which
  rebuilds the jitted server half from the deployment manifest — compiled
  functions cannot cross a process boundary).
* :class:`FleetClient` — the front door: one socket per worker, requests
  routed by the SAME registered policies the simulator uses
  (``repro.serving.fleet.ROUTERS``), with per-request timeouts and
  bounded retries that re-route around dead or stalled workers.
* :class:`RealFleet` — the process manager: spawns ``n_servers`` worker
  processes from one deployment manifest + parameter pytree, wires up a
  :class:`FleetClient`, and on :meth:`RealFleet.close` drains in-flight
  requests (graceful SHUTDOWN frame) before joining — returning the PIDs
  of any worker that had to be killed, so CI can gate on "no leaked
  workers".
* :func:`run_load` — the open-loop load generator (N clients at a fixed
  decision rate, the Table 6 protocol) whose latency sample feeds the
  measured-vs-predicted p95 calibration.

Wire format: length-prefixed frames (``!I`` byte count, then a 1-byte
message type + body) carrying the EXISTING wire-codec payloads —
:func:`pack_payload` serialises a codec payload dict (data tensor +
quantisation headers) such that :func:`unpack_payload` reproduces every
tensor bitwise, so the socket path is numerically identical to in-process
serving (asserted per codec in tests/test_realfleet.py).
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import queue
import socket
import struct
import threading
import time
from typing import Callable, Optional, Sequence, Union

import numpy as np

from repro.schema import check_version
from repro.serving.fleet import Router, get_router

SHAPING_VERSION = 1

# ---------------------------------------------------------------------------
# Framing: length-prefixed messages carrying wire-codec payloads
# ---------------------------------------------------------------------------

MSG_REQ = 1        # !I req_id + packed payload
MSG_RESP = 2       # !I req_id + !H served-batch-size + packed {"action": a}
MSG_ERR = 3        # !I req_id + utf-8 message
MSG_SHUTDOWN = 4   # empty body: drain queued requests, respond, exit


def _dtype_token(dtype: np.dtype) -> str:
    """Reversible wire name for a dtype.  ``dtype.str`` is
    endianness-explicit for every native dtype but collapses extension
    dtypes (``ml_dtypes.bfloat16``) to an opaque void — use the registered
    name for those."""
    return dtype.str if dtype.str[1] != "V" else dtype.name


def _dtype_from_token(token: str) -> np.dtype:
    try:
        return np.dtype(token)
    except TypeError:
        import ml_dtypes  # bf16 wire codec: extension dtypes by name
        return np.dtype(getattr(ml_dtypes, token))


def pack_payload(payload) -> bytes:
    """Serialise a wire-codec payload dict to bytes, bitwise-reversibly.

    Per tensor: key, dtype token (endianness-explicit), shape, then the
    raw C-order buffer.  Works for any codec's payload (data +
    scalar/per-channel quantisation headers alike).
    """
    parts = [struct.pack("!B", len(payload))]
    for key in sorted(payload):
        arr = np.asarray(payload[key])
        kb, db = key.encode(), _dtype_token(arr.dtype).encode()
        raw = arr.tobytes(order="C")
        parts += [struct.pack("!H", len(kb)), kb,
                  struct.pack("!H", len(db)), db,
                  struct.pack("!B", arr.ndim),
                  struct.pack(f"!{arr.ndim}I", *arr.shape),
                  struct.pack("!Q", len(raw)), raw]
    return b"".join(parts)


def unpack_payload(data: bytes) -> dict:
    """Inverse of :func:`pack_payload` (numpy arrays, bitwise-equal)."""
    (n,) = struct.unpack_from("!B", data, 0)
    off = 1
    out = {}
    for _ in range(n):
        (klen,) = struct.unpack_from("!H", data, off); off += 2
        key = data[off:off + klen].decode(); off += klen
        (dlen,) = struct.unpack_from("!H", data, off); off += 2
        dtype = _dtype_from_token(data[off:off + dlen].decode()); off += dlen
        (ndim,) = struct.unpack_from("!B", data, off); off += 1
        shape = struct.unpack_from(f"!{ndim}I", data, off); off += 4 * ndim
        (nbytes,) = struct.unpack_from("!Q", data, off); off += 8
        out[key] = np.frombuffer(data[off:off + nbytes],
                                 dtype=dtype).reshape(shape)
        off += nbytes
    return out


def _send_frame(sock: socket.socket, mtype: int, body: bytes = b"",
                lock: Optional[threading.Lock] = None) -> None:
    data = struct.pack("!IB", len(body) + 1, mtype) + body
    if lock is not None:
        with lock:
            sock.sendall(data)
    else:
        sock.sendall(data)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return bytes(buf)


def _recv_frame(sock: socket.socket):
    """(message type, body) or (None, None) on a clean EOF."""
    hdr = _recv_exact(sock, 4)
    if hdr is None:
        return None, None
    (length,) = struct.unpack("!I", hdr)
    data = _recv_exact(sock, length)
    if data is None:
        return None, None
    return data[0], data[1:]


# ---------------------------------------------------------------------------
# The worker: one continuous-batching policy server
# ---------------------------------------------------------------------------

_SHUTDOWN = object()


# ---------------------------------------------------------------------------
# Ingress shaping: token-bucket on the worker's request path
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapingConfig:
    """Token-bucket ingress shaping for one worker's socket.

    The sims model a bandwidth-shaped uplink in front of the fleet; raw
    localhost loopback has none, so calibration cells were only ever
    measured unshaped (the PR 7 caveat).  This config shapes each
    worker's REQUEST ingress to ``rate_mbps`` with a ``burst_bytes``
    bucket — the tc-tbf stand-in — and is stamped into
    ``BENCH_realfleet.json`` so shaped and unshaped measurements never
    get conflated.
    """
    rate_mbps: float
    burst_bytes: int = 16384

    def __post_init__(self):
        if self.rate_mbps <= 0.0:
            raise ValueError(f"rate_mbps must be > 0: {self.rate_mbps}")
        if self.burst_bytes < 1:
            raise ValueError(f"burst_bytes must be >= 1: {self.burst_bytes}")

    def to_dict(self) -> dict:
        return {"version": SHAPING_VERSION,
                "rate_mbps": self.rate_mbps,
                "burst_bytes": self.burst_bytes}

    @classmethod
    def from_dict(cls, d: dict) -> "ShapingConfig":
        check_version("ShapingConfig", d.get("version", SHAPING_VERSION),
                      (SHAPING_VERSION,))
        return cls(rate_mbps=float(d["rate_mbps"]),
                   burst_bytes=int(d.get("burst_bytes", 16384)))

    def bucket(self) -> "TokenBucket":
        return TokenBucket(rate_bps=self.rate_mbps * 1e6,
                           burst_bytes=self.burst_bytes)


class TokenBucket:
    """Thread-safe GCRA token bucket: ``reserve(nbytes)`` returns how
    long the caller must sleep before admitting ``nbytes``.

    Virtual-scheduling form: ``_tat`` is the theoretical arrival time of
    the NEXT conforming byte; a reservation pushes it forward by the
    payload's transmission time at ``rate_bps`` and the caller waits
    until the new ``_tat`` minus the burst allowance.  An idle bucket
    regains its full burst; the first ``burst_bytes`` always pass
    unshaped.  ``clock`` is injectable so tests run on virtual time.
    """

    def __init__(self, *, rate_bps: float, burst_bytes: int,
                 clock: Callable[[], float] = time.monotonic):
        if rate_bps <= 0.0:
            raise ValueError(f"rate_bps must be > 0: {rate_bps}")
        self._bytes_per_s = rate_bps / 8.0
        self._burst_s = burst_bytes / self._bytes_per_s
        self._tat = -np.inf          # full burst available at t=0
        self._clock = clock
        self._lock = threading.Lock()

    def reserve(self, nbytes: int) -> float:
        with self._lock:
            now = self._clock()
            tat = max(self._tat, now)
            self._tat = tat + nbytes / self._bytes_per_s
            return max(0.0, self._tat - self._burst_s - now)


@dataclasses.dataclass
class _Request:
    conn: socket.socket
    lock: threading.Lock
    req_id: int
    payload: dict


class WorkerServer:
    """One micro-batching policy server on a localhost TCP socket.

    ``serve_batch_fn`` maps a stacked payload dict (leading batch axis on
    every tensor, exactly ``repro.core.wire.stack_payloads``) to stacked
    actions — the same callable :class:`~repro.serving.server.
    BatchingPolicyServer` wraps in-process.

    Admission is CONTINUOUS batching: the serve loop blocks for the first
    request, then admits everything already queued (up to ``max_batch``)
    and launches immediately — requests arriving while a batch is in
    service queue up and form the next batch.  There is no ``max_wait``
    hold: the in-service batch is the batching window, so a lone client
    never waits out a timer (the batch-hold p95 dip the sims model away)
    and a loaded server still amortises t(B).

    A ``MSG_SHUTDOWN`` frame starts a graceful drain: every request
    already received is served and answered, then the loop exits.
    """

    def __init__(self, serve_batch_fn: Callable, *, max_batch: int = 8,
                 host: str = "127.0.0.1", port: int = 0,
                 shaper: Optional[TokenBucket] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1: {max_batch}")
        self.serve_batch_fn = serve_batch_fn
        self.max_batch = max_batch
        self.shaper = shaper
        self.shaped_sleep_s = 0.0
        self._host, self._port = host, port
        self._q: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self._draining = False
        self._conns: list[socket.socket] = []
        self.n_served = 0
        self.batch_sizes: list[int] = []
        self.addr: Optional[tuple[str, int]] = None

    # ---- lifecycle ---------------------------------------------------------
    def start(self) -> tuple[str, int]:
        """Bind, listen, and serve on background threads; returns the
        bound (host, port)."""
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((self._host, self._port))
        self._listener.listen()
        self.addr = self._listener.getsockname()
        self._accept_t = threading.Thread(target=self._accept_loop,
                                          daemon=True)
        self._serve_t = threading.Thread(target=self._serve_loop, daemon=True)
        self._accept_t.start()
        self._serve_t.start()
        return self.addr

    def join(self, timeout: Optional[float] = None) -> None:
        """Block until the serve loop exits (graceful drain or stop)."""
        self._serve_t.join(timeout)

    def stop(self) -> None:
        """Hard stop: abort the loop and drop every connection (used by
        tests to simulate a worker crash without a process kill)."""
        self._stop.set()
        with contextlib.suppress(OSError):
            self._listener.close()
        for c in self._conns:
            # shutdown() before close(): close() alone does not send FIN
            # while another thread is blocked in recv() on the same socket,
            # so peers would only notice via their request timeout
            with contextlib.suppress(OSError):
                c.shutdown(socket.SHUT_RDWR)
            with contextlib.suppress(OSError):
                c.close()

    # ---- socket side -------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._conns.append(conn)
            threading.Thread(target=self._reader, args=(conn,),
                             daemon=True).start()

    def _reader(self, conn: socket.socket) -> None:
        lock = threading.Lock()
        while not self._stop.is_set():
            try:
                mtype, body = _recv_frame(conn)
            except OSError:
                return
            if mtype is None:
                return
            if mtype == MSG_SHUTDOWN:
                self._q.put(_SHUTDOWN)
                return
            if mtype == MSG_REQ:
                if self.shaper is not None:
                    # ingress shaping: hold the frame (and, like a backed-
                    # up pipe, everything behind it on this connection)
                    # until the bucket admits its bytes.  All connections
                    # share one bucket — the worker's front door.
                    wait = self.shaper.reserve(len(body))
                    if wait > 0.0:
                        self.shaped_sleep_s += wait
                        time.sleep(wait)
                (req_id,) = struct.unpack_from("!I", body)
                self._q.put(_Request(conn, lock, req_id,
                                     unpack_payload(body[4:])))

    # ---- the continuous-batching admission loop ----------------------------
    def _admit(self) -> Optional[list[_Request]]:
        """Next micro-batch, or None when stopped / drained.

        Blocks for the first request, then sweeps the queue WITHOUT
        waiting: whatever arrived during the previous batch's service is
        admitted now (capped at ``max_batch``); later arrivals go to the
        next batch.
        """
        batch: list[_Request] = []
        while not batch:
            if self._stop.is_set():
                return None
            try:
                item = self._q.get(timeout=0.05)
            except queue.Empty:
                if self._draining:
                    return None
                continue
            if item is _SHUTDOWN:
                self._draining = True
                continue
            batch.append(item)
        while len(batch) < self.max_batch:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                break
            if item is _SHUTDOWN:
                self._draining = True
                break
            batch.append(item)
        return batch

    def _serve_loop(self) -> None:
        while True:
            batch = self._admit()
            if batch is None:
                break
            self._serve(batch)
        self._stop.set()
        with contextlib.suppress(OSError):
            self._listener.close()

    def _serve(self, batch: list[_Request]) -> None:
        stacked = {k: np.stack([r.payload[k] for r in batch])
                   for k in batch[0].payload}
        try:
            out = np.asarray(self.serve_batch_fn(stacked))
        except Exception as e:  # repro: allow(broad-except) -- serve_batch_fn is arbitrary user code; answer MSG_ERR rather than hang the clients
            msg = f"{type(e).__name__}: {e}".encode()[:2000]
            for r in batch:
                with contextlib.suppress(OSError):
                    _send_frame(r.conn, MSG_ERR,
                                struct.pack("!I", r.req_id) + msg, r.lock)
            return
        for i, r in enumerate(batch):
            body = struct.pack("!IH", r.req_id, len(batch)) \
                + pack_payload({"action": out[i]})
            with contextlib.suppress(OSError):
                _send_frame(r.conn, MSG_RESP, body, r.lock)
        self.n_served += len(batch)
        self.batch_sizes.append(len(batch))


def _worker_main(manifest: dict, params, max_batch: int, conn,
                 precompile: bool = True,
                 shaping: Optional[dict] = None) -> None:
    """Entry point of one spawned worker process.

    Rebuilds the jitted server half from the deployment manifest (jitted
    callables cannot cross a process boundary; the manifest + numpy
    parameter pytree can), optionally pre-compiles every admissible batch
    shape so the first live micro-batches are not compile-skewed, then
    reports its bound (host, port) through ``conn`` and serves until a
    SHUTDOWN frame drains it.
    """
    from repro.deploy import Deployment, DeploymentConfig  # noqa: import in child
    cfg = DeploymentConfig.from_dict(manifest)
    dep = Deployment.build(cfg)
    serve = dep.server_batch_fn(params)
    if precompile:
        edge = dep.split.edge_step(
            Deployment._split_params(params)["edge"],
            np.zeros((1, cfg.in_h, cfg.in_w, cfg.spec.layers[0].c_in),
                     np.float32))
        # per-request payloads keep their leading 1-axis (stacking matches
        # wire.stack_payloads: the micro-batch is (B, 1, ...))
        example = {k: np.asarray(v) for k, v in edge.items()}
        for b in range(1, max_batch + 1):
            np.asarray(serve({k: np.stack([v] * b)
                              for k, v in example.items()}))
    shaper = (ShapingConfig.from_dict(shaping).bucket()
              if shaping is not None else None)
    ws = WorkerServer(serve, max_batch=max_batch, shaper=shaper)
    conn.send(ws.start())
    conn.close()
    ws.join()


# ---------------------------------------------------------------------------
# The front door: router + retries over per-worker sockets
# ---------------------------------------------------------------------------

class FleetTimeout(Exception):
    """A request exhausted its per-attempt timeout and retry budget."""


class FleetError(Exception):
    """The worker answered with an error frame."""


class _Pending:
    __slots__ = ("event", "result", "error", "batch")

    def __init__(self):
        self.event = threading.Event()
        self.result = None
        self.error = None
        self.batch = 0


class _ServerConn:
    """One worker connection: framed send + a reader thread matching
    responses to pending requests by id."""

    def __init__(self, addr: tuple[str, int], *, connect_timeout_s: float):
        self.addr = addr
        self.sock = socket.create_connection(addr, timeout=connect_timeout_s)
        self.sock.settimeout(None)
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._send_lock = threading.Lock()
        self._plock = threading.Lock()
        self._pending: dict[int, _Pending] = {}
        self.alive = True
        self.n_sent = 0
        threading.Thread(target=self._reader, daemon=True).start()

    @property
    def n_outstanding(self) -> int:
        with self._plock:
            return len(self._pending)

    def request_async(self, req_id: int, payload_bytes: bytes) -> _Pending:
        p = _Pending()
        with self._plock:
            self._pending[req_id] = p
        try:
            _send_frame(self.sock, MSG_REQ,
                        struct.pack("!I", req_id) + payload_bytes,
                        self._send_lock)
        except OSError as e:
            self.forget(req_id)
            self._fail_all(ConnectionError(f"send to {self.addr}: {e}"))
            raise ConnectionError(str(e)) from e
        self.n_sent += 1
        return p

    def forget(self, req_id: int) -> None:
        with self._plock:
            self._pending.pop(req_id, None)

    def _fail_all(self, err: Exception) -> None:
        self.alive = False
        with self._plock:
            pending, self._pending = dict(self._pending), {}
        for p in pending.values():
            p.error = err
            p.event.set()

    def _reader(self) -> None:
        while True:
            try:
                mtype, body = _recv_frame(self.sock)
            except OSError as e:
                self._fail_all(ConnectionError(f"recv from {self.addr}: {e}"))
                return
            if mtype is None:
                self._fail_all(ConnectionError(
                    f"worker at {self.addr} closed the connection"))
                return
            if mtype == MSG_RESP:
                req_id, batch = struct.unpack_from("!IH", body)
                with self._plock:
                    p = self._pending.pop(req_id, None)
                if p is not None:
                    p.result = unpack_payload(body[6:])["action"]
                    p.batch = batch
                    p.event.set()
            elif mtype == MSG_ERR:
                (req_id,) = struct.unpack_from("!I", body)
                with self._plock:
                    p = self._pending.pop(req_id, None)
                if p is not None:
                    p.error = FleetError(body[4:].decode(errors="replace"))
                    p.event.set()

    def send_shutdown(self) -> None:
        with contextlib.suppress(OSError):
            _send_frame(self.sock, MSG_SHUTDOWN, b"", self._send_lock)

    def close(self) -> None:
        # shutdown() wakes our reader thread (close() alone would leave it
        # blocked in recv and the fd open)
        with contextlib.suppress(OSError):
            self.sock.shutdown(socket.SHUT_RDWR)
        with contextlib.suppress(OSError):
            self.sock.close()


class FleetClient:
    """Routes requests to a set of live workers through the registered
    routing policies, with per-request timeouts and bounded retries.

    The router sees the same view the simulator gives it — per-server
    outstanding counts as ``queue_lens`` and a busy/idle ``free`` estimate
    (``now`` when idle, ``now + outstanding * est_service_s`` when busy;
    wall-clock cannot observe a remote server's true free time).  A retry
    excludes the failed server and re-routes; a connection error marks the
    worker dead for all subsequent requests.
    """

    def __init__(self, addrs: Sequence[tuple[str, int]], *,
                 router: Union[str, Router] = "round_robin",
                 timeout_s: float = 10.0, retries: int = 2,
                 est_service_s: float = 1e-3,
                 connect_timeout_s: float = 10.0):
        self.conns = [_ServerConn(a, connect_timeout_s=connect_timeout_s)
                      for a in addrs]
        self.set_router(router)
        self.timeout_s = timeout_s
        self.retries = retries
        self.est_service_s = est_service_s
        self._seq = itertools.count()       # routing sequence (sim's `seq`)
        self._ids = itertools.count()       # wire request ids
        self.stats = {"requests": 0, "retries": 0, "timeouts": 0,
                      "errors": 0, "per_server": [0] * len(addrs),
                      "max_served_batch": 0}

    @property
    def n_servers(self) -> int:
        return len(self.conns)

    def set_router(self, router: Union[str, Router]) -> None:
        self.router = router
        self._route = get_router(router)

    def _pick(self, client: int, seq: int, tried: set) -> Optional[int]:
        avail = [s for s in range(self.n_servers)
                 if self.conns[s].alive and s not in tried]
        if not avail:
            return None
        now = time.monotonic()
        queue_lens = [c.n_outstanding for c in self.conns]
        free = [now + queue_lens[s] * self.est_service_s
                if queue_lens[s] else now for s in range(self.n_servers)]
        s = self._route(client, seq, now, queue_lens, free)
        if s in avail:
            return s
        # the registered routers know nothing about dead/excluded workers;
        # snap to the least-loaded available one deterministically
        return min(avail, key=lambda x: (queue_lens[x], x))

    def request(self, payload, *, client: int = 0,
                timeout_s: Optional[float] = None) -> np.ndarray:
        """Send one request, wait for its action; retries re-route.

        ``payload`` is a wire-codec payload dict (or pre-packed bytes —
        the load generator packs once and reuses the buffer).
        """
        body = payload if isinstance(payload, bytes) else pack_payload(payload)
        timeout = self.timeout_s if timeout_s is None else timeout_s
        self.stats["requests"] += 1
        tried: set[int] = set()
        last_err: Optional[Exception] = None
        seq = next(self._seq)
        for attempt in range(self.retries + 1):
            if attempt:
                self.stats["retries"] += 1
            s = self._pick(client, seq, tried)
            if s is None:
                break
            req_id = next(self._ids)
            try:
                p = self.conns[s].request_async(req_id, body)
            except ConnectionError as e:
                last_err, tried = e, tried | {s}
                continue
            self.stats["per_server"][s] += 1
            if not p.event.wait(timeout):
                self.conns[s].forget(req_id)
                self.stats["timeouts"] += 1
                last_err = FleetTimeout(
                    f"server {s} {self.conns[s].addr}: no response in "
                    f"{timeout:.2f}s")
                tried.add(s)
                continue
            if p.error is not None:
                last_err, tried = p.error, tried | {s}
                if isinstance(p.error, FleetError):
                    self.stats["errors"] += 1
                continue
            self.stats["max_served_batch"] = max(
                self.stats["max_served_batch"], p.batch)
            return p.result
        raise FleetTimeout(
            f"request failed after {self.retries + 1} attempt(s) across "
            f"servers {sorted(tried) or 'none-available'}: {last_err}") \
            from last_err

    def shutdown(self, *, wait_pending_s: float = 10.0) -> None:
        """Graceful drain: SHUTDOWN every worker, wait for in-flight
        responses, then close the sockets."""
        for c in self.conns:
            if c.alive:
                c.send_shutdown()
        deadline = time.monotonic() + wait_pending_s
        for c in self.conns:
            while c.alive and c.n_outstanding \
                    and time.monotonic() < deadline:
                time.sleep(0.01)
        for c in self.conns:
            c.close()


# ---------------------------------------------------------------------------
# The process manager
# ---------------------------------------------------------------------------

class RealFleet:
    """``n_servers`` spawned worker processes + a routed front door.

    Built from ONE deployment manifest dict and a numpy parameter pytree
    (both picklable across the spawn boundary; each worker rebuilds its
    jitted server half via ``Deployment.build``).  Use
    :meth:`~repro.deploy.Deployment.fleet` to construct from a built
    deployment, or this class directly with a manifest.
    """

    def __init__(self, manifest: dict, params, *, n_servers: int = 1,
                 router: Union[str, Router] = "round_robin",
                 max_batch: int = 8, timeout_s: float = 10.0,
                 retries: int = 2, precompile: bool = True,
                 shaping: Optional[Union[ShapingConfig, dict]] = None,
                 mp_context: str = "spawn"):
        if n_servers < 1:
            raise ValueError(f"n_servers must be >= 1: {n_servers}")
        self.manifest = dict(manifest)
        self.params = params
        self.n_servers = n_servers
        self.router = router
        self.max_batch = max_batch
        self.timeout_s = timeout_s
        self.retries = retries
        self.precompile = precompile
        if isinstance(shaping, dict):
            shaping = ShapingConfig.from_dict(shaping)
        self.shaping = shaping
        self._mp_context = mp_context
        self.processes: list = []
        self.client: Optional[FleetClient] = None
        self.closed = False

    # ---- lifecycle ---------------------------------------------------------
    def start(self, *, start_timeout_s: float = 120.0) -> "RealFleet":
        """Spawn the workers, collect their ports, connect the client."""
        import multiprocessing as mp
        ctx = mp.get_context(self._mp_context)
        pipes = []
        for _ in range(self.n_servers):
            parent_conn, child_conn = ctx.Pipe(duplex=False)
            p = ctx.Process(target=_worker_main,
                            args=(self.manifest, self.params, self.max_batch,
                                  child_conn, self.precompile,
                                  None if self.shaping is None
                                  else self.shaping.to_dict()),
                            daemon=True)
            p.start()
            child_conn.close()
            self.processes.append(p)
            pipes.append(parent_conn)
        addrs = []
        deadline = time.monotonic() + start_timeout_s
        try:
            for i, conn in enumerate(pipes):
                # poll in short slices so a worker that died during startup
                # fails the launch immediately instead of eating the full
                # start timeout
                while not conn.poll(0.2):
                    p = self.processes[i]
                    if not p.is_alive():
                        raise RuntimeError(
                            f"worker {i} (pid {p.pid}) died during startup "
                            f"(exitcode={p.exitcode}); spawned workers "
                            f"re-import the parent __main__ module — run "
                            f"from a file/pytest, not stdin")
                    if time.monotonic() > deadline:
                        raise RuntimeError(
                            f"worker {i} (pid {p.pid}) did not report a "
                            f"port within {start_timeout_s:.0f}s")
                addrs.append(conn.recv())
                conn.close()
        except BaseException:
            self._kill_all()
            raise
        self.client = FleetClient(addrs, router=self.router,
                                  timeout_s=self.timeout_s,
                                  retries=self.retries)
        return self

    def __enter__(self) -> "RealFleet":
        return self if self.client is not None else self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ---- serving -----------------------------------------------------------
    def request(self, payload, *, client: int = 0,
                timeout_s: Optional[float] = None) -> np.ndarray:
        if self.client is None:
            raise RuntimeError("fleet not started (call start())")
        return self.client.request(payload, client=client,
                                   timeout_s=timeout_s)

    def set_router(self, router: Union[str, Router]) -> None:
        """Switch the front door's routing policy (workers are untouched —
        routing is a parent-side decision, exactly as in the sim)."""
        self.router = router
        if self.client is not None:
            self.client.set_router(router)

    @property
    def stats(self) -> dict:
        return {} if self.client is None else self.client.stats

    # ---- shutdown ----------------------------------------------------------
    def _kill_all(self) -> None:
        for p in self.processes:
            if p.is_alive():
                p.terminate()
        for p in self.processes:
            if p.is_alive():
                p.join(timeout=2.0)
            if p.is_alive():
                p.kill()
                p.join(timeout=2.0)

    def close(self, *, grace_s: float = 15.0) -> list[int]:
        """Graceful shutdown: drain in-flight requests, join the workers.

        Returns the PIDs of workers that did NOT exit gracefully and had
        to be terminated — the CI leak gate asserts this is empty.
        """
        if self.closed:
            return []
        self.closed = True
        if self.client is not None:
            self.client.shutdown(wait_pending_s=grace_s)
        deadline = time.monotonic() + grace_s
        for p in self.processes:
            p.join(timeout=max(0.1, deadline - time.monotonic()))
        leaked = [p.pid for p in self.processes if p.is_alive()]
        self._kill_all()
        return leaked


# ---------------------------------------------------------------------------
# Open-loop load generation (the Table 6 protocol, for real)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LoadReport:
    """Latency sample from one :func:`run_load` run."""

    latencies_s: np.ndarray        # decision latency per completed request
    n_requests: int
    n_failures: int
    duration_s: float
    failures: tuple = ()

    def p95(self) -> float:
        if self.latencies_s.size == 0:
            return float("inf")
        return float(np.percentile(self.latencies_s, 95))

    def p50(self) -> float:
        if self.latencies_s.size == 0:
            return float("inf")
        return float(np.percentile(self.latencies_s, 50))


def run_load(client: FleetClient, payload, *, n_clients: int = 8,
             rate_hz: float = 10.0, duration_s: float = 2.0,
             timeout_s: Optional[float] = None) -> LoadReport:
    """N clients issuing requests at a fixed rate against the fleet.

    Mirrors ``QueueSim._request_arrivals``: clients are staggered by
    ``period / n_clients`` and each issues every ``period`` seconds.
    Latency is measured from the SCHEDULED observation time to response
    receipt (so a backlog at the client counts against latency, exactly
    as queueing does in the sim).  The payload is packed once and the
    same bytes are reused for every request — load generation must not
    contend with the workers for compute.
    """
    body = payload if isinstance(payload, bytes) else pack_payload(payload)
    period = 1.0 / rate_hz
    t_start = time.monotonic() + 0.05
    lats: list[float] = []
    failures: list[tuple] = []

    def client_loop(c: int) -> None:
        # schedule in offsets from t_start, NOT by accumulating onto the
        # monotonic clock: adding `period` to a large clock value rounds
        # differently depending on the host's uptime, which made the
        # request COUNT (k*period < duration) machine-state-dependent
        offset = c * period / n_clients
        k = 0
        while offset + k * period < duration_s:
            t_k = t_start + offset + k * period
            now = time.monotonic()
            if now < t_k:
                time.sleep(t_k - now)
            try:
                client.request(body, client=c, timeout_s=timeout_s)
                lats.append(time.monotonic() - t_k)
            except (FleetTimeout, FleetError, ConnectionError) as e:
                failures.append((c, t_k - t_start, repr(e)))
            k += 1

    threads = [threading.Thread(target=client_loop, args=(c,))
               for c in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return LoadReport(latencies_s=np.asarray(sorted(lats), float),
                      n_requests=len(lats) + len(failures),
                      n_failures=len(failures), duration_s=duration_s,
                      failures=tuple(failures))


__all__ = ["FleetClient", "FleetError", "FleetTimeout", "LoadReport",
           "RealFleet", "ShapingConfig", "TokenBucket", "WorkerServer",
           "pack_payload", "run_load", "unpack_payload", "MSG_REQ",
           "MSG_RESP", "MSG_ERR", "MSG_SHUTDOWN"]
