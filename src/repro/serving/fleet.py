"""Fleet-scale serving simulation: ``n_servers`` sharded micro-batching.

The paper's Table 6 saturates ONE server at ~10^2 clients; the road to
"heavy traffic from millions of users" is horizontal: ``n_servers``
independent micro-batching servers behind one routing layer.
:class:`FleetQueueSim` extends :class:`~repro.serving.server.BatchQueueSim`
into that fleet:

* every client's observation still crosses the SHARED shaped uplink (the
  bandwidth-shaped ingress in front of the fleet — uploads serialise
  FIFO exactly as in the single-server sims);
* on arrival each request is routed to one of ``n_servers`` servers by a
  pluggable policy (``ROUTERS`` registry): ``round_robin`` (stateless
  spreading), ``least_loaded`` (fewest outstanding requests, then
  earliest-free), or ``client_affinity`` (deterministic hash of the
  client id, so one client's requests always hit the same server and
  their actions return in order);
* each server runs the SAME micro-batching policy as ``BatchQueueSim``
  (greedy launch up to ``max_batch``, optional ``max_wait_s`` hold),
  charges its OWN measured t(B) service curve, and returns its batch's
  actions over its OWN serialised downlink.

With ``n_servers=1`` every router degenerates to "server 0" and the
event-driven engine reproduces ``BatchQueueSim.latencies`` bitwise
(asserted in tests/test_fleet.py), so the fleet numbers are anchored to
the single-server Table 6 reproduction.

Fleet sizing (the capacity-planning questions Table 6 cannot answer):

* :meth:`FleetQueueSim.max_clients` — supported clients at a fixed fleet
  size (geometric + binary search over the monotone p95 curve, so fleet
  sweeps stay tractable at thousands of clients);
* :meth:`FleetQueueSim.min_servers` — smallest fleet meeting a p95
  budget for a target client population.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Callable, Optional, Sequence, Union

import numpy as np

from repro.serving.server import BatchQueueSim

# ---------------------------------------------------------------------------
# Routing policies
# ---------------------------------------------------------------------------
# A router maps one request to a server index.  Signature:
#     router(client, seq, t_arrival, queue_lens, free) -> int
# ``client`` is the client id, ``seq`` the global arrival sequence number,
# ``t_arrival`` the request's post-uplink arrival time, ``queue_lens[s]``
# the number of requests queued (not yet launched) at server s, and
# ``free[s]`` the time server s finishes its current batch.  Routers must
# be deterministic: the simulators are regression-pinned.

Router = Callable[[int, int, float, Sequence[int], Sequence[float]], int]

ROUTERS: dict[str, Router] = {}


def register_router(name: str, fn: Router) -> Router:
    """Register a routing policy (also usable as a plug-in point)."""
    ROUTERS[name] = fn
    return fn


def router_names() -> tuple[str, ...]:
    return tuple(ROUTERS)


def get_router(router: Union[str, Router]) -> Router:
    if callable(router):
        return router
    try:
        return ROUTERS[router]
    except KeyError:
        raise ValueError(f"unknown router {router!r}; registered: "
                         f"{', '.join(ROUTERS)}") from None


def _mix32(c: int) -> int:
    """Deterministic 32-bit integer mix (xor-shift-multiply finaliser).

    Python's ``hash`` is salted per process for str and identity for
    small ints (which would make power-of-two fleets route ``c % n`` —
    fine for balance, useless as a hash); this mix is stable across
    runs and platforms, so affinity pinning survives restarts exactly
    like a consistent-hash LB tier.
    """
    c &= 0xffffffff
    c = ((c ^ (c >> 16)) * 0x45d9f3b) & 0xffffffff
    c = ((c ^ (c >> 16)) * 0x45d9f3b) & 0xffffffff
    return (c ^ (c >> 16)) & 0xffffffff


def _round_robin(client, seq, t, queue_lens, free):
    return seq % len(free)


def _client_affinity(client, seq, t, queue_lens, free):
    return _mix32(client) % len(free)


def _least_loaded(client, seq, t, queue_lens, free):
    # outstanding work = queued requests + the in-flight batch (1 if the
    # server is still busy at arrival time); earliest-free then lowest
    # index break ties deterministically
    return min(range(len(free)),
               key=lambda s: (queue_lens[s] + (1 if free[s] > t else 0),
                              max(free[s] - t, 0.0), s))


register_router("round_robin", _round_robin)
register_router("client_affinity", _client_affinity)
register_router("least_loaded", _least_loaded)


# ---------------------------------------------------------------------------
# The fleet simulator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FleetQueueSim(BatchQueueSim):
    """``n_servers`` sharded :class:`BatchQueueSim` behind one router.

    ``service_model`` (shared) or ``service_models`` (one t(B) curve per
    server, for heterogeneous fleets) give each server its service-time
    curve; each server also owns a serialised downlink with the uplink's
    symmetric parameters.  The uplink itself — the shaped ingress — stays
    shared across the whole fleet.
    """

    n_servers: int = 1
    router: Union[str, Router] = "round_robin"
    service_models: Optional[Sequence[Callable[[int], float]]] = None

    def _server_service(self, s: int) -> Callable[[int], float]:
        if self.service_models is not None:
            if len(self.service_models) != self.n_servers:
                raise ValueError(
                    f"{len(self.service_models)} service models for "
                    f"{self.n_servers} servers")
            return self.service_models[s]
        return self.service

    # ---- the event-driven engine ------------------------------------------
    engine: str = "heap"          # "heap" (next-event queue) | "scan" (ref)

    def _simulate(self, n_clients: int) -> np.ndarray:
        """Structured per-request trace, in observation order.

        Columns: client, server, t_obs, arrival, recv.  Events are
        processed in time order — request arrivals (routed immediately)
        interleaved with per-server batch launches — with arrivals at
        time t handled before launches at time t, matching the inclusive
        ``arrival <= launch`` batch-fill rule of ``BatchQueueSim``.

        Two engines compute the identical trace: ``heap`` (default) keeps
        the pending per-server launches in a lazily-revalidated
        ``heapq`` next-event queue — O(log S) per event — while ``scan``
        (the reference) recomputes every server's launch time per event,
        O(S); the O(events x S) scan dominates wall time past ~32
        servers.  Bitwise equality of the two engines is asserted in
        tests/test_fleet.py.
        """
        if self.n_servers < 1:
            raise ValueError(f"n_servers must be >= 1: {self.n_servers}")
        if self.engine not in ("heap", "scan"):
            raise ValueError(f"unknown engine {self.engine!r}; "
                             f"one of: heap, scan")
        route = get_router(self.router)
        arr = self._request_arrivals(n_clients)
        n, S = len(arr), self.n_servers
        service = [self._server_service(s) for s in range(S)]
        free = [0.0] * S
        down_free = [0.0] * S
        queues: list[deque] = [deque() for _ in range(S)]
        n_queued = [0] * S
        trace = np.zeros(n, dtype=[("client", np.int64),
                                   ("server", np.int64),
                                   ("t_obs", np.float64),
                                   ("arrival", np.float64),
                                   ("recv", np.float64)])
        ptr = 0                      # next unrouted request (arrival order)

        def launch_time(s: int) -> float:
            """Earliest launch at server s given what has been routed.

            Mirrors BatchQueueSim: greedy launches as soon as the server
            is free and work exists; with a hold, launch when the batch
            fills or the deadline expires, whichever is first.  A later
            arrival can only move the launch EARLIER (by filling the
            batch), and arrivals are processed first, so scheduling off
            currently-routed requests is exact.
            """
            q = queues[s]
            ready = max(free[s], q[0][1])
            if self.max_wait_s <= 0.0:
                return ready
            if len(q) >= self.max_batch:
                return max(ready, min(q[self.max_batch - 1][1],
                                      ready + self.max_wait_s))
            return ready + self.max_wait_s

        # ---- next-launch selection: heap vs scan --------------------------
        # Heap entries are (launch_time, server).  launch_time(s) only
        # changes when a request is routed to s or s launches a batch,
        # and BOTH events push a fresh entry — so the current value is
        # always present and any entry that disagrees with launch_time(s)
        # is stale and simply dropped on peek (classic lazy deletion;
        # re-pushing a correction here instead would duplicate the
        # current entry per stale and grow the heap quadratically on
        # saturated servers).  Ties break on the lower server index in
        # both engines ((t, s) tuple order == the scan's strict-<
        # first-s-wins).
        heap: list[tuple[float, int]] = []

        def heap_push(s: int) -> None:
            if queues[s]:
                heapq.heappush(heap, (launch_time(s), s))

        def next_launch_heap():
            while heap:
                t, s = heap[0]
                if not queues[s] or launch_time(s) != t:
                    heapq.heappop(heap)           # stale: drop, the push
                    continue                      # at the last schedule
                return s, t                       # change supersedes it
            return -1, np.inf

        def next_launch_scan():
            best_s, best_launch = -1, np.inf
            for s in range(S):
                if not queues[s]:
                    continue
                launch = launch_time(s)
                if launch < best_launch:
                    best_s, best_launch = s, launch
            return best_s, best_launch

        use_heap = self.engine == "heap"
        next_launch = next_launch_heap if use_heap else next_launch_scan

        while ptr < n or any(n_queued):
            best_s, best_launch = next_launch()
            if ptr < n and arr[ptr][1] <= best_launch:
                t_obs, arrival, client = arr[ptr]
                s = route(client, ptr, arrival, n_queued, free)
                if not 0 <= s < S:
                    raise ValueError(f"router sent request to server {s} "
                                     f"of {S}")
                queues[s].append((t_obs, arrival, ptr))
                n_queued[s] += 1
                ptr += 1
                if use_heap:
                    heap_push(s)
                continue
            q = queues[best_s]
            batch = []
            while q and len(batch) < self.max_batch \
                    and q[0][1] <= best_launch:
                batch.append(q.popleft())
            n_queued[best_s] -= len(batch)
            done = best_launch + service[best_s](len(batch))
            recv, down_free[best_s] = self._drain_downlink(
                done, len(batch), down_free[best_s])
            for (t_obs, arrival, idx), r in zip(batch, recv):
                trace[idx] = (arr[idx][2], best_s, t_obs, arrival, r)
            free[best_s] = done
            if use_heap:
                heapq.heappop(heap)               # consume the launch event
                heap_push(best_s)                 # leftover queue reschedules
        return trace

    def trace(self, n_clients: int) -> np.ndarray:
        """Per-request (client, server, t_obs, arrival, recv) record
        array in observation order — the raw material for ordering and
        balance assertions."""
        return self._simulate(n_clients)

    def latencies(self, n_clients: int) -> np.ndarray:
        t = self._simulate(n_clients)
        return t["recv"] - t["t_obs"]

    # ---- fleet sizing ------------------------------------------------------
    def max_clients(self, *, p95_budget_s: float = 0.1,
                    n_max: int = 4096) -> int:
        """Largest client population with p95 within budget.

        A geometric sweep followed by binary search replaces the
        single-server linear scan — a fleet supporting thousands of
        clients would otherwise cost thousands of simulations.  The
        sweep runs the FULL doubling ladder rather than stopping at the
        first failure: p95 DIPS after small N when a batch hold makes a
        lone client wait out ``max_wait_s``, or when affinity routing on
        a heterogeneous fleet hashes the only clients onto a slow shard,
        so a small-N failure does not imply saturation.  Beyond the dip
        p95 is monotone (shared uplink + FIFO queues) and the bisection
        between the largest pass and the next failure is exact.
        """
        budget = p95_budget_s
        probes, n = [], 1
        while True:
            probes.append((n, self.p95(n) <= budget))
            if n >= n_max:
                break
            n = min(2 * n, n_max)
        passing = [n for n, ok in probes if ok]
        if not passing:
            return 0
        lo = max(passing)
        fails_above = [n for n, ok in probes if not ok and n > lo]
        if not fails_above:
            return lo                 # passed at the n_max cap
        hi = min(fails_above)
        while hi - lo > 1:            # invariant: lo passes, hi fails
            mid = (lo + hi) // 2
            if self.p95(mid) <= budget:
                lo = mid
            else:
                hi = mid
        return lo

    def min_servers(self, n_clients: int, *, p95_budget_s: float = 0.1,
                    n_servers_max: int = 64) -> int:
        """Smallest fleet serving ``n_clients`` within the p95 budget
        (0 when even ``n_servers_max`` cannot).  The capacity-planning
        inverse of :meth:`max_clients`."""
        for s in range(1, n_servers_max + 1):
            if self.with_servers(s).p95(n_clients) <= p95_budget_s:
                return s
        return 0

    def with_servers(self, n_servers: int,
                     router: Union[str, Router, None] = None) \
            -> "FleetQueueSim":
        """This fleet at a different size (service curves shared)."""
        return dataclasses.replace(
            self, n_servers=n_servers,
            router=self.router if router is None else router,
            service_models=None if self.service_models is None
            else tuple(self.service_models[s % len(self.service_models)]
                       for s in range(n_servers)))


__all__ = ["FleetQueueSim", "ROUTERS", "Router", "get_router",
           "register_router", "router_names"]
