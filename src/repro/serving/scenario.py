"""Scenario engine: named, seeded serving conditions + per-client adaptation.

The paper evaluates under ONE static ``tc netem`` uplink and three fixed
devices.  A :class:`Scenario` names a whole serving CONDITION — a link
shape from the adversarial family in :mod:`repro.serving.netsim`
(trace-driven dropouts, Markov "Wi-Fi rate-adaptation" regimes, loss with
retransmit, stochastic jitter), a device zoo from
:mod:`repro.serving.profiles`, the client population/rate, and an
adaptation-mode ladder — in one frozen, JSON-round-trippable schema with
an explicit seed, registered in ``SCENARIOS`` exactly like routers and
wire codecs.  ``Deployment.scenario_sim(name)`` and the CLI
``--scenario`` flag drive a manifest through any registered scenario;
``benchmarks/scenarios.py`` sweeps the (scenario x router x adaptation)
grid.

Adaptation closes the loop per client: each decision picks one
:class:`AdaptationMode` — a (payload scale, extra encode time, fidelity)
point standing for a codec / split-point / compression choice — from the
client's OBSERVED link feedback (measured transfer bandwidth and queueing
delay of past payloads, available only once those transfers complete — no
clairvoyance).  The rule-based baseline (``"rule"``) sends the
highest-fidelity mode whose predicted decision latency fits a budget, the
paper's break-even logic generalised to time-varying links;
``register_adaptation`` is the pluggable policy hook (a learned
controller slots in without touching the sim).  ``"none"`` and
``"static:<i>"`` are the no-adaptation baselines.

The delivered-return proxy scores what an RL deployment actually earns:
each decision contributes its mode's fidelity if it arrives within the
deadline and zero otherwise, averaged over requests.  A static
full-fidelity config loses return to deadline misses under adversarial
links; a static compact config caps return at its fidelity everywhere;
the controller's job is to dominate the best static on return at no worse
p95 and no more uplink bytes (gated in ``benchmarks/scenarios.py
--smoke`` and tests/test_scenarios.py).

Determinism contract: a scenario's seed fully determines its link trace,
and every sim entry point resets the link (including its RNG) before
replaying — same name + seed in, bitwise-identical latencies out.  With
``n_servers=1``, a static-link scenario under ``"none"`` reduces bitwise
to the existing :class:`~repro.serving.server.BatchQueueSim` path.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Union

import numpy as np

from repro.schema import check_version
from repro.serving import netsim, profiles
from repro.serving.fleet import FleetQueueSim
from repro.serving.netsim import MBPS

SCENARIO_VERSION = 1


def _freeze(x):
    """Recursively convert JSON containers to hashable tuples."""
    if isinstance(x, (list, tuple)):
        return tuple(_freeze(v) for v in x)
    if isinstance(x, dict):
        return tuple(sorted((str(k), _freeze(v)) for k, v in x.items()))
    return x


def _thaw(x):
    """Tuples back to JSON lists (the top-level (key, value) pairing is
    undone by :meth:`Scenario.params_dict`, not here)."""
    if isinstance(x, tuple):
        return [_thaw(v) for v in x]
    return x


@dataclasses.dataclass(frozen=True)  # repro: allow(schema-version) -- nested in Scenario; versioned by the parent's SCENARIO_VERSION field
class AdaptationMode:
    """One point on the codec/split-point ladder a client can pick.

    ``payload_scale`` multiplies the deployment's wire payload (codec +
    split-point choice: fp32 -> int8 is 1/4, extra spatial downsampling
    1/4 again, ship-the-frame server-only is > 1), ``encode_s`` is the
    EXTRA on-device time the mode costs before the payload hits the
    uplink (heavier compression is not free), and ``fidelity`` in [0, 1]
    is the mode's relative decision quality — the weight it earns in the
    delivered-return proxy.
    """
    name: str
    payload_scale: float = 1.0
    encode_s: float = 0.0
    fidelity: float = 1.0

    def __post_init__(self):
        if self.payload_scale <= 0.0:
            raise ValueError(f"payload_scale must be > 0: "
                             f"{self.payload_scale}")
        if self.encode_s < 0.0:
            raise ValueError(f"encode_s must be >= 0: {self.encode_s}")
        if not 0.0 <= self.fidelity <= 1.0:
            raise ValueError(f"fidelity must be in [0, 1]: {self.fidelity}")

    def to_dict(self) -> dict:
        return {"name": self.name, "payload_scale": self.payload_scale,
                "encode_s": self.encode_s, "fidelity": self.fidelity}

    @classmethod
    def from_dict(cls, d: dict) -> "AdaptationMode":
        return cls(name=d["name"],
                   payload_scale=float(d["payload_scale"]),
                   encode_s=float(d["encode_s"]),
                   fidelity=float(d["fidelity"]))


FULL_MODE = AdaptationMode("full", 1.0, 0.0, 1.0)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, seeded serving condition (frozen, JSON-round-trippable).

    ``link_kind`` names a builder in ``netsim.LINK_KINDS`` and
    ``link_params`` its JSON-shaped kwargs as sorted (key, value) pairs
    (nested sequences are tuples); seeded link kinds receive ``seed``.
    ``devices`` are profile names cycled across the fleet's servers.
    ``modes`` is the adaptation ladder; mode 0 is the deployment default
    (what ``"none"`` always sends).
    """
    name: str
    link_kind: str
    link_params: tuple = ()
    seed: int = 0
    devices: tuple = ("jetson_nano",)
    modes: tuple = (FULL_MODE,)
    rate_hz: float = 10.0
    horizon_s: float = 10.0
    n_clients: int = 8
    deadline_s: float = 0.1
    adversarial: bool = False
    notes: str = ""

    def __post_init__(self):
        # canonicalise: pairs or dict in, sorted frozen (key, value) out —
        # so construction order never breaks equality or round-trips
        object.__setattr__(self, "link_params",
                           _freeze(dict(self.link_params)))
        object.__setattr__(self, "devices", tuple(self.devices))
        object.__setattr__(self, "modes", tuple(self.modes))
        if not self.name:
            raise ValueError("scenario needs a name")
        if self.link_kind not in netsim.LINK_KINDS:
            raise ValueError(f"unknown link kind {self.link_kind!r}; "
                             f"registered: {sorted(netsim.LINK_KINDS)}")
        if not isinstance(self.seed, int) or self.seed < 0:
            raise ValueError(f"seed must be a non-negative int: {self.seed}")
        if not self.modes:
            raise ValueError("scenario needs >= 1 adaptation mode")
        if len({m.name for m in self.modes}) != len(self.modes):
            raise ValueError("mode names must be unique")
        if not self.devices:
            raise ValueError("scenario needs >= 1 device profile")
        if self.rate_hz <= 0 or self.horizon_s <= 0 or self.deadline_s <= 0:
            raise ValueError("rate_hz, horizon_s, deadline_s must be > 0")
        if self.n_clients < 1:
            raise ValueError(f"n_clients must be >= 1: {self.n_clients}")

    @property
    def is_static(self) -> bool:
        """True when the link does not vary over time (the reduction
        contract: at n_servers=1 these replay ``BatchQueueSim`` bitwise
        under the ``\"none\"`` controller)."""
        return self.link_kind == "static"

    def params_dict(self) -> dict:
        return {k: _thaw(v) if isinstance(v, tuple) else v
                for k, v in self.link_params}

    def make_link(self):
        """Build this scenario's link; ``reset()`` replays it bitwise."""
        return netsim.make_link(self.link_kind, seed=self.seed,
                                **self.params_dict())

    def service_models(self, n_servers: int) -> tuple:
        return profiles.zoo(self.devices, n_servers)

    def validate(self) -> None:
        """Full validation: field checks happened at construction; this
        also builds the link and resolves every device profile."""
        self.make_link()
        for d in self.devices:
            profiles.get_profile(d)

    # ---- serialisation (mirrors DeploymentConfig's manifest contract) ----
    def to_dict(self) -> dict:
        return {
            "version": SCENARIO_VERSION,
            "name": self.name,
            "seed": self.seed,
            "link": {"kind": self.link_kind, "params": self.params_dict()},
            "devices": list(self.devices),
            "modes": [m.to_dict() for m in self.modes],
            "rate_hz": self.rate_hz,
            "horizon_s": self.horizon_s,
            "n_clients": self.n_clients,
            "deadline_s": self.deadline_s,
            "adversarial": self.adversarial,
            "notes": self.notes,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Scenario":
        d = dict(d)
        check_version("Scenario", d.pop("version", SCENARIO_VERSION),
                      (SCENARIO_VERSION,))
        link = d.pop("link")
        return cls(name=d["name"], seed=int(d.get("seed", 0)),
                   link_kind=link["kind"],
                   link_params=_freeze(link.get("params", {})),
                   devices=tuple(d.get("devices", ("jetson_nano",))),
                   modes=tuple(AdaptationMode.from_dict(m)
                               for m in d.get("modes", [])) or (FULL_MODE,),
                   rate_hz=float(d.get("rate_hz", 10.0)),
                   horizon_s=float(d.get("horizon_s", 10.0)),
                   n_clients=int(d.get("n_clients", 8)),
                   deadline_s=float(d.get("deadline_s", 0.1)),
                   adversarial=bool(d.get("adversarial", False)),
                   notes=str(d.get("notes", "")))

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_json(cls, s: str) -> "Scenario":
        return cls.from_dict(json.loads(s))

    # ---- the sim ---------------------------------------------------------
    def sim(self, payload_bytes: int, *, n_servers: int = 1,
            router="round_robin", max_batch: int = 8,
            max_wait_s: float = 0.0, action_bytes: int = 64,
            adaptation="none",
            service_models=None) -> "ScenarioFleetSim":
        """This scenario as a runnable :class:`ScenarioFleetSim` for a
        deployment whose default wire payload is ``payload_bytes``."""
        if service_models is None:
            service_models = self.service_models(n_servers)
        return ScenarioFleetSim(
            service_time_s=0.0, uplink=self.make_link(),
            payload_bytes=payload_bytes, action_bytes=action_bytes,
            rate_hz=self.rate_hz, horizon_s=self.horizon_s,
            max_batch=max_batch, max_wait_s=max_wait_s,
            n_servers=n_servers, router=router,
            service_models=tuple(service_models),
            modes=self.modes, adaptation=adaptation,
            deadline_s=self.deadline_s)


# ---------------------------------------------------------------------------
# Scenario registry
# ---------------------------------------------------------------------------

SCENARIOS: dict[str, Scenario] = {}


def register_scenario(s: Scenario) -> Scenario:
    s.validate()
    SCENARIOS[s.name] = s
    return s


def get_scenario(name: Union[str, Scenario]) -> Scenario:
    if isinstance(name, Scenario):
        return name
    try:
        return SCENARIOS[name]
    except KeyError:
        raise ValueError(f"unknown scenario {name!r}; registered: "
                         f"{', '.join(SCENARIOS)}") from None


def scenario_names() -> tuple[str, ...]:
    return tuple(SCENARIOS)


# ---------------------------------------------------------------------------
# Adaptation controllers
# ---------------------------------------------------------------------------

class StaticController:
    """No adaptation: every client always sends ``modes[idx]``."""

    def __init__(self, modes, payload_bytes: int, deadline_s: float,
                 *, idx: int = 0):
        if not 0 <= idx < len(modes):
            raise ValueError(f"static mode index {idx} out of range "
                             f"for {len(modes)} modes")
        self.idx = idx

    def choose(self, client: int, t_obs: float) -> int:
        return self.idx

    def observe(self, client: int, mode_idx: int, t_send: float,
                trace) -> None:
        pass


class RuleController:
    """Rule-based per-client adaptation: break-even logic on observed
    link feedback.

    Each completed transfer teaches the client its current link: measured
    transfer bandwidth ``8 * bytes / (tx_done - start)`` and queueing
    delay ``start - t_send``.  Feedback becomes visible only at the
    transfer's arrival time (no clairvoyance — a payload stuck in a
    dropout teaches nothing until it lands).  The client additionally
    reads its own send queue, the signal a real sender gets for free
    from its ACK clock: a transfer still outstanding ``age`` seconds
    after it was sent bounds the current bandwidth above by
    ``8 * bytes / age``, so congestion is detected one decision after it
    starts instead of one full drain later.  Each decision then sends
    the highest-fidelity mode whose PREDICTED latency (extra encode +
    last queueing delay + payload / estimated bandwidth) fits
    ``budget_frac * deadline_s``; when no mode fits, the
    lowest-predicted-latency mode.  Before any feedback: mode 0, the
    deployment default.
    """

    def __init__(self, modes, payload_bytes: int, deadline_s: float,
                 *, budget_frac: float = 0.5):
        self.modes = tuple(modes)
        self.payload_bytes = int(payload_bytes)
        self.budget_s = float(budget_frac) * float(deadline_s)
        # client -> [(t_send, avail_at, bw, qd, payload_bytes)]
        self._pending: dict[int, list] = {}
        self._state: dict[int, tuple] = {}    # client -> (bw_bps, queue_s)

    def choose(self, client: int, t_obs: float) -> int:
        pending = self._pending.get(client, [])
        ripe = [p for p in pending if p[1] <= t_obs]
        if ripe:
            self._state[client] = ripe[-1][2:4]
            pending = [p for p in pending if p[1] > t_obs]
            self._pending[client] = pending
        bw, qd = self._state.get(client, (np.inf, 0.0))
        if pending:
            # oldest still-outstanding transfer: implied bandwidth bound
            t_send, _, _, _, payload = pending[0]
            age = t_obs - t_send
            if age > self.budget_s:
                bw = min(bw, 8.0 * payload / age)
                qd = 0.0
        best, best_pred, fallback = None, np.inf, 0
        for i, m in enumerate(self.modes):
            payload = max(1, int(round(self.payload_bytes * m.payload_scale)))
            pred = m.encode_s + qd + 8.0 * payload / bw
            if pred <= self.budget_s and (best is None or
                                          m.fidelity >
                                          self.modes[best].fidelity):
                best = i
            if pred < best_pred:
                best_pred, fallback = pred, i
        return best if best is not None else fallback

    def observe(self, client: int, mode_idx: int, t_send: float,
                trace) -> None:
        tx = trace.tx_done - trace.start
        bw = 8.0 * trace.payload_bytes / tx if tx > 0.0 else np.inf
        qd = max(0.0, trace.start - t_send)
        self._pending.setdefault(client, []).append(
            (t_send, trace.arrival, bw, qd, trace.payload_bytes))


# factory(modes, payload_bytes, deadline_s) -> controller
ADAPTATIONS: dict[str, Callable] = {}


def register_adaptation(name: str, factory: Callable) -> Callable:
    """Pluggable policy hook: register a controller factory with
    signature ``factory(modes, payload_bytes, deadline_s) -> controller``
    where a controller has ``choose(client, t_obs) -> mode_idx`` and
    ``observe(client, mode_idx, t_send, link_trace)``."""
    ADAPTATIONS[name] = factory
    return factory


def get_adaptation(name: Union[str, Callable]) -> Callable:
    if callable(name):
        return name
    if isinstance(name, str) and name.startswith("static:"):
        idx = int(name.split(":", 1)[1])
        return lambda modes, pb, dl: StaticController(modes, pb, dl, idx=idx)
    try:
        return ADAPTATIONS[name]
    except KeyError:
        raise ValueError(f"unknown adaptation {name!r}; registered: "
                         f"{', '.join(ADAPTATIONS)} (or static:<i>)") \
            from None


def adaptation_names() -> tuple[str, ...]:
    return tuple(ADAPTATIONS)


register_adaptation("none", StaticController)
register_adaptation("rule", RuleController)


# ---------------------------------------------------------------------------
# The scenario simulator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ScenarioReport:
    """Per-run scorecard: latency tail, uplink byte bill, and the
    delivered-return proxy (mean over requests of mode fidelity for
    in-deadline decisions, zero for late ones)."""
    latencies: np.ndarray
    mode_idx: np.ndarray
    total_uplink_bytes: int
    delivered_return: float
    deadline_s: float
    mode_names: tuple

    @property
    def n_requests(self) -> int:
        return int(self.latencies.size)

    @property
    def p95_s(self) -> float:
        return float(np.percentile(self.latencies, 95))

    @property
    def mean_s(self) -> float:
        return float(np.mean(self.latencies))

    @property
    def deadline_hit_rate(self) -> float:
        return float(np.mean(self.latencies <= self.deadline_s))

    def mode_counts(self) -> dict:
        return {name: int(np.sum(self.mode_idx == i))
                for i, name in enumerate(self.mode_names)}


@dataclasses.dataclass
class ScenarioFleetSim(FleetQueueSim):
    """:class:`FleetQueueSim` under a scenario: per-request adaptation.

    Before each request crosses the uplink, the controller picks one
    :class:`AdaptationMode` for that client — scaling the payload and
    charging the mode's extra encode time — and is fed the resulting
    link trace as delayed feedback.  Everything downstream (routing,
    per-server micro-batching, serialised downlinks) is the unmodified
    fleet engine.  With the default single full mode and the ``"none"``
    controller this IS ``FleetQueueSim`` (and at n_servers=1,
    ``BatchQueueSim``) bitwise.

    Arrivals are re-sorted (stably) into arrival order before the event
    engine runs: a no-op for monotone links, and it upholds the engine's
    time-order assumption when jittery links reorder arrivals.
    """

    modes: tuple = (FULL_MODE,)
    adaptation: Union[str, Callable] = "none"
    deadline_s: float = 0.1

    def _request_arrivals(self, n_clients: int):
        self.uplink.reset()
        factory = get_adaptation(self.adaptation)
        ctrl = factory(self.modes, self.payload_bytes, self.deadline_s)
        period = 1.0 / self.rate_hz
        events = []
        for c in range(n_clients):
            t = c * period / n_clients       # staggered clients
            while t < self.horizon_s:
                events.append((t, c))
                t += period
        events.sort()
        arr, mode_idx, nbytes = [], [], []
        for t_obs, c in events:
            m = ctrl.choose(c, t_obs)
            if not 0 <= m < len(self.modes):
                raise ValueError(f"controller chose mode {m} of "
                                 f"{len(self.modes)}")
            mode = self.modes[m]
            payload = max(1, int(round(self.payload_bytes
                                       * mode.payload_scale)))
            tr = self.uplink.send(t_obs + mode.encode_s, payload)
            ctrl.observe(c, m, t_obs + mode.encode_s, tr)
            arr.append((t_obs, tr.arrival, c))
            mode_idx.append(m)
            nbytes.append(payload)
        order = np.argsort(np.asarray([a for _, a, _ in arr]), kind="stable")
        self._last_mode_idx = np.asarray(mode_idx, np.int64)[order]
        self._last_bytes = np.asarray(nbytes, np.int64)[order]
        return [arr[i] for i in order]

    def report(self, n_clients: int) -> ScenarioReport:
        """Run the scenario and score it (latencies in request order,
        aligned with the modes that produced them)."""
        tr = self._simulate(n_clients)
        lat = tr["recv"] - tr["t_obs"]
        fid = np.asarray([m.fidelity for m in self.modes])[
            self._last_mode_idx]
        delivered = float(np.mean(np.where(lat <= self.deadline_s,
                                           fid, 0.0)))
        return ScenarioReport(
            latencies=lat, mode_idx=self._last_mode_idx.copy(),
            total_uplink_bytes=int(self._last_bytes.sum()),
            delivered_return=delivered, deadline_s=self.deadline_s,
            mode_names=tuple(m.name for m in self.modes))


# ---------------------------------------------------------------------------
# Built-in scenarios
# ---------------------------------------------------------------------------

# The adaptation ladder used by the adversarial built-ins: mode 0 is the
# deployment default (full payload, nothing extra to pay), "compact" is a
# heavier on-device compression (int8 + spatial downsample: 1/8 the
# bytes) costing 30 ms extra encode and a fidelity haircut.
DEFAULT_MODES = (AdaptationMode("full", 1.0, 0.0, 1.0),
                 AdaptationMode("compact", 0.125, 0.030, 0.7))

register_scenario(Scenario(
    name="static_100mbps", link_kind="static",
    link_params=(("bandwidth_bps", 100 * MBPS), ("propagation_s", 0.002)),
    devices=("jetson_nano",),
    notes="Table 6 reference uplink: one static 100 Mb/s shaped link"))

register_scenario(Scenario(
    name="static_10mbps", link_kind="static",
    link_params=(("bandwidth_bps", 10 * MBPS), ("propagation_s", 0.002)),
    devices=("jetson_nano",),
    notes="below the paper's ~50 Mb/s break-even: uplink-bound serving"))

register_scenario(Scenario(
    name="zoo_static", link_kind="static",
    link_params=(("bandwidth_bps", 100 * MBPS), ("propagation_s", 0.002)),
    devices=("jetson_nano", "pi_4b", "pi_zero_2w"),
    notes="heterogeneous fleet on the reference uplink: routing policy "
          "decides how much the slow shards hurt"))

register_scenario(Scenario(
    name="jittery_wifi", link_kind="jitter",
    link_params=(("bandwidth_bps", 40 * MBPS), ("propagation_s", 0.004),
                 ("jitter_s", 0.004)),
    devices=("jetson_nano",), seed=7,
    notes="seeded netem-style delay variation on a 40 Mb/s uplink"))

register_scenario(Scenario(
    name="lossy_uplink", link_kind="lossy",
    link_params=(("bandwidth_bps", 40 * MBPS), ("loss_p", 0.05),
                 ("rto_s", 0.03), ("propagation_s", 0.004)),
    devices=("jetson_nano",), seed=11, adversarial=True,
    modes=DEFAULT_MODES,
    notes="5% Bernoulli loss, 30 ms RTO retransmits, head-of-line "
          "blocking"))

register_scenario(Scenario(
    name="trace_dropout", link_kind="trace",
    link_params=(("schedule", ((0.0, 100 * MBPS), (3.0, 4 * MBPS),
                               (4.0, 100 * MBPS), (7.0, 4 * MBPS),
                               (8.0, 100 * MBPS))),
                 ("propagation_s", 0.002)),
    devices=("jetson_nano",), horizon_s=12.0, adversarial=True,
    modes=DEFAULT_MODES,
    notes="trace-driven adversary: two 1 s dropouts to 4 Mb/s carve "
          "~17% of the horizon out of a 100 Mb/s uplink — the designed "
          "adaptation gate (deterministic)"))

register_scenario(Scenario(
    name="wifi_markov", link_kind="markov",
    link_params=(("states_bps", (100 * MBPS, 20 * MBPS, 2 * MBPS)),
                 ("transition", ((0.90, 0.08, 0.02),
                                 (0.30, 0.55, 0.15),
                                 (0.10, 0.30, 0.60))),
                 ("dwell_s", 0.25), ("propagation_s", 0.004)),
    devices=("jetson_nano",), seed=13, horizon_s=12.0, adversarial=True,
    modes=DEFAULT_MODES,
    notes="Wi-Fi rate-adaptation regimes: seeded Markov hops between "
          "100/20/2 Mb/s every 250 ms"))


__all__ = ["AdaptationMode", "FULL_MODE", "DEFAULT_MODES", "Scenario",
           "SCENARIOS", "SCENARIO_VERSION", "register_scenario",
           "get_scenario", "scenario_names", "StaticController",
           "RuleController", "ADAPTATIONS", "register_adaptation",
           "get_adaptation", "adaptation_names", "ScenarioReport",
           "ScenarioFleetSim"]
