"""Server-side policy execution + multi-client queueing simulation.

``PolicyServer`` wraps a jitted server-half function and measures its
service time on this host.  ``BatchingPolicyServer`` is its micro-batching
replacement: it forms micro-batches (up to ``max_batch`` requests, waiting
at most ``max_wait_s`` for the batch to fill) and serves them with ONE
batched call, measuring the service-time curve t(B) that
:class:`BatchServiceModel` interpolates.

``QueueSim`` reproduces the paper's Table 6 setting: N clients at a fixed
decision rate against one FIFO server, reporting p95 decision latency
(queueing + service + transfer).  ``BatchQueueSim`` extends it with
micro-batching semantics: when the server frees up it launches whatever
has arrived (capped at ``max_batch``), optionally holding the batch open
``max_wait_s`` for stragglers, and charges the whole batch the batched
service time t(B) instead of B sequential services.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Optional, Sequence

import numpy as np

from repro.serving.netsim import ShapedLink


@dataclasses.dataclass
class PolicyServer:
    """serve_fn(payload) -> action; service_time_s measured if not given."""

    serve_fn: Callable
    service_time_s: Optional[float] = None

    def measure(self, example_payload, *, iters: int = 20,
                warmup: int = 2) -> float:
        # compile + warmup, BLOCKED before the clock starts: jax dispatch
        # is async, so an unblocked warmup bleeds into the timed region
        # and the first timed iterations pay cache-cold costs
        out = self.serve_fn(example_payload)
        for _ in range(warmup):
            out = self.serve_fn(example_payload)
        _block(out)
        t0 = time.perf_counter()
        for _ in range(iters):
            out = self.serve_fn(example_payload)
        _block(out)
        self.service_time_s = (time.perf_counter() - t0) / iters
        return self.service_time_s


def _block(x):
    try:
        import jax
    except ImportError:
        return
    jax.block_until_ready(x)


@dataclasses.dataclass(frozen=True)
class BatchServiceModel:
    """Measured batched service-time curve t(B), piecewise-linear.

    ``points`` are (batch_size, seconds) samples sorted by batch size;
    queries between samples interpolate.  Queries past the largest
    measured sample are OUT OF RANGE and handled per ``out_of_range``:

    * ``"extrapolate"`` (default) — continue with the marginal
      per-request cost of the last segment (the asymptotic regime where
      fixed launch overhead is amortised), warning ONCE per model that
      the value is extrapolated, not measured;
    * ``"clamp"`` — return t(max measured B), warning once;
    * ``"raise"`` — refuse with ``ValueError``.

    The silent-extrapolation default fed the sims (and the real-fleet
    calibration gate) unmeasured numbers whenever a batch exceeded the
    measured range; the real fleet caps its admission at
    :attr:`max_measured_batch` instead (see ``Deployment.fleet``).
    """

    points: tuple[tuple[int, float], ...]
    out_of_range: str = "extrapolate"
    _warned: bool = dataclasses.field(default=False, compare=False,
                                      repr=False)

    def __post_init__(self):
        if not self.points:
            raise ValueError("BatchServiceModel needs >= 1 measured point")
        bs = [b for b, _ in self.points]
        if bs != sorted(set(bs)):
            raise ValueError(f"points must be sorted/unique in batch: {bs}")
        if self.out_of_range not in ("extrapolate", "clamp", "raise"):
            raise ValueError(f"out_of_range must be extrapolate|clamp|raise,"
                             f" got {self.out_of_range!r}")

    @property
    def max_measured_batch(self) -> int:
        """Largest batch size the curve was actually measured at."""
        return self.points[-1][0]

    def _out_of_range(self, batch: int) -> float:
        bs = np.array([b for b, _ in self.points], float)
        ts = np.array([t for _, t in self.points], float)
        if self.out_of_range == "raise":
            raise ValueError(
                f"t({batch}) is beyond the measured range (largest "
                f"measured B={self.max_measured_batch}); re-measure with "
                f"larger batch_sizes or use out_of_range='extrapolate'")
        if not self._warned:
            object.__setattr__(self, "_warned", True)
            how = ("clamped to t(max)" if self.out_of_range == "clamp"
                   else "extrapolated")
            warnings.warn(
                f"BatchServiceModel: t({batch}) queried beyond the measured "
                f"range (largest measured B={self.max_measured_batch}); "
                f"{how}, not a measurement",
                RuntimeWarning, stacklevel=3)
        if self.out_of_range == "clamp":
            return float(ts[-1])
        if len(bs) > 1:
            slope = (ts[-1] - ts[-2]) / (bs[-1] - bs[-2])
        else:
            slope = ts[-1] / bs[-1]
        return float(ts[-1] + slope * (batch - bs[-1]))

    def __call__(self, batch: int) -> float:
        bs = np.array([b for b, _ in self.points], float)
        ts = np.array([t for _, t in self.points], float)
        if batch <= bs[-1]:
            return float(np.interp(batch, bs, ts))
        return self._out_of_range(batch)


@dataclasses.dataclass
class BatchingPolicyServer:
    """Micro-batching policy server.

    ``serve_batch_fn`` maps a stacked micro-batch payload (every tensor
    gains a leading batch axis; see ``repro.core.wire.stack_payloads``) to
    stacked actions.  ``measure`` times it across batch sizes, yielding the
    t(B) curve that drives :class:`BatchQueueSim`; ``max_batch`` /
    ``max_wait_s`` are the batching policy the simulator reproduces.
    """

    serve_batch_fn: Callable
    max_batch: int = 8
    max_wait_s: float = 0.0
    service_times_s: Optional[dict[int, float]] = None

    def serve(self, payloads: Sequence) -> list:
        """Serve queued single-request payloads as ONE batched call."""
        from repro.core.wire import stack_payloads  # lazy: jax-optional
        if len(payloads) > self.max_batch:
            raise ValueError(f"{len(payloads)} requests > max_batch "
                             f"{self.max_batch}")
        out = self.serve_batch_fn(stack_payloads(payloads))
        return [out[i] for i in range(len(payloads))]

    def measure(self, example_payload, *,
                batch_sizes: Sequence[int] = (1, 2, 4, 8),
                iters: int = 10, warmup: int = 2) -> dict[int, float]:
        """Measure t(B) on this host for each micro-batch size."""
        import jax
        import jax.numpy as jnp
        times: dict[int, float] = {}
        for b in sorted(set(batch_sizes)):
            batch = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (b,) + a.shape),
                example_payload)
            # compile + warmup, blocked before the clock starts (async
            # dispatch would otherwise bleed into the timed region)
            out = self.serve_batch_fn(batch)
            for _ in range(warmup):
                out = self.serve_batch_fn(batch)
            _block(out)
            t0 = time.perf_counter()
            for _ in range(iters):
                out = self.serve_batch_fn(batch)
            _block(out)
            times[b] = (time.perf_counter() - t0) / iters
        self.service_times_s = times
        return times

    def service_model(self, *,
                      out_of_range: str = "extrapolate") -> BatchServiceModel:
        if not self.service_times_s:
            raise ValueError("call measure() first")
        return BatchServiceModel(tuple(sorted(self.service_times_s.items())),
                                 out_of_range=out_of_range)


@dataclasses.dataclass
class QueueSim:
    """Deterministic FIFO queue: N clients, fixed rate, one server.

    Decision latency per request = uplink transfer + queueing + service +
    downlink transfer.  ``max_clients`` sweeps N until p95 exceeds the
    budget (the paper's Table 6 protocol: 10 Hz, p95 < 100 ms).
    """

    service_time_s: float
    uplink: ShapedLink
    payload_bytes: int
    action_bytes: int = 64
    rate_hz: float = 10.0
    horizon_s: float = 10.0

    def _request_arrivals(self, n_clients: int) \
            -> list[tuple[float, float, int]]:
        """(t_obs, server_arrival, client) per request, observation order.

        The uplink serialises transfers FIFO, so arrivals are
        non-decreasing in this order.
        """
        self.uplink.reset()
        period = 1.0 / self.rate_hz
        events = []          # (obs_time, client)
        for c in range(n_clients):
            t = c * period / n_clients       # staggered clients
            while t < self.horizon_s:
                events.append((t, c))
                t += period
        events.sort()
        return [(t_obs, self.uplink.send(t_obs, self.payload_bytes).arrival,
                 c) for t_obs, c in events]

    def _drain_downlink(self, done: float, n_actions: int,
                        down_free: float) -> tuple[list[float], float]:
        """Receive times of ``n_actions`` actions completing at ``done``.

        The action return rides the same link model (downlink assumed
        symmetric), but the downlink SERIALISES: each action payload
        transmits after the previous one (and after whatever the link was
        still sending), so a batch of B actions costs B transfer slots,
        not one.  Returns (per-action receive times, new downlink-busy
        time).
        """
        act_tx = self.uplink.tx_time(self.action_bytes)
        start = max(done, down_free)
        recv = [start + (m + 1) * act_tx + self.uplink.propagation_s
                for m in range(n_actions)]
        return recv, start + n_actions * act_tx

    def latencies(self, n_clients: int) -> np.ndarray:
        server_free = 0.0
        down_free = 0.0
        lat = []
        for t_obs, arrival, _ in self._request_arrivals(n_clients):
            start = max(arrival, server_free)
            done = start + self.service_time_s
            server_free = done
            (recv,), down_free = self._drain_downlink(done, 1, down_free)
            lat.append(recv - t_obs)
        return np.asarray(lat)

    def p95(self, n_clients: int) -> float:
        return float(np.percentile(self.latencies(n_clients), 95))

    def _zero_scan_limit(self, p95_budget_s: float) -> int:
        """How far past a failing p95 to keep scanning while NOTHING has
        passed yet.  FIFO p95 is monotone in N, so a failure at N=1
        means saturation: 0.  Batch-hold subclasses override — their
        p95 dips after small N."""
        return 0

    def max_clients(self, *, p95_budget_s: float = 0.1,
                    n_max: int = 512) -> int:
        best = 0
        limit = self._zero_scan_limit(p95_budget_s)
        for n in range(1, n_max + 1):
            if self.p95(n) <= p95_budget_s:
                best = n
            elif best or n >= limit:
                # monotone beyond saturation — stop, even at best == 0
                # (p95(1) already over budget) once past the small-N
                # transient window
                break
        return best


@dataclasses.dataclass
class BatchQueueSim(QueueSim):
    """Micro-batching server against the same client population.

    When the server frees up it launches a batch: all requests that have
    arrived (up to ``max_batch``), after optionally holding the launch up
    to ``max_wait_s`` for the batch to fill.  The whole batch occupies the
    server for ``service_model(B)`` (falling back to the batch-invariant
    ``service_time_s`` when no model is given); the B actions then
    serialise on the downlink, each charged its own transfer slot.  With
    ``max_batch=1``/``max_wait_s=0`` this reduces exactly to the FIFO
    :class:`QueueSim`.
    """

    max_batch: int = 8
    max_wait_s: float = 0.0
    service_model: Optional[Callable[[int], float]] = None

    def service(self, batch: int) -> float:
        if self.service_model is not None:
            return self.service_model(batch)
        return self.service_time_s

    def _zero_scan_limit(self, p95_budget_s: float) -> int:
        """With a batch hold, p95 is NOT monotone at small N: a lone
        client waits out ``max_wait_s`` every decision, so p95(1) can
        exceed a budget that a well-fed batching server meets easily.
        Holds stop binding once ~max_batch requests arrive within the
        relevant window (the hold, or the budget when that is tighter),
        so keep scanning past zero until twice that population."""
        if self.max_wait_s <= 0.0 or p95_budget_s <= 0.0:
            return 0
        window = min(self.max_wait_s, p95_budget_s)
        return int(np.ceil(2.0 * self.max_batch / (self.rate_hz * window)))

    def latencies(self, n_clients: int) -> np.ndarray:
        arr = self._request_arrivals(n_clients)
        n = len(arr)
        server_free = 0.0
        down_free = 0.0
        lat = np.empty(n)
        i = 0
        while i < n:
            ready = max(server_free, arr[i][1])
            j_fill = i + self.max_batch - 1
            if j_fill < n and arr[j_fill][1] <= ready:
                launch = ready           # batch already full when server free
            elif self.max_wait_s > 0.0:
                deadline = ready + self.max_wait_s
                fill = arr[j_fill][1] if j_fill < n else np.inf
                launch = max(ready, min(deadline, fill))
            else:
                launch = ready           # greedy: take what's there
            k = i
            while k < n and k - i < self.max_batch and arr[k][1] <= launch:
                k += 1
            done = launch + self.service(k - i)
            # B actions serialise on the downlink — the batch does NOT
            # collapse into one action transfer
            recv, down_free = self._drain_downlink(done, k - i, down_free)
            for m in range(i, k):
                lat[m] = recv[m - i] - arr[m][0]
            server_free = done
            i = k
        return lat
