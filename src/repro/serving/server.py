"""Server-side policy execution + multi-client queueing simulation.

``PolicyServer`` wraps a jitted server-half function and measures its
service time on this host.  ``QueueSim`` reproduces the paper's Table 6
setting: N clients at a fixed decision rate against one FIFO server,
reporting p95 decision latency (queueing + service + transfer).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.serving.netsim import ShapedLink


@dataclasses.dataclass
class PolicyServer:
    """serve_fn(payload) -> action; service_time_s measured if not given."""

    serve_fn: Callable
    service_time_s: Optional[float] = None

    def measure(self, example_payload, *, iters: int = 20) -> float:
        self.serve_fn(example_payload)  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = self.serve_fn(example_payload)
        _block(out)
        self.service_time_s = (time.perf_counter() - t0) / iters
        return self.service_time_s


def _block(x):
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass


@dataclasses.dataclass
class QueueSim:
    """Deterministic FIFO queue: N clients, fixed rate, one server.

    Decision latency per request = uplink transfer + queueing + service +
    downlink transfer.  ``max_clients`` sweeps N until p95 exceeds the
    budget (the paper's Table 6 protocol: 10 Hz, p95 < 100 ms).
    """

    service_time_s: float
    uplink: ShapedLink
    payload_bytes: int
    action_bytes: int = 64
    rate_hz: float = 10.0
    horizon_s: float = 10.0

    def latencies(self, n_clients: int) -> np.ndarray:
        self.uplink.reset()
        period = 1.0 / self.rate_hz
        events = []          # (obs_time, client)
        for c in range(n_clients):
            t = c * period / n_clients       # staggered clients
            while t < self.horizon_s:
                events.append((t, c))
                t += period
        events.sort()
        server_free = 0.0
        lat = []
        for t_obs, _ in events:
            tr = self.uplink.send(t_obs, self.payload_bytes)
            start = max(tr.arrival, server_free)
            done = start + self.service_time_s
            server_free = done
            # action return: small payload, same link model (downlink
            # assumed symmetric and uncongested)
            t_recv = done + self.uplink.tx_time(self.action_bytes) \
                + self.uplink.propagation_s
            lat.append(t_recv - t_obs)
        return np.asarray(lat)

    def p95(self, n_clients: int) -> float:
        return float(np.percentile(self.latencies(n_clients), 95))

    def max_clients(self, *, p95_budget_s: float = 0.1,
                    n_max: int = 512) -> int:
        best = 0
        for n in range(1, n_max + 1):
            if self.p95(n) <= p95_budget_s:
                best = n
            elif best:       # monotone beyond saturation
                break
        return best
