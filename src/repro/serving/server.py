"""Server-side policy execution + multi-client queueing simulation.

``PolicyServer`` wraps a jitted server-half function and measures its
service time on this host.  ``BatchingPolicyServer`` is its micro-batching
replacement: it forms micro-batches (up to ``max_batch`` requests, waiting
at most ``max_wait_s`` for the batch to fill) and serves them with ONE
batched call, measuring the service-time curve t(B) that
:class:`BatchServiceModel` interpolates.

``QueueSim`` reproduces the paper's Table 6 setting: N clients at a fixed
decision rate against one FIFO server, reporting p95 decision latency
(queueing + service + transfer).  ``BatchQueueSim`` extends it with
micro-batching semantics: when the server frees up it launches whatever
has arrived (capped at ``max_batch``), optionally holding the batch open
``max_wait_s`` for stragglers, and charges the whole batch the batched
service time t(B) instead of B sequential services.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.serving.netsim import ShapedLink


@dataclasses.dataclass
class PolicyServer:
    """serve_fn(payload) -> action; service_time_s measured if not given."""

    serve_fn: Callable
    service_time_s: Optional[float] = None

    def measure(self, example_payload, *, iters: int = 20) -> float:
        self.serve_fn(example_payload)  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = self.serve_fn(example_payload)
        _block(out)
        self.service_time_s = (time.perf_counter() - t0) / iters
        return self.service_time_s


def _block(x):
    try:
        import jax
    except ImportError:
        return
    jax.block_until_ready(x)


@dataclasses.dataclass(frozen=True)
class BatchServiceModel:
    """Measured batched service-time curve t(B), piecewise-linear.

    ``points`` are (batch_size, seconds) samples sorted by batch size;
    queries between samples interpolate, queries past the largest sample
    extrapolate with the marginal per-request cost of the last segment
    (the asymptotic regime where fixed launch overhead is amortised).
    """

    points: tuple[tuple[int, float], ...]

    def __post_init__(self):
        if not self.points:
            raise ValueError("BatchServiceModel needs >= 1 measured point")
        bs = [b for b, _ in self.points]
        if bs != sorted(set(bs)):
            raise ValueError(f"points must be sorted/unique in batch: {bs}")

    def __call__(self, batch: int) -> float:
        bs = np.array([b for b, _ in self.points], float)
        ts = np.array([t for _, t in self.points], float)
        if batch <= bs[-1]:
            return float(np.interp(batch, bs, ts))
        if len(bs) > 1:
            slope = (ts[-1] - ts[-2]) / (bs[-1] - bs[-2])
        else:
            slope = ts[-1] / bs[-1]
        return float(ts[-1] + slope * (batch - bs[-1]))


@dataclasses.dataclass
class BatchingPolicyServer:
    """Micro-batching policy server.

    ``serve_batch_fn`` maps a stacked micro-batch payload (every tensor
    gains a leading batch axis; see ``repro.core.wire.stack_payloads``) to
    stacked actions.  ``measure`` times it across batch sizes, yielding the
    t(B) curve that drives :class:`BatchQueueSim`; ``max_batch`` /
    ``max_wait_s`` are the batching policy the simulator reproduces.
    """

    serve_batch_fn: Callable
    max_batch: int = 8
    max_wait_s: float = 0.0
    service_times_s: Optional[dict[int, float]] = None

    def serve(self, payloads: Sequence) -> list:
        """Serve queued single-request payloads as ONE batched call."""
        from repro.core.wire import stack_payloads  # lazy: jax-optional
        if len(payloads) > self.max_batch:
            raise ValueError(f"{len(payloads)} requests > max_batch "
                             f"{self.max_batch}")
        out = self.serve_batch_fn(stack_payloads(payloads))
        return [out[i] for i in range(len(payloads))]

    def measure(self, example_payload, *,
                batch_sizes: Sequence[int] = (1, 2, 4, 8),
                iters: int = 10) -> dict[int, float]:
        """Measure t(B) on this host for each micro-batch size."""
        import jax
        import jax.numpy as jnp
        times: dict[int, float] = {}
        for b in sorted(set(batch_sizes)):
            batch = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (b,) + a.shape),
                example_payload)
            self.serve_batch_fn(batch)  # compile
            t0 = time.perf_counter()
            for _ in range(iters):
                out = self.serve_batch_fn(batch)
            _block(out)
            times[b] = (time.perf_counter() - t0) / iters
        self.service_times_s = times
        return times

    def service_model(self) -> BatchServiceModel:
        if not self.service_times_s:
            raise ValueError("call measure() first")
        return BatchServiceModel(tuple(sorted(self.service_times_s.items())))


@dataclasses.dataclass
class QueueSim:
    """Deterministic FIFO queue: N clients, fixed rate, one server.

    Decision latency per request = uplink transfer + queueing + service +
    downlink transfer.  ``max_clients`` sweeps N until p95 exceeds the
    budget (the paper's Table 6 protocol: 10 Hz, p95 < 100 ms).
    """

    service_time_s: float
    uplink: ShapedLink
    payload_bytes: int
    action_bytes: int = 64
    rate_hz: float = 10.0
    horizon_s: float = 10.0

    def _request_arrivals(self, n_clients: int) -> list[tuple[float, float]]:
        """(t_obs, server_arrival) per request, in observation order.

        The uplink serialises transfers FIFO, so arrivals are
        non-decreasing in this order.
        """
        self.uplink.reset()
        period = 1.0 / self.rate_hz
        events = []          # (obs_time, client)
        for c in range(n_clients):
            t = c * period / n_clients       # staggered clients
            while t < self.horizon_s:
                events.append((t, c))
                t += period
        events.sort()
        return [(t_obs, self.uplink.send(t_obs, self.payload_bytes).arrival)
                for t_obs, _ in events]

    def _return_time(self, done: float) -> float:
        # action return: small payload, same link model (downlink assumed
        # symmetric and uncongested)
        return done + self.uplink.tx_time(self.action_bytes) \
            + self.uplink.propagation_s

    def latencies(self, n_clients: int) -> np.ndarray:
        server_free = 0.0
        lat = []
        for t_obs, arrival in self._request_arrivals(n_clients):
            start = max(arrival, server_free)
            done = start + self.service_time_s
            server_free = done
            lat.append(self._return_time(done) - t_obs)
        return np.asarray(lat)

    def p95(self, n_clients: int) -> float:
        return float(np.percentile(self.latencies(n_clients), 95))

    def max_clients(self, *, p95_budget_s: float = 0.1,
                    n_max: int = 512) -> int:
        best = 0
        for n in range(1, n_max + 1):
            if self.p95(n) <= p95_budget_s:
                best = n
            elif best:       # monotone beyond saturation
                break
        return best


@dataclasses.dataclass
class BatchQueueSim(QueueSim):
    """Micro-batching server against the same client population.

    When the server frees up it launches a batch: all requests that have
    arrived (up to ``max_batch``), after optionally holding the launch up
    to ``max_wait_s`` for the batch to fill.  The whole batch occupies the
    server for ``service_model(B)`` (falling back to the batch-invariant
    ``service_time_s`` when no model is given) and every member's action
    returns at batch completion.  With ``max_batch=1``/``max_wait_s=0``
    this reduces exactly to the FIFO :class:`QueueSim`.
    """

    max_batch: int = 8
    max_wait_s: float = 0.0
    service_model: Optional[Callable[[int], float]] = None

    def service(self, batch: int) -> float:
        if self.service_model is not None:
            return self.service_model(batch)
        return self.service_time_s

    def latencies(self, n_clients: int) -> np.ndarray:
        arr = self._request_arrivals(n_clients)
        n = len(arr)
        server_free = 0.0
        lat = np.empty(n)
        i = 0
        while i < n:
            ready = max(server_free, arr[i][1])
            j_fill = i + self.max_batch - 1
            if j_fill < n and arr[j_fill][1] <= ready:
                launch = ready           # batch already full when server free
            elif self.max_wait_s > 0.0:
                deadline = ready + self.max_wait_s
                fill = arr[j_fill][1] if j_fill < n else np.inf
                launch = max(ready, min(deadline, fill))
            else:
                launch = ready           # greedy: take what's there
            k = i
            while k < n and k - i < self.max_batch and arr[k][1] <= launch:
                k += 1
            done = launch + self.service(k - i)
            t_recv = self._return_time(done)
            for m in range(i, k):
                lat[m] = t_recv - arr[m][0]
            server_free = done
            i = k
        return lat
