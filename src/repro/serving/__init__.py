from repro.serving.netsim import ShapedLink, LinkTrace
from repro.serving.server import PolicyServer, QueueSim
from repro.serving.client import EdgeClient, DecisionLoop

__all__ = ["ShapedLink", "LinkTrace", "PolicyServer", "QueueSim",
           "EdgeClient", "DecisionLoop"]
