"""Serving: the deployed half of the split-policy system.

The canonical way to construct everything in this package is the
declarative deployment API::

    from repro.deploy import Deployment, DeploymentConfig

    dep = Deployment.build(DeploymentConfig.standard(k=4, c_in=12, h=84))
    params = dep.init(key)
    client, server = dep.serving_pair(params)   # EdgeClient + batching server

``DeploymentConfig`` names the encoder spec, input size, execution
backend, wire codec, head placement and micro-batching policy in one
frozen, JSON-serialisable manifest; ``Deployment.build`` resolves it into
the compiled PassPlan, the :class:`~repro.core.split.SplitModel`, and the
ready client/server pair below.  The classes in this package remain the
building blocks that Deployment assembles (and that tests/simulations
drive directly).

Module map
----------
``netsim``
    Deterministic bandwidth-shaped link (the ``tc netem`` stand-in):
    :class:`ShapedLink` serialises transfers FIFO with finite bandwidth,
    propagation delay and optional deterministic jitter.  Plus the
    scenario engine's adversarial family — :class:`TraceLink`
    (trace-driven piecewise bandwidth, integrated across regime
    boundaries), :class:`MarkovLink` (seeded Wi-Fi-style regime
    switching), :class:`LossyLink` (Bernoulli loss + RTO retransmit,
    head-of-line blocking), :class:`StochasticJitterLink` — every
    stochastic link replays bitwise from its seed on ``reset()``, and
    ``LINK_KINDS``/``make_link`` name link shapes for JSON schemas.
``profiles``
    The device zoo: :class:`DeviceProfile` names one hardware class
    (Jetson Nano / Pi 4B / Pi Zero 2W / workstation t(B) curves + encode
    cost); ``zoo`` cycles profiles across a fleet's servers.
``scenario``
    Named serving CONDITIONS: frozen, JSON-round-trippable
    :class:`Scenario` (seeded link + device zoo + client population +
    adaptation-mode ladder) in the ``SCENARIOS`` registry;
    :class:`ScenarioFleetSim` runs one through the fleet engine with a
    per-client adaptation controller (``"none"`` / ``"rule"`` /
    ``register_adaptation``) and scores latency, uplink bytes and the
    delivered-return proxy.  Drive from a manifest via
    ``Deployment.scenario_sim`` or ``python -m repro.deploy --scenario``;
    sweep via ``benchmarks/scenarios.py``.
``client``
    On-device half: :class:`EdgeClient` (the deployment's ``edge_fn`` —
    fused encoder + wire codec — with single and batched measurement) and
    :class:`DecisionLoop` (the paper's Figure-5 obs -> action pipeline for
    one client).
``server``
    Remote half: :class:`PolicyServer` (one request per call, the paper's
    FIFO baseline) and :class:`BatchingPolicyServer` (micro-batching: up
    to ``max_batch`` queued requests served by ONE batched call — the
    policy comes from ``DeploymentConfig.max_batch/max_wait_ms``; measures
    the t(B) service curve interpolated by :class:`BatchServiceModel`).
    Queueing simulators reproduce Table 6: :class:`QueueSim` (strict
    FIFO) and :class:`BatchQueueSim` (batch-aware — launches whatever has
    arrived when the server frees up, optionally holding ``max_wait_s``
    for the batch to fill).  Downlink accounting serialises: a batch of
    B actions charges B transfer slots on the return link, not one.
``fleet``
    Fleet scale: :class:`FleetQueueSim` shards the batch-aware
    simulation across ``n_servers`` micro-batching servers behind a
    pluggable router (``ROUTERS``: ``round_robin`` / ``least_loaded`` /
    ``client_affinity`` hash pinning), each with its own t(B) curve and
    serialised downlink, all fed from the shared shaped uplink.  Fleet
    sizing via ``max_clients`` (geometric + binary search) and
    ``min_servers``; ``n_servers=1`` reduces bitwise to
    :class:`BatchQueueSim`.
``realfleet``
    The fleet for REAL: :class:`RealFleet` spawns ``n_servers``
    continuous-batching :class:`WorkerServer` processes from one
    deployment manifest (localhost TCP, length-prefixed frames carrying
    the existing wire-codec payloads bitwise), fronted by
    :class:`FleetClient` — the SAME registered routers as the sim, plus
    per-request timeouts and re-routing retries.  ``run_load`` drives the
    Table 6 open-loop protocol against it so measured p95 can be
    calibrated against :class:`FleetQueueSim` predictions
    (``benchmarks/realfleet.py``).  Workers optionally token-bucket-shape
    request ingress (:class:`ShapingConfig` / :class:`TokenBucket`) — the
    measured counterpart of the sims' shaped uplink.  Construct via
    :meth:`repro.deploy.Deployment.fleet`.

The batched request path end-to-end: each client encodes ONE frame
(``Deployment.edge_fn`` / ``SplitModel.edge_step``), payloads are stacked
with ``repro.core.wire.stack_payloads`` (per-request quantisation headers
survive stacking), and the server decodes + projects the whole
micro-batch in one call (``Deployment.server_batch_fn`` /
``SplitModel.server_step_batch``).
"""
from repro.serving.netsim import (LINK_KINDS, LinkTrace, LossyLink,
                                  MarkovLink, ShapedLink,
                                  StochasticJitterLink, TraceLink,
                                  make_link, register_link_kind)
from repro.serving.server import (BatchingPolicyServer, BatchQueueSim,
                                  BatchServiceModel, PolicyServer, QueueSim)
from repro.serving.fleet import (FleetQueueSim, ROUTERS, get_router,
                                 register_router, router_names)
from repro.serving.client import EdgeClient, DecisionLoop
from repro.serving.profiles import (DEVICE_PROFILES, DeviceProfile,
                                    get_profile, register_profile, zoo)
from repro.serving.scenario import (ADAPTATIONS, SCENARIOS, AdaptationMode,
                                    Scenario, ScenarioFleetSim,
                                    ScenarioReport, get_adaptation,
                                    get_scenario, register_adaptation,
                                    register_scenario, scenario_names)
from repro.serving.realfleet import (FleetClient, FleetError, FleetTimeout,
                                     LoadReport, RealFleet, ShapingConfig,
                                     TokenBucket, WorkerServer,
                                     pack_payload, run_load, unpack_payload)

__all__ = ["ShapedLink", "LinkTrace", "TraceLink", "MarkovLink",
           "LossyLink", "StochasticJitterLink", "LINK_KINDS", "make_link",
           "register_link_kind", "PolicyServer", "BatchingPolicyServer",
           "BatchServiceModel", "BatchQueueSim", "QueueSim", "FleetQueueSim",
           "ROUTERS", "get_router", "register_router", "router_names",
           "EdgeClient", "DecisionLoop", "DeviceProfile", "DEVICE_PROFILES",
           "get_profile", "register_profile", "zoo", "Scenario",
           "SCENARIOS", "ScenarioFleetSim", "ScenarioReport",
           "AdaptationMode", "ADAPTATIONS", "register_scenario",
           "get_scenario", "scenario_names", "register_adaptation",
           "get_adaptation", "FleetClient", "FleetError", "FleetTimeout",
           "LoadReport", "RealFleet", "ShapingConfig", "TokenBucket",
           "WorkerServer", "pack_payload", "run_load", "unpack_payload"]
