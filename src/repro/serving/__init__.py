"""Serving: the deployed half of the split-policy system.

Module map
----------
``netsim``
    Deterministic bandwidth-shaped link (the ``tc netem`` stand-in):
    :class:`ShapedLink` serialises transfers FIFO with finite bandwidth,
    propagation delay and optional deterministic jitter.
``client``
    On-device half: :class:`EdgeClient` (encoder + wire codec, single and
    batched measurement) and :class:`DecisionLoop` (the paper's Figure-5
    obs -> action pipeline for one client).
``server``
    Remote half: :class:`PolicyServer` (one request per call, the paper's
    FIFO baseline) and :class:`BatchingPolicyServer` (micro-batching: up
    to ``max_batch`` queued requests served by ONE batched call; measures
    the t(B) service curve interpolated by :class:`BatchServiceModel`).
    Queueing simulators reproduce Table 6: :class:`QueueSim` (strict
    FIFO) and :class:`BatchQueueSim` (batch-aware — launches whatever has
    arrived when the server frees up, optionally holding ``max_wait_s``
    for the batch to fill).

The batched request path end-to-end: each client encodes ONE frame
(``repro.core.split.SplitModel.edge_step``), payloads are stacked with
``repro.core.wire.stack_payloads`` (per-request quantisation headers
survive stacking), and the server decodes + projects the whole
micro-batch in one call (``SplitModel.server_step_batch`` /
``benchmarks.decision_latency.build``'s ``split_server_batch_fn``).
"""
from repro.serving.netsim import ShapedLink, LinkTrace
from repro.serving.server import (BatchingPolicyServer, BatchQueueSim,
                                  BatchServiceModel, PolicyServer, QueueSim)
from repro.serving.client import EdgeClient, DecisionLoop

__all__ = ["ShapedLink", "LinkTrace", "PolicyServer", "BatchingPolicyServer",
           "BatchServiceModel", "BatchQueueSim", "QueueSim", "EdgeClient",
           "DecisionLoop"]
