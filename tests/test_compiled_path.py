"""Compiled-path (REPRO_PALLAS_COMPILE=1) validation tier.

Exercises the fused and fused+head kernels COMPILED (interpret=False) at
the ``max_safe_batch`` VMEM boundary and far past it through the
``fused+stream`` batch pipeline.  Most CPU-only JAX builds cannot lower a
non-interpret pallas_call at all ("Only interpret mode is supported on
CPU backend"), so the whole module skips with an explicit marker unless
:func:`repro.kernels.pallas_compat.compiled_pallas_supported` probes
true (TPU hosts, or CPU builds with compiled-Pallas support).  CI runs
this file under ``REPRO_PALLAS_COMPILE=1``; on its CPU runners the skip
marker IS the expected outcome.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.miniconv import miniconv_init, standard_spec
from repro.kernels.pallas_compat import compiled_pallas_supported
from repro.kernels.miniconv_pass import (miniconv_encoder,
                                         miniconv_encoder_stream)

pytestmark = pytest.mark.skipif(
    not compiled_pallas_supported(),
    reason="compiled (non-interpret) Pallas is not supported on this "
           "host's JAX backend — compiled-path tier requires TPU or a "
           "compiled-Pallas-capable build")

X = 48          # deployment-scale input, small enough for CI arrays


@pytest.fixture(scope="module")
def fixture():
    spec = standard_spec()
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    plan = spec.plan(X)
    ws = [params[f"layer{i}"]["kernel"] for i in range(len(spec.layers))]
    bs = [params[f"layer{i}"]["bias"] for i in range(len(spec.layers))]
    hw = jax.random.normal(jax.random.PRNGKey(1),
                           (plan.flat_features, 32)) * 0.05
    hb = jax.random.normal(jax.random.PRNGKey(2), (32,)) * 0.05
    return plan, ws, bs, hw, hb


def _x(b):
    return jax.random.uniform(jax.random.PRNGKey(b), (b, X, X, 12))


@pytest.mark.parametrize("with_head", [False, True])
def test_compiled_fused_at_max_safe_boundary(fixture, with_head):
    """A compiled fused launch at exactly max_safe_batch frames runs and
    matches the interpret-mode oracle."""
    plan, ws, bs, hw, hb = fixture
    head = plan.head(32) if with_head else None
    b = min(plan.max_safe_batch(head=head), 32)
    assert b >= 1
    kw = dict(head_w=hw, head_b=hb) if with_head else {}
    got = miniconv_encoder(_x(b), ws, bs, plan, interpret=False, **kw)
    want = miniconv_encoder(_x(b), ws, bs, plan, interpret=True, **kw)
    if with_head:
        np.testing.assert_allclose(got[0], want[0], atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(got[1], want[1], atol=1e-4, rtol=1e-4)
    else:
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("with_head", [False, True])
def test_compiled_stream_past_max_safe(fixture, with_head):
    """B = 4x the chunk streams through one compiled pipelined launch,
    bitwise-equal to compiled chunk-by-chunk fused execution."""
    plan, ws, bs, hw, hb = fixture
    chunk = min(plan.max_safe_batch(head=plan.head(32) if with_head
                                    else None), 8)
    assert chunk >= 1
    b = 4 * chunk
    kw = dict(head_w=hw, head_b=hb) if with_head else {}
    x = _x(b)
    pipe = miniconv_encoder_stream(x, ws, bs, plan, chunk_b=chunk,
                                   interpret=False, pipelined=True, **kw)
    multi = miniconv_encoder_stream(x, ws, bs, plan, chunk_b=chunk,
                                    interpret=False, pipelined=False, **kw)
    if with_head:
        np.testing.assert_array_equal(pipe[0], multi[0])
        np.testing.assert_array_equal(pipe[1], multi[1])
    else:
        np.testing.assert_array_equal(pipe, multi)
