"""Attention module: chunked-vs-naive equivalence, GQA/qk-norm/bias/
softcap variants, decode-vs-forward cache consistency, windowed decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.attention import (AttentionConfig, attention, attention_init,
                                chunked_attention, decode_attention,
                                init_kv_cache, make_attention_mask,
                                _scores_to_out)


def _cfg(**kw):
    base = dict(d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                block_q=32, block_k=32)
    base.update(kw)
    return AttentionConfig(**base)


def _qkv(cfg, B=2, S=128, key=0):
    k = jax.random.PRNGKey(key)
    ks = jax.random.split(k, 3)
    H = cfg.n_heads
    return [jax.random.normal(kk, (B, S, H, cfg.head_dim)) for kk in ks]


@pytest.mark.parametrize("window", [None, 16, 48])
@pytest.mark.parametrize("softcap", [None, 30.0])
@pytest.mark.parametrize("skip", [False, True])
def test_chunked_matches_naive(window, softcap, skip):
    cfg = _cfg(sliding_window=window, attn_logit_softcap=softcap,
               skip_masked_blocks=skip)
    q, k, v = _qkv(cfg)
    ref = _scores_to_out(cfg, q, k, v, make_attention_mask(cfg, 128, 128))
    out = chunked_attention(cfg, q, k, v)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_chunked_grads_match():
    cfg = _cfg()
    q, k, v = _qkv(cfg)
    g1 = jax.grad(lambda q, k, v: chunked_attention(cfg, q, k, v).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(
        lambda q, k, v: _scores_to_out(
            cfg, q, k, v, make_attention_mask(cfg, 128, 128)).sum(),
        argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("kv_heads,qk_norm,bias,masked", [
    (4, False, False, False), (2, False, False, True),
    (1, True, True, False), (2, True, False, False), (4, False, False, True),
])
def test_decode_matches_forward(kv_heads, qk_norm, bias, masked):
    """Sequential one-token decode reproduces the full forward pass
    (both DUS and masked-where cache updates)."""
    cfg = _cfg(n_kv_heads=kv_heads, qk_norm=qk_norm, qkv_bias=bias,
               chunked_threshold=10_000, masked_cache_update=masked)
    key = jax.random.PRNGKey(1)
    params = attention_init(key, cfg)
    B, S = 2, 16
    x = jax.random.normal(key, (B, S, cfg.d_model))
    full = attention(params, cfg, x)

    cache = init_kv_cache(cfg, B, S, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = decode_attention(params, cfg, x[:, t:t + 1], cache,
                                    jnp.int32(t))
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(dec, full, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("gather", [False, True])
def test_windowed_decode_gather_equivalence(gather):
    """§Perf windowed gather must be bit-compatible with full-mask decode."""
    cfg = _cfg(sliding_window=8, windowed_decode_gather=gather,
               chunked_threshold=10_000)
    key = jax.random.PRNGKey(2)
    params = attention_init(key, cfg)
    B, S = 1, 32
    x = jax.random.normal(key, (B, S, cfg.d_model))
    cache = init_kv_cache(cfg, B, S, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = decode_attention(params, cfg, x[:, t:t + 1], cache,
                                    jnp.int32(t))
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    # reference: full forward with the sliding-window mask
    ref = attention(params, cfg, x)
    np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-4)


def test_attention_uses_chunked_above_threshold():
    cfg = _cfg(chunked_threshold=64)
    key = jax.random.PRNGKey(3)
    params = attention_init(key, cfg)
    x = jax.random.normal(key, (1, 128, cfg.d_model))
    out = attention(params, cfg, x)           # chunked path
    cfg2 = dataclasses.replace(cfg, chunked_threshold=10_000)
    ref = attention(params, cfg2, x)           # naive path
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)
