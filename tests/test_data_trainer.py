"""Synthetic data pipeline + Trainer + checkpointing integration."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticLM, lm_batches, zipf_tokens
from repro.models.registry import get_model
from repro.train import checkpoint
from repro.train.trainer import TrainConfig, Trainer


def test_zipf_tokens_distribution():
    toks = zipf_tokens(jax.random.PRNGKey(0), (20_000,), 1000)
    assert int(toks.min()) >= 0 and int(toks.max()) < 1000
    # zipf: rank-0 strictly more frequent than rank-100
    counts = np.bincount(np.asarray(toks), minlength=1000)
    assert counts[0] > counts[100] > 0


def test_synthetic_lm_batches_deterministic():
    it1 = lm_batches(512, 2, 64, seed=7)
    it2 = lm_batches(512, 2, 64, seed=7)
    b1, b2 = next(it1), next(it2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (2, 64)
    assert int(b1["tokens"][0, 0]) == 1  # BOS


def test_synthetic_lm_learnable_structure():
    """Template layer makes next-token stats predictable: a bigram model
    beats uniform by a wide margin."""
    src = SyntheticLM(vocab=64, seq_len=128, structure=0.9)
    toks = np.asarray(src.batch(jax.random.PRNGKey(0), 16)["tokens"])
    big = np.ones((64, 64))
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            big[a, b] += 1
    big /= big.sum(1, keepdims=True)
    nll = -np.mean([np.log(big[a, b]) for row in toks
                    for a, b in zip(row[:-1], row[1:])])
    assert nll < np.log(64) * 0.8


def test_trainer_loss_decreases():
    cfg, _ = get_model("qwen3-0.6b", reduced=True)
    trainer = Trainer(cfg, TrainConfig(batch=4, steps=25, lr=1e-3,
                                       log_every=5))
    data = lm_batches(cfg.vocab, 4, 64)
    _, _, history = trainer.run(data)
    assert history[-1]["loss"] < history[0]["loss"] - 0.2


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.int32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16) * 1.5,
                  "d": jnp.zeros((), jnp.float32)}}
    path = os.path.join(tmp_path, "ckpt")
    checkpoint.save(path, tree, step=42)
    restored = checkpoint.restore(path, tree)
    assert checkpoint.latest_step(path) == 42
    for l1, l2 in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(l1, np.float32),
                                      np.asarray(l2, np.float32))
        assert l1.dtype == l2.dtype


def test_checkpoint_into_trainer(tmp_path):
    cfg, model = get_model("mamba2-130m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "m")
    checkpoint.save(path, {"params": params})
    restored = checkpoint.restore(path, {"params": params})["params"]
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                                cfg.vocab)
    l1, _ = model.forward(params, tokens)
    l2, _ = model.forward(restored, tokens)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
