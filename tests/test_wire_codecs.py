"""Property tests for every wire codec (hypothesis).

Two families of guarantees, for ALL registered codecs:

* decode(encode(x)) stays within the codec's analytic quantisation error
  bound (float32 exact, bf16 relative, uint8/int8 half-step absolute);
* ``wire_bytes(shape)`` EXACTLY equals the byte size of the real encoded
  payload (data + quantisation headers) — the latency model and the
  roofline accounting bill the link with this number, so it must not
  drift from what ``encode`` actually emits.  The ``Int8ChannelCodec``
  override (per-channel scale header) was previously untested.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.wire import CODECS, get_codec, roundtrip

SHAPES = st.lists(st.integers(1, 6), min_size=1, max_size=4).map(tuple)


def _array(shape, seed, loc, scale):
    x = loc + scale * jax.random.normal(jax.random.PRNGKey(seed), shape)
    return x.astype(jnp.float32)


def _payload_nbytes(payload) -> int:
    return sum(np.asarray(v).nbytes for v in payload.values())


@given(st.sampled_from(sorted(CODECS)), SHAPES, st.integers(0, 2 ** 16),
       st.floats(-100, 100), st.floats(0.01, 50))
@settings(max_examples=80, deadline=None)
def test_wire_bytes_equals_real_payload_size(name, shape, seed, loc, scale):
    codec = get_codec(name)
    payload = codec.encode(_array(shape, seed, loc, scale))
    assert codec.wire_bytes(shape) == _payload_nbytes(payload), \
        (name, shape, {k: (v.shape, v.dtype) for k, v in payload.items()})


@given(SHAPES, st.integers(0, 2 ** 16), st.floats(-100, 100),
       st.floats(0.01, 50))
@settings(max_examples=60, deadline=None)
def test_uint8_roundtrip_half_step_bound(shape, seed, loc, scale):
    x = _array(shape, seed, loc, scale)
    y = roundtrip(get_codec("uint8"), x)
    step = max(float(x.max() - x.min()), 1e-8) / 255.0
    assert float(jnp.abs(y - x).max()) <= step / 2 + 1e-5 * max(abs(loc), 1)


@given(SHAPES, st.integers(0, 2 ** 16), st.floats(-100, 100),
       st.floats(0.01, 50))
@settings(max_examples=60, deadline=None)
def test_int8_channel_roundtrip_per_channel_bound(shape, seed, loc, scale):
    x = _array(shape, seed, loc, scale)
    y = roundtrip(get_codec("int8_channel"), x)
    axes = tuple(range(x.ndim - 1))
    ch_scale = np.maximum(np.asarray(jnp.max(jnp.abs(x), axis=axes)),
                          1e-8) / 127.0
    err = np.asarray(jnp.abs(y - x)).max(axis=axes) if x.ndim > 1 \
        else np.asarray(jnp.abs(y - x))
    assert np.all(err <= ch_scale / 2 + 1e-5 * np.maximum(ch_scale, 1))


@given(SHAPES, st.integers(0, 2 ** 16), st.floats(-100, 100),
       st.floats(0.01, 50))
@settings(max_examples=40, deadline=None)
def test_float32_exact_bf16_relative(shape, seed, loc, scale):
    x = _array(shape, seed, loc, scale)
    assert float(jnp.abs(roundtrip(get_codec("float32"), x) - x).max()) == 0
    y = roundtrip(get_codec("bf16"), x)
    # bf16: 8 mantissa bits -> relative error <= 2^-8 of magnitude
    bound = 2.0 ** -8 * np.maximum(np.abs(np.asarray(x)), 1e-30)
    assert np.all(np.abs(np.asarray(y - x)) <= bound + 1e-30)


@given(st.sampled_from(sorted(CODECS)), st.integers(1, 6),
       st.integers(0, 2 ** 16))
@settings(max_examples=40, deadline=None)
def test_encode_batch_matches_per_example_encode(name, batch, seed):
    """Batched encoding must keep PER-EXAMPLE quantisation: each member's
    payload equals what the single-frame path produces, and the batched
    wire accounting equals batch * wire_bytes."""
    codec = get_codec(name)
    # per-example dynamic ranges differ by orders of magnitude
    x = jax.random.uniform(jax.random.PRNGKey(seed), (batch, 3, 3, 4))
    x = x * (10.0 ** jnp.arange(batch)).reshape(batch, 1, 1, 1)
    bp = codec.encode_batch(x)
    assert _payload_nbytes(bp) == codec.wire_bytes_batch(x.shape[1:], batch)
    for i in range(batch):
        single = codec.encode(x[i])
        for k in single:
            np.testing.assert_allclose(np.asarray(bp[k][i]),
                                       np.asarray(single[k]), rtol=1e-6)
    # decode_batch round-trips to the per-example roundtrip
    y = codec.decode_batch(bp)
    singles = jnp.stack([roundtrip(codec, x[i]) for i in range(batch)])
    np.testing.assert_allclose(y, singles, rtol=1e-5, atol=1e-6)


def test_wire_bytes_batch_is_linear():
    for name, codec in CODECS.items():
        one = codec.wire_bytes((7, 5, 4))
        assert codec.wire_bytes_batch((7, 5, 4), 8) == 8 * one, name
