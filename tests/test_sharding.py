"""Sharding rules: divisibility-greedy assignment, cache specs, and the
activation-constraint context."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_host_mesh
from repro.models import sharding as shd


@pytest.fixture(scope="module")
def mesh():
    # 1x1 mesh with the production axis names: rule logic (divisibility
    # against axis size 1) is exercised without forcing extra devices
    return make_host_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Duck-typed mesh with production axis sizes for pure rule tests."""
    def __init__(self, shape):
        self.shape = shape


PROD = FakeMesh({"data": 16, "model": 16})
PROD_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_attention_param_rules():
    assert shd.param_spec("scan/b0_attn/attn/wq/kernel", (28, 1024, 2048),
                          PROD) == P(None, "data", "model")
    assert shd.param_spec("scan/b0_attn/attn/wo/kernel", (28, 2048, 1024),
                          PROD) == P(None, "model", "data")


def test_vocab_fallback_when_not_divisible():
    # mamba2 vocab 50280 is not divisible by 16: embedding falls back to
    # replicated vocab + FSDP d_model
    spec = shd.param_spec("embed/embedding", (50280, 768), PROD)
    assert spec == P(None, "data")
    spec2 = shd.param_spec("embed/embedding", (151936, 1024), PROD)
    assert spec2 == P("model", "data")


def test_moe_expert_rules():
    # llama4: 16 experts divide "data" -> expert-parallel
    s = shd.param_spec("scan/b0_attn/moe/experts/gate/kernel",
                       (48, 16, 5120, 8192), PROD)
    assert s == P(None, "data", None, "model")
    # qwen2-moe: 60 experts do not divide 16 -> FSDP the D dim instead
    s2 = shd.param_spec("scan/b0_attn/moe/experts/gate/kernel",
                        (24, 60, 2048, 1408), PROD)
    assert s2 == P(None, None, "data", "model")


def test_axis_used_once_per_leaf():
    # both dims divisible by "data" but the axis must be used only once
    s = shd.param_spec("x/experts/gate/kernel", (16, 16, 128), PROD)
    assert list(s).count("data") <= 1


def test_norms_replicated():
    assert shd.param_spec("final_norm/scale", (1024,), PROD) == P()


def test_cache_spec_batch_sharded():
    # decode_32k: batch 128 -> data axes; kv heads 16 -> model
    s = shd.cache_spec("scan/b0_attn/k", (24, 128, 32768, 16, 128), PROD,
                       128)
    assert s == P(None, "data", None, "model", None)


def test_cache_spec_long_context_seq_sharded():
    # long_500k: batch 1 -> sequence gets "data"; kv=8 not divisible ->
    # head_dim gets "model"
    s = shd.cache_spec("scan/b0_attn/k", (32, 1, 524288, 8, 128), PROD, 1)
    assert s == P(None, None, "data", None, "model")


def test_cache_spec_multipod():
    s = shd.cache_spec("scan/b0_attn/v", (24, 128, 1024, 16, 128),
                       PROD_MP, 128)
    assert s[1] == ("pod", "data")


def test_data_spec_fallbacks():
    assert shd.data_spec(PROD_MP, 2, 256)[0] == ("pod", "data")
    assert shd.data_spec(PROD_MP, 2, 16)[0] == "data"   # 16 < 32
    assert shd.data_spec(PROD_MP, 2, 1) == P(None, None)


def test_constrain_noop_outside_context(mesh):
    x = jnp.ones((4, 8))
    assert shd.constrain_act(x) is x


def test_constrain_inside_context(mesh):
    x = jnp.ones((4, 8, 16))
    with shd.activation_sharding(mesh, 4):
        y = shd.constrain_act(x)          # wraps in a constraint
        z = shd.constrain(x, ("batch", None, "model"))
    assert y.shape == x.shape and z.shape == x.shape


def test_param_shardings_tree(mesh):
    from repro.models.registry import abstract_params, get_model
    _, model = get_model("qwen3-0.6b", reduced=True)
    p = abstract_params(model)
    sh = shd.param_shardings(p, mesh)
    assert jax.tree.structure(sh) == jax.tree.structure(p)
