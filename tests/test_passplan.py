"""PassPlan IR: budget properties, shape truth, and agreement between every
place that used to duplicate the ceil/floor shape math."""
import math

import jax
import pytest

from repro.core.latency import SplitConfig
from repro.core.miniconv import (LayerSpec, MiniConvSpec, ShaderBudget,
                                 miniconv_feature_shape, standard_spec)
from repro.core.passplan import (build_pass_plan, count_passes, out_size,
                                 out_spatial_chain, same_pads)
from repro.core.wire import feature_bytes

SPECS = {
    "k4": standard_spec(12, 4),
    "k16": standard_spec(12, 16),
    "c6": MiniConvSpec((LayerSpec(4, 2, 4, 6),
                        LayerSpec(3, 2, 6, 16),
                        LayerSpec(3, 1, 16, 6, activation="sigmoid"))),
    "single": MiniConvSpec((LayerSpec(3, 1, 8, 4),)),
}
SIZES = [64, 84, 100, 101, 400]


@pytest.mark.parametrize("name", sorted(SPECS))
@pytest.mark.parametrize("x", SIZES)
def test_every_pass_respects_budget(name, x):
    spec = SPECS[name]
    plan = build_pass_plan(spec, x)
    for p in plan.passes:
        assert spec.budget.check_pass(p.kernel, p.c_in) == []
        assert p.samples <= spec.budget.max_samples
        assert p.in_textures <= spec.budget.max_textures
        assert 1 <= p.out_hi - p.out_lo <= 4
    assert plan.max_pass_samples <= spec.budget.max_samples


@pytest.mark.parametrize("name", sorted(SPECS))
@pytest.mark.parametrize("x", SIZES)
def test_total_passes_matches_spec(name, x):
    spec = SPECS[name]
    plan = build_pass_plan(spec, x)
    assert plan.total_passes == spec.total_passes == count_passes(spec)
    assert plan.total_passes == sum(l.n_passes for l in spec.layers)
    # groups partition the channels exactly
    for lp in plan.layers:
        slices = [(p.out_lo, p.out_hi) for p in plan.passes
                  if p.layer == lp.index]
        assert slices[0][0] == 0 and slices[-1][1] == lp.c_out
        for (a, b), (c, d) in zip(slices, slices[1:]):
            assert b == c


@pytest.mark.parametrize("name", sorted(SPECS))
@pytest.mark.parametrize("x", SIZES)
def test_plan_shapes_are_the_truth(name, x):
    """plan == MiniConvSpec.* == actual XLA conv output shapes."""
    import jax.numpy as jnp
    from repro.core.miniconv import miniconv_apply, miniconv_init

    spec = SPECS[name]
    plan = build_pass_plan(spec, x)
    assert plan.feature_shape == miniconv_feature_shape(spec, x, x)
    assert plan.out_h == spec.out_spatial(x)
    assert plan.feature_bytes == spec.feature_bytes(x)
    assert plan.flops_per_frame == spec.flops_per_frame(x)
    if x > 100:       # keep the conv check cheap
        return
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    obs = jnp.zeros((1, x, x, spec.layers[0].c_in))
    feats = miniconv_apply(params, spec, obs)
    assert feats.shape[1:] == plan.feature_shape


def test_wire_and_latency_agree_with_plan_for_non_divisible_sizes():
    """The ISSUE-1 satellite: 100x100 through 3 stride-2 layers is 13x13
    (ceil), not 12x12 (the old floor accounting)."""
    assert out_spatial_chain(100, (2, 2, 2)) == 13
    assert feature_bytes(100, 3, 4) == 4 * 13 * 13
    assert SplitConfig(100, 3, 4, 0.1).feature_bytes == 4 * 13 * 13
    # divisible sizes unchanged (paper numbers)
    assert feature_bytes(400, 3, 4) == 4 * 50 * 50
    spec = standard_spec(12, 4)
    assert spec.feature_bytes(100) == build_pass_plan(spec, 100).feature_bytes


def test_same_pads_matches_xla_rule():
    for size in (7, 8, 84, 101):
        for k, s in ((3, 1), (3, 2), (4, 2)):
            lo, hi = same_pads(size, k, s)
            out = out_size(size, s)
            assert lo + hi == max((out - 1) * s + k - size, 0)
            assert hi - lo in (0, 1)


def test_over_budget_plan_raises_at_build_time():
    bad = MiniConvSpec((LayerSpec(5, 2, 12, 16),))    # 75 samples > 64
    with pytest.raises(ValueError):
        build_pass_plan(bad, 64)
    tight = ShaderBudget(max_samples=48)
    ok = MiniConvSpec((LayerSpec(4, 2, 12, 16),), budget=tight)  # exactly 48
    build_pass_plan(ok, 64)


def test_texture_bindings_pack_rgba():
    plan = build_pass_plan(standard_spec(12, 4), 64)
    p0 = plan.passes[0]
    assert p0.texture_bindings == ((0, 4), (4, 8), (8, 12))
    assert p0.in_textures == 3 and p0.samples == 4 * 4 * 3
