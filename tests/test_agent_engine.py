"""Unified Agent protocol + device-resident engines (ISSUE 5).

Covers: the Agent bundle contract for all three algorithms; the
off-policy engine's chunk plan and device loop; the end-of-training
truncation accounting bugfix (episodes are counted consistently instead
of silently dropping final partials); and the serve-from-manifest
round-trip — a TRAINED policy served through EdgeClient -> wire ->
BatchingPolicyServer matches the in-process policy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.envs import make_pixel_env
from repro.rl.agent import Agent, TrainState, make_agent
from repro.rl.ddpg import DDPGConfig
from repro.rl.ppo import PPOConfig
from repro.rl.sac import SACConfig
from repro.rl.rollout import make_engine
from repro.rl.train import (TrainResult, _flush_truncated, _track_episodes,
                            train)

# tiny configs: enough to exercise warmup -> train transitions and at
# least one interleaved gradient update without heavy compiles
SMALL = {
    "sac": SACConfig(batch_size=8, buffer_size=64, learning_starts=8,
                     n_envs=2),
    "ddpg": DDPGConfig(batch_size=8, buffer_size=64, learning_starts=8,
                       n_envs=2),
    "ppo": PPOConfig(n_envs=2, n_steps=8, n_epochs=1, n_minibatches=2),
}


def _agent(algo, env):
    from repro.rl.train import _pipeline_encoder
    enc = _pipeline_encoder("miniconv4", env.obs_shape[-1])
    return make_agent(algo, enc, env.action_dim, cfg=SMALL[algo])


# ------------------------------------------------------------- the protocol
@pytest.mark.parametrize("algo", ["ppo", "sac", "ddpg"])
def test_agent_protocol(algo):
    env = make_pixel_env("pendulum", train=True)
    agent = _agent(algo, env)
    assert isinstance(agent, Agent)
    assert agent.on_policy == (algo == "ppo")
    state = agent.init(jax.random.PRNGKey(0))
    assert isinstance(state, TrainState)
    assert (state.target == {}) == (algo == "ppo")
    obs = jnp.zeros((3, 84, 84, 9))
    action, extras = agent.act(state.params, obs, jax.random.PRNGKey(1))
    assert action.shape == (3, env.action_dim)
    if algo == "ppo":                       # trajectory extras for the update
        assert set(extras) == {"logp", "value"}
        assert extras["value"].shape == (3,)
    else:
        assert extras == {}
    # target_update is pure and type-preserving
    state2 = agent.target_update(state)
    assert isinstance(state2, TrainState)
    # serving head: feats -> deterministic batched action
    head = agent.policy_head(state.params)
    a = head(jnp.zeros((5, 512)))
    assert a.shape == (5, env.action_dim)
    assert np.isfinite(np.asarray(a)).all()


def test_make_agent_rejects_unknown():
    env = make_pixel_env("pendulum", train=True)
    from repro.rl.train import _pipeline_encoder
    enc = _pipeline_encoder("miniconv4", env.obs_shape[-1])
    with pytest.raises(ValueError, match="unknown algorithm"):
        make_agent("td3", enc, env.action_dim)


# ----------------------------------------------------------------- the plan
def test_offpolicy_plan_shapes():
    env = make_pixel_env("pendulum", train=True)
    agent = _agent("ddpg", env)              # learning_starts=8, n_envs=2
    plan = make_engine(env, agent, total_steps=40).plan()
    # 20 vectorised steps: 4 warmup (8 random env steps) + 16 train
    assert plan[0] == ("warmup", 4)
    assert all(kind == "train" for kind, _ in plan[1:])
    assert sum(n for _, n in plan) == 20
    # budget smaller than warmup: pure random, no train chunks.  The
    # budget is baked in at construction (the ring is sized from it), so
    # a different budget means a different engine.
    assert make_engine(env, agent, total_steps=6).plan() == [("warmup", 3)]


def test_onpolicy_plan_shapes():
    env = make_pixel_env("pendulum", train=True)
    agent = _agent("ppo", env)               # n_envs=2, n_steps=8
    assert make_engine(env, agent, total_steps=64).plan() == \
        [("iter", 8)] * 4
    assert make_engine(env, agent, total_steps=1).plan() == \
        [("iter", 8)]                        # at least one iteration


# ------------------------------------------------- truncation accounting fix
def test_track_episodes_counts_dones_and_flushes_partials():
    """Regression (ISSUE 5 bugfix): the final truncated episode's partial
    return used to be dropped silently; episodes = completed + flushed
    partials, and every reward lands in exactly one of them."""
    rewards = np.array([[1.0, 10.0], [2.0, 20.0], [4.0, 40.0]])
    dones = np.array([[0, 0], [1, 0], [0, 0]], dtype=bool)
    returns, ep_ret, ep_len = [], np.zeros(2), np.zeros(2, np.int64)
    ep_ret, ep_len = _track_episodes(returns, ep_ret, ep_len, rewards, dones)
    assert returns == [3.0]                      # env 0 finished at t=1
    truncated = _flush_truncated(ep_ret, ep_len)
    assert truncated == [4.0, 70.0]              # both partials flushed
    assert sum(returns) + sum(truncated) == rewards.sum()
    # an env that JUST finished has nothing to flush
    assert _flush_truncated(np.zeros(2), np.zeros(2, np.int64)) == []


def test_train_result_stats_cover_truncated():
    res = TrainResult("pendulum", "ddpg", "miniconv4",
                      episode_returns=[1.0, 2.0], wall_time_s=1.0,
                      truncated_returns=[5.0], env_steps=30)
    assert res.all_returns == [1.0, 2.0, 5.0]
    # Best/Mean/Final stay the paper's per-episode stats: a short partial
    # must not become "Best" — completed episodes win when any exist
    assert res.best == 2.0 and res.mean == pytest.approx(1.5)
    s = res.summary()
    assert s["episodes"] == 3 and s["episodes_truncated"] == 1
    assert s["steps_per_sec"] == pytest.approx(30.0)
    # smoke scale: nothing completed -> truncated partials keep stats finite
    only_trunc = TrainResult("pendulum", "ddpg", "miniconv4", [], 1.0,
                             truncated_returns=[5.0])
    assert only_trunc.best == 5.0 and only_trunc.mean == 5.0
    # no episodes at all -> stats are NaN but summary stays well-formed
    empty = TrainResult("pendulum", "ddpg", "miniconv4", [], 1.0)
    assert np.isnan(empty.best) and empty.summary()["episodes"] == 0


@pytest.mark.slow
def test_truncated_episodes_reported_at_smoke_scale():
    """At 64 steps over 2 envs no pendulum episode (200 steps) can finish:
    the seed loop reported episodes=0 here; the fixed accounting reports
    one truncated partial per env."""
    res = train("pendulum", "miniconv4", total_steps=64,
                cfg=SMALL["ddpg"])
    assert res.episode_returns == []
    assert len(res.truncated_returns) == 2
    assert res.summary()["episodes"] == 2
    assert np.isfinite(res.mean) and np.isfinite(res.best)
    assert res.env_steps == 64


# ------------------------------------------------------- engines end-to-end
@pytest.mark.slow
@pytest.mark.parametrize("task,algo", [("pendulum", "ddpg"),
                                       ("hopper", "sac")])
def test_offpolicy_engine_trains_on_device(task, algo):
    """Warmup + interleaved device updates produce finite parameters,
    per-chunk (T, N) reward/done arrays and a served-ready TrainState."""
    res = train(task, "miniconv4", total_steps=48, cfg=SMALL[algo], seed=1)
    assert res.algo == algo
    assert res.env_steps == 48
    assert res.summary()["episodes"] >= 2     # >= one partial per env
    assert np.isfinite(res.mean)
    assert res.params is not None
    flat = jax.tree.leaves(res.params)
    assert flat and all(np.isfinite(np.asarray(x)).all() for x in flat)


@pytest.mark.slow
def test_onpolicy_engine_trains():
    res = train("walker", "miniconv4", total_steps=32, cfg=SMALL["ppo"],
                seed=1)
    assert res.algo == "ppo" and res.env_steps == 32   # two (8, 2) iters
    assert np.isfinite(res.mean)
    assert res.params is not None


# --------------------------------------------- serve-from-manifest roundtrip
@pytest.mark.slow
def test_trained_policy_serves_from_manifest():
    """ISSUE 5 satellite (closes PR 3's 'serve the trained policy from one
    manifest'): train(deploy_config=...) -> TrainResult.params ->
    Deployment.serving_pair; the EdgeClient -> wire -> BatchingPolicyServer
    action equals the in-process policy on the same observation."""
    from repro.deploy import Deployment, DeploymentConfig
    cfg = DeploymentConfig.from_encoder_name("miniconv4", c_in=9,
                                             backend="xla")
    res = train("pendulum", "miniconv4", total_steps=16, cfg=SMALL["ddpg"],
                deploy_config=cfg, seed=3)
    dep = Deployment.build(cfg)
    agent = make_agent("ddpg", dep.encoder, 1, cfg=SMALL["ddpg"])
    head = agent.policy_head(res.params)

    env = make_pixel_env("pendulum", train=False)
    _, obs = env.reset(jax.random.PRNGKey(0))
    obs = obs[None]

    # served: one manifest, trained params, full wire path
    client, server = dep.serving_pair(res.params, head=head)
    payload = client.encode_fn(obs)
    served = np.asarray(server.serve([payload])[0])

    # in-process, quantisation-aware: same math as the served path (the
    # batched server step may differ by float ulps under jit)
    enc_params = res.params["encoder"]
    feats = dep.split.server_step(enc_params["server"],
                                  dep.split.edge_step(enc_params["edge"],
                                                      obs))
    inproc = np.asarray(head(feats)[0])
    np.testing.assert_allclose(served, inproc, rtol=1e-5, atol=1e-6)

    # and close to the float (no-wire) policy: only uint8 feature
    # quantisation separates them
    float_feats = dep.encoder.apply(enc_params, obs)
    float_action = np.asarray(head(float_feats)[0])
    np.testing.assert_allclose(served, float_action, atol=0.25)
    assert served.shape == (1,) and np.isfinite(served).all()
