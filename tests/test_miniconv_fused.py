"""Parity of the fused execution tiers against the legacy per-pass kernel
(the reference oracle) and XLA SAME convs, across strides, kernel sizes,
odd/even inputs and c_out not divisible by 4 (interpret mode, fp32)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.miniconv import (LayerSpec, MiniConvSpec, miniconv_apply,
                                 miniconv_init, standard_spec)
from repro.kernels.ops import miniconv_layer

MODES = ("per_pass", "grouped", "fused")


def _run_all(spec, h, w, *, batch=1, seed=0):
    params = miniconv_init(jax.random.PRNGKey(seed), spec)
    x = jax.random.uniform(jax.random.PRNGKey(seed + 1),
                           (batch, h, w, spec.layers[0].c_in))
    ref = miniconv_apply(params, spec, x)                  # XLA oracle
    outs = {m: miniconv_apply(params, spec, x, use_kernel=m) for m in MODES}
    return ref, outs


@pytest.mark.parametrize("kernel,stride", [(3, 1), (3, 2), (4, 2)])
@pytest.mark.parametrize("size", [(16, 16), (17, 23)])   # even / odd
@pytest.mark.parametrize("c_out", [4, 6, 16])
def test_single_layer_parity(kernel, stride, size, c_out):
    spec = MiniConvSpec((LayerSpec(kernel, stride, 8, c_out),))
    ref, outs = _run_all(spec, *size)
    for mode, out in outs.items():
        assert out.shape == ref.shape, (mode, out.shape, ref.shape)
        np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5,
                                   err_msg=mode)


@pytest.mark.parametrize("h,w", [(84, 84), (83, 59)])
@pytest.mark.parametrize("k", [4, 16])
def test_standard_spec_family_parity(h, w, k):
    """The ISSUE-1 acceptance criterion: fused matches per-pass within 1e-5
    on the standard_spec family."""
    spec = standard_spec(c_in=12, k=k)
    ref, outs = _run_all(spec, h, w, batch=2)
    np.testing.assert_allclose(outs["fused"], outs["per_pass"],
                               atol=1e-5, rtol=1e-5)
    for mode, out in outs.items():
        np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5,
                                   err_msg=mode)


def test_multi_layer_c_out_not_divisible_by_4():
    """Specs with K % 4 != 0 validate AND execute (the old kernel path
    crashed on an assert); sigmoid on an intermediate ragged layer must not
    leak through the zero-padded channels."""
    spec = MiniConvSpec((LayerSpec(4, 2, 4, 6, activation="sigmoid"),
                         LayerSpec(3, 2, 6, 16),
                         LayerSpec(3, 1, 16, 6)))
    spec.validate()
    ref, outs = _run_all(spec, 33, 19)
    assert ref.shape[-1] == 6
    for mode, out in outs.items():
        np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5,
                                   err_msg=mode)


def test_layer_kernel_c_out_6_no_crash():
    """Direct layer-level check of the padded final output group."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (2, 12, 12, 8))
    w = jax.random.normal(key, (3, 3, 8, 6)) * 0.1
    b = jnp.zeros((6,))
    from repro.nn.layers import conv2d
    ref = conv2d({"kernel": w, "bias": b}, x, stride=2, padding="SAME")
    for fused_groups in (False, True):
        out = miniconv_layer(x, w, b, stride=2, interpret=True,
                             fused_groups=fused_groups)
        assert out.shape == ref.shape
        np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("tile_h", [1, 3, 8, 64])
def test_fused_tile_h_sweep(tile_h):
    """Every row tiling (including tile_h > out_h and non-divisible
    out_h) produces identical features."""
    spec = standard_spec(c_in=4, k=4)
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    x = jax.random.uniform(jax.random.PRNGKey(1), (1, 84, 84, 4))
    ref = miniconv_apply(params, spec, x)
    out = miniconv_apply(params, spec, x, use_kernel="fused", tile_h=tile_h)
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


def test_fused_batch_independence():
    """Scratch re-initialisation across batch grid steps: batched run ==
    stacked single runs."""
    spec = standard_spec(c_in=4, k=4)
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    x = jax.random.uniform(jax.random.PRNGKey(1), (3, 32, 32, 4))
    batched = miniconv_apply(params, spec, x, use_kernel="fused")
    singles = jnp.concatenate(
        [miniconv_apply(params, spec, x[i:i + 1], use_kernel="fused")
         for i in range(3)])
    np.testing.assert_allclose(batched, singles, atol=1e-6, rtol=1e-6)


def test_use_kernel_true_is_per_pass_alias():
    spec = standard_spec(c_in=4, k=4)
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    x = jax.random.uniform(jax.random.PRNGKey(1), (1, 16, 16, 4))
    a = miniconv_apply(params, spec, x, use_kernel=True)
    b = miniconv_apply(params, spec, x, use_kernel="per_pass")
    np.testing.assert_allclose(a, b, atol=0, rtol=0)


def test_bad_mode_raises():
    spec = standard_spec(c_in=4, k=4)
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    x = jnp.zeros((1, 16, 16, 4))
    with pytest.raises(ValueError):
        miniconv_apply(params, spec, x, use_kernel="warp")
