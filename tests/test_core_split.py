"""The paper's core: SplitModel partition + wire codecs + break-even
latency model.  Property tests use hypothesis (optional dev dependency:
see requirements-dev.txt; the module is skipped when absent)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.latency import (LinkModel, SplitConfig,
                                break_even_bandwidth,
                                decision_latency_server_only,
                                decision_latency_split,
                                paper_pi_zero_config)
from repro.core.miniconv import (PI_ZERO_BUDGET, LayerSpec, MiniConvSpec,
                                 miniconv_apply, miniconv_init,
                                 standard_spec)
from repro.core.split import make_split_policy, straight_through
from repro.core.wire import CODECS, feature_bytes, frame_bytes_rgba, \
    get_codec, roundtrip
from repro.models.registry import get_model


# ---------------------------------------------------------------- wire
@given(st.sampled_from(sorted(CODECS)),
       st.integers(2, 6), st.integers(2, 6),
       st.floats(-100, 100), st.floats(0.1, 50))
@settings(max_examples=60, deadline=None)
def test_codec_roundtrip_error_bound(name, h, w, loc, scale):
    codec = get_codec(name)
    x = loc + scale * jax.random.normal(jax.random.PRNGKey(h * w),
                                        (h, w, 4))
    y = roundtrip(codec, x)
    rng = float(x.max() - x.min())
    err = float(jnp.abs(y - x).max())
    if name == "float32":
        assert err == 0.0
    elif name == "uint8":
        assert err <= rng / 255.0 + 1e-4
    elif name == "int8_channel":
        amax = np.asarray(jnp.max(jnp.abs(x), axis=(0, 1)))
        assert err <= float(amax.max()) / 127.0 + 1e-4
    else:  # bf16
        assert err <= 0.01 * max(abs(loc) + 3 * scale, 1.0)


@given(st.integers(1, 8), st.integers(1, 64), st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_wire_bytes_exact(c, h, w):
    assert get_codec("uint8").wire_bytes((h, w, c)) == h * w * c + 8
    assert get_codec("bf16").wire_bytes((h, w, c)) == 2 * h * w * c
    assert get_codec("float32").wire_bytes((h, w, c)) == 4 * h * w * c


def test_feature_vs_frame_bytes_paper_numbers():
    # paper: X=400, n=3, K=4 -> frame 640000 B, feature 4*(50^2)=10000 B
    assert frame_bytes_rgba(400) == 4 * 400 * 400
    assert feature_bytes(400, 3, 4) == 4 * 50 * 50


# ------------------------------------------------------------- latency
def test_paper_break_even_number():
    """Paper §4.2: X=400, n=3, j~0.1s, K=4 => ~50.4 Mb/s."""
    b = break_even_bandwidth(paper_pi_zero_config())
    assert abs(b / 1e6 - 50.4) < 0.1


@given(st.integers(64, 1024), st.integers(1, 4), st.sampled_from([4, 16]),
       st.floats(0.01, 1.0))
@settings(max_examples=60, deadline=None)
def test_split_wins_below_break_even(x, n, k, j):
    cfg = SplitConfig(x_size=x, n_stride2=n, k_channels=k, encode_time_s=j)
    b_star = break_even_bandwidth(cfg)
    if b_star <= 0:
        return
    for frac, should_win in [(0.5, True), (2.0, False)]:
        link = LinkModel(bandwidth_bps=b_star * frac)
        so = decision_latency_server_only(cfg, link, action_bytes=0)
        sp = decision_latency_split(cfg, link, action_bytes=0)
        assert (sp < so) == should_win


@given(st.floats(0.01, 1.0), st.floats(0.01, 1.0))
@settings(max_examples=30, deadline=None)
def test_break_even_monotone_in_encode_time(j1, j2):
    if j1 > j2:
        j1, j2 = j2, j1
    mk = lambda j: break_even_bandwidth(SplitConfig(400, 3, 4, j))
    assert mk(j1) >= mk(j2)   # slower device => split wins less often


# ------------------------------------------------------------ miniconv
def test_shader_budget_paper_constraints():
    assert PI_ZERO_BUDGET.max_textures == 8
    assert PI_ZERO_BUDGET.max_samples == 64
    assert PI_ZERO_BUDGET.max_in_channels == 32
    # 4x4 kernel over 12 channels = 48 samples: OK
    assert PI_ZERO_BUDGET.check_pass(4, 12) == []
    # 5x5 over 12 channels = 75 samples: over budget
    assert PI_ZERO_BUDGET.check_pass(5, 12)
    # 40 input channels exceeds 8 textures
    assert PI_ZERO_BUDGET.check_pass(1, 40)


def test_invalid_spec_raises():
    bad = MiniConvSpec((LayerSpec(5, 2, 12, 16),))  # 75 samples
    with pytest.raises(ValueError):
        bad.validate()


@pytest.mark.parametrize("k", [4, 16])
def test_standard_spec_properties(k):
    spec = standard_spec(12, k)
    assert spec.k_out == k
    assert spec.n_stride2 == 3
    assert spec.out_spatial(84) == 11
    # bytes on the wire shrink vs an RGBA frame
    assert spec.feature_bytes(400) < frame_bytes_rgba(400)


def test_miniconv_apply_shapes_and_kernel_path():
    spec = standard_spec(12, 4)
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 84, 84, 12))
    feats = miniconv_apply(params, spec, x)
    assert feats.shape == (2, 11, 11, 4)
    feats_k = miniconv_apply(params, spec, x, use_kernel=True)
    np.testing.assert_allclose(feats, feats_k, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------- split model
def test_split_policy_composes():
    spec = standard_spec(12, 4)
    enc_params = miniconv_init(jax.random.PRNGKey(0), spec)
    head = jax.random.normal(jax.random.PRNGKey(1), (11 * 11 * 4, 3)) * 0.1

    sm = make_split_policy(
        lambda p, obs: miniconv_apply(p, spec, obs),
        lambda p, f: f.reshape(f.shape[0], -1) @ p,
        codec="float32")
    obs = jax.random.uniform(jax.random.PRNGKey(2), (2, 84, 84, 12))
    # deployment path == training path for the lossless codec
    payload = sm.edge_step(enc_params, obs)
    out_deploy = sm.server_step(head, payload)
    out_train = sm.apply({"edge": enc_params, "server": head}, obs)
    np.testing.assert_allclose(out_deploy, out_train, atol=1e-6)


def test_split_policy_uint8_close():
    spec = standard_spec(12, 4)
    enc_params = miniconv_init(jax.random.PRNGKey(0), spec)
    head = jax.random.normal(jax.random.PRNGKey(1), (11 * 11 * 4, 3)) * 0.1
    sm = make_split_policy(
        lambda p, obs: miniconv_apply(p, spec, obs),
        lambda p, f: f.reshape(f.shape[0], -1) @ p,
        codec="uint8")
    obs = jax.random.uniform(jax.random.PRNGKey(2), (2, 84, 84, 12))
    q = sm.server_step(head, sm.edge_step(enc_params, obs))
    f = sm.edge_apply(enc_params, obs).reshape(2, -1) @ head
    np.testing.assert_allclose(q, f, atol=0.05, rtol=0.1)


def test_straight_through_gradient_is_identity():
    codec = get_codec("uint8")
    x = jax.random.uniform(jax.random.PRNGKey(0), (4, 4))
    g = jax.grad(lambda x: straight_through(codec, x).sum())(x)
    np.testing.assert_allclose(g, jnp.ones_like(x))


def test_transformer_split_equals_monolith():
    """The paper's partition applied to an assigned LLM: edge + server
    halves reproduce the monolithic forward exactly (float32 codec)."""
    cfg, model = get_model("qwen3-0.6b", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 3,
                                cfg.vocab)
    mono, _ = model.forward(params, tokens)
    edge_p, server_p = model.split_params(params, 1)
    h = model.edge_forward(edge_p, tokens)
    logits = model.server_forward(server_p, h)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(mono, np.float32),
                               atol=1e-3, rtol=1e-3)
