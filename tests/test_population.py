"""Population engine + the paper's final-100-episode eval protocol.

Covers: PopulationSpec member enumeration and program grouping (static
vs VMAPPABLE overrides), the serialisation round-trip, the population
PRNG chain, lane independence of the batched env helpers, and — the
acceptance bar — member 0 of a population being BITWISE-equal to a
single ``train()`` run at the same seed, hyperparameter lanes training
independently inside one program (an lr=0 lane stays frozen at init),
and the eval protocol replaying bitwise at a fixed seed.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.envs import make_pixel_env
from repro.rl.agent import make_agent
from repro.rl.ddpg import DDPGConfig
from repro.rl.ppo import PPOConfig
from repro.rl.sac import SACConfig
from repro.rl.population import (PopulationSpec, SPEC_VERSION, evaluate,
                                 final_100_mean, make_evaluator,
                                 make_population_evaluator,
                                 split_member_keys, train_population)
from repro.rl.train import _pipeline_encoder, train

# tiny off-policy config: warmup -> train transition plus real gradient
# updates, small enough to compile fast (mirrors test_agent_engine.SMALL)
SMALL = {"batch_size": 8, "buffer_size": 64, "learning_starts": 8,
         "n_envs": 2}
STEPS = 32


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# ----------------------------------------------------------------- the spec
def test_spec_member_enumeration():
    spec = PopulationSpec(tasks=("pendulum", "hopper"), seeds=(0, 7),
                          variants=({"lr": 1e-3}, {"lr": 1e-4}))
    assert spec.n_members == 8
    members = spec.members()
    assert [m.index for m in members] == list(range(8))
    # task-major, then variant, then seed
    assert [(m.task, m.variant_index, m.seed) for m in members[:4]] == [
        ("pendulum", 0, 0), ("pendulum", 0, 7),
        ("pendulum", 1, 0), ("pendulum", 1, 7)]
    assert members[4].task == "hopper" and members[4].algo == "sac"
    assert members[0].algo == "ddpg"


def test_spec_canonicalisation():
    # a single task string, dict variants and pair variants all normalise
    a = PopulationSpec(tasks="pendulum", seeds=(0,),
                       variants=({"lr": 1e-3, "gamma": 0.9},))
    b = PopulationSpec(tasks=("pendulum",), seeds=(0,),
                       variants=((("gamma", 0.9), ("lr", 1e-3)),))
    assert a == b
    with pytest.raises(ValueError, match="unknown task"):
        PopulationSpec(tasks=("cartpole",), seeds=(0,))
    with pytest.raises(ValueError, match="seed"):
        PopulationSpec(tasks=("pendulum",), seeds=())


def test_spec_roundtrip_and_version():
    spec = PopulationSpec(tasks=("pendulum",), seeds=(0, 1),
                          variants=({"lr": 1e-3}, {}),
                          total_steps=64, cfg_overrides={"n_envs": 2})
    assert PopulationSpec.from_dict(spec.to_dict()) == spec
    stale = spec.to_dict()
    stale["version"] = SPEC_VERSION + 1
    with pytest.raises(ValueError, match="version"):
        PopulationSpec.from_dict(stale)


def test_programs_static_vs_vmappable():
    # lr is VMAPPABLE -> both variants share ONE program with an lr column
    spec = PopulationSpec(tasks=("pendulum",), seeds=(0,),
                          variants=({"lr": 1e-3}, {"lr": 0.0}),
                          cfg_overrides=SMALL)
    progs = spec.programs()
    assert len(progs) == 1
    assert progs[0].hyper_fields == ("lr",)
    np.testing.assert_array_equal(
        np.asarray(progs[0].hyper_arrays()["lr"]),
        np.float32([1e-3, 0.0]))

    # batch_size is static (a shape) -> the program splits
    spec = PopulationSpec(tasks=("pendulum",), seeds=(0,),
                          variants=({"batch_size": 8}, {"batch_size": 16}),
                          cfg_overrides=SMALL)
    assert len(spec.programs()) == 2

    # tasks never share a program (different envs / algorithms)
    spec = PopulationSpec(tasks=("pendulum", "hopper"), seeds=(0,))
    assert len(spec.programs()) == 2

    with pytest.raises(ValueError, match="no field"):
        PopulationSpec(tasks=("pendulum",), seeds=(0,),
                       variants=({"learning_rate": 1e-3},)).programs()


def test_vmappable_declared():
    for cls, expect in ((PPOConfig, {"lr", "gamma", "clip_eps"}),
                        (SACConfig, {"lr", "gamma", "tau"}),
                        (DDPGConfig, {"lr", "gamma", "action_noise"})):
        fields = {f.name for f in dataclasses.fields(cls)}
        assert expect <= cls.VMAPPABLE <= fields
        # shape-bearing fields must never be marked vmappable
        assert not {"n_envs", "batch_size", "buffer_size"} & cls.VMAPPABLE


def test_final_100_mean():
    assert np.isnan(final_100_mean([]))
    assert final_100_mean([1.0, 2.0, 3.0]) == 2.0
    # >100 episodes: only the last 100 count (the paper's "Final")
    r = [0.0] * 50 + [2.0] * 100
    assert final_100_mean(r) == 2.0


# ------------------------------------------------------- PRNG + env batching
def test_split_member_keys_matches_single_split():
    keys = jnp.stack([jax.random.PRNGKey(s) for s in (0, 3, 11)])
    a, b = split_member_keys(keys)
    for p in range(3):
        ea, eb = jax.random.split(keys[p])
        assert np.array_equal(np.asarray(a[p]), np.asarray(ea))
        assert np.array_equal(np.asarray(b[p]), np.asarray(eb))


def test_population_env_batches_are_lane_independent():
    env = make_pixel_env("pendulum", train=True)
    P, N = 2, 2
    keys = jnp.stack([jax.random.split(jax.random.PRNGKey(s), N)
                      for s in (0, 1)])
    states, obs = env.reset_population(keys)
    assert obs.shape[:2] == (P, N)
    actions = jnp.zeros((P, N, env.action_dim)).at[1].set(0.5)
    states2, obs2, rew, done = env.step_population(states, actions)
    # each lane is exactly the per-member batched env
    for p in range(P):
        s_ref, o_ref = env.reset_batch(keys[p])
        assert _tree_equal(o_ref, obs[p])
        _, o2_ref, r_ref, d_ref = env.step_batch(s_ref, actions[p])
        assert _tree_equal(o2_ref, obs2[p])
        assert np.array_equal(np.asarray(r_ref), np.asarray(rew[p]))


# ------------------------------------------------- training parity (bitwise)
@pytest.fixture(scope="module")
def pop_run():
    """P=2 seeds, WITH gradient updates, plus the protocol eval on a
    shortened window — shared by the parity/eval/e2e tests below."""
    spec = PopulationSpec(tasks=("pendulum",), seeds=(0, 1),
                          total_steps=STEPS, cfg_overrides=SMALL)
    return train_population(spec, eval_episodes=4, eval_max_steps=8)


@pytest.fixture(scope="module")
def single_run():
    return train("pendulum", "miniconv4", total_steps=STEPS, seed=0,
                 cfg=DDPGConfig(**SMALL))


@pytest.mark.slow
def test_member0_bitwise_equals_single_run(pop_run, single_run):
    """The acceptance bar: exact lane mode reproduces ``train()`` at the
    same seed bitwise — params AND the episode-return stream."""
    m0, m1 = pop_run.members[0], pop_run.members[1]
    assert _tree_equal(m0.params, single_run.params)
    assert m0.episode_returns == single_run.episode_returns
    assert m0.truncated_returns == single_run.truncated_returns
    assert m0.env_steps == single_run.env_steps
    # and the other seed genuinely trained a different agent
    assert not _tree_equal(m1.params, single_run.params)


@pytest.mark.slow
def test_hyper_lanes_train_independently():
    """One program, two lr lanes: the lr=0 lane must end bitwise at its
    init params while the lr>0 lane moves — hyperparameters really flow
    through the traced update, per member."""
    spec = PopulationSpec(tasks=("pendulum",), seeds=(0,),
                          variants=({"lr": 1e-3}, {"lr": 0.0}),
                          total_steps=STEPS, cfg_overrides=SMALL)
    assert len(spec.programs()) == 1
    res = train_population(spec, eval_episodes=0)
    # reference init params: the driver's chain is seed -> (k_init, _) and
    # the engine init splits again into (k_agent, k_env) before agent.init
    env = make_pixel_env("pendulum", train=True)
    enc = _pipeline_encoder("miniconv4", env.obs_shape[-1])
    agent = make_agent("ddpg", enc, env.action_dim, cfg=DDPGConfig(**SMALL))
    k_init, _ = jax.random.split(jax.random.PRNGKey(0))
    k_agent, _ = jax.random.split(k_init)
    init_params = agent.init(k_agent).params
    frozen = res.members[1]      # variant 1 = lr 0.0
    trained = res.members[0]     # variant 0 = lr 1e-3
    assert _tree_equal(frozen.params, init_params)
    assert not _tree_equal(trained.params, init_params)


@pytest.mark.slow
def test_onpolicy_population_parity():
    """The on-policy (PPO) lane path: member 0 bitwise vs train()."""
    ppo = {"n_envs": 2, "n_steps": 4, "n_epochs": 1, "n_minibatches": 2}
    spec = PopulationSpec(tasks=("walker",), seeds=(0, 1), total_steps=16,
                          cfg_overrides=ppo)
    res = train_population(spec, eval_episodes=0)
    single = train("walker", "miniconv4", total_steps=16, seed=0,
                   cfg=PPOConfig(**ppo))
    assert _tree_equal(res.members[0].params, single.params)
    assert res.members[0].truncated_returns == single.truncated_returns


# ----------------------------------------------------------- eval protocol
@pytest.mark.slow
def test_evaluate_bitwise_replay(pop_run):
    env = make_pixel_env("pendulum", train=False)
    enc = _pipeline_encoder("miniconv4", env.obs_shape[-1])
    agent = make_agent("ddpg", enc, env.action_dim)
    params = pop_run.members[0].params
    r1 = evaluate(agent, params, 4, env=env, seed=5, max_steps=8)
    r2 = evaluate(agent, params, 4, env=env, seed=5, max_steps=8)
    assert r1.shape == (4,)
    assert np.array_equal(r1, r2)
    # a different seed draws different episodes
    r3 = evaluate(agent, params, 4, env=env, seed=6, max_steps=8)
    assert not np.array_equal(r1, r3)
    with pytest.raises(ValueError, match="env= or task="):
        evaluate(agent, params, 4)


@pytest.mark.slow
def test_population_evaluator_lanes(pop_run):
    """Exact-mode rows equal the single evaluator, and permuting members
    permutes rows bitwise (lanes never interact)."""
    env = make_pixel_env("pendulum", train=False)
    enc = _pipeline_encoder("miniconv4", env.obs_shape[-1])
    agent = make_agent("ddpg", enc, env.action_dim)
    m0, m1 = pop_run.members[0], pop_run.members[1]
    key = jax.random.PRNGKey(2)

    stack = lambda a, b: jax.tree.map(
        lambda x, y: jnp.stack([x, y]), a, b)
    pop_eval = make_population_evaluator(env, agent, 4, max_steps=8)
    fwd = np.asarray(pop_eval(stack(m0.params, m1.params), key))
    rev = np.asarray(pop_eval(stack(m1.params, m0.params), key))
    assert np.array_equal(fwd[0], rev[1]) and np.array_equal(fwd[1], rev[0])

    single = make_evaluator(env, agent, 4, max_steps=8)
    ref0 = np.asarray(single(m0.params, key))
    assert np.array_equal(fwd[0], ref0)


@pytest.mark.slow
def test_population_end_to_end(pop_run):
    """Eval'd members carry the protocol metric; the winner exports
    straight into the serving pipeline."""
    from repro.deploy import Deployment, DeploymentConfig
    assert all(m.eval_returns is not None and m.eval_returns.shape == (4,)
               for m in pop_run.members)
    assert all(np.isfinite(m.final_100_mean) for m in pop_run.members)
    best = pop_run.best_member()
    assert best.final_100_mean == max(m.final_100_mean
                                      for m in pop_run.members)
    summ = pop_run.summary()
    assert summ["best_member"] == best.index
    assert summ["n_programs"] == 1

    env = make_pixel_env("pendulum", train=False)
    cfg = DeploymentConfig.from_encoder_name("miniconv4",
                                             c_in=env.obs_shape[-1])
    dep = Deployment.build(cfg)
    agent = make_agent("ddpg", dep.encoder, env.action_dim)
    client, server = dep.export_best(pop_run,
                                     head=agent.policy_head(best.params))
    _, obs = env.reset(jax.random.PRNGKey(0))
    action = np.asarray(server.serve([client.encode_fn(obs[None])])[0])
    assert action.shape == (env.action_dim,)
    assert np.all(np.isfinite(action))
