"""Real multi-process fleet (repro.serving.realfleet).

Three layers, cheap to expensive:

* framing — pack/unpack is bitwise for every registered wire codec, and
  frames round-trip over a real socket pair;
* threaded WorkerServer + FleetClient — continuous-batching admission,
  timeout-not-hang, crash re-routing, graceful drain, open-loop load
  generation (no process spawn, no jax model);
* spawned processes — the acceptance test: a 2-server fleet built from
  one deployment manifest serves actions over sockets BITWISE-equal to
  in-process serving, through all three registered routers, survives a
  worker kill, and shuts down without leaking processes.
"""
import socket
import struct
import threading
import time

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.wire import CODECS
from repro.serving.realfleet import (MSG_REQ, MSG_RESP, MSG_SHUTDOWN,
                                     FleetClient, FleetTimeout, WorkerServer,
                                     _recv_frame, _send_frame, pack_payload,
                                     run_load, unpack_payload)


# ------------------------------------------------------------------ framing
@pytest.mark.parametrize("name", sorted(CODECS))
def test_pack_unpack_bitwise_per_codec(name):
    """Socket serialisation reproduces every codec's payload (data AND
    quantisation headers) bitwise — the wire format adds framing, never
    numerics."""
    x = jax.random.uniform(jax.random.PRNGKey(0), (1, 5, 5, 4))
    payload = {k: np.asarray(v) for k, v in CODECS[name].encode(x).items()}
    back = unpack_payload(pack_payload(payload))
    assert set(back) == set(payload)
    for k in payload:
        assert back[k].dtype == payload[k].dtype
        assert back[k].shape == payload[k].shape
        assert back[k].tobytes() == payload[k].tobytes()


def test_frame_roundtrip_over_socket():
    a, b = socket.socketpair()
    try:
        _send_frame(a, MSG_REQ, b"\x00\x01payload")
        mtype, body = _recv_frame(b)
        assert mtype == MSG_REQ and body == b"\x00\x01payload"
        _send_frame(b, MSG_RESP)               # empty body is legal
        assert _recv_frame(a) == (MSG_RESP, b"")
        a.close()
        assert _recv_frame(b) == (None, None)  # clean EOF, not an exception
    finally:
        a.close()
        b.close()


# ------------------------------------------- threaded worker + front door
def _payload(value, n=2):
    return {"data": np.full((n,), float(value), np.float32)}


def test_continuous_batching_admits_during_service():
    """Requests arriving while a micro-batch is in service form the NEXT
    batch — the service time is the batching window, no max_wait hold."""
    in_service = threading.Event()
    release = threading.Event()

    def slow_double(stacked):
        in_service.set()
        release.wait(5.0)
        return stacked["data"] * 2.0

    ws = WorkerServer(slow_double, max_batch=8)
    addr = ws.start()
    fc = FleetClient([addr], timeout_s=10.0, retries=0)
    results = {}

    def issue(i):
        results[i] = fc.request(_payload(i))

    threads = [threading.Thread(target=issue, args=(0,))]
    threads[0].start()
    assert in_service.wait(5.0)        # batch [0] is on the "GPU"
    for i in (1, 2, 3):                # these arrive during its service
        t = threading.Thread(target=issue, args=(i,))
        t.start()
        threads.append(t)
    deadline = time.monotonic() + 5.0
    while ws._q.qsize() < 3 and time.monotonic() < deadline:
        time.sleep(0.01)               # all three queued at the worker
    release.set()
    for t in threads:
        t.join(10.0)
    for i in range(4):
        np.testing.assert_array_equal(results[i],
                                      np.full((2,), 2.0 * i, np.float32))
    assert ws.batch_sizes[0] == 1      # lone first request never held
    assert ws.batch_sizes[1] == 3      # the backlog launched as ONE batch
    assert fc.stats["max_served_batch"] == 3
    fc.shutdown()
    ws.join(5.0)


def test_timeout_surfaces_instead_of_hanging():
    def stuck(stacked):
        time.sleep(3.0)
        return stacked["data"]

    ws = WorkerServer(stuck, max_batch=2)
    addr = ws.start()
    fc = FleetClient([addr], timeout_s=0.15, retries=0)
    t0 = time.monotonic()
    with pytest.raises(FleetTimeout):
        fc.request(_payload(0))
    assert time.monotonic() - t0 < 1.5
    assert fc.stats["timeouts"] == 1
    ws.stop()
    fc.shutdown(wait_pending_s=0.1)


def test_crash_mid_request_reroutes_retry():
    """A worker dying mid-request fails the pending request immediately
    (connection EOF, not a timeout) and the retry re-routes to a live
    worker."""
    crashing = {}

    def crash(stacked):
        crashing["ws"].stop()          # drops every connection, no response
        raise RuntimeError("worker crashed mid-batch")

    ws0 = WorkerServer(crash, max_batch=2)
    crashing["ws"] = ws0
    ws1 = WorkerServer(lambda s: s["data"] + 1.0, max_batch=2)
    a0, a1 = ws0.start(), ws1.start()
    fc = FleetClient([a0, a1], router="round_robin", timeout_s=5.0,
                     retries=2)
    out = fc.request(_payload(0))      # seq 0 -> server 0 -> crash -> retry
    np.testing.assert_array_equal(out, np.ones((2,), np.float32))
    assert fc.stats["retries"] >= 1
    assert fc.stats["per_server"][1] == 1
    assert not fc.conns[0].alive       # marked dead for future requests
    out2 = fc.request(_payload(1))     # routes straight to the live worker
    np.testing.assert_array_equal(out2, np.full((2,), 2.0, np.float32))
    fc.shutdown()
    ws1.join(5.0)


def test_graceful_shutdown_drains_queued_requests():
    """Every request received before SHUTDOWN is served and answered
    before the worker exits."""
    def slowish(stacked):
        time.sleep(0.03)
        return stacked["data"]

    ws = WorkerServer(slowish, max_batch=2)
    addr = ws.start()
    s = socket.create_connection(addr)
    try:
        body = pack_payload(_payload(7, n=3))
        for rid in range(3):
            _send_frame(s, MSG_REQ, struct.pack("!I", rid) + body)
        _send_frame(s, MSG_SHUTDOWN)
        got = set()
        for _ in range(3):
            mtype, b = _recv_frame(s)
            assert mtype == MSG_RESP
            rid, _bsz = struct.unpack_from("!IH", b)
            got.add(rid)
            np.testing.assert_array_equal(
                unpack_payload(b[6:])["action"],
                np.full((3,), 7.0, np.float32))
        assert got == {0, 1, 2}
    finally:
        s.close()
    ws.join(5.0)
    assert ws.n_served == 3


def test_run_load_open_loop():
    ws = WorkerServer(lambda s: s["data"] * 2.0, max_batch=4)
    addr = ws.start()
    fc = FleetClient([addr], timeout_s=5.0)
    rep = run_load(fc, _payload(1), n_clients=2, rate_hz=20.0,
                   duration_s=0.5)
    assert rep.n_requests == 20        # 2 clients x 20 Hz x 0.5 s
    assert rep.n_failures == 0
    assert 0.0 < rep.p50() <= rep.p95()
    fc.shutdown()
    ws.join(5.0)


# ----------------------------------------------------- spawned processes
def test_real_fleet_two_servers_bitwise_and_crash():
    """The acceptance test: a manifest-built 2-worker fleet on localhost
    serves socket actions bitwise-equal to in-process serving through all
    three registered routers, re-routes around a killed worker, and shuts
    down without leaking processes."""
    from repro.deploy import Deployment, DeploymentConfig

    cfg = DeploymentConfig.standard(k=4, c_in=4, h=24, backend="xla",
                                    max_batch=2, n_servers=2,
                                    router="round_robin")
    dep = Deployment.build(cfg)
    params = dep.init(jax.random.PRNGKey(0))
    client, server = dep.serving_pair(params)
    n = 6
    obs = jax.random.uniform(jax.random.PRNGKey(1), (n, 24, 24, 4))
    payloads = [client.encode_fn(obs[i:i + 1]) for i in range(n)]
    want = [np.asarray(server.serve([p])[0]) for p in payloads]

    fleet = dep.fleet(params, timeout_s=60.0)
    try:
        got = [fleet.request(p, client=i) for i, p in enumerate(payloads)]
        for w, g in zip(want, got):
            np.testing.assert_array_equal(w, g)
        assert all(c > 0 for c in fleet.stats["per_server"])  # RR spread
        # same fleet, other routers: routing is a parent-side decision
        for router in ("least_loaded", "client_affinity"):
            fleet.set_router(router)
            np.testing.assert_array_equal(
                want[0], fleet.request(payloads[0], client=3))
        # kill a worker: requests re-route and results stay bitwise-equal
        fleet.processes[0].kill()
        fleet.processes[0].join(10.0)
        fleet.set_router("round_robin")
        got2 = [fleet.request(p, client=i) for i, p in enumerate(payloads)]
        for w, g in zip(want, got2):
            np.testing.assert_array_equal(w, g)
        assert fleet.stats["per_server"][1] >= n
    finally:
        leaked = fleet.close()
    assert leaked == []


# ------------------------------------------------------- ingress shaping
def test_token_bucket_gcra_with_injected_clock():
    from repro.serving.realfleet import TokenBucket
    now = [0.0]
    tb = TokenBucket(rate_bps=8e6, burst_bytes=10_000,  # 1 MB/s, 10 kB burst
                     clock=lambda: now[0])
    assert tb.reserve(10_000) == 0.0          # the burst rides free
    assert tb.reserve(10_000) == pytest.approx(0.01)   # 10 kB at 1 MB/s
    now[0] = 1.0                              # bucket refills while idle
    assert tb.reserve(10_000) == 0.0
    # sustained over-rate with a frozen clock: debt grows linearly
    for _ in range(100):
        wait = tb.reserve(1_000)
    assert wait == pytest.approx(0.1)         # 110 kB since t=1, 10 kB burst


def test_shaping_config_roundtrip_and_bucket():
    from repro.serving.realfleet import ShapingConfig, TokenBucket
    cfg = ShapingConfig(rate_mbps=2.0, burst_bytes=4096)
    assert ShapingConfig.from_dict(cfg.to_dict()) == cfg
    assert isinstance(cfg.bucket(), TokenBucket)
    with pytest.raises(ValueError):
        ShapingConfig(rate_mbps=0.0)
    with pytest.raises(ValueError):
        ShapingConfig(rate_mbps=1.0, burst_bytes=0)


def test_worker_front_door_shapes_ingress():
    """A shaped WorkerServer answers correctly AND measurably sleeps:
    requests beyond the burst pay the token-bucket wait before they are
    admitted to the batching queue."""
    from repro.serving.realfleet import ShapingConfig
    body = pack_payload(_payload(1, n=256))   # ~1 kB on the wire
    # tiny burst, 1 Mb/s: every request after the first must wait
    shaper = ShapingConfig(rate_mbps=1.0, burst_bytes=len(body)).bucket()
    ws = WorkerServer(lambda s: s["data"] * 2.0, max_batch=4,
                      shaper=shaper)
    addr = ws.start()
    fc = FleetClient([addr], timeout_s=10.0)
    t0 = time.monotonic()
    for _ in range(4):
        np.testing.assert_array_equal(fc.request(_payload(1, n=256)),
                                      _payload(2, n=256)["data"])
    elapsed = time.monotonic() - t0
    expected = 3 * len(body) * 8 / 1e6        # 3 post-burst waits
    assert ws.shaped_sleep_s >= 0.5 * expected
    assert elapsed >= 0.5 * expected
    fc.shutdown()
    ws.join(5.0)
    # unshaped control: no accumulated sleep
    ws2 = WorkerServer(lambda s: s["data"] * 2.0, max_batch=4)
    addr2 = ws2.start()
    fc2 = FleetClient([addr2], timeout_s=10.0)
    fc2.request(_payload(3))
    assert ws2.shaped_sleep_s == 0.0
    fc2.shutdown()
    ws2.join(5.0)
