"""Grouped-capacity MoE: reference equivalence at ample capacity, drop
behaviour, load-balance loss, gradient flow, group-size invariances."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.layers import swiglu
from repro.nn.moe import MoEConfig, _group_size, moe_apply, moe_init


def _setup(E=4, K=2, D=32, F=64, cf=8.0, G=16, shared=0, gate=False,
           key=0):
    cfg = MoEConfig(d_model=D, d_ff_expert=F, n_experts=E, top_k=K,
                    capacity_factor=cf, group_size=G,
                    n_shared_experts=shared, shared_expert_gate=gate)
    params = moe_init(jax.random.PRNGKey(key), cfg)
    return cfg, params


def _dense_reference(params, cfg, x):
    """Per-token loop: route, run top-k experts, combine (no capacity)."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = xt.astype(jnp.float32) @ params["router"]["kernel"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / gate_vals.sum(-1, keepdims=True)
    out = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        acc = jnp.zeros((D,), xt.dtype)
        for j in range(cfg.top_k):
            e = int(idx[t, j])
            w = jax.tree.map(lambda p: p[e], params["experts"])
            acc = acc + gate_vals[t, j] * swiglu(w, xt[t])
        out = out.at[t].set(acc)
    if "shared" in params:
        shared = swiglu(params["shared"], xt)
        if "shared_gate" in params:
            g = jax.nn.sigmoid(xt @ params["shared_gate"]["kernel"])
            shared = shared * g
        out = out + shared
    return out.reshape(B, S, D)


@pytest.mark.parametrize("shared,gate", [(0, False), (1, False), (2, True)])
def test_matches_dense_reference_at_ample_capacity(shared, gate):
    cfg, params = _setup(cf=16.0, shared=shared, gate=gate)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, _ = moe_apply(params, cfg, x)
    ref = _dense_reference(params, cfg, x)
    np.testing.assert_allclose(y, ref, atol=1e-4, rtol=1e-4)


def test_group_size_does_not_change_routing_much():
    """Different group sizes only differ via capacity drops; with ample
    capacity results are identical."""
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 32))
    outs = []
    for G in (8, 16, 64):
        cfg, params = _setup(cf=32.0, G=G, key=5)
        outs.append(moe_apply(params, cfg, x)[0])
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(outs[1], outs[2], atol=1e-5, rtol=1e-5)


def test_capacity_drops_tokens():
    """With capacity factor << 1 some tokens must be dropped (output 0
    for the routed part) but the layer stays finite."""
    cfg, params = _setup(cf=0.25, K=1)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, cfg.d_model))
    y, aux = moe_apply(params, cfg, x)
    assert jnp.isfinite(y).all()
    cfg2, _ = _setup(cf=16.0, K=1)
    y2, _ = moe_apply(params, cfg2, x)
    assert float(jnp.abs(y - y2).max()) > 1e-6  # drops changed the output


def test_aux_loss_bounds():
    """Switch LB loss: >= 1 (perfectly balanced) and <= E (collapsed)."""
    cfg, params = _setup(E=8, K=2)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 32, cfg.d_model))
    _, aux = moe_apply(params, cfg, x)
    lb = float(aux["moe_aux_loss"])
    assert 0.9 <= lb <= cfg.n_experts + 1e-3


def test_gradients_flow_to_all_param_groups():
    cfg, params = _setup(shared=1, gate=True)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, cfg.d_model))

    def loss(p):
        y, aux = moe_apply(p, cfg, x)
        return (y ** 2).mean() + 0.01 * aux["moe_aux_loss"]

    g = jax.grad(loss)(params)
    for path in ("router", "experts", "shared", "shared_gate"):
        total = sum(float(jnp.abs(l).sum())
                    for l in jax.tree.leaves(g[path]))
        assert total > 0, f"no gradient reached {path}"


def test_group_size_helper_tiles_tokens():
    cfg = MoEConfig(d_model=8, d_ff_expert=8, n_experts=2, top_k=1,
                    group_size=512)
    assert _group_size(cfg, 1024) == 512
    assert 1000 % _group_size(cfg, 1000) == 0
    assert _group_size(cfg, 7) == 7
