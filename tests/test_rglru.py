"""RG-LRU: associative-scan forward vs sequential decode."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.rglru import (RGLRUConfig, rglru_decode_step, rglru_forward,
                            rglru_init, rglru_init_state, rglru_scan)


def test_scan_matches_loop():
    key = jax.random.PRNGKey(0)
    a = jax.nn.sigmoid(jax.random.normal(key, (2, 12, 8)))
    bx = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 8))
    h = rglru_scan(a, bx)
    ref = []
    hh = jnp.zeros((2, 8))
    for t in range(12):
        hh = a[:, t] * hh + bx[:, t]
        ref.append(hh)
    np.testing.assert_allclose(h, jnp.stack(ref, 1), atol=1e-5, rtol=1e-5)


def test_decode_matches_forward():
    cfg = RGLRUConfig(d_model=16, d_rnn=16)
    key = jax.random.PRNGKey(2)
    params = rglru_init(key, cfg)
    B, S = 2, 10
    u = jax.random.normal(key, (B, S, cfg.d_model)) * 0.5
    full = rglru_forward(params, cfg, u)
    state = rglru_init_state(cfg, B)
    outs = []
    for t in range(S):
        o, state = rglru_decode_step(params, cfg, u[:, t:t + 1], state)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(dec, full, atol=1e-3, rtol=1e-3)
