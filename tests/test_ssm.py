"""Mamba-2 SSD: chunked scan vs naive recurrence, decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.ssm import (SSMConfig, ssd_chunked, ssm_decode_step,
                          ssm_forward, ssm_init, ssm_init_state)


def _naive_ssd(x, dt, A, B, C, D):
    """Direct O(S^2-free) recurrence: h_t = h_{t-1} * exp(dt_t A) +
    dt_t B_t x_t ; y_t = C_t h_t + D x_t."""
    b, S, H, P = x.shape
    G, N = B.shape[-2], B.shape[-1]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)
    h = jnp.zeros((b, H, P, N))
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A[None, :])                 # (b,H)
        h = h * dA[:, :, None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dt[:, t], x[:, t], Bh[:, t])
        y = jnp.einsum("bhpn,bhn->bhp", h, Ch[:, t])
        ys.append(y + x[:, t] * D[None, :, None])
    return jnp.stack(ys, axis=1), h


@pytest.mark.parametrize("S,chunk", [(16, 4), (32, 8), (24, 24)])
def test_ssd_chunked_matches_naive(S, chunk):
    cfg = SSMConfig(d_model=16, d_state=8, head_dim=4, expand=2,
                    n_groups=1, chunk=chunk)
    b, H, P, G, N = 2, cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    key = jax.random.PRNGKey(S)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (b, S, G, N))
    C = jax.random.normal(ks[4], (b, S, G, N))
    D = jnp.ones((H,))
    y, h = ssd_chunked(cfg, x, dt, A, B, C, D)
    y_ref, h_ref = _naive_ssd(x, dt, A, B, C, D)
    np.testing.assert_allclose(y, y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h, h_ref, atol=1e-4, rtol=1e-4)


def test_decode_matches_forward():
    cfg = SSMConfig(d_model=16, d_state=8, head_dim=4, expand=2, chunk=8)
    key = jax.random.PRNGKey(0)
    params = ssm_init(key, cfg)
    B, S = 2, 16
    u = jax.random.normal(key, (B, S, cfg.d_model)) * 0.5
    full = ssm_forward(params, cfg, u)
    state = ssm_init_state(cfg, B)
    outs = []
    for t in range(S):
        o, state = ssm_decode_step(params, cfg, u[:, t:t + 1], state)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(dec, full, atol=1e-3, rtol=1e-3)


def test_state_carries_across_segments():
    """forward(seq) == forward(first half) + forward(second half, h0)."""
    cfg = SSMConfig(d_model=16, d_state=8, head_dim=4, expand=2, chunk=4)
    key = jax.random.PRNGKey(1)
    params = ssm_init(key, cfg)
    u = jax.random.normal(key, (1, 16, cfg.d_model)) * 0.5
    full = ssm_forward(params, cfg, u)
    # conv state does not carry in this API; restrict check to a seam at
    # a conv_width boundary using the raw ssd core instead
    y1, h = ssm_forward(params, cfg, u[:, :8], return_state=True)
    assert jnp.isfinite(y1).all() and jnp.isfinite(h).all()
    np.testing.assert_allclose(full[:, :8], y1, atol=1e-4, rtol=1e-4)
