"""Fleet-scale serving sim conformance (ISSUE 4).

Anchors: n_servers=1 reduces BITWISE to BatchQueueSim for every router;
client_affinity keeps each client's responses ordered; capacity is
monotone in fleet size; the fleet shape round-trips through the
DeploymentConfig manifest.  Plus the queue-accounting bugfix sweep:
serialised downlink and max_clients early exit.
"""
import dataclasses

import numpy as np
import pytest

from repro.serving.fleet import (FleetQueueSim, ROUTERS, get_router,
                                 register_router, router_names, _mix32)
from repro.serving.netsim import shaped
from repro.serving.server import BatchQueueSim, BatchServiceModel, QueueSim

MODEL = BatchServiceModel(((1, 0.008), (2, 0.009), (4, 0.011), (8, 0.015)))


def _fleet(**kw):
    kw.setdefault("service_time_s", 0.008)
    kw.setdefault("uplink", shaped(100))
    kw.setdefault("payload_bytes", 10_000)
    kw.setdefault("horizon_s", 5.0)
    return FleetQueueSim(**kw)


# ---------------------------------------------------------------- routers
def test_router_registry():
    assert set(router_names()) >= {"round_robin", "least_loaded",
                                   "client_affinity"}
    with pytest.raises(ValueError, match="unknown router"):
        get_router("nope")
    assert get_router("round_robin") is ROUTERS["round_robin"]
    custom = lambda client, seq, t, q, free: 0
    assert get_router(custom) is custom                # callables pass through
    register_router("_test_pin_zero", custom)
    try:
        assert get_router("_test_pin_zero") is custom
    finally:
        del ROUTERS["_test_pin_zero"]


def test_affinity_hash_deterministic_and_spread():
    assert _mix32(7) == _mix32(7)                      # stable across calls
    # 256 sequential client ids spread over 8 servers reasonably evenly
    counts = np.bincount([_mix32(c) % 8 for c in range(256)], minlength=8)
    assert counts.min() > 0 and counts.max() < 2.5 * counts.mean()


def test_router_out_of_range_rejected():
    bad = _fleet(n_servers=2, router=lambda *a: 5)
    with pytest.raises(ValueError, match="router sent request"):
        bad.latencies(2)


# ------------------------------------------------- single-server reduction
@pytest.mark.parametrize("router", ["round_robin", "least_loaded",
                                    "client_affinity"])
@pytest.mark.parametrize("max_wait_s", [0.0, 0.002, 1.0])
def test_n_servers_1_reduces_bitwise_to_batch_sim(router, max_wait_s):
    common = dict(service_time_s=0.008, payload_bytes=10_000,
                  horizon_s=5.0, max_batch=8, max_wait_s=max_wait_s,
                  service_model=MODEL)
    for n in (1, 7, 32):
        ref = BatchQueueSim(uplink=shaped(100), **common)
        flt = FleetQueueSim(uplink=shaped(100), n_servers=1, router=router,
                            **common)
        np.testing.assert_array_equal(flt.latencies(n), ref.latencies(n))


def test_n_servers_1_max_batch_1_is_fifo():
    fifo = QueueSim(service_time_s=0.008, uplink=shaped(100),
                    payload_bytes=10_000, horizon_s=5.0)
    flt = _fleet(n_servers=1, max_batch=1)
    np.testing.assert_array_equal(flt.latencies(16), fifo.latencies(16))


# ---------------------------------------------------------------- ordering
def _hetero(router):
    """2-server fleet where server 1 is 30x slower: round_robin bounces a
    client between a fast and a slow server; affinity pins it."""
    slow = BatchServiceModel(((1, 0.060), (8, 0.070)))
    fast = BatchServiceModel(((1, 0.002), (8, 0.003)))
    return _fleet(n_servers=2, router=router, max_batch=8,
                  service_models=(fast, slow), horizon_s=3.0)


def test_client_affinity_preserves_per_client_order():
    tr = _hetero("client_affinity").trace(6)
    for c in range(6):
        mine = tr[tr["client"] == c]
        assert len(set(mine["server"])) == 1           # pinned to one server
        assert np.all(np.diff(mine["recv"]) > 0)       # responses in order


def test_round_robin_reorders_on_heterogeneous_fleet():
    """The contrast that motivates affinity routing: per-request spreading
    across a fast and a slow server returns some client's actions out of
    order.  5 clients on 2 servers make each client alternate servers
    (global seq parity flips every round); a service gap longer than the
    decision period then inverts consecutive responses."""
    slow = BatchServiceModel(((1, 0.250), (8, 0.260)))
    fast = BatchServiceModel(((1, 0.002), (8, 0.003)))
    sim = _fleet(n_servers=2, router="round_robin", max_batch=8,
                 service_models=(fast, slow), horizon_s=3.0)
    tr = sim.trace(5)
    out_of_order = any(np.any(np.diff(tr[tr["client"] == c]["recv"]) < 0)
                       for c in range(5))
    assert out_of_order


def test_least_loaded_prefers_idle_server():
    tr = _hetero("least_loaded").trace(2)
    # with a 30x slow server 1, load-aware routing sends almost all
    # traffic to fast server 0 (slow one only gets probed when 0 is busy)
    assert np.mean(tr["server"] == 0) > 0.7


def test_round_robin_spreads_evenly():
    tr = _fleet(n_servers=4, router="round_robin", max_batch=8,
                service_model=MODEL, horizon_s=2.0).trace(8)
    counts = np.bincount(tr["server"], minlength=4)
    assert counts.min() >= counts.max() - 1            # seq % n exactly


# ------------------------------------------------------------- monotonicity
def test_capacity_monotone_in_n_servers():
    base = _fleet(payload_bytes=2_000, horizon_s=2.0, max_batch=8,
                  service_model=MODEL)
    for router in router_names():
        caps = [base.with_servers(s, router).max_clients(n_max=1024)
                for s in (1, 2, 4, 8)]
        assert all(a <= b for a, b in zip(caps, caps[1:])), (router, caps)
        assert caps[2] >= 2 * caps[0]                  # 4 servers >= 2x one


def test_p95_monotone_in_clients_at_fixed_fleet():
    sim = _fleet(n_servers=4, service_model=MODEL, horizon_s=2.0)
    p95s = [sim.p95(n) for n in (4, 16, 64, 128)]
    assert all(a <= b + 1e-9 for a, b in zip(p95s, p95s[1:]))


def test_fleet_max_clients_matches_linear_scan():
    """The geometric+binary search equals the single-server linear scan
    (same monotone p95 curve, same early-exit-at-zero semantics)."""
    common = dict(service_time_s=0.008, payload_bytes=10_000,
                  horizon_s=5.0, max_batch=8, service_model=MODEL)
    lin = BatchQueueSim(uplink=shaped(100), **common)
    fast = FleetQueueSim(uplink=shaped(100), n_servers=1, **common)
    assert fast.max_clients(n_max=128) == lin.max_clients(n_max=128)
    # over-budget at N=1 -> 0 either way
    tiny = dataclasses.replace(fast, service_model=None,
                               service_time_s=0.5)
    assert tiny.max_clients(n_max=32) == 0


def test_min_servers_solver():
    base = _fleet(payload_bytes=2_000, horizon_s=2.0, max_batch=8,
                  service_model=MODEL, router="least_loaded")
    one = base.with_servers(1).max_clients(n_max=512)
    need = base.min_servers(2 * one, n_servers_max=8)
    assert 2 <= need <= 4                  # ~2x clients needs ~2x servers
    assert base.min_servers(8 * one, n_servers_max=2) == 0   # can't


def test_service_models_length_validated():
    bad = _fleet(n_servers=3, service_models=(MODEL,))
    with pytest.raises(ValueError, match="service models"):
        bad.latencies(2)
    assert _fleet(n_servers=1).with_servers(2).n_servers == 2


# ----------------------------------------------------------- queue accounting
def test_batch_downlink_serialises():
    """A batch of B actions charges B downlink transfer slots (the bug:
    one `_return_time` for the whole batch understated batched p95)."""
    model = BatchServiceModel(((1, 0.3), (8, 0.3)))
    fat = dict(uplink=shaped(1),                         # 1 Mb/s downlink
               payload_bytes=100, action_bytes=25_000,   # 0.2 s per action
               horizon_s=0.25, rate_hz=4.0)              # 1 request/client
    sim = BatchQueueSim(service_time_s=0.3, max_batch=8,
                        service_model=model, **fat)
    tx = sim.uplink.tx_time(25_000)
    lat = sim.latencies(4)                 # observation order
    t_obs = np.arange(4) / (4.0 * 4.0)     # staggered clients, 4 Hz
    recv = lat + t_obs
    # request 0 occupies the server (0.3 s); 1..3 batch together and
    # their actions drain the downlink one tx apart — the buggy
    # one-transfer-per-batch accounting made these diffs 0
    np.testing.assert_allclose(np.diff(recv[1:]), tx, rtol=1e-9)
    # and the fleet engine (n_servers=1) agrees exactly
    flt = FleetQueueSim(service_time_s=0.3, max_batch=8,
                        service_model=model, n_servers=1, **fat)
    np.testing.assert_array_equal(flt.latencies(4), lat)


def test_max_clients_survives_batch_hold_dip():
    """With max_wait_s > 0, p95 is NOT monotone at small N (a lone
    client waits out the hold), so a failing p95(1) must not be read as
    saturation: the scan keeps going and finds the true capacity."""
    bat = BatchQueueSim(service_time_s=0.008, uplink=shaped(100),
                        payload_bytes=10_000, rate_hz=10.0, horizon_s=5.0,
                        max_batch=8, max_wait_s=0.05, service_model=MODEL)
    assert bat.p95(1) > 0.06                   # the hold sinks N=1
    assert bat.max_clients(p95_budget_s=0.06, n_max=128) == 53
    # and the fleet's geometric sweep clears the same dip
    flt = FleetQueueSim(service_time_s=0.008, uplink=shaped(100),
                        payload_bytes=10_000, rate_hz=10.0, horizon_s=5.0,
                        max_batch=8, max_wait_s=0.05, service_model=MODEL,
                        n_servers=1)
    assert flt.max_clients(p95_budget_s=0.06, n_max=128) == 53


def test_fleet_max_clients_survives_affinity_dip():
    """client_affinity on a heterogeneous fleet: the only client can
    hash onto the slow shard (p95(1) terrible), while at scale the slow
    shard carries < 5% of traffic and drops out of the 95th percentile —
    capacity search must not bail at the small-N failure."""
    slow = BatchServiceModel(((1, 0.5), (8, 0.51)))
    fast = BatchServiceModel(((1, 0.002), (8, 0.003)))
    n_srv = 32
    models = tuple(slow if s == _mix32(0) % n_srv else fast
                   for s in range(n_srv))
    flt = _fleet(service_time_s=0.002, payload_bytes=2_000, horizon_s=2.0,
                 max_batch=8, n_servers=n_srv, router="client_affinity",
                 service_models=models)
    assert flt.p95(1) > 0.1                    # lone client on slow shard
    assert flt.max_clients(p95_budget_s=0.1, n_max=256) == 256


def test_max_clients_early_exits_when_over_budget_at_one():
    calls = []

    class Counting(QueueSim):
        def p95(self, n):
            calls.append(n)
            return super().p95(n)

    sim = Counting(service_time_s=0.5, uplink=shaped(100),
                   payload_bytes=10_000, horizon_s=2.0)
    assert sim.max_clients(p95_budget_s=0.1, n_max=512) == 0
    assert calls == [1]                    # ONE sim, not n_max scans


# ------------------------------------------------------------- heap engine
@pytest.mark.parametrize("router", ["round_robin", "least_loaded",
                                    "client_affinity"])
@pytest.mark.parametrize("max_wait_s", [0.0, 0.002, 1.0])
def test_heap_engine_bitwise_equals_scan(router, max_wait_s):
    """The heapq next-event engine reproduces the O(events x n_servers)
    launch-scan reference BITWISE across the router x max_wait grid."""
    common = dict(service_time_s=0.008, payload_bytes=10_000,
                  horizon_s=5.0, max_batch=8, max_wait_s=max_wait_s,
                  service_model=MODEL, router=router)
    for n_servers in (1, 3, 8):
        heap = FleetQueueSim(uplink=shaped(100), n_servers=n_servers,
                             engine="heap", **common)
        scan = dataclasses.replace(heap, engine="scan")
        np.testing.assert_array_equal(heap.latencies(24), scan.latencies(24))


def test_heap_engine_bitwise_on_heterogeneous_fleet():
    """Per-server t(B) curves exercise server-dependent launch times."""
    slow = BatchServiceModel(((1, 0.060), (8, 0.070)))
    fast = BatchServiceModel(((1, 0.002), (8, 0.003)))
    for router in router_names():
        heap = _fleet(n_servers=4, router=router, max_batch=8,
                      max_wait_s=0.01,
                      service_models=(fast, slow, fast, slow),
                      horizon_s=3.0)
        scan = dataclasses.replace(heap, engine="scan")
        np.testing.assert_array_equal(heap.latencies(17), scan.latencies(17))


def test_heap_engine_default_and_validated():
    assert _fleet().engine == "heap"
    with pytest.raises(ValueError, match="unknown engine"):
        dataclasses.replace(_fleet(), engine="btree").latencies(2)


def test_heap_engine_saturated_single_server_is_linear():
    """Regression: the lazy-deletion peek must DROP stale entries, not
    re-push corrections — re-pushing duplicated the current entry per
    stale and made a saturated server's heap grow per launch (observed
    500x slowdown vs the scan at n=2048).  Saturation = arrivals far
    outpace service, the regime fleet capacity searches probe."""
    import time
    model = BatchServiceModel(((1, 0.00012), (8, 0.00051)))
    sim = _fleet(service_time_s=0.00012, uplink=shaped(1000),
                 payload_bytes=492, horizon_s=2.0, max_batch=8,
                 service_model=model, n_servers=1)
    t0 = time.perf_counter()
    lat = sim.latencies(1024)
    elapsed = time.perf_counter() - t0
    np.testing.assert_array_equal(
        lat, dataclasses.replace(sim, engine="scan").latencies(1024))
    assert elapsed < 30.0, f"saturated heap sim took {elapsed:.1f}s"


def test_heap_engine_32_server_smoke():
    """A >= 32-server fleet completes fast — the regime where the launch
    scan's O(events x n_servers) inner loop used to dominate."""
    import time
    sim = _fleet(service_time_s=0.002, payload_bytes=2_000, horizon_s=2.0,
                 max_batch=8, service_model=MODEL, n_servers=32,
                 router="least_loaded")
    t0 = time.perf_counter()
    lat = sim.latencies(512)
    elapsed = time.perf_counter() - t0
    assert len(lat) > 0 and np.isfinite(lat).all()
    assert elapsed < 10.0, f"32-server sim took {elapsed:.1f}s"


# ----------------------------------------------------------------- manifest
def test_manifest_roundtrip_fleet_fields():
    from repro.deploy import DeploymentConfig
    cfg = DeploymentConfig.standard(k=4, c_in=4, h=32, n_servers=8,
                                    router="client_affinity")
    cfg.validate()
    back = DeploymentConfig.from_json(cfg.to_json())
    assert back == cfg
    assert back.n_servers == 8 and back.router == "client_affinity"
    # pre-fleet manifests (no fields) still load, defaulting to 1 server
    d = cfg.to_dict()
    del d["n_servers"], d["router"]
    old = DeploymentConfig.from_dict(d)
    assert old.n_servers == 1 and old.router == "round_robin"


def test_manifest_fleet_validation():
    from repro.deploy import DeploymentConfig
    with pytest.raises(ValueError, match="n_servers"):
        DeploymentConfig.standard(k=4, c_in=4, h=32, n_servers=0).validate()
    with pytest.raises(ValueError, match="unknown router"):
        DeploymentConfig.standard(k=4, c_in=4, h=32,
                                  router="random").validate()


def test_deployment_fleet_sim_from_manifest():
    from repro.deploy import Deployment, DeploymentConfig
    cfg = DeploymentConfig.standard(k=4, c_in=4, h=32, backend="xla",
                                    n_servers=4, router="least_loaded",
                                    max_batch=8)
    dep = Deployment.build(cfg)
    sim = dep.fleet_sim(MODEL, uplink=shaped(100), horizon_s=2.0)
    assert sim.n_servers == 4 and sim.router == "least_loaded"
    assert sim.payload_bytes == dep.wire_bytes
    assert sim.max_batch == cfg.max_batch
    assert sim.p95(8) > 0
    # explicit overrides beat the manifest
    assert dep.fleet_sim(MODEL, uplink=shaped(100), n_servers=2,
                         router="round_robin").n_servers == 2
