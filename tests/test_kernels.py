"""Per-kernel allclose vs the pure-jnp oracle, swept over shapes/dtypes
(interpret mode executes the kernel body in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.miniconv_pass import miniconv_pass
from repro.kernels.ops import causal_attention, miniconv_layer, same_pad
from repro.kernels.ref import attention_ref, miniconv_pass_ref


@pytest.mark.parametrize("h,w", [(16, 16), (20, 28), (33, 17)])
@pytest.mark.parametrize("kernel,stride", [(3, 1), (3, 2), (4, 2), (1, 1)])
@pytest.mark.parametrize("c_in", [4, 8, 12])
def test_miniconv_pass_shapes(h, w, kernel, stride, c_in):
    key = jax.random.PRNGKey(h * w + kernel)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (2, h, w, c_in), jnp.float32)
    wgt = jax.random.normal(k2, (kernel, kernel, c_in, 4)) * 0.1
    b = jax.random.normal(k3, (4,)) * 0.1
    if h < kernel or w < kernel:
        pytest.skip("kernel larger than input")
    out = miniconv_pass(x, wgt, b, stride=stride, interpret=True)
    ref = miniconv_pass_ref(x, wgt, b, stride=stride)
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_miniconv_pass_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 12, 12, 8)).astype(dtype)
    w = (jax.random.normal(key, (3, 3, 8, 4)) * 0.1).astype(dtype)
    b = jnp.zeros((4,), dtype)
    out = miniconv_pass(x, w, b, stride=1, interpret=True)
    ref = miniconv_pass_ref(x, w, b, stride=1)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_miniconv_layer_matches_same_conv():
    """Multi-pass layer (c_out > 4, SAME padding) == XLA SAME conv."""
    from repro.nn.layers import conv2d
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (2, 21, 21, 8))
    w = jax.random.normal(key, (3, 3, 8, 12)) * 0.1
    b = jnp.zeros((12,))
    out = miniconv_layer(x, w, b, stride=2, interpret=True)
    ref = conv2d({"kernel": w, "bias": b}, x, stride=2, padding="SAME")
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("s", [128, 256])
@pytest.mark.parametrize("window", [None, 64])
@pytest.mark.parametrize("blocks", [(64, 64), (128, 64)])
def test_flash_attention_vs_ref(s, window, blocks):
    bq, bk = blocks
    key = jax.random.PRNGKey(s)
    q, k, v = [jax.random.normal(kk, (1, 2, s, 32)) for kk in
               jax.random.split(key, 3)]
    out = flash_attention(q, k, v, causal=True, sliding_window=window,
                          block_q=bq, block_k=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=True, sliding_window=window)
    np.testing.assert_allclose(out, ref, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    key = jax.random.PRNGKey(7)
    q, k, v = [jax.random.normal(kk, (1, 2, 128, 32)).astype(dtype)
               for kk in jax.random.split(key, 3)]
    out = causal_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_same_pad_matches_xla_same():
    from repro.nn.layers import conv2d
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (1, 13, 17, 4))
    w = jax.random.normal(key, (4, 4, 4, 4)) * 0.1
    xp = same_pad(x, 4, 2)
    ref = conv2d({"kernel": w}, x, stride=2, padding="SAME")
    out = miniconv_pass_ref(xp, w, jnp.zeros((4,)), stride=2)
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)
