"""ISSUE-8 conformance: the scenario engine.

* LINKS: the adversarial link family (trace/markov/lossy/jitter) —
  piecewise bandwidth integration across regime boundaries, seeded
  determinism with full RNG restore on ``reset()``, query-pattern
  independence of the Markov chain, and the ``make_link`` registry.
* SCHEMA: ``Scenario`` ``to_dict``/``from_dict``/JSON round-trip
  (including property-based, when hypothesis is available), loud
  rejection of unknown kinds/profiles/versions, and canonicalised
  ``link_params`` (construction order never breaks equality).
* DETERMINISM: same name + seed in, bitwise-identical latencies and
  byte bills out; a different seed diverges.
* REDUCTION: every static built-in at n_servers=1 under ``"none"``
  replays ``BatchQueueSim`` bitwise.
* ADAPTATION: RuleController unit behaviour (default mode before
  feedback, downshift on slow ripe feedback AND on an overdue
  outstanding transfer, recovery, per-client isolation) and the
  acceptance gate: on ``trace_dropout`` the rule controller matches or
  beats the best static configuration (return-ranked) on delivered
  return, p95 and uplink bytes simultaneously.
* WIRING: ``Deployment.scenario_sim`` and the ``--scenario`` CLI flag.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.serving.netsim import (MBPS, LossyLink, MarkovLink, ShapedLink,
                                  StochasticJitterLink, TraceLink,
                                  make_link, shaped)
from repro.serving.profiles import (DEVICE_PROFILES, DeviceProfile,
                                    get_profile, profile_names, zoo)
from repro.serving.scenario import (ADAPTATIONS, DEFAULT_MODES, FULL_MODE,
                                    SCENARIOS, AdaptationMode,
                                    RuleController, Scenario,
                                    ScenarioFleetSim, StaticController,
                                    get_adaptation, get_scenario,
                                    scenario_names)
from repro.serving.server import BatchQueueSim

PAYLOAD = 10_000


# ---------------------------------------------------------------- links
def test_trace_link_integrates_across_boundaries():
    """8 Mbit sent at t=0.5 on 8->0->16 Mb/s: 4 Mbit clear before the
    outage at t=1, nothing moves for a second, the rest takes 0.25 s."""
    link = TraceLink(schedule=((0.0, 8e6), (1.0, 0.0), (2.0, 16e6)),
                     propagation_s=0.0)
    tr = link.send(0.5, 1_000_000)         # 8e6 bits
    assert tr.start == pytest.approx(0.5)
    assert tr.tx_done == pytest.approx(2.25)
    # nominal rate (peak) is the downlink accounting hook
    assert link.tx_time(1_000_000) == pytest.approx(0.5)


def test_trace_link_validates_schedule():
    with pytest.raises(ValueError, match="start at t=0"):
        TraceLink(schedule=((1.0, 1e6),))
    with pytest.raises(ValueError, match="strictly increasing"):
        TraceLink(schedule=((0.0, 1e6), (2.0, 2e6), (2.0, 3e6)))
    with pytest.raises(ValueError, match="positive"):
        TraceLink(schedule=((0.0, 1e6), (1.0, 0.0)))   # forever-outage


def test_markov_link_seeded_replay_and_divergence():
    kw = dict(states_bps=(100 * MBPS, 2 * MBPS), dwell_s=0.1,
              transition=((0.5, 0.5), (0.5, 0.5)))
    link = MarkovLink(seed=3, **kw)
    a = [link.send(0.05 * i, 40_000).arrival for i in range(50)]
    link.reset()
    b = [link.send(0.05 * i, 40_000).arrival for i in range(50)]
    assert a == b                           # reset restores the RNG too
    other = MarkovLink(seed=4, **kw)
    c = [other.send(0.05 * i, 40_000).arrival for i in range(50)]
    assert a != c


def test_markov_chain_independent_of_query_pattern():
    """The realised regime trace depends only on the seed — probing the
    link early must not consume different RNG draws than jumping straight
    to a late time."""
    kw = dict(states_bps=(10e6, 1e6), dwell_s=0.1, seed=9,
              transition=((0.7, 0.3), (0.4, 0.6)))
    sparse = MarkovLink(**kw)
    late = sparse.send(5.0, 50_000)
    dense = MarkovLink(**kw)
    for i in range(50):
        dense.bandwidth_at(0.1 * i)        # probe every dwell first
    dense.reset()                          # then replay from scratch
    assert dense.send(5.0, 50_000) == late


def test_markov_link_validates():
    with pytest.raises(ValueError, match="positive"):
        MarkovLink(states_bps=(1e6, 0.0), transition=((1, 0), (1, 0)))
    with pytest.raises(ValueError, match="stochastic"):
        MarkovLink(states_bps=(1e6, 2e6), transition=((0.9, 0.2),
                                                      (0.5, 0.5)))
    with pytest.raises(ValueError, match="2x2"):
        MarkovLink(states_bps=(1e6, 2e6), transition=((1.0,),))


def test_lossy_link_retransmits_block_head_of_line():
    """loss_p ~ 1: every attempt burns tx + RTO until retries run out,
    and the link stays busy through the gaps."""
    always = LossyLink(bandwidth_bps=8e6, loss_p=0.999, rto_s=0.05,
                       max_retries=3, propagation_s=0.0, seed=0)
    tr = always.send(0.0, 100_000)         # tx = 0.1 s per attempt
    assert tr.tx_done == pytest.approx(0.1 + 3 * (0.05 + 0.1))
    nxt = always.send(0.0, 100_000)
    assert nxt.start == pytest.approx(tr.tx_done)   # HoL blocking
    clean = LossyLink(bandwidth_bps=8e6, loss_p=0.0, propagation_s=0.002)
    ref = ShapedLink(bandwidth_bps=8e6, propagation_s=0.002)
    assert clean.send(0.0, 100_000) == ref.send(0.0, 100_000)
    with pytest.raises(ValueError, match="loss_p"):
        LossyLink(bandwidth_bps=8e6, loss_p=1.0)


def test_stochastic_jitter_seeded_and_occupancy_free():
    link = StochasticJitterLink(bandwidth_bps=8e6, propagation_s=0.001,
                                jitter_s=0.004, seed=5)
    ref = ShapedLink(bandwidth_bps=8e6, propagation_s=0.001)
    a = [link.send(0.0, 10_000) for _ in range(10)]
    for tr, rr in zip(a, (ref.send(0.0, 10_000) for _ in range(10))):
        assert tr.tx_done == rr.tx_done    # jitter never occupies the link
        assert 0.0 <= tr.arrival - tr.tx_done - 0.001 < 0.008
    link.reset()
    b = [link.send(0.0, 10_000) for _ in range(10)]
    assert a == b


def test_make_link_registry():
    st = make_link("static", bandwidth_bps=5e6, propagation_s=0.001)
    assert isinstance(st, ShapedLink) and st.bandwidth_bps == 5e6
    mk = make_link("markov", seed=21, states_bps=(1e6,),
                   transition=((1.0,),))
    assert isinstance(mk, MarkovLink) and mk.seed == 21
    assert make_link("lossy", seed=1, bandwidth_bps=1e6,
                     loss_p=0.1).seed == 1
    with pytest.raises(KeyError, match="registered"):
        make_link("carrier_pigeon")


# ---------------------------------------------------------------- profiles
def test_profile_registry_and_zoo_cycles():
    pz = get_profile("pi_zero_2w")
    assert pz.encode_s == pytest.approx(0.100)       # the paper's ~0.1 s
    models = zoo(("jetson_nano", "pi_4b"), 5)
    assert len(models) == 5
    j, p = get_profile("jetson_nano"), get_profile("pi_4b")
    for s, prof in zip(range(5), (j, p, j, p, j)):
        assert models[s](1) == pytest.approx(prof.service_points[0][1])
    with pytest.raises(KeyError, match="registered"):
        get_profile("abacus")
    with pytest.raises(ValueError, match="at least one"):
        zoo((), 2)


def test_profile_validates_eagerly():
    with pytest.raises(ValueError):
        DeviceProfile(name="bad", service_points=(), encode_s=0.01)
    with pytest.raises(ValueError, match="encode_s"):
        DeviceProfile(name="bad", service_points=((1, 0.01),),
                      encode_s=-1.0)


# ---------------------------------------------------------------- schema
def test_builtin_scenarios_roundtrip_json():
    assert len(SCENARIOS) >= 7
    for name in scenario_names():
        s = get_scenario(name)
        d = s.to_dict()
        json.dumps(d)                                # JSON-safe
        assert Scenario.from_dict(d) == s
        assert Scenario.from_json(s.to_json()) == s


def test_scenario_link_params_order_insensitive():
    a = Scenario(name="x", link_kind="static",
                 link_params=(("propagation_s", 0.001),
                              ("bandwidth_bps", 1e6)))
    b = Scenario(name="x", link_kind="static",
                 link_params={"bandwidth_bps": 1e6,
                              "propagation_s": 0.001})
    assert a == b
    assert a.params_dict() == {"bandwidth_bps": 1e6,
                               "propagation_s": 0.001}


def test_scenario_rejects_loudly():
    with pytest.raises(ValueError, match="unknown link kind"):
        Scenario(name="x", link_kind="warp")
    with pytest.raises(ValueError, match="seed"):
        Scenario(name="x", link_kind="static", seed=-1)
    with pytest.raises(ValueError, match="unique"):
        Scenario(name="x", link_kind="static",
                 modes=(FULL_MODE, AdaptationMode("full", 0.5, 0.0, 0.5)))
    with pytest.raises(ValueError, match="version"):
        Scenario.from_dict({**get_scenario("static_10mbps").to_dict(),
                            "version": 99})
    with pytest.raises(ValueError, match="unknown scenario"):
        get_scenario("area_51")
    # validate() resolves profiles: unknown device fails there
    bad = Scenario(name="x", link_kind="static",
                   link_params={"bandwidth_bps": 1e6},
                   devices=("abacus",))
    with pytest.raises(KeyError, match="registered"):
        bad.validate()


def test_adaptation_mode_validates():
    with pytest.raises(ValueError, match="payload_scale"):
        AdaptationMode("m", payload_scale=0.0)
    with pytest.raises(ValueError, match="fidelity"):
        AdaptationMode("m", fidelity=1.5)


def test_scenario_roundtrip_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    mode = st.builds(
        AdaptationMode, name=st.just("m"),
        payload_scale=st.floats(0.01, 4.0, allow_nan=False),
        encode_s=st.floats(0.0, 0.25, allow_nan=False),
        fidelity=st.floats(0.0, 1.0, allow_nan=False))
    modes = st.lists(mode, min_size=1, max_size=3).map(
        lambda ms: tuple(dataclasses.replace(m, name=f"m{i}")
                         for i, m in enumerate(ms)))
    link = st.one_of(
        st.tuples(st.just("static"),
                  st.fixed_dictionaries(
                      {"bandwidth_bps": st.floats(1e5, 1e9,
                                                  allow_nan=False)})),
        st.tuples(st.just("jitter"),
                  st.fixed_dictionaries(
                      {"bandwidth_bps": st.floats(1e5, 1e9,
                                                  allow_nan=False),
                       "jitter_s": st.floats(0.0, 0.01,
                                             allow_nan=False)})),
        st.tuples(st.just("lossy"),
                  st.fixed_dictionaries(
                      {"bandwidth_bps": st.floats(1e5, 1e9,
                                                  allow_nan=False),
                       "loss_p": st.floats(0.0, 0.5, allow_nan=False)})))
    scenario = st.builds(
        lambda kind_params, **kw: Scenario(
            link_kind=kind_params[0], link_params=kind_params[1], **kw),
        link,
        name=st.sampled_from(["a", "b", "long-name"]),
        seed=st.integers(0, 2 ** 31),
        devices=st.lists(st.sampled_from(profile_names()),
                         min_size=1, max_size=3).map(tuple),
        modes=modes,
        rate_hz=st.floats(0.1, 100.0, allow_nan=False),
        horizon_s=st.floats(0.1, 60.0, allow_nan=False),
        n_clients=st.integers(1, 64),
        deadline_s=st.floats(0.001, 1.0, allow_nan=False),
        adversarial=st.booleans())

    @hyp.given(s=scenario)
    @hyp.settings(max_examples=50, deadline=None)
    def roundtrips(s):
        assert Scenario.from_dict(s.to_dict()) == s
        assert Scenario.from_json(s.to_json()) == s
        s.validate()

    roundtrips()


# ------------------------------------------------------------ determinism
@pytest.mark.parametrize("name", ["wifi_markov", "lossy_uplink",
                                  "jittery_wifi", "trace_dropout"])
def test_scenario_seed_determinism_bitwise(name):
    s = get_scenario(name)
    r1 = s.sim(PAYLOAD, adaptation="rule").report(s.n_clients)
    r2 = s.sim(PAYLOAD, adaptation="rule").report(s.n_clients)
    np.testing.assert_array_equal(r1.latencies, r2.latencies)
    np.testing.assert_array_equal(r1.mode_idx, r2.mode_idx)
    assert r1.total_uplink_bytes == r2.total_uplink_bytes
    assert r1.delivered_return == r2.delivered_return


def test_scenario_reseed_diverges():
    s = get_scenario("wifi_markov")
    r1 = s.sim(PAYLOAD).report(s.n_clients)
    r2 = dataclasses.replace(s, seed=s.seed + 1).sim(PAYLOAD).report(
        s.n_clients)
    assert not np.array_equal(r1.latencies, r2.latencies)


def test_sim_entry_point_resets_shared_link_state():
    """One ScenarioFleetSim instance re-run (and re-used link) replays
    bitwise — the sim entry point owns the reset."""
    s = get_scenario("lossy_uplink")
    sim = s.sim(PAYLOAD, adaptation="rule")
    a = sim.report(s.n_clients)
    b = sim.report(s.n_clients)            # same instance, same link
    np.testing.assert_array_equal(a.latencies, b.latencies)
    assert a.total_uplink_bytes == b.total_uplink_bytes


def test_link_instance_reuse_across_sims_regression():
    """ONE link object threaded through several separately-constructed
    sims (the sizing-sweep pattern) must not leak ``_busy_until``,
    transfer counters or RNG state from run to run: every sim entry
    point resets the link, and reset restores the RNG too."""
    link = LossyLink(bandwidth_bps=40 * MBPS, loss_p=0.2, rto_s=0.01,
                     seed=5)
    mk = dict(service_time_s=0.0, payload_bytes=PAYLOAD, rate_hz=20.0,
              horizon_s=2.0, max_batch=4, max_wait_s=0.0,
              service_model=get_profile("jetson_nano").service_model())
    first = BatchQueueSim(uplink=link, **mk).latencies(6)
    link.send(0.0, 10 ** 7)                 # dirty the link between runs
    again = BatchQueueSim(uplink=link, **mk).latencies(6)
    np.testing.assert_array_equal(first, again)
    fresh = BatchQueueSim(uplink=LossyLink(bandwidth_bps=40 * MBPS,
                                           loss_p=0.2, rto_s=0.01,
                                           seed=5), **mk).latencies(6)
    np.testing.assert_array_equal(first, fresh)


# -------------------------------------------------------------- reduction
@pytest.mark.parametrize("name", ["static_100mbps", "static_10mbps",
                                  "zoo_static"])
def test_static_scenarios_reduce_bitwise_to_batch_sim(name):
    s = get_scenario(name)
    assert s.is_static
    sim = s.sim(PAYLOAD, n_servers=1, adaptation="none")
    ref = BatchQueueSim(service_time_s=0.0, uplink=s.make_link(),
                        payload_bytes=PAYLOAD, rate_hz=s.rate_hz,
                        horizon_s=s.horizon_s, max_batch=8,
                        max_wait_s=0.0,
                        service_model=get_profile(
                            s.devices[0]).service_model())
    np.testing.assert_array_equal(sim.latencies(s.n_clients),
                                  ref.latencies(s.n_clients))


# ------------------------------------------------------------- controllers
def _trace(start, tx_done, arrival, nbytes):
    from repro.serving.netsim import LinkTrace
    return LinkTrace(start=start, tx_done=tx_done, arrival=arrival,
                     payload_bytes=nbytes)


def test_rule_controller_default_mode_before_feedback():
    ctrl = RuleController(DEFAULT_MODES, PAYLOAD, 0.1)
    assert ctrl.choose(0, 0.0) == 0


def test_rule_controller_downshifts_on_slow_ripe_feedback():
    ctrl = RuleController(DEFAULT_MODES, PAYLOAD, 0.1)   # budget 50 ms
    # 10 kB took a full second: bw = 80 kb/s, nothing fits the budget,
    # fallback is the lowest-predicted-latency mode (compact)
    ctrl.observe(0, 0, 0.0, _trace(0.0, 1.0, 1.0, PAYLOAD))
    assert ctrl.choose(0, 1.5) == 1
    # other clients saw nothing and stay on the default
    assert ctrl.choose(1, 1.5) == 0


def test_rule_controller_overdue_outstanding_downshifts():
    """The ACK-clock signal: a transfer still outstanding past the budget
    bounds bandwidth above BEFORE its feedback lands."""
    ctrl = RuleController(DEFAULT_MODES, PAYLOAD, 0.1)
    ctrl.observe(0, 0, 0.0, _trace(0.0, 10.0, 10.0, PAYLOAD))
    assert ctrl.choose(0, 0.01) == 0       # too young to condemn
    assert ctrl.choose(0, 1.0) == 1        # age 1 s >> 50 ms budget
    assert ctrl.choose(1, 1.0) == 0        # per-client isolation


def test_rule_controller_recovers_on_fast_feedback():
    ctrl = RuleController(DEFAULT_MODES, PAYLOAD, 0.1)
    ctrl.observe(0, 0, 0.0, _trace(0.0, 1.0, 1.0, PAYLOAD))
    assert ctrl.choose(0, 1.5) == 1
    # a compact payload then flies: 1250 B in 1 ms -> 10 Mb/s, full fits
    ctrl.observe(0, 1, 2.0, _trace(2.0, 2.001, 2.002, 1250))
    assert ctrl.choose(0, 2.5) == 0


def test_static_controller_and_adaptation_registry():
    assert StaticController(DEFAULT_MODES, PAYLOAD, 0.1).choose(3, 9.9) == 0
    ctrl = get_adaptation("static:1")(DEFAULT_MODES, PAYLOAD, 0.1)
    assert ctrl.choose(0, 0.0) == 1
    with pytest.raises(ValueError, match="out of range"):
        get_adaptation("static:7")(DEFAULT_MODES, PAYLOAD, 0.1)
    with pytest.raises(ValueError, match="unknown adaptation"):
        get_adaptation("oracle")
    assert set(ADAPTATIONS) >= {"none", "rule"}
    # callables pass straight through (the pluggable-policy hook)
    assert get_adaptation(RuleController) is RuleController


def test_scenario_sim_rejects_out_of_range_controller_choice():
    s = get_scenario("trace_dropout")
    sim = s.sim(PAYLOAD,
                adaptation=lambda modes, pb, dl: type(
                    "Bad", (), {"choose": lambda self, c, t: 99,
                                "observe": lambda self, *a: None})())
    with pytest.raises(ValueError, match="chose mode"):
        sim.report(2)


# ------------------------------------------------------- the adaptation gate
def test_trace_dropout_rule_beats_best_static():
    """The acceptance criterion on the designed deterministic adversary:
    the rule controller matches-or-beats the best static configuration
    (ranked by delivered return — the config you would actually deploy
    without adaptation) on ALL of return, p95 and uplink bytes."""
    s = get_scenario("trace_dropout")
    assert s.adversarial
    statics = [s.sim(PAYLOAD, adaptation=f"static:{i}").report(s.n_clients)
               for i in range(len(s.modes))]
    rule = s.sim(PAYLOAD, adaptation="rule").report(s.n_clients)
    best = max(statics, key=lambda r: r.delivered_return)
    assert rule.delivered_return >= best.delivered_return
    assert rule.p95_s <= best.p95_s
    assert rule.total_uplink_bytes <= best.total_uplink_bytes
    # it actually adapts: both modes used, and the dropouts do hurt the
    # full-payload static (otherwise the gate would be vacuous)
    counts = rule.mode_counts()
    assert counts["full"] > 0 and counts["compact"] > 0
    assert best.deadline_hit_rate < 1.0


def test_none_equals_static0():
    s = get_scenario("trace_dropout")
    a = s.sim(PAYLOAD, adaptation="none").report(s.n_clients)
    b = s.sim(PAYLOAD, adaptation="static:0").report(s.n_clients)
    np.testing.assert_array_equal(a.latencies, b.latencies)
    assert a.total_uplink_bytes == b.total_uplink_bytes


def test_report_scorecard_fields():
    s = get_scenario("static_100mbps")
    rep = s.sim(PAYLOAD).report(4)
    assert rep.n_requests == len(rep.latencies) > 0
    assert 0.0 <= rep.deadline_hit_rate <= 1.0
    assert rep.total_uplink_bytes == rep.n_requests * PAYLOAD
    assert rep.mode_counts() == {"full": rep.n_requests}
    assert rep.p95_s >= 0.0 and rep.mean_s >= 0.0


# ---------------------------------------------------------------- wiring
def test_deployment_scenario_sim_and_cli(tmp_path):
    jax = pytest.importorskip("jax")
    from repro.deploy import Deployment, DeploymentConfig, main
    dep = Deployment.build(DeploymentConfig.standard(
        k=4, c_in=4, h=24, backend="xla", max_batch=4))
    sim = dep.scenario_sim("trace_dropout", adaptation="rule")
    assert isinstance(sim, ScenarioFleetSim)
    assert sim.payload_bytes == dep.wire_bytes
    assert sim.max_batch == 4
    rep = sim.report(2)
    assert rep.n_requests > 0
    # inline Scenario objects work too (not just registered names)
    inline = dataclasses.replace(get_scenario("static_10mbps"),
                                 name="inline", horizon_s=1.0)
    assert dep.scenario_sim(inline).report(2).n_requests > 0
    # the CLI flag drives the per-policy scorecard end-to-end
    main(["--k", "4", "--c-in", "4", "--x", "24", "--backend", "xla",
          "--max-batch", "4", "--out", str(tmp_path / "m.json"),
          "--scenario", "static_100mbps"])
