"""Conformance suite for the batched split-policy serving path (ISSUE 2).

Three layers of guarantees, all in interpret mode:

* KERNEL: the batched fused encoder (batch = outer grid dimension) is
  bitwise-independent per example — a (B, H, W, C) launch equals B
  single-frame launches — across B, odd/even spatial sizes and ragged
  c_out; the fused projection epilogue equals encoder-then-matmul.
* WIRE: batched encode/decode keeps per-example quantisation headers, so
  a request's payload is identical whether it was served alone or inside
  a micro-batch.
* QUEUE: BatchQueueSim degenerates exactly to the FIFO QueueSim at
  max_batch=1 and dominates it under a sublinear service curve.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.miniconv import (LayerSpec, MiniConvSpec, miniconv_apply,
                                 miniconv_init, standard_spec)
from repro.core.passplan import HeadPlan
from repro.core.split import make_miniconv_split
from repro.core.wire import get_codec, stack_payloads, unstack_payload
from repro.rl.buffers import ReplayBuffer
from repro.rl.networks import make_encoder
from repro.serving.netsim import shaped
from repro.serving.server import (BatchingPolicyServer, BatchQueueSim,
                                  BatchServiceModel, QueueSim)


def _spec(c_out: int) -> MiniConvSpec:
    spec = MiniConvSpec((LayerSpec(4, 2, 4, 8),
                         LayerSpec(3, 2, 8, c_out, activation="sigmoid")))
    spec.validate()
    return spec


# ---------------------------------------------------------------- kernel
@pytest.mark.parametrize("b", [1, 3, 8])
@pytest.mark.parametrize("size", [(16, 16), (17, 23)])    # even / odd X
@pytest.mark.parametrize("c_out", [4, 6, 16])
def test_batched_fused_equals_per_example_loop(b, size, c_out):
    spec = _spec(c_out)
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    x = jax.random.uniform(jax.random.PRNGKey(1), (b, *size, 4))
    batched = miniconv_apply(params, spec, x, use_kernel="fused")
    singles = jnp.concatenate(
        [miniconv_apply(params, spec, x[i:i + 1], use_kernel="fused")
         for i in range(b)])
    assert batched.shape == singles.shape
    np.testing.assert_allclose(batched, singles, atol=1e-5, rtol=1e-5)
    # and both match the XLA oracle
    ref = miniconv_apply(params, spec, x)
    np.testing.assert_allclose(batched, ref, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("c_out", [4, 6])
@pytest.mark.parametrize("tile_h", [4, 8])
def test_fused_epilogue_equals_encoder_then_matmul(c_out, tile_h):
    """The projection epilogue must equal encoder -> flatten -> dense,
    including when the final tile over-runs out_h and when zero-padded
    RGBA channels carry sigmoid(bias) != 0 garbage."""
    spec = _spec(c_out)
    params = miniconv_init(jax.random.PRNGKey(2), spec)
    x = jax.random.uniform(jax.random.PRNGKey(3), (3, 17, 23, 4))
    plan = spec.plan(17, 23)
    hw = jax.random.normal(jax.random.PRNGKey(4),
                           (plan.flat_features, 32)) * 0.1
    hb = jax.random.normal(jax.random.PRNGKey(5), (32,))

    ref_feats = miniconv_apply(params, spec, x)
    ref_z = jax.nn.relu(ref_feats.reshape(3, -1) @ hw + hb)
    feats, z = miniconv_apply(params, spec, x, use_kernel="fused",
                              head=(hw, hb), tile_h=tile_h)
    np.testing.assert_allclose(feats, ref_feats, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(z, ref_z, atol=1e-5, rtol=1e-5)
    # the XLA-mode head epilogue agrees too (training/deployment parity)
    _, z_xla = miniconv_apply(params, spec, x, head=(hw, hb))
    np.testing.assert_allclose(z_xla, ref_z, atol=1e-6, rtol=1e-6)


def test_pre_tiled_head_matches_per_call_tiling():
    """prepare_fused_head lets hot paths skip the per-launch weight
    tiling; results must be identical to passing the raw (F, D) weight."""
    from repro.kernels.miniconv_pass import (miniconv_encoder,
                                             prepare_fused_head)
    spec = _spec(6)
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    plan = spec.plan(17, 23)
    x = jax.random.uniform(jax.random.PRNGKey(1), (3, 17, 23, 4))
    hw = jax.random.normal(jax.random.PRNGKey(2),
                           (plan.flat_features, 32)) * 0.1
    hb = jnp.zeros((32,))
    ws = [params[f"layer{i}"]["kernel"] for i in range(len(spec.layers))]
    bs = [params[f"layer{i}"]["bias"] for i in range(len(spec.layers))]
    _, z_raw = miniconv_encoder(x, ws, bs, plan, tile_h=4, head_w=hw,
                                head_b=hb)
    hw3 = prepare_fused_head(hw, plan, tile_h=4)
    assert hw3.ndim == 3
    _, z_tiled = miniconv_encoder(x, ws, bs, plan, tile_h=4, head_w=hw3,
                                  head_b=hb)
    np.testing.assert_allclose(z_tiled, z_raw, atol=1e-6, rtol=1e-6)


def test_fused_head_encoder_matches_unfused():
    """make_encoder(fused_head=True) == edge apply + server projection."""
    enc_ref = make_encoder("miniconv4", c_in=4)
    enc_fused = make_encoder("miniconv4", c_in=4, use_kernel="fused",
                             fused_head=True)
    params = enc_ref.init(jax.random.PRNGKey(0))
    obs = jax.random.uniform(jax.random.PRNGKey(1), (5, 84, 84, 4))
    np.testing.assert_allclose(enc_fused.apply(params, obs),
                               enc_ref.apply(params, obs),
                               atol=1e-4, rtol=1e-4)


def test_head_plan_accounting():
    plan = standard_spec(c_in=4, k=4).plan(84)
    head = plan.head(512)
    assert isinstance(head, HeadPlan)
    assert head.in_dim == plan.flat_features == plan.out_h * plan.out_w * 4
    assert head.flops == 2 * head.in_dim * 512
    assert plan.flops_per_batch(8) == 8 * plan.flops_per_frame
    assert plan.flops_per_batch(8, head) == \
        8 * (plan.flops_per_frame + head.flops)
    with pytest.raises(ValueError):
        plan.flops_per_batch(8, HeadPlan(in_dim=7, out_dim=512))
    with pytest.raises(ValueError):
        plan.head(0)


# ---------------------------------------------------------------- wire
def test_batched_payload_matches_single_request_payloads():
    """A micro-batch member's wire bytes are identical to what the
    single-frame path would have sent (per-example quantisation)."""
    codec = get_codec("uint8")
    spec = standard_spec(c_in=4, k=4)
    split = make_miniconv_split(spec, lambda p, f: f, h=32)
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    # wildly different dynamic ranges per example
    obs = jax.random.uniform(jax.random.PRNGKey(1), (4, 32, 32, 4))
    obs = obs * jnp.array([1.0, 10.0, 0.1, 100.0]).reshape(4, 1, 1, 1)
    batched = split.edge_step_batch(params, obs)
    for i in range(4):
        single = codec.encode(split.edge_apply(params, obs[i:i + 1])[0])
        np.testing.assert_array_equal(batched["data"][i], single["data"])
        np.testing.assert_allclose(batched["scale"][i], single["scale"],
                                   rtol=1e-6)
    # server-side batch decode round-trips
    feats = split.server_step_batch(params, batched)
    assert feats.shape[0] == 4


def test_stack_unstack_payload_roundtrip():
    codec = get_codec("uint8")
    payloads = [codec.encode(jax.random.uniform(jax.random.PRNGKey(i),
                                                (1, 5, 5, 4)))
                for i in range(3)]
    stacked = stack_payloads(payloads)
    assert stacked["data"].shape == (3, 1, 5, 5, 4)
    back = unstack_payload(stacked)
    for a, b in zip(payloads, back):
        np.testing.assert_array_equal(a["data"], b["data"])
    with pytest.raises(ValueError):
        stack_payloads([])


def test_split_wire_bytes_batch():
    spec = standard_spec(c_in=4, k=4)
    split = make_miniconv_split(spec, lambda p, f: f, h=84)
    assert split.wire_bytes(batch=8) == 8 * split.wire_bytes()


def test_batching_server_serve_and_measure():
    """BatchingPolicyServer serves stacked requests with one call and its
    measured curve builds a usable service model."""
    calls = []

    def serve_batch_fn(payload):
        calls.append(payload["data"].shape[0])
        return payload["data"].sum(axis=tuple(range(1, payload["data"].ndim)))

    srv = BatchingPolicyServer(serve_batch_fn=serve_batch_fn, max_batch=4)
    codec = get_codec("float32")
    payloads = [codec.encode(jnp.full((2, 2), float(i))) for i in range(3)]
    out = srv.serve(payloads)
    assert calls == [3] and len(out) == 3
    assert float(out[2]) == pytest.approx(8.0)
    with pytest.raises(ValueError):
        srv.serve(payloads * 2)           # 6 > max_batch

    times = srv.measure(payloads[0], batch_sizes=(1, 2, 4), iters=2)
    assert set(times) == {1, 2, 4}
    model = srv.service_model()
    assert model(1) == times[1] and model(4) == times[4]
    assert model(2) == pytest.approx(times[2])


# ---------------------------------------------------------------- queue
def test_queue_sim_table6_protocol_regression():
    """Pin the paper's Table 6 protocol (10 Hz, p95 < 100 ms budget):
    ``max_clients`` is deterministic across repeated runs, monotone
    non-increasing in service time, and matches the frozen values for
    the reference configuration (100 Mb/s link, 10 kB payload).

    Lives here rather than test_serving.py so it runs even when the
    optional hypothesis dependency (which skips that whole module) is
    absent.
    """
    def max_clients(svc):
        sim = QueueSim(service_time_s=svc, uplink=shaped(100),
                       payload_bytes=10_000, rate_hz=10.0, horizon_s=5.0)
        return sim.max_clients(p95_budget_s=0.1, n_max=128)

    svcs = (0.002, 0.004, 0.008, 0.016, 0.032)
    ns = [max_clients(s) for s in svcs]
    assert ns == [max_clients(s) for s in svcs]      # run-to-run invariant
    assert all(a >= b for a, b in zip(ns, ns[1:]))   # monotone in service
    assert ns == [50, 25, 12, 6, 3]                  # frozen regression pin


def _sims(**kw):
    common = dict(service_time_s=0.008, uplink=shaped(100),
                  payload_bytes=10_000, horizon_s=5.0)
    fifo = QueueSim(**common)
    common["uplink"] = shaped(100)
    bat = BatchQueueSim(**common, **kw)
    return fifo, bat


def test_batch_sim_max_batch_1_is_fifo():
    fifo, bat = _sims(max_batch=1, max_wait_s=0.0)
    for n in (1, 7, 32):
        np.testing.assert_allclose(bat.latencies(n), fifo.latencies(n))


def test_batch_sim_dominates_fifo_with_sublinear_service():
    model = BatchServiceModel(((1, 0.008), (2, 0.009), (4, 0.011),
                               (8, 0.015)))
    fifo, bat = _sims(max_batch=8, max_wait_s=0.0, service_model=model)
    for n in (8, 32, 64):
        assert bat.p95(n) <= fifo.p95(n) + 1e-9
    # at saturation the gain is large and max_clients grows
    assert bat.p95(64) < fifo.p95(64) / 5
    assert bat.max_clients(n_max=128) > fifo.max_clients(n_max=128)


def test_batch_sim_deterministic():
    model = BatchServiceModel(((1, 0.008), (8, 0.015)))
    _, bat = _sims(max_batch=8, max_wait_s=0.002, service_model=model)
    a, b = bat.latencies(16), bat.latencies(16)
    np.testing.assert_array_equal(a, b)


def test_batch_sim_max_wait_holds_launch():
    """With a long max_wait and idle server, the first request waits for
    the batch to fill (or the deadline), never launching before ready."""
    model = BatchServiceModel(((1, 0.001), (2, 0.001)))
    _, bat = _sims(max_batch=2, max_wait_s=1.0, service_model=model)
    # 2 clients at 10 Hz: requests pair up; latency includes the wait for
    # the partner request (staggered by period/2 = 50 ms), not the 1 s cap
    lat = bat.latencies(2)
    assert 0.04 < float(np.median(lat)) < 0.08


def test_service_model_interpolation_and_extrapolation():
    model = BatchServiceModel(((1, 0.010), (4, 0.016)))
    assert model(1) == pytest.approx(0.010)
    assert model(2) == pytest.approx(0.012)
    assert model(4) == pytest.approx(0.016)
    # past the measured range the value is extrapolated, no longer silent
    with pytest.warns(RuntimeWarning, match="beyond the measured range"):
        assert model(8) == pytest.approx(0.016 + 4 * 0.002)  # marginal slope
    with pytest.raises(ValueError):
        BatchServiceModel(())
    with pytest.raises(ValueError):
        BatchServiceModel(((4, 0.1), (1, 0.2)))


# ---------------------------------------------------------------- replay
def test_replay_sample_batched_encoding():
    """sample(encode_fn=...) encodes obs and next_obs in ONE stacked call
    and the features equal per-split encoding."""
    buf = ReplayBuffer(capacity=16, obs_shape=(8, 8, 4), action_dim=2)
    rng = np.random.default_rng(0)
    obs = rng.random((8, 8, 8, 4), np.float32)
    nxt = rng.random((8, 8, 8, 4), np.float32)
    buf.add_batch(obs, rng.random((8, 2), np.float32),
                  rng.random(8,), nxt, np.zeros(8))
    n_calls, seen = [], []

    def encode_fn(x):
        n_calls.append(1)
        seen.append(x.shape)
        return np.asarray(x).sum(axis=(1, 2, 3))

    batch = buf.sample(4, encode_fn=encode_fn)
    assert len(n_calls) == 1                  # one launch for obs+next_obs
    assert seen[0][0] == 8                    # 2 * batch stacked
    np.testing.assert_allclose(batch["obs_feats"],
                               batch["obs"].sum(axis=(1, 2, 3)), rtol=1e-5)
    np.testing.assert_allclose(batch["next_obs_feats"],
                               batch["next_obs"].sum(axis=(1, 2, 3)),
                               rtol=1e-5)
    assert "obs_feats" not in buf.sample(4)   # default unchanged
