"""ISSUE-3 conformance: the declarative Deployment API.

* MANIFEST: ``DeploymentConfig`` ``to_dict``/``from_dict``/JSON round-trip
  (including property-based, when hypothesis is available) and a reloaded
  manifest rebuilds a pipeline with IDENTICAL encoder outputs and wire
  payloads.
* SHIMS: the legacy constructors (``rl.networks.make_encoder``,
  ``core.split.make_miniconv_split``) are thin shims whose outputs
  bitwise-match ``Deployment.build`` across execution backends.
* REGISTRY: unknown backends/modes fail loudly listing the registered set.
* VMEM: the batch-size-aware budget check (``build_pass_plan(batch=B)``,
  ``PassPlan.max_safe_batch``) and its surfacing on ``Deployment``.
* KERNEL: the lane-padded fused-head epilogue (D % 128 != 0) matches the
  unpadded XLA reference.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backends import backend_names, get_backend
from repro.core.miniconv import (LayerSpec, MiniConvSpec, ShaderBudget,
                                 miniconv_apply, miniconv_init,
                                 standard_spec)
from repro.core.passplan import DEFAULT_VMEM_LIMIT, build_pass_plan
from repro.core.split import make_miniconv_split
from repro.deploy import CONFIG_VERSION, Deployment, DeploymentConfig
from repro.rl.networks import make_encoder
from repro.serving.client import EdgeClient
from repro.serving.server import BatchingPolicyServer


SMALL = DeploymentConfig.standard(k=4, c_in=4, h=24)


# ---------------------------------------------------------------- manifest
def test_config_dict_roundtrip():
    cfg = DeploymentConfig.standard(k=4, c_in=12, h=84, backend="fused",
                                    codec="uint8", max_batch=4,
                                    max_wait_ms=2.5, quantize_in_train=True)
    d = cfg.to_dict()
    assert d["version"] == CONFIG_VERSION
    json.dumps(d)                         # JSON-safe
    assert DeploymentConfig.from_dict(d) == cfg
    assert DeploymentConfig.from_json(cfg.to_json()) == cfg


def test_config_backend_aliases_canonicalise():
    a = DeploymentConfig.standard(k=4, c_in=4, h=24, backend="per_pass")
    b = DeploymentConfig.standard(k=4, c_in=4, h=24, backend="reference")
    assert a == b and a.backend == "reference"
    # the legacy use_kernel booleans resolve too
    assert DeploymentConfig.standard(k=4, c_in=4, h=24,
                                     backend=False).backend == "xla"
    assert DeploymentConfig.standard(k=4, c_in=4, h=24,
                                     backend=True).backend == "reference"


def test_config_rejects_unknown_fields_loudly():
    with pytest.raises(ValueError, match="registered backends"):
        DeploymentConfig.standard(k=4, c_in=4, h=24, backend="warp")
    with pytest.raises(ValueError, match="codec"):
        DeploymentConfig.standard(k=4, c_in=4, h=24,
                                  codec="zip").validate()
    with pytest.raises(ValueError, match="head_placement"):
        dataclasses.replace(SMALL, head_placement="edge").validate()
    with pytest.raises(ValueError, match="version"):
        DeploymentConfig.from_dict({**SMALL.to_dict(), "version": 99})


def test_config_roundtrip_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    layer = st.builds(
        LayerSpec,
        kernel=st.integers(1, 5), stride=st.integers(1, 3),
        c_in=st.integers(1, 32), c_out=st.integers(1, 24),
        activation=st.sampled_from(["relu", "sigmoid", "linear"]))
    spec = st.builds(
        MiniConvSpec,
        layers=st.lists(layer, min_size=1, max_size=4).map(tuple),
        budget=st.builds(ShaderBudget,
                         max_textures=st.integers(1, 16),
                         max_samples=st.integers(1, 256)))
    config = st.builds(
        DeploymentConfig,
        spec=spec,
        in_h=st.integers(1, 128), in_w=st.integers(1, 128),
        backend=st.sampled_from(backend_names(include_aliases=True)),
        interpret=st.sampled_from([None, True, False]),
        codec=st.sampled_from(["float32", "bf16", "uint8", "int8_channel"]),
        head_dim=st.integers(1, 640),
        head_act=st.sampled_from(["relu", "sigmoid", "linear"]),
        head_placement=st.sampled_from(["server", "fused"]),
        max_batch=st.integers(1, 32),
        max_wait_ms=st.floats(0, 10, allow_nan=False),
        tile_h=st.integers(1, 16),
        quantize_in_train=st.booleans())

    @hyp.given(cfg=config)
    @hyp.settings(max_examples=50, deadline=None)
    def roundtrips(cfg):
        assert DeploymentConfig.from_dict(cfg.to_dict()) == cfg
        assert DeploymentConfig.from_json(cfg.to_json()) == cfg

    roundtrips()


def test_reloaded_manifest_reproduces_outputs_and_payloads():
    """The acceptance criterion: a serialised DeploymentConfig reloaded
    from dict reproduces identical encoder outputs and wire payloads."""
    cfg = DeploymentConfig.standard(k=4, c_in=4, h=24, backend="fused")
    dep = Deployment.build(cfg)
    dep2 = Deployment.build(DeploymentConfig.from_dict(cfg.to_dict()))
    key = jax.random.PRNGKey(0)
    params, params2 = dep.init(key), dep2.init(key)
    obs = jax.random.uniform(jax.random.PRNGKey(1), (2, 24, 24, 4))
    np.testing.assert_array_equal(dep.encoder.apply(params, obs),
                                  dep2.encoder.apply(params2, obs))
    p1 = dep.split.edge_step(params["edge"], obs)
    p2 = dep2.split.edge_step(params2["edge"], obs)
    assert set(p1) == set(p2)
    for k in p1:
        np.testing.assert_array_equal(p1[k], p2[k])


# ---------------------------------------------------------------- shims
@pytest.mark.parametrize("use_kernel", [False, "fused", "per_pass",
                                        "grouped"])
def test_make_encoder_shim_bitwise_matches_deployment(use_kernel):
    enc = make_encoder("miniconv4", c_in=4, use_kernel=use_kernel)
    dep = Deployment.build(DeploymentConfig.from_encoder_name(
        "miniconv4", c_in=4, backend=use_kernel))
    key = jax.random.PRNGKey(0)
    params, dparams = enc.init(key), dep.init(key)
    jax.tree.map(np.testing.assert_array_equal, params, dparams)
    obs = jax.random.uniform(jax.random.PRNGKey(1), (2, 84, 84, 4))
    np.testing.assert_array_equal(enc.apply(params, obs),
                                  dep.encoder.apply(dparams, obs))


def test_make_encoder_fused_head_shim_bitwise_matches_deployment():
    enc = make_encoder("miniconv4", c_in=4, use_kernel="fused",
                       fused_head=True)
    dep = Deployment.build(DeploymentConfig.from_encoder_name(
        "miniconv4", c_in=4, backend="fused", head_placement="fused"))
    key = jax.random.PRNGKey(2)
    params = enc.init(key)
    obs = jax.random.uniform(jax.random.PRNGKey(3), (3, 84, 84, 4))
    np.testing.assert_array_equal(enc.apply(params, obs),
                                  dep.encoder.apply(dep.init(key), obs))


@pytest.mark.parametrize("use_kernel", ["fused", "per_pass"])
def test_make_miniconv_split_shim_bitwise_matches_deployment(use_kernel):
    spec = standard_spec(c_in=4, k=4)
    split = make_miniconv_split(spec, lambda p, f: f, h=24,
                                use_kernel=use_kernel)
    dep = Deployment.build(DeploymentConfig(spec=spec, in_h=24, in_w=24,
                                            backend=use_kernel))
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    obs = jax.random.uniform(jax.random.PRNGKey(1), (1, 24, 24, 4))
    a = split.edge_step(params, obs)
    b = dep.split.edge_step(params, obs)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    assert split.wire_bytes() == dep.wire_bytes
    # custom server half survives the shim
    feats = split.server_step(None, a)
    np.testing.assert_allclose(feats, dep.codec.decode(b), rtol=1e-6)


def test_split_shim_rejects_wrong_deploy_size():
    """The deployment split stays size-strict in fused mode (a plan built
    for 24x24 must not silently serve 32x32 frames)."""
    spec = standard_spec(c_in=4, k=4)
    split = make_miniconv_split(spec, lambda p, f: f, h=24)
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    with pytest.raises(ValueError, match="plan was built"):
        split.edge_apply(params, jnp.zeros((1, 32, 32, 4)))


# ---------------------------------------------------------------- registry
def test_unknown_backend_error_lists_registered():
    with pytest.raises(ValueError) as ei:
        get_backend("warp")
    msg = str(ei.value)
    for name in ("xla", "reference", "grouped", "fused", "fused+head"):
        assert name in msg


def test_miniconv_apply_unknown_mode_lists_backends():
    spec = standard_spec(c_in=4, k=4)
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    x = jnp.zeros((1, 16, 16, 4))
    with pytest.raises(ValueError, match="registered backends"):
        miniconv_apply(params, spec, x, use_kernel="warp")


# ---------------------------------------------------------------- serving
def test_serving_pair_from_config():
    cfg = dataclasses.replace(SMALL, max_batch=3, max_wait_ms=4.0)
    dep = Deployment.build(cfg)
    params = dep.init(jax.random.PRNGKey(0))
    client, server = dep.serving_pair(params)
    assert isinstance(client, EdgeClient)
    assert isinstance(server, BatchingPolicyServer)
    assert server.max_batch == 3
    assert server.max_wait_s == pytest.approx(0.004)
    obs = jax.random.uniform(jax.random.PRNGKey(1), (2, 24, 24, 4))
    payloads = [client.encode_fn(obs[i:i + 1]) for i in range(2)]
    assert client.wire_bytes == dep.wire_bytes
    served = server.serve(payloads)
    ref = dep.encoder.apply(params, obs)
    np.testing.assert_allclose(jnp.stack(served), ref, atol=5e-2)
    with pytest.raises(ValueError):
        server.serve(payloads * 2)        # 4 > max_batch


# ---------------------------------------------------------------- VMEM
def test_vmem_bytes_affine_in_batch():
    plan = standard_spec(c_in=4, k=4).plan(84)
    d1 = plan.vmem_bytes(2) - plan.vmem_bytes(1)
    d2 = plan.vmem_bytes(9) - plan.vmem_bytes(8)
    assert d1 == d2 > 0
    head = plan.head(512)
    assert plan.vmem_bytes(1, head=head) > plan.vmem_bytes(1)


def test_build_pass_plan_batch_budget_check():
    spec = standard_spec(c_in=4, k=4)
    plan = build_pass_plan(spec, 84, batch=8)        # fits the real budget
    safe = plan.max_safe_batch()
    assert safe >= 8
    with pytest.raises(ValueError, match="max safe batch"):
        build_pass_plan(spec, 84, batch=safe + 1,
                        vmem_limit=plan.vmem_bytes(safe))
    # spec.plan passthrough
    with pytest.raises(ValueError, match="VMEM"):
        spec.plan(84, batch=10 ** 6)
    assert plan.max_safe_batch(vmem_limit=plan.vmem_bytes(3)) == 3


def test_deployment_surfaces_max_safe_batch():
    dep = Deployment.build(SMALL)
    assert dep.max_safe_batch == dep.plan.max_safe_batch(
        tile_h=SMALL.tile_h)
    # fusing the head consumes VMEM for the tiled weight -> smaller B
    fused_head = Deployment.build(
        dataclasses.replace(SMALL, backend="fused+head"))
    assert fused_head.max_safe_batch <= dep.max_safe_batch
    assert fused_head.max_safe_batch == dep.plan.max_safe_batch(
        head=dep.head_plan, tile_h=SMALL.tile_h)


def test_deployment_build_rejects_unlaunchable_compiled_batch():
    """Compiled fused deployments whose micro-batch busts VMEM must fail
    at build time, not on the device."""
    big = DeploymentConfig.standard(k=4, c_in=12, h=2048, backend="fused",
                                    interpret=False, max_batch=64)
    with pytest.raises(ValueError, match="VMEM"):
        Deployment.build(big)
    # the same config is buildable in interpret mode (no VMEM ceiling)
    Deployment.build(dataclasses.replace(big, interpret=None))


# ---------------------------------------------------------------- lane pad
@pytest.mark.parametrize("d_out", [96, 160])
def test_fused_head_lane_padding_parity(d_out):
    """Projection widths that are NOT lane-multiples (D % 128 != 0) are
    zero-padded to 128 lanes inside the kernel; the sliced result must
    equal the unpadded XLA epilogue exactly as before."""
    spec = standard_spec(c_in=4, k=4)
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 24, 24, 4))
    plan = spec.plan(24)
    hw = jax.random.normal(jax.random.PRNGKey(2),
                           (plan.flat_features, d_out)) * 0.1
    hb = jax.random.normal(jax.random.PRNGKey(3), (d_out,))
    feats_ref = miniconv_apply(params, spec, x)
    z_ref = jax.nn.relu(feats_ref.reshape(2, -1) @ hw + hb)
    feats, z = miniconv_apply(params, spec, x, use_kernel="fused",
                              head=(hw, hb))
    assert z.shape == (2, d_out)
    np.testing.assert_allclose(feats, feats_ref, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(z, z_ref, atol=1e-5, rtol=1e-5)


def test_fused_head_lane_padding_sigmoid_garbage_cancelled():
    """sigmoid(0) = 0.5 in the padded lanes must never leak into the
    returned projection (the slice must drop exactly the padding)."""
    spec = standard_spec(c_in=4, k=4)
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    x = jax.random.uniform(jax.random.PRNGKey(1), (1, 24, 24, 4))
    plan = spec.plan(24)
    hw = jax.random.normal(jax.random.PRNGKey(2),
                           (plan.flat_features, 48)) * 0.1
    _, z = miniconv_apply(params, spec, x, use_kernel="fused",
                          head=(hw, None), head_act="sigmoid")
    feats_ref = miniconv_apply(params, spec, x)
    z_ref = jax.nn.sigmoid(feats_ref.reshape(1, -1) @ hw)
    assert z.shape == (1, 48)
    np.testing.assert_allclose(z, z_ref, atol=1e-5, rtol=1e-5)
