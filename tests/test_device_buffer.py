"""Device ring buffer vs the numpy ReplayBuffer reference (ISSUE 5).

Property tests: for capacities smaller than, equal to, and larger than
the number of inserted rows, the device-resident pytree ring
(``DeviceReplayBuffer``) matches the host numpy ``ReplayBuffer`` on
insert position, wraparound, fill accounting and sample-index behaviour.
A deterministic grid version of the parity check runs everywhere; the
hypothesis generalisation runs where hypothesis is installed (CI).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.rl.buffers import (DeviceReplayBuffer, ReplayBuffer,
                              buffer_add, buffer_sample, device_buffer,
                              sample_indices)

OBS_SHAPE = (3, 3, 2)
ACTION_DIM = 2


def _transitions(rng, n):
    obs = rng.random((n,) + OBS_SHAPE).astype(np.float32)
    nxt = rng.random((n,) + OBS_SHAPE).astype(np.float32)
    act = rng.uniform(-1, 1, (n, ACTION_DIM)).astype(np.float32)
    rew = rng.standard_normal(n).astype(np.float32)
    done = rng.random(n) < 0.3
    return obs, act, rew, nxt, done


def _assert_parity(n_add, capacity, n_batches, seed):
    """After a sequence of fixed-width adds — under-filled, exactly full,
    and wrapped-around many times — storage, cursor and fill count are
    identical to the numpy reference."""
    rng = np.random.default_rng(seed)
    ref = ReplayBuffer(capacity, OBS_SHAPE, ACTION_DIM)
    buf = device_buffer(capacity, OBS_SHAPE, ACTION_DIM, n_add=n_add)
    add_jit = jax.jit(buffer_add)       # the engine inserts under jit
    for _ in range(n_batches):
        obs, act, rew, nxt, done = _transitions(rng, n_add)
        ref.add_batch(obs, act, rew, nxt, done)
        buf = add_jit(buf, jnp.asarray(obs), jnp.asarray(act),
                      jnp.asarray(rew), jnp.asarray(nxt), jnp.asarray(done))
    assert int(buf.size) == len(ref)
    assert int(buf.idx) == ref.idx
    np.testing.assert_array_equal(np.asarray(buf.obs), ref.obs)
    np.testing.assert_array_equal(np.asarray(buf.next_obs), ref.next_obs)
    np.testing.assert_array_equal(np.asarray(buf.actions), ref.actions)
    np.testing.assert_array_equal(np.asarray(buf.rewards), ref.rewards)
    np.testing.assert_array_equal(np.asarray(buf.dones), ref.dones)


@pytest.mark.parametrize("n_add", [1, 3])
@pytest.mark.parametrize("cap_mult,n_batches",
                         [(4, 2),       # capacity > rows added
                          (4, 4),       # capacity == rows added
                          (2, 7),       # capacity < rows added (wraps)
                          (1, 5)])      # every add overwrites the ring
def test_insert_wraparound_matches_numpy_reference(n_add, cap_mult,
                                                   n_batches):
    _assert_parity(n_add, n_add * cap_mult, n_batches, seed=n_batches)


def test_insert_wraparound_property():
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis "
                             "(pip install -r requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=25)
    @given(n_add=st.integers(1, 4), cap_mult=st.integers(1, 5),
           n_batches=st.integers(1, 14), seed=st.integers(0, 2**16))
    def prop(n_add, cap_mult, n_batches, seed):
        _assert_parity(n_add, n_add * cap_mult, n_batches, seed)

    prop()


@pytest.mark.parametrize("n_batches,batch,seed",
                         [(1, 8, 0), (2, 16, 1), (4, 5, 2), (9, 64, 3)])
def test_sample_indices_uniform_over_filled_region(n_batches, batch, seed):
    """Sampling inside jit draws only from the filled region and the
    minibatch gathers exactly the stored (dequantised) rows."""
    n_add, capacity = 3, 12
    rng = np.random.default_rng(seed)
    buf = device_buffer(capacity, OBS_SHAPE, ACTION_DIM, n_add=n_add)
    for _ in range(n_batches):
        obs, act, rew, nxt, done = _transitions(rng, n_add)
        buf = buffer_add(buf, jnp.asarray(obs), jnp.asarray(act),
                         jnp.asarray(rew), jnp.asarray(nxt),
                         jnp.asarray(done))
    key = jax.random.PRNGKey(seed)
    idxs = np.asarray(sample_indices(key, batch, buf.size))
    assert idxs.shape == (batch,)
    assert (idxs >= 0).all() and (idxs < int(buf.size)).all()
    out = jax.jit(lambda b, k: buffer_sample(b, batch, k))(buf, key)
    # the same key draws the same indices, so the gather is checkable.
    # pixels: XLA rewrites /255.0 as a reciprocal multiply under jit, so
    # dequantisation is 1 ulp (~6e-8) off exact division — allow that.
    np.testing.assert_allclose(
        np.asarray(out["obs"]),
        np.asarray(buf.obs)[idxs].astype(np.float32) / 255.0,
        rtol=0, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(out["rewards"]),
                                  np.asarray(buf.rewards)[idxs])
    np.testing.assert_array_equal(np.asarray(out["actions"]),
                                  np.asarray(buf.actions)[idxs])


def test_fixed_width_invariant_enforced():
    with pytest.raises(ValueError, match="multiple of"):
        device_buffer(10, OBS_SHAPE, ACTION_DIM, n_add=4)
    buf = device_buffer(12, OBS_SHAPE, ACTION_DIM, n_add=4)
    obs, act, rew, nxt, done = _transitions(np.random.default_rng(0), 3)
    with pytest.raises(ValueError, match="insert width"):
        buffer_add(buf, jnp.asarray(obs), jnp.asarray(act),
                   jnp.asarray(rew), jnp.asarray(nxt), jnp.asarray(done))


def test_buffer_is_a_pytree_with_static_width():
    buf = device_buffer(8, OBS_SHAPE, ACTION_DIM, n_add=2)
    leaves = jax.tree.leaves(buf)
    assert len(leaves) == 7                 # n_add is static metadata
    buf2 = jax.tree.map(lambda x: x, buf)
    assert isinstance(buf2, DeviceReplayBuffer) and buf2.n_add == 2
