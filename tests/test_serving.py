"""Serving runtime: shaped link determinism/FIFO, queue simulation
monotonicity, and agreement between DecisionLoop and the paper's
analytic latency model."""
import warnings

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.latency import (LinkModel, SplitConfig,
                                decision_latency_server_only,
                                decision_latency_split)
from repro.serving.client import DecisionLoop, EdgeClient
from repro.serving.netsim import ShapedLink, shaped
from repro.serving.server import (BatchingPolicyServer, BatchQueueSim,
                                  BatchServiceModel, PolicyServer, QueueSim)


def test_link_tx_time():
    link = ShapedLink(bandwidth_bps=8e6, propagation_s=0.0)
    assert link.tx_time(1_000_000) == pytest.approx(1.0)


def test_link_fifo_serialises():
    link = ShapedLink(bandwidth_bps=8e6, propagation_s=0.001)
    t1 = link.send(0.0, 500_000)      # 0.5 s tx
    t2 = link.send(0.0, 500_000)      # must queue behind t1
    assert t1.tx_done == pytest.approx(0.5)
    assert t2.start == pytest.approx(0.5)
    assert t2.arrival == pytest.approx(1.001)


def test_link_reset():
    link = shaped(10)
    link.send(0.0, 10_000)
    link.reset()
    assert link.send(0.0, 10_000).start == 0.0


@given(st.floats(1, 1000), st.integers(100, 1_000_000))
@settings(max_examples=30, deadline=None)
def test_decision_loop_matches_latency_model(mbps, payload):
    """netsim pipeline == paper's closed-form model for a single client."""
    link = ShapedLink(bandwidth_bps=mbps * 1e6, propagation_s=0.002)
    loop = DecisionLoop(link=link, server_time_s=0.01, split=False,
                        payload_bytes=payload, action_bytes=64)
    got = loop.decision_latency()
    want = (8 * payload / (mbps * 1e6) + 0.01
            + 8 * 64 / (mbps * 1e6) + 0.004)
    assert got == pytest.approx(want, rel=1e-6)


def test_split_vs_server_only_crossover():
    """Bandwidth sweep reproduces the paper's crossover structure: split
    wins at low bandwidth, loses at high bandwidth."""
    frame, feat, j, srv = 640_000, 10_000, 0.1, 0.005
    lat = {}
    for mbps in (10, 25, 50, 100, 1000):
        so = DecisionLoop(link=shaped(mbps), server_time_s=srv,
                          split=False, payload_bytes=frame)
        sp = DecisionLoop(link=shaped(mbps), server_time_s=srv,
                          split=True, edge_time_s=j, payload_bytes=feat)
        lat[mbps] = (so.median_latency(10), sp.median_latency(10))
    assert lat[10][1] < lat[10][0]          # split wins at 10 Mb/s
    assert lat[1000][1] > lat[1000][0]      # compute-bound at 1 Gb/s


def test_queue_p95_monotone_in_clients():
    q = QueueSim(service_time_s=0.008, uplink=shaped(100),
                 payload_bytes=10_000, horizon_s=5.0)
    p95s = [q.p95(n) for n in (1, 4, 16, 64)]
    assert all(a <= b + 1e-9 for a, b in zip(p95s, p95s[1:]))


def test_table6_pins_with_serialised_downlink():
    """Frozen Table 6 values AFTER the downlink-accounting fix (a batch
    of B actions charges B serialised transfer slots, not one).

    At the paper's 64 B actions the per-action transfer is ~5 us against
    millisecond service times, so the FIFO pins match the seed values —
    the fix matters for fat actions (asserted in tests/test_fleet.py) —
    while the batched pin is now exact rather than understated.
    """
    def fifo_max(svc):
        return QueueSim(service_time_s=svc, uplink=shaped(100),
                        payload_bytes=10_000, rate_hz=10.0,
                        horizon_s=5.0).max_clients(p95_budget_s=0.1,
                                                   n_max=128)
    assert [fifo_max(s) for s in (0.002, 0.004, 0.008, 0.016, 0.032)] \
        == [50, 25, 12, 6, 3]
    model = BatchServiceModel(((1, 0.008), (2, 0.009), (4, 0.011),
                               (8, 0.015)))
    bat = BatchQueueSim(service_time_s=0.008, uplink=shaped(100),
                        payload_bytes=10_000, rate_hz=10.0, horizon_s=5.0,
                        max_batch=8, service_model=model)
    assert bat.max_clients(p95_budget_s=0.1, n_max=256) == 54


def test_jitter_delays_arrival_not_link_occupancy():
    """Regression for the jitter double-count: jitter is extra propagation
    delay on ONE transfer's arrival (tc-netem semantics) — it never
    occupies the link, so back-to-back sends under jitter still serialise
    at exactly tx_time spacing."""
    link = ShapedLink(bandwidth_bps=8e6, propagation_s=0.001,
                      jitter_s=0.010)
    tx = link.tx_time(500_000)                     # 0.5 s each
    traces = [link.send(0.0, 500_000) for _ in range(3)]
    assert [t.start for t in traces] == pytest.approx([0.0, tx, 2 * tx])
    assert [t.tx_done - t.start for t in traces] == pytest.approx([tx] * 3)
    # jitter shows up ONLY on arrival, cycling 0.5x/1.0x/1.5x with mean
    # exactly jitter_s (the old (n%3)/2 pattern averaged jitter_s/2 AND
    # leaked into _busy_until)
    jit = [t.arrival - t.tx_done - link.propagation_s for t in traces]
    assert jit == pytest.approx([0.005, 0.010, 0.015])
    assert float(np.mean(jit)) == pytest.approx(link.jitter_s)


def test_service_model_out_of_range_modes():
    pts = ((1, 0.008), (2, 0.009), (4, 0.011))
    model = BatchServiceModel(pts)
    assert model.max_measured_batch == 4
    with pytest.warns(RuntimeWarning, match="beyond the measured range"):
        v = model(8)
    assert v == pytest.approx(0.011 + 4 * 0.001)   # last-segment slope
    with warnings.catch_warnings():                # warns ONCE per model
        warnings.simplefilter("error")
        model(16)
    clamp = BatchServiceModel(pts, out_of_range="clamp")
    with pytest.warns(RuntimeWarning, match="clamped"):
        assert clamp(100) == pytest.approx(0.011)
    strict = BatchServiceModel(pts, out_of_range="raise")
    assert strict(4) == pytest.approx(0.011)       # in-range untouched
    with pytest.raises(ValueError, match="beyond the measured range"):
        strict(5)
    with pytest.raises(ValueError):
        BatchServiceModel(pts, out_of_range="nope")


def test_measure_warmup_and_blocking_call_counts():
    """Every measure loop runs compile + ``warmup`` calls BEFORE the clock
    and `iters` calls inside it — the warmup is what absorbs async-dispatch
    and cache-cold skew."""
    n = [0]

    def count_fn(_):
        n[0] += 1
        return np.zeros(2)

    PolicyServer(count_fn).measure(None, iters=5, warmup=3)
    assert n[0] == 1 + 3 + 5

    n[0] = 0
    EdgeClient(encode_fn=count_fn, wire_bytes=1).measure(None, iters=4,
                                                         warmup=2)
    assert n[0] == 1 + 2 + 4

    import jax.numpy as jnp
    n[0] = 0
    srv = BatchingPolicyServer(serve_batch_fn=count_fn, max_batch=4)
    srv.measure({"data": jnp.ones((2,))}, batch_sizes=(1, 2), iters=3,
                warmup=2)
    assert n[0] == 2 * (1 + 2 + 3)


def test_scalability_split_serves_more_clients():
    """Table 6 structure: smaller service time + payload => more clients
    within the same p95 budget."""
    so = QueueSim(service_time_s=0.008, uplink=shaped(100),
                  payload_bytes=640_000, horizon_s=5.0)
    sp = QueueSim(service_time_s=0.003, uplink=shaped(100),
                  payload_bytes=10_000, horizon_s=5.0)
    n_so = so.max_clients(n_max=128)
    n_sp = sp.max_clients(n_max=128)
    assert n_sp > n_so >= 1
